"""Host-device mesh bootstrap for CPU benchmark runs.

Importing this module — **before anything imports jax** — forces an
``xla_force_host_platform_device_count`` mesh (one device per core,
capped at 8) so the jax sweep backend exercises its sharded multi-device
path on plain CPU hosts, exactly as recorded in ``BENCH_sweep.json``.
Both benchmark entry points (``benchmarks.sweep_bench`` and the
``benchmarks.run`` harness) import it first; if jax is already
initialised the bootstrap is a silent no-op and the run proceeds on
whatever mesh exists.  ``PSP_BENCH_HOST_DEVICES=0`` disables it, any
other value pins the mesh size.
"""
import os
import sys

if "jax" not in sys.modules:
    # the one PSP_* read that can't go through repro.core.env: importing
    # that package drags jax into the process before the XLA flag below
    # is set, defeating the bootstrap.  The variable is still registered
    # there (docs table + tests/test_env.py pin it).
    _n = os.environ.get("PSP_BENCH_HOST_DEVICES")
    _n = (os.cpu_count() or 1) if _n is None else int(_n)
    if _n > 1 and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={min(_n, 8)}").strip()
