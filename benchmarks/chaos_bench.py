"""Chaos benchmark: recovery latency + goodput under the standard fault plan.

Two segments, one artifact (``results/benchmarks/chaos.json``; schema in
``docs/BENCHMARKS.md``; gated by ``tools/check_bench.py --chaos``):

* **cluster** — two real multi-process runs of
  :func:`repro.launch.cluster.run_cluster` with identical seeds/shape:
  a no-fault reference and a faulted run under the ``standard`` plan
  (one SIGKILL a third of the way in, one stalled straggler halfway).
  Measured: **recovery latency** — wall seconds from the SIGKILL to the
  victim's first *contributing* push after its respawn rejoined as a
  churn joiner (kill → rejoin → first push, the full
  detect/respawn/restore/re-anchor/contribute path) — and **goodput**,
  total server pushes per wall second, reported for both runs plus
  their ratio (how much training throughput one kill + one stall
  actually costs).
* **serving** — an open-loop request stream served while a
  :class:`repro.serving.ChaosPublisher` executes the plan's publish
  faults (torn-snapshot storm, delayed publication) on the snapshot bus
  and the decode worker is killed once mid-stream (the plan's kill
  tick, reused as a request index).  Measured: completed/dropped
  requests, hot-swaps that still landed, worker restarts and
  re-admissions, watcher skip/retry counts, tokens/s.  The invariant —
  **zero drops** — is what the whole robustness tier buys.

Run + artifact::

    PYTHONPATH=src python -m benchmarks.chaos_bench
    PYTHONPATH=src python -m benchmarks.chaos_bench --smoke   # no artifact

``--smoke`` shrinks both segments for CI; its timings are noise but
every invariant (victim rejoined and contributed, zero drops, live
workers never restarted) still holds and is still gated.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from typing import Dict

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "benchmarks", "chaos.json")


def cluster_chaos(workers: int = 3, ticks: int = 30, dim: int = 16,
                  batch: int = 4, tick_min_wall: float = 0.5,
                  seed: int = 3) -> Dict:
    """No-fault vs standard-plan cluster run → recovery + goodput dict."""
    from repro.core.faults import make_plan
    from repro.core.spmd_psp import PSPConfig
    from repro.launch.cluster import run_cluster

    cfg = PSPConfig(barrier="pbsp", n_workers=workers, staleness=3,
                    sample_size=max(1, workers - 1))

    def _run(plan_spec):
        plan = make_plan(plan_spec, n_workers=workers, ticks=ticks)
        with tempfile.TemporaryDirectory(prefix="psp_chaos_") as d:
            res = run_cluster(cfg, dim, ticks, d, batch=batch, plan=plan,
                              tick_min_wall=tick_min_wall,
                              tick_timeout=120.0)
        res.pop("final_params", None)
        return res

    ref = _run("none")
    faulted = _run(f"standard:worker={seed % workers}")
    victims = sorted({w for _t, kind, w in
                      [tuple(e) for e in faulted["events"]]
                      if kind == "leave"})
    latencies = [rec["latency_s"] for rec in faulted["recovery"].values()
                 if "latency_s" in rec]
    live_restarts = sum(e for w, e in faulted["epochs"].items()
                        if int(w) not in victims)
    return {
        "workers": workers, "ticks": ticks, "dim": dim, "batch": batch,
        "plan": faulted["plan"],
        "nofault": {"pushes": ref["total_pushes"],
                    "wall_s": round(ref["wall_s"], 3),
                    "goodput_pushes_per_s": round(ref["pushes_per_s"], 4)},
        "faulted": {"pushes": faulted["total_pushes"],
                    "wall_s": round(faulted["wall_s"], 3),
                    "goodput_pushes_per_s":
                        round(faulted["pushes_per_s"], 4),
                    "events": faulted["events"],
                    "epochs": faulted["epochs"],
                    "recovery": faulted["recovery"]},
        "goodput_ratio": round(faulted["pushes_per_s"]
                               / max(ref["pushes_per_s"], 1e-9), 4),
        "recovery_latency_s": round(max(latencies), 3) if latencies
        else None,
        "victims": victims,
        "live_restarts": live_restarts,
        "completed": bool(ref.get("completed")
                          and faulted.get("completed")),
    }


def serving_chaos(arch: str = "qwen2-0.5b", requests: int = 16,
                  rate_rps: float = 4.0, batch: int = 2, max_new: int = 4,
                  prompt_len: int = 8, seed: int = 0) -> Dict:
    """Open-loop serving under publish chaos + one decode-worker death."""
    import benchmarks._host_mesh  # noqa: F401  (host mesh before jax)
    import jax
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.core.faults import make_plan
    from repro.models import init_model
    from repro.serving import (ChaosPublisher, InferenceServer, Request,
                               ServeConfig, ServingEngine, SnapshotWatcher)

    cfg = reduced(get_config(arch))
    p0 = init_model(cfg, jax.random.PRNGKey(seed))
    scfg = ServeConfig(batch=batch, max_len=128, max_new_tokens=max_new,
                       seed=seed)
    plan = make_plan("standard", n_workers=1, ticks=requests)
    kills = [e.tick for e in plan.events if e.kind == "kill"]
    kill_at = min(kills[0], requests - 1) if kills else None

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=prompt_len)
               .astype(np.int32) for _ in range(requests)]

    with tempfile.TemporaryDirectory(prefix="psp_chaos_serve_") as d:
        pub = ChaosPublisher(d, plan, async_write=False)
        watcher = SnapshotWatcher(d, p0, backoff_base=0.05,
                                  backoff_max=0.2, jitter_seed=seed)
        eng = ServingEngine(p0, cfg, scfg, version=0)
        futs = []
        t0 = time.perf_counter()
        with InferenceServer(eng, watcher=watcher, poll_every=2,
                             max_restarts=2) as srv:
            for i in range(requests):
                # one publication per request: the plan's torn storm and
                # delayed publish land on these indices
                pub.publish(i + 1, init_model(cfg,
                                              jax.random.PRNGKey(i + 1)))
                futs.append(srv.submit(Request(prompt=prompts[i])))
                if kill_at is not None and i == kill_at:
                    srv.inject_worker_fault()
                lag = (i + 1) / rate_rps - (time.perf_counter() - t0)
                if lag > 0:
                    time.sleep(lag)
            comps = [f.result(timeout=600) for f in futs]
        wall = time.perf_counter() - t0
        stats = srv.stats

    total_tokens = sum(len(c.tokens) for c in comps)
    return {
        "arch": cfg.name, "requests": requests, "rate_rps": rate_rps,
        "batch": batch, "max_new_tokens": max_new,
        "wall_s": round(wall, 3),
        "completed": len(comps),
        "dropped": requests - len(comps),
        "tokens_per_s": round(total_tokens / wall, 3),
        "versions_served": sorted({c.snapshot_version for c in comps}),
        "swaps": stats.swaps,
        "worker_restarts": stats.worker_restarts,
        "readmitted": stats.readmitted,
        "timeouts": stats.timeouts,
        "snapshots_skipped": stats.snapshots_skipped,
        "watcher_retries": watcher.retries,
        "publish_faults": dict(pub.counters),
    }


def chaos_suite(*, smoke: bool = False) -> Dict:
    """Run both segments; ``smoke`` shrinks shapes (invariants intact)."""
    if smoke:
        cluster = cluster_chaos(workers=3, ticks=24, tick_min_wall=0.4)
        serving = serving_chaos(requests=10, rate_rps=8.0)
    else:
        cluster = cluster_chaos()
        serving = serving_chaos()
    return {"smoke": smoke, "cluster": cluster, "serving": serving}


def main(argv=None) -> int:
    """CLI entry: run the chaos benchmark, write/print the artifact."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI: every invariant still holds, "
                         "timings are noise; does NOT write the "
                         "committed artifact")
    a = ap.parse_args(argv)
    res = chaos_suite(smoke=a.smoke)
    if not a.smoke or a.out != OUT_PATH:
        os.makedirs(os.path.dirname(a.out), exist_ok=True)
        with open(a.out, "w") as f:
            json.dump(res, f, indent=1)
        print(f"wrote {a.out}")
    c, s = res["cluster"], res["serving"]
    print(f"cluster: {c['workers']}w x {c['ticks']}t plan={c['plan']}  "
          f"goodput {c['faulted']['goodput_pushes_per_s']:.2f}/s vs "
          f"{c['nofault']['goodput_pushes_per_s']:.2f}/s "
          f"(ratio {c['goodput_ratio']:.2f})")
    print(f"  recovery latency {c['recovery_latency_s']}s  "
          f"victims {c['victims']}  live restarts {c['live_restarts']}")
    print(f"serving: {s['completed']}/{s['requests']} done  "
          f"dropped {s['dropped']}  swaps {s['swaps']}  "
          f"restarts {s['worker_restarts']} "
          f"(readmitted {s['readmitted']})  "
          f"faults {s['publish_faults']}")
    ok = (c["completed"] and c["recovery_latency_s"] is not None
          and c["live_restarts"] == 0 and s["dropped"] == 0
          and s["swaps"] >= 1 and s["worker_restarts"] >= 1)
    if not ok:
        print("FAIL: chaos invariants violated")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
