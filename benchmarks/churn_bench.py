"""Elastic-trainer churn benchmark: convergence vs virtual wall-clock.

The paper's scalability story is about *dynamic* node populations; this
benchmark measures it on the training side: the elastic SPMD trainer
(:mod:`repro.core.spmd_psp` with ``PSPConfig(churn=...)``) runs the
linear task under Poisson leave/join churn for every barrier
(BSP / SSP / ASP / pBSP / pSSP) and records the normalized model error
against **virtual wall-clock** — the trade-off Elastic-BSP and
Dynamic-SSP optimize for, now measurable per barrier policy.  Output
schema and the figure → command map live in ``docs/BENCHMARKS.md``.

    PYTHONPATH=src python -m benchmarks.churn_bench [--full]

Also registered as the ``elastic_churn`` entry of ``benchmarks.run``.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.spmd_psp import ChurnConfig, PSPConfig, elastic_drive

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "benchmarks", "elastic_churn.json")

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")
D = 32


def _run_one(barrier: str, ticks: int, workers: int,
             churn: ChurnConfig) -> Dict:
    """One elastic run: (virtual time, error) trace + summary scalars."""
    cfg = PSPConfig(barrier=barrier, n_workers=workers, sample_size=2,
                    staleness=3, straggler_frac=0.25, churn=churn)
    w_true, it = elastic_drive(cfg, D, ticks)
    times, errors, alive = [], [], []
    for i, (st, m) in enumerate(it):
        if i % 10 == 0 or i == ticks - 1:
            err = float(jnp.linalg.norm(st.server_params["w"] - w_true)
                        / jnp.linalg.norm(w_true))
            times.append(float(st.now))
            errors.append(err)
            alive.append(int(m["alive"]))
    return {
        "virtual_time": times,
        "error": errors,
        "alive": alive,
        "final_error": errors[-1],
        "final_virtual_time": times[-1],
        "mean_alive": float(np.mean(alive)),
        "total_pushes": int(st.total_pushes),
        "leaves": int(st.leave_cursor),
        "joins": int(st.join_cursor),
    }


def elastic_churn(full: bool = False, backend: str | None = None) -> Dict:
    """Convergence-vs-virtual-wall-clock under churn, all five barriers.

    ``backend`` is accepted for harness uniformity and ignored — the
    elastic trainer *is* the jax backend under test.  ``full`` scales
    ticks and workers up (still CPU-friendly).
    """
    ticks, workers = (900, 16) if full else (300, 8)
    churn = ChurnConfig(leave_rate=1.5, join_rate=1.5, horizon=60.0, seed=7)
    # no JSON dump here: the benchmarks.run harness persists every entry's
    # result to this same path; the standalone CLI dumps in main()
    return {name: _run_one(name, ticks, workers, churn) for name in FIVE}


def main(argv=None) -> None:
    """CLI entry: ``python -m benchmarks.churn_bench [--full]``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args(argv)
    res = elastic_churn(full=a.full)
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(res, f, indent=1)
    print(f"{'barrier':8s} {'err@T':>8s} {'virt_T':>7s} {'pushes':>7s} "
          f"{'alive':>6s} {'churn':>7s}")
    for name in FIVE:
        r = res[name]
        print(f"{name:8s} {r['final_error']:8.4f} "
              f"{r['final_virtual_time']:7.2f} {r['total_pushes']:7d} "
              f"{r['mean_alive']:6.1f} "
              f"{r['leaves']:3d}-/{r['joins']}+")


if __name__ == "__main__":
    main()
