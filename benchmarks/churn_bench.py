"""Elastic-trainer churn benchmark: convergence vs virtual wall-clock.

The paper's scalability story is about *dynamic* node populations; this
benchmark measures it on the training side: the elastic SPMD trainer
(:mod:`repro.core.spmd_psp` with ``PSPConfig(churn=...)``) runs the
linear task under Poisson leave/join churn for every barrier policy and
records the normalized model error against **virtual wall-clock** — the
trade-off Elastic-BSP and Dynamic-SSP optimize for, now measurable per
policy.  Two scenario rows per policy:

* **churn** (top-level keys, one per barrier): Poisson leave/join with a
  25% straggler tail — the PR-4 scenario, now including the adaptive
  policies (``dssp`` / ``ebsp`` / ``apbsp`` / ``apssp``).
* **stragglers** (the ``"stragglers"`` key): static membership with a
  heavy 35% straggler tail — the scenario the adaptive policies target;
  ``"adaptive_vs_static"`` scores each adaptive policy against its
  static parent at equal virtual time (error interpolated at the
  earlier of the two final times), so ``dominates`` means *strictly
  lower error for the same virtual wall-clock*.

Output schema and the figure → command map live in
``docs/BENCHMARKS.md``.

    PYTHONPATH=src python -m benchmarks.churn_bench [--full]

Also registered as the ``elastic_churn`` entry of ``benchmarks.run``;
:func:`benchmarks.figures.fig6_adaptive_churn` reshapes this result into
the adaptive-vs-static curve series.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.spmd_psp import ChurnConfig, PSPConfig, elastic_drive

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "benchmarks", "elastic_churn.json")

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")
ADAPTIVE = ("dssp", "ebsp", "apbsp", "apssp")
#: adaptive policy → the static protocol it reduces to when pinned
PARENT = {"dssp": "ssp", "ebsp": "bsp", "apbsp": "pbsp", "apssp": "pssp"}
NINE = FIVE + ADAPTIVE
D = 32


def _run_one(barrier: str, ticks: int, workers: int,
             churn: ChurnConfig | None,
             straggler_frac: float = 0.25, **cfg_kw) -> Dict:
    """One elastic run: (virtual time, error) trace + summary scalars."""
    cfg = PSPConfig(barrier=barrier, n_workers=workers, sample_size=2,
                    staleness=3, straggler_frac=straggler_frac, churn=churn,
                    **cfg_kw)
    w_true, it = elastic_drive(cfg, D, ticks)
    times, errors, alive = [], [], []
    for i, (st, m) in enumerate(it):
        if i % 10 == 0 or i == ticks - 1:
            err = float(jnp.linalg.norm(st.server_params["w"] - w_true)
                        / jnp.linalg.norm(w_true))
            times.append(float(st.now))
            errors.append(err)
            alive.append(int(m["alive"]))
    return {
        "virtual_time": times,
        "error": errors,
        "alive": alive,
        "final_error": errors[-1],
        "final_virtual_time": times[-1],
        "mean_alive": float(np.mean(alive)),
        "total_pushes": int(st.total_pushes),
        "leaves": int(st.leave_cursor),
        "joins": int(st.join_cursor),
    }


def _err_at(run: Dict, t: float) -> float:
    """Error interpolated at virtual time ``t`` (curves are monotone in t)."""
    return float(np.interp(t, run["virtual_time"], run["error"]))


def _adaptive_vs_static(runs: Dict[str, Dict]) -> Dict[str, Dict]:
    """Score each adaptive policy against its static parent.

    Comparison at *equal virtual wall-clock*: both error curves are read
    at the earlier of the two final times, so a policy can't "win" by
    simply running longer.
    """
    out = {}
    for name, parent in PARENT.items():
        a, p = runs[name], runs[parent]
        t = min(a["final_virtual_time"], p["final_virtual_time"])
        err_a, err_p = _err_at(a, t), _err_at(p, t)
        out[name] = {
            "parent": parent,
            "virtual_time": t,
            "error": err_a,
            "parent_error": err_p,
            "error_ratio": err_a / max(err_p, 1e-12),
            "dominates": bool(err_a < err_p),
        }
    return out


def _sweep(ticks: int, workers: int) -> Dict:
    """Both scenarios × all nine policies at the given scale."""
    churn = ChurnConfig(leave_rate=1.5, join_rate=1.5, horizon=60.0, seed=7)
    res: Dict = {name: _run_one(name, ticks, workers, churn)
                 for name in NINE}
    # max_advance=8: Elastic-BSP's slack budget sized to the straggler
    # slowdown — at the default 4 the EMA slack can't cover a 4× tail
    # and ebsp pays BSP's wait *and* staleness noise.  Only ebsp reads
    # the knob.  The gap-driven policies (dssp, apbsp, apssp) equal
    # their parents here by construction: under *constant* straggling
    # the progress gap equilibrates at the threshold (thr = clip(gap)
    # is a fixed point at the ceiling), so their adaptivity shows up in
    # the churn scenario instead.
    stragglers = {name: _run_one(name, ticks, workers, churn=None,
                                 straggler_frac=0.35, max_advance=8)
                  for name in NINE}
    res["stragglers"] = stragglers
    res["adaptive_vs_static"] = {
        "churn": _adaptive_vs_static({k: res[k] for k in NINE}),
        "stragglers": _adaptive_vs_static(stragglers),
    }
    return res


@functools.lru_cache(maxsize=2)
def elastic_churn(full: bool = False, backend: str | None = None) -> Dict:
    """Convergence-vs-virtual-wall-clock, static + adaptive barrier rows.

    Cached per ``(full, backend)``: the ``benchmarks.run`` harness reads
    this result twice (the ``elastic_churn`` entry and the
    ``fig6_adaptive_churn`` reshape) and the 18 trainer runs are the
    expensive part.  Callers must not mutate the returned dict.

    ``backend`` is accepted for harness uniformity and ignored — the
    elastic trainer *is* the jax backend under test.  ``full`` scales
    ticks and workers up (still CPU-friendly).  Top-level keys stay one
    per barrier (churn scenario) so older consumers of the PR-4 schema
    keep working; the straggler scenario and the adaptive-vs-static
    scoreboard ride along under their own keys.
    """
    ticks, workers = (900, 16) if full else (300, 8)
    return _sweep(ticks, workers)


def main(argv=None) -> None:
    """CLI entry: ``python -m benchmarks.churn_bench [--full|--smoke]``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid (60 ticks, 6 workers) — the CI fast "
                         "lane's adaptive-policy benchmark smoke; "
                         "convergence numbers are NOT meaningful at "
                         "this scale, only schema and runnability")
    a = ap.parse_args(argv)
    res = _sweep(60, 6) if a.smoke else elastic_churn(full=a.full)
    if not a.smoke:     # the smoke grid must not clobber the real artifact
        os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
        with open(OUT_PATH, "w") as f:
            json.dump(res, f, indent=1)
    for scenario, runs in (("churn", {k: res[k] for k in NINE}),
                           ("stragglers", res["stragglers"])):
        print(f"-- {scenario} --")
        print(f"{'barrier':8s} {'err@T':>8s} {'virt_T':>7s} {'pushes':>7s} "
              f"{'alive':>6s} {'churn':>7s}")
        for name in NINE:
            r = runs[name]
            print(f"{name:8s} {r['final_error']:8.4f} "
                  f"{r['final_virtual_time']:7.2f} {r['total_pushes']:7d} "
                  f"{r['mean_alive']:6.1f} "
                  f"{r['leaves']:3d}-/{r['joins']}+")
    print("-- adaptive vs static parent (equal virtual time) --")
    for scenario in ("churn", "stragglers"):
        for name, s in res["adaptive_vs_static"][scenario].items():
            mark = "<" if s["dominates"] else ">="
            print(f"{scenario:11s} {name:6s} err {s['error']:.4f} {mark} "
                  f"{s['parent']} {s['parent_error']:.4f} "
                  f"(ratio {s['error_ratio']:.2f})")


if __name__ == "__main__":
    main()
