"""Figs 4 & 5: bounds on the mean/variance of the PSP lag distribution.

Sweeps a = F(r)^·  over (0, 1) for sampling counts β ∈ {1, 5, 100} with
r = 4, T = 10000 — exactly the paper's plot axes.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.bounds import mean_lag_bound, variance_lag_bound

BETAS = (1, 5, 100)
R, T = 4, 10_000


def fig4_mean_bound() -> Dict:
    """x-axis is a = F(r)^β (the paper's Fig-4 axis; the discontinuities it
    discusses live at a=0 and a=1); per curve F(r) = a^{1/β}."""
    grid = np.linspace(0.02, 0.98, 49)
    out = {}
    for beta in BETAS:
        out[f"beta={beta}"] = {
            "a": grid.tolist(),
            "bound": [float(mean_lag_bound(a ** (1.0 / beta), beta, R, T))
                      for a in grid]}
    return out


def fig5_variance_bound() -> Dict:
    grid = np.linspace(0.02, 0.98, 49)
    out = {}
    for beta in BETAS:
        out[f"beta={beta}"] = {
            "a": grid.tolist(),
            "bound": [float(variance_lag_bound(a ** (1.0 / beta), beta, R,
                                               T)) for a in grid]}
    return out


def derived_summary() -> str:
    """The paper's headline: small β reaches near-optimal bounds (at equal
    a, larger β means heavier underlying lag yet a comparable bound)."""
    a = 0.5
    b1 = mean_lag_bound(a ** (1.0 / 1), 1, R, T)
    b5 = mean_lag_bound(a ** (1.0 / 5), 5, R, T)
    b100 = mean_lag_bound(a ** (1.0 / 100), 100, R, T)
    return (f"mean_bound@a=0.5 beta1={b1:.2f} beta5={b5:.2f} "
            f"beta100={b100:.2f}")
