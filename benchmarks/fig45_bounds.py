"""Figs 4 & 5: bounds on the mean/variance of the PSP lag distribution.

Sweeps a = F(r)^·  over (0, 1) for sampling counts β ∈ {1, 5, 100} with
r = 4, T = 10000 — exactly the paper's plot axes.  Fig 4 additionally
overlays an *empirical* mean lag per β measured by one batched pSSP sweep
through :func:`repro.core.vector_sim.run_sweep`, tying the theory curves to
the simulated system.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.barriers import make_barrier
from repro.core.bounds import mean_lag_bound, variance_lag_bound
from repro.core.simulator import SimConfig
from repro.core.vector_sim import run_sweep

BETAS = (1, 5, 100)
R, T = 4, 10_000


def empirical_mean_lags(full: bool = False,
                        backend: str = "numpy") -> Dict[int, float]:
    """Simulated mean lag for each β (one vectorized pSSP sweep)."""
    n, dur = (1000, 40.0) if full else (200, 10.0)
    cfgs = [SimConfig(n_nodes=n, duration=dur, dim=32, seed=0,
                      barrier=make_barrier("pssp", staleness=R,
                                           sample_size=beta))
            for beta in BETAS]
    out = {}
    for beta, r in zip(BETAS, run_sweep(cfgs, backend=backend)):
        out[beta] = float((r.steps.max() - r.steps).mean())
    return out


def fig4_mean_bound(full: bool = False, backend: str = "numpy") -> Dict:
    """x-axis is a = F(r)^β (the paper's Fig-4 axis; the discontinuities it
    discusses live at a=0 and a=1); per curve F(r) = a^{1/β}."""
    grid = np.linspace(0.02, 0.98, 49)
    lags = empirical_mean_lags(full, backend)
    out = {}
    for beta in BETAS:
        out[f"beta={beta}"] = {
            "a": grid.tolist(),
            "bound": [float(mean_lag_bound(a ** (1.0 / beta), beta, R, T))
                      for a in grid],
            "empirical_mean_lag": lags[beta]}
    return out


def fig5_variance_bound() -> Dict:
    grid = np.linspace(0.02, 0.98, 49)
    out = {}
    for beta in BETAS:
        out[f"beta={beta}"] = {
            "a": grid.tolist(),
            "bound": [float(variance_lag_bound(a ** (1.0 / beta), beta, R,
                                               T)) for a in grid]}
    return out


def derived_summary() -> str:
    """The paper's headline: small β reaches near-optimal bounds (at equal
    a, larger β means heavier underlying lag yet a comparable bound)."""
    a = 0.5
    b1 = mean_lag_bound(a ** (1.0 / 1), 1, R, T)
    b5 = mean_lag_bound(a ** (1.0 / 5), 5, R, T)
    b100 = mean_lag_bound(a ** (1.0 / 100), 100, R, T)
    return (f"mean_bound@a=0.5 beta1={b1:.2f} beta5={b5:.2f} "
            f"beta100={b100:.2f}")
