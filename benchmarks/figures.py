"""Simulator-backed reproductions of the paper's figures (Figs 1–3).

Each function returns a dict of series suitable for CSV/JSON dumping and a
one-line derived summary; ``benchmarks.run`` orchestrates them.  Default
scale is CI-friendly (200 nodes / 20 s); ``full=True`` reproduces the
paper's 1000-node / 40 s setting with β = 1% of the system size.

Every figure is a *sweep* — barrier × scenario parameter — so all of them
are routed through the vectorized batch engine
(:func:`repro.core.vector_sim.run_sweep`): one call advances every scenario
of a figure simultaneously instead of looping the event-driven simulator.
Each figure accepts ``backend="numpy"|"jax"`` and forwards it to
:func:`run_sweep`; :func:`fig1_error_bands` adds mean ± std bands over
seeds (one batched call — seeds are just extra rows).  Bands default to
the numpy backend, which decorrelates rows via finisher-ordered stream
consumption (the jax backend shares dynamics draws across rows: exact
per-row marginals, but cross-row correlation would understate seed-to-seed
spread).
"""
from __future__ import annotations

import functools
from typing import Dict, Sequence

import numpy as np

from repro.configs.psp_linear import PSPLinearConfig
from repro.core.barriers import make_barrier
from repro.core.simulator import SimConfig
from repro.core.vector_sim import run_sweep

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")


def _scale(full: bool) -> PSPLinearConfig:
    if full:
        return PSPLinearConfig()
    return PSPLinearConfig(n_nodes=200, dim=100, duration=20.0)


def _bar(name: str, c: PSPLinearConfig):
    return make_barrier(name, staleness=c.ssp_staleness,
                        sample_size=c.sample_size)


def _cfg(name: str, c: PSPLinearConfig, **kw) -> SimConfig:
    kw.setdefault("seed", c.seed)
    return SimConfig(n_nodes=c.n_nodes, duration=c.duration, dim=c.dim,
                     barrier=_bar(name, c), **kw)


@functools.lru_cache(maxsize=4)
def _fig1_sweep(full: bool, backend: str = "numpy"):
    """Figs 1a/1d/1e share the same five runs — sweep once per scale."""
    c = _scale(full)
    return c, run_sweep([_cfg(name, c) for name in FIVE], backend=backend)


def fig1_progress(full: bool = False, backend: str = "numpy") -> Dict:
    """Fig 1a/1b: final step distribution of the five strategies."""
    c, results = _fig1_sweep(full, backend)
    out = {}
    for name, r in zip(FIVE, results):
        out[name] = {"mean": float(r.mean_progress),
                     "min": int(r.steps.min()), "max": int(r.steps.max()),
                     "cdf_steps": np.sort(r.steps).tolist()[:: max(1,
                         c.n_nodes // 50)]}
    return out


def fig1_sample_sweep(full: bool = False, backend: str = "numpy") -> Dict:
    """Fig 1c: pBSP parameterised by sample size 0 → 64."""
    c = _scale(full)
    betas = (0, 1, 2, 4, 16, 64)
    cfgs = [SimConfig(n_nodes=c.n_nodes, duration=c.duration, dim=c.dim,
                      barrier=(make_barrier("asp") if beta == 0 else
                               make_barrier("pbsp", sample_size=beta)),
                      seed=c.seed)
            for beta in betas]
    out = {}
    for beta, r in zip(betas, run_sweep(cfgs, backend=backend)):
        out[f"beta={beta}"] = {"mean": float(r.mean_progress),
                               "spread": int(r.steps.max() - r.steps.min())}
    return out


def fig1_error(full: bool = False, backend: str = "numpy") -> Dict:
    """Fig 1d: normalized L2 model error over time."""
    _, results = _fig1_sweep(full, backend)
    out = {}
    for name, r in zip(FIVE, results):
        out[name] = {"times": r.times.tolist(),
                     "errors": r.errors.tolist(),
                     "final": float(r.final_error)}
    return out


def fig1_messages(full: bool = False, backend: str = "numpy") -> Dict:
    """Fig 1e: cumulative updates received by the server."""
    _, results = _fig1_sweep(full, backend)
    out = {}
    for name, r in zip(FIVE, results):
        out[name] = {"times": r.times.tolist(),
                     "updates": r.server_updates.tolist(),
                     "total": int(r.total_updates)}
    return out


def fig1_error_bands(full: bool = False, seeds: Sequence[int] = (0, 1, 2, 3),
                     backend: str = "numpy") -> Dict:
    """Fig 1d with mean ± std bands over seeds.

    One batched :func:`run_sweep` call advances all barrier × seed rows
    simultaneously; per barrier the band is ``mean ± std`` of the error
    trace across seeds (``lo``/``hi`` clipped at 0 — errors are norms).
    """
    c = _scale(full)
    cfgs = [_cfg(name, c, seed=s) for name in FIVE for s in seeds]
    results = run_sweep(cfgs, backend=backend)
    out = {}
    for i, name in enumerate(FIVE):
        rs = results[i * len(seeds):(i + 1) * len(seeds)]
        errs = np.stack([r.errors for r in rs])          # [S, M]
        mean, std = errs.mean(axis=0), errs.std(axis=0)
        out[name] = {"times": rs[0].times.tolist(),
                     "mean": mean.tolist(),
                     "std": std.tolist(),
                     "lo": np.maximum(mean - std, 0.0).tolist(),
                     "hi": (mean + std).tolist(),
                     "final_mean": float(mean[-1]),
                     "final_std": float(std[-1])}
    return out


def fig2_stragglers(full: bool = False, backend: str = "numpy") -> Dict:
    """Fig 2a/2b: straggler-fraction sweep 0 → 30% (4× slow)."""
    c = _scale(full)
    fracs = (0.0, 0.05, 0.1, 0.2, 0.3)
    results = run_sweep([_cfg(name, c, straggler_frac=frac)
                         for name in FIVE for frac in fracs],
                        backend=backend)
    out = {}
    for i, name in enumerate(FIVE):
        rows, base = [], None
        for frac, r in zip(fracs, results[i * len(fracs):]):
            if base is None:
                base = (r.mean_progress, r.final_error)
            rows.append({"frac": frac,
                         "progress_ratio": float(r.mean_progress / base[0]),
                         "error_increase": float(r.final_error - base[1])})
        out[name] = rows
    return out


def fig2_slowness(full: bool = False, backend: str = "numpy") -> Dict:
    """Fig 2c: 5% stragglers, slowness 1× → 16×."""
    c = _scale(full)
    slows = (1.0, 2.0, 4.0, 8.0, 16.0)
    results = run_sweep([_cfg(name, c, straggler_frac=0.05,
                              straggler_slowdown=slow)
                         for name in FIVE for slow in slows],
                        backend=backend)
    out = {}
    for i, name in enumerate(FIVE):
        rows, base = [], None
        for slow, r in zip(slows, results[i * len(slows):]):
            if base is None:
                base = r.mean_progress
            rows.append({"slowness": slow,
                         "progress_ratio": float(r.mean_progress / base)})
        out[name] = rows
    return out


def fig3_scalability(full: bool = False, backend: str = "numpy") -> Dict:
    """Fig 3: 5% stragglers, system size 100 → 1000 (fixed 10-node sample).

    Sizes form distinct structural groups; ``run_sweep`` batches each size
    across all five barriers automatically.
    """
    sizes = (100, 250, 500, 1000) if full else (50, 100, 200)
    duration = 40.0 if full else 20.0
    results = run_sweep([SimConfig(
        n_nodes=n, duration=duration, dim=100,
        barrier=make_barrier(name, staleness=4, sample_size=10),
        straggler_frac=0.05, seed=0)
        for name in FIVE for n in sizes], backend=backend)
    out = {}
    for i, name in enumerate(FIVE):
        rows, base = [], None
        for n, r in zip(sizes, results[i * len(sizes):]):
            if base is None:
                base = r.mean_progress
            rows.append({"n": n, "progress_pct": float(
                100.0 * r.mean_progress / base)})
        out[name] = rows
    return out


def fig6_adaptive_churn(full: bool = False, backend: str = "numpy") -> Dict:
    """Adaptive-vs-static convergence curves (virtual wall-clock x-axis).

    The PR-6 deliverable figure: for each adaptive barrier policy
    (DSSP / Elastic-BSP / annealed pBSP / annealed pSSP) and its static
    parent, the normalized-error-vs-virtual-time trace of the elastic
    SPMD trainer under the two :mod:`benchmarks.churn_bench` scenarios
    (Poisson churn, heavy stragglers).  Series are keyed
    ``{scenario}/{policy}`` with a ``pair`` field linking each adaptive
    curve to its parent; the ``adaptive_vs_static`` scoreboard (error at
    equal virtual time) rides along under ``"scoreboard"``.

    ``backend`` is accepted for harness uniformity and ignored — the
    elastic trainer is jax-only.
    """
    from benchmarks import churn_bench

    res = churn_bench.elastic_churn(full=full, backend=backend)
    out: Dict = {"scoreboard": res["adaptive_vs_static"]}
    scenarios = {"churn": {k: res[k] for k in churn_bench.NINE},
                 "stragglers": res["stragglers"]}
    for scenario, runs in scenarios.items():
        for name, parent in churn_bench.PARENT.items():
            for member, role in ((name, "adaptive"), (parent, "static")):
                r = runs[member]
                out[f"{scenario}/{member}"] = {
                    "role": role,
                    "pair": f"{name} vs {parent}",
                    "virtual_time": r["virtual_time"],
                    "error": r["error"],
                    "final_error": r["final_error"],
                }
    return out
