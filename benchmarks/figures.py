"""Simulator-backed reproductions of the paper's figures (Figs 1–3).

Each function returns a dict of series suitable for CSV/JSON dumping and a
one-line derived summary; ``benchmarks.run`` orchestrates them.  Default
scale is CI-friendly (200 nodes / 20 s); ``full=True`` reproduces the
paper's 1000-node / 40 s setting with β = 1% of the system size.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.configs.psp_linear import PSPLinearConfig
from repro.core.barriers import make_barrier
from repro.core.simulator import SimConfig, run_simulation

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")


def _scale(full: bool) -> PSPLinearConfig:
    if full:
        return PSPLinearConfig()
    return PSPLinearConfig(n_nodes=200, dim=100, duration=20.0)


def _bar(name: str, c: PSPLinearConfig):
    return make_barrier(name, staleness=c.ssp_staleness,
                        sample_size=c.sample_size)


def _run(name: str, c: PSPLinearConfig, **kw):
    cfg = SimConfig(n_nodes=c.n_nodes, duration=c.duration, dim=c.dim,
                    barrier=_bar(name, c), seed=c.seed, **kw)
    return run_simulation(cfg)


def fig1_progress(full: bool = False) -> Dict:
    """Fig 1a/1b: final step distribution of the five strategies."""
    c = _scale(full)
    out = {}
    for name in FIVE:
        r = _run(name, c)
        out[name] = {"mean": float(r.mean_progress),
                     "min": int(r.steps.min()), "max": int(r.steps.max()),
                     "cdf_steps": np.sort(r.steps).tolist()[:: max(1,
                         c.n_nodes // 50)]}
    return out


def fig1_sample_sweep(full: bool = False) -> Dict:
    """Fig 1c: pBSP parameterised by sample size 0 → 64."""
    c = _scale(full)
    out = {}
    for beta in (0, 1, 2, 4, 16, 64):
        bar = make_barrier("asp") if beta == 0 else \
            make_barrier("pbsp", sample_size=beta)
        r = run_simulation(SimConfig(n_nodes=c.n_nodes, duration=c.duration,
                                     dim=c.dim, barrier=bar, seed=c.seed))
        out[f"beta={beta}"] = {"mean": float(r.mean_progress),
                               "spread": int(r.steps.max() - r.steps.min())}
    return out


def fig1_error(full: bool = False) -> Dict:
    """Fig 1d: normalized L2 model error over time."""
    c = _scale(full)
    out = {}
    for name in FIVE:
        r = _run(name, c)
        out[name] = {"times": r.times.tolist(),
                     "errors": r.errors.tolist(),
                     "final": float(r.final_error)}
    return out


def fig1_messages(full: bool = False) -> Dict:
    """Fig 1e: cumulative updates received by the server."""
    c = _scale(full)
    out = {}
    for name in FIVE:
        r = _run(name, c)
        out[name] = {"times": r.times.tolist(),
                     "updates": r.server_updates.tolist(),
                     "total": int(r.total_updates)}
    return out


def fig2_stragglers(full: bool = False) -> Dict:
    """Fig 2a/2b: straggler-fraction sweep 0 → 30% (4× slow)."""
    c = _scale(full)
    out = {}
    for name in FIVE:
        base = None
        rows = []
        for frac in (0.0, 0.05, 0.1, 0.2, 0.3):
            r = _run(name, c, straggler_frac=frac)
            if base is None:
                base = (r.mean_progress, r.final_error)
            rows.append({"frac": frac,
                         "progress_ratio": float(r.mean_progress / base[0]),
                         "error_increase": float(r.final_error - base[1])})
        out[name] = rows
    return out


def fig2_slowness(full: bool = False) -> Dict:
    """Fig 2c: 5% stragglers, slowness 1× → 16×."""
    c = _scale(full)
    out = {}
    for name in FIVE:
        rows = []
        base = None
        for slow in (1.0, 2.0, 4.0, 8.0, 16.0):
            r = _run(name, c, straggler_frac=0.05, straggler_slowdown=slow)
            if base is None:
                base = r.mean_progress
            rows.append({"slowness": slow,
                         "progress_ratio": float(r.mean_progress / base)})
        out[name] = rows
    return out


def fig3_scalability(full: bool = False) -> Dict:
    """Fig 3: 5% stragglers, system size 100 → 1000 (fixed 10-node sample)."""
    sizes = (100, 250, 500, 1000) if full else (50, 100, 200)
    out = {}
    for name in FIVE:
        rows = []
        base = None
        for n in sizes:
            bar = make_barrier(name, staleness=4, sample_size=10)
            r = run_simulation(SimConfig(
                n_nodes=n, duration=20.0 if not full else 40.0,
                dim=100, barrier=bar, straggler_frac=0.05, seed=0))
            if base is None:
                base = r.mean_progress
            rows.append({"n": n, "progress_pct": float(
                100.0 * r.mean_progress / base)})
        out[name] = rows
    return out
