"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Prints the per-(arch × shape) three-term roofline for the single-pod mesh
(EXPERIMENTS.md §Roofline is generated from this) and flags the dominant
bottleneck.  ``derived`` = count of combos per bottleneck class.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(mesh: str = "single") -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        if "_psp__" in path:
            continue    # PSP trainer artifacts live in §Perf pair 3
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(mesh: str = "single") -> List[dict]:
    out = []
    for r in load(mesh):
        if r.get("status") != "ok" or "roofline" not in r:
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": r.get("status", "?"),
                        "reason": r.get("reason", r.get("error", ""))[:60]})
            continue
        rf = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "bottleneck": rf["bottleneck"],
            "useful_ratio": rf["useful_ratio"],
            "temp_gb": r["memory"]["temp_bytes"] / 1e9,
            "args_gb": r["memory"]["argument_bytes"] / 1e9,
        })
    return out


def print_table(mesh: str = "single") -> Dict[str, int]:
    rows = table(mesh)
    counts: Dict[str, int] = {}
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'coll_s':>10s} {'bneck':>10s} {'useful':>7s} {'temp_GB':>8s}")
    print(hdr)
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:24s} {r['shape']:12s} -- {r['status']}: "
                  f"{r.get('reason','')}")
            continue
        counts[r["bottleneck"]] = counts.get(r["bottleneck"], 0) + 1
        print(f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
              f"{r['bottleneck']:>10s} {r['useful_ratio']:7.3f} "
              f"{r['temp_gb']:8.2f}")
    return counts
