"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Prints the per-(arch × shape) three-term roofline for the single-pod mesh
(EXPERIMENTS.md §Roofline is generated from this) and flags the dominant
bottleneck.  ``derived`` = count of combos per bottleneck class.

Also exports :func:`sweep_tick_row` — the sweep engine's hot path (the
fused ``psp_tick`` inside its chunked scan) scored against the same
three-term roofline, so the table covers the control-plane kernel and
not just the model archs.
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(mesh: str = "single") -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        if "_psp__" in path:
            continue    # PSP trainer artifacts live in §Perf pair 3
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(mesh: str = "single") -> List[dict]:
    out = []
    for r in load(mesh):
        if r.get("status") != "ok" or "roofline" not in r:
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": r.get("status", "?"),
                        "reason": r.get("reason", r.get("error", ""))[:60]})
            continue
        rf = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "bottleneck": rf["bottleneck"],
            "useful_ratio": rf["useful_ratio"],
            "temp_gb": r["memory"]["temp_bytes"] / 1e9,
            "args_gb": r["memory"]["argument_bytes"] / 1e9,
        })
    return out


def sweep_tick_row(n_nodes: int = 128, dim: int = 32, rows: int = 8) -> dict:
    """Roofline row for the fused sweep-tick hot path (ROADMAP leftover).

    Lowers the *production* chunked scan — the fused
    :func:`repro.kernels.psp_tick` tick inside its donated ``lax.scan``
    chunk — for a representative straggler-sweep batch, runs the
    trip-count-aware HLO cost analysis on the compiled module, and
    scores per-chunk FLOPs/bytes against the TPU-v5e roofline
    (:class:`repro.roofline.analysis.HW`).  The compiled chunk is also
    timed on this host (best-of-3), so the row records both the analytic
    distance to the accelerator roofline and the achieved tick rate of
    the current backend: ``useful_ratio`` is the fraction of the v5e
    roofline the measured run achieves (≈ 0 on a CPU host, meaningful on
    TPU).
    """
    import jax
    from repro.core import vector_sim_jax
    from repro.core.barriers import make_barrier
    from repro.core.simulator import SimConfig
    from repro.core.vector_sim import VectorSimulator
    from repro.roofline.analysis import roofline_report
    from repro.roofline.hlo_cost import analyze_hlo

    cfgs = [SimConfig(n_nodes=n_nodes, duration=10.0, dim=dim, seed=s,
                      straggler_frac=0.2,
                      barrier=make_barrier("pssp", staleness=4,
                                           sample_size=2))
            for s in range(rows)]
    sim = VectorSimulator(cfgs, backend="jax")
    try:
        chunk_fn, plan, params, carry, xs_chunks = \
            vector_sim_jax._prepare(sim)
        xs = xs_chunks[0]
        ticks = int(jax.tree_util.tree_leaves(xs)[0].shape[0]) * plan.stride
        compiled = chunk_fn.lower(params, carry, xs).compile()
        hlo = compiled.as_text()
        cost = analyze_hlo(hlo)
        rep = roofline_report(
            {"flops": cost.flops, "bytes accessed": cost.bytes}, hlo,
            chips=1, model_flops_total=float(cost.flops))
        best = float("inf")
        for _ in range(3):           # donated carry: fresh copies per call
            c = {k: v.copy() for k, v in carry.items()}
            t0 = time.time()
            out, _ = chunk_fn(params, c, xs)
            jax.block_until_ready(out)
            best = min(best, time.time() - t0)
        roofline_s = max(rep.compute_s, rep.memory_s, rep.collective_s)
        nbytes = lambda tree: sum(
            v.size * v.dtype.itemsize for v in jax.tree_util.tree_leaves(tree))
        return {
            "arch": "sweep_tick", "status": "ok",
            "shape": f"B{rows}xP{n_nodes}xd{dim}x{ticks}t",
            "compute_s": rep.compute_s, "memory_s": rep.memory_s,
            "collective_s": rep.collective_s, "bottleneck": rep.bottleneck,
            "useful_ratio": min(roofline_s / max(best, 1e-12), 1.0),
            "temp_gb": nbytes(carry) / 1e9,
            "args_gb": (nbytes(params) + nbytes(xs)) / 1e9,
            "ticks_per_chunk": ticks,
            "flops_per_tick": cost.flops / max(ticks, 1),
            "bytes_per_tick": cost.bytes / max(ticks, 1),
            "arithmetic_intensity": cost.flops / max(cost.bytes, 1),
            "measured_chunk_s": best,
            "measured_tick_us": best / max(ticks, 1) * 1e6,
            "host_backend": jax.default_backend(),
        }
    finally:
        vector_sim_jax._compiled_chunk.cache_clear()


def print_table(mesh: str = "single") -> Dict[str, int]:
    rows = table(mesh)
    counts: Dict[str, int] = {}
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'coll_s':>10s} {'bneck':>10s} {'useful':>7s} {'temp_GB':>8s}")
    print(hdr)
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:24s} {r['shape']:12s} -- {r['status']}: "
                  f"{r.get('reason','')}")
            continue
        counts[r["bottleneck"]] = counts.get(r["bottleneck"], 0) + 1
        print(f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
              f"{r['bottleneck']:>10s} {r['useful_ratio']:7.3f} "
              f"{r['temp_gb']:8.2f}")
    return counts
