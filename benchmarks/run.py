"""Benchmark harness — one entry per paper figure/table.

Prints ``name,us_per_call,derived`` CSV (one line per benchmark) and dumps
the full series to results/benchmarks/*.json.  Every figure sweep routes
through the vectorized grid engine
(:func:`repro.core.vector_sim.run_sweep`) on the backend selected by
``--backend`` — numpy array ops, or the device-resident jax scan whose
control-plane tick is the fused kernel of :mod:`repro.kernels.psp_tick`
(churn and ragged shapes run natively on both; there is no event-engine
fallback).  Flag reference and the figure → command map live in
``docs/BENCHMARKS.md``.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig1_progress]
                                            [--backend numpy|jax]

``--full`` runs the paper-scale settings (1000 nodes / 40 s / β = 1%);
default is a CI-friendly reduced scale with identical structure.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks import _host_mesh  # noqa: F401  (must precede jax import)
from benchmarks import churn_bench, fig45_bounds, figures, sweep_bench
from benchmarks.roofline_bench import print_table, sweep_tick_row, table

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                       "benchmarks")


def _derived_fig1(res):
    return ("pbsp_vs_bsp_progress="
            f"{res['pbsp']['mean'] / max(res['bsp']['mean'], 1e-9):.2f}")


def _derived_fig1_err(res):
    best = min(res, key=lambda k: res[k]["final"])
    return f"lowest_error={best}:{res[best]['final']:.4f}"


def _derived_fig1_msg(res):
    return ("asp_vs_bsp_updates="
            f"{res['asp']['total'] / max(res['bsp']['total'], 1):.1f}x")


def _derived_fig1_bands(res):
    best = min(res, key=lambda k: res[k]["final_mean"])
    return (f"lowest_error={best}:{res[best]['final_mean']:.4f}"
            f"±{res[best]['final_std']:.4f}")


def _derived_fig2(res):
    worst = res["bsp"][-1]["progress_ratio"]
    rob = res["pbsp"][-1]["progress_ratio"]
    return f"at30pct: bsp={worst:.2f} pbsp={rob:.2f}"


def _derived_fig2c(res):
    return (f"at16x: bsp={res['bsp'][-1]['progress_ratio']:.2f} "
            f"pbsp={res['pbsp'][-1]['progress_ratio']:.2f}")


def _derived_fig3(res):
    return (f"largest: bsp={res['bsp'][-1]['progress_pct']:.0f}% "
            f"pssp={res['pssp'][-1]['progress_pct']:.0f}%")


def _derived_sweep(res):
    keys = sorted(res, key=lambda k: int(k.split("=")[1]))
    return (f"spread beta0={res[keys[0]]['spread']} "
            f"beta_max={res[keys[-1]]['spread']}")


BENCHES = [
    ("fig1_progress", figures.fig1_progress, _derived_fig1),
    ("fig1_sample_sweep", figures.fig1_sample_sweep, _derived_sweep),
    ("fig1_error", figures.fig1_error, _derived_fig1_err),
    # bands are pinned to the numpy backend regardless of --backend: the
    # jax backend shares dynamics draws across rows, which would understate
    # seed-to-seed spread (see benchmarks/figures.py docstring)
    ("fig1_error_bands",
     lambda full=False, backend="numpy": figures.fig1_error_bands(full=full),
     _derived_fig1_bands),
    ("fig1_messages", figures.fig1_messages, _derived_fig1_msg),
    ("fig2_stragglers", figures.fig2_stragglers, _derived_fig2),
    ("fig2_slowness", figures.fig2_slowness, _derived_fig2c),
    ("fig3_scalability", figures.fig3_scalability, _derived_fig3),
    ("fig4_mean_bound", fig45_bounds.fig4_mean_bound,
     lambda res: fig45_bounds.derived_summary()),
    ("fig5_variance_bound",
     lambda full=False, backend="numpy": fig45_bounds.fig5_variance_bound(),
     lambda res: fig45_bounds.derived_summary()),
    # out_path=None: the harness persists the result itself below; only
    # the standalone sweep_bench CLI regenerates the committed CI-gate
    # baseline BENCH_sweep.json
    ("sweep_engine",
     lambda full=False, backend=None:
         sweep_bench.sweep_speedup(full=full, out_path=None),
     lambda res: f"speedup={res['summary']['best_speedup_vs_event']:.1f}x "
                 f"max_dev={res['summary']['max_progress_deviation']:.3f}"),
    # elastic SPMD trainer under Poisson churn: the convergence-vs-
    # virtual-wall-clock trade-off with a dynamic worker set
    ("elastic_churn", churn_bench.elastic_churn,
     lambda res: "err@T " + " ".join(
         f"{k}={res[k]['final_error']:.3f}" for k in ("bsp", "pssp", "asp"))),
    # adaptive-vs-static reshape of the same runs (elastic_churn result
    # is cached, so the 18 trainer runs are not repeated)
    ("fig6_adaptive_churn", figures.fig6_adaptive_churn,
     lambda res: "dominant " + (",".join(
         name for name, s in res["scoreboard"]["stragglers"].items()
         if s["dominates"]) or "none") + " (stragglers)"),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (1000 nodes, 40s)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--backend", default="numpy", choices=("numpy", "jax"),
                    help="grid engine for the figure sweeps")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax-profiler trace of the whole run "
                         "into DIR (open with TensorBoard/Perfetto); perf "
                         "PRs argue from these traces")
    a = ap.parse_args(argv)
    os.makedirs(OUT_DIR, exist_ok=True)
    sweep_bench.enable_compile_cache()

    if a.profile:
        import jax
        jax.profiler.start_trace(a.profile)
    try:
        _run_benches(a)
    finally:
        if a.profile:
            import jax
            jax.profiler.stop_trace()
            print(f"profile,0,trace_dir={a.profile}")


def _run_benches(a) -> None:
    """Execute the selected benchmarks (split out so ``--profile`` can
    bracket every compiled region in one trace)."""
    print("name,us_per_call,derived")
    for name, fn, derive in BENCHES:
        if a.only and name != a.only:
            continue
        t0 = time.time()
        res = fn(full=a.full, backend=a.backend)
        us = (time.time() - t0) * 1e6
        with open(os.path.join(OUT_DIR, name + ".json"), "w") as f:
            json.dump(res, f)
        print(f"{name},{us:.0f},{derive(res)}")

    if not a.skip_roofline and (a.only in (None, "roofline")):
        t0 = time.time()
        rows = table("single")
        if not rows:
            print("note: no dry-run artifacts (run repro.launch.dryrun); "
                  "roofline table holds the sweep-tick row only")
        # the sweep engine's own hot path sits in the same table as the
        # model archs (ROADMAP: sweep-kernel roofline row)
        rows.append(sweep_tick_row())
        ok = [r for r in rows if r["status"] == "ok"]
        with open(os.path.join(OUT_DIR, "roofline.json"), "w") as f:
            json.dump(rows, f, indent=1)
        counts = {}
        for r in ok:
            counts[r["bottleneck"]] = counts.get(r["bottleneck"], 0) + 1
        us = (time.time() - t0) * 1e6
        print(f"roofline,{us:.0f},combos={len(ok)} bottlenecks={counts}")


if __name__ == "__main__":
    main()
