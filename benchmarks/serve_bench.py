"""Serving-tier benchmark: open-loop load with mid-stream snapshot swaps.

An open-loop generator (arrivals on a fixed schedule, independent of
completions — the load does not politely wait for a slow server) drives
the request-lifecycle :class:`repro.serving.ServingEngine` while a
:class:`SnapshotPublisher`/:class:`SnapshotWatcher` pair performs **two
mid-stream hot-swaps** (at 1/3 and 2/3 of arrivals).  Measured:

* ``tokens_per_s`` — decoded tokens over the serving wall-clock;
* per-token latency (gap between a request's consecutive tokens),
  per-request latency (scheduled arrival → completion, so queueing
  delay counts — the open-loop convention) and first-token latency,
  each as p50/p99;
* ``swap_stall_s`` — wall time the decode loop spent inside
  ``watcher.poll()`` for each swap that loaded (the serving-side cost
  of a hot-swap);
* ``dropped`` — must be 0: a swap never cancels in-flight work.

Run + artifact (the committed baseline lives in
``results/benchmarks/serve.json``; schema in ``docs/BENCHMARKS.md``;
regression-gated by ``tools/check_bench.py --serve``)::

    PYTHONPATH=src python -m benchmarks.serve_bench
    PYTHONPATH=src python -m benchmarks.serve_bench --smoke   # no artifact

The ``--smoke`` grid only proves schema + runnability (and still
performs both swaps); its timings are not meaningful.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from typing import Dict, List

import benchmarks._host_mesh  # noqa: F401  (forced host mesh before jax)

import jax
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.models import init_model
from repro.serving import (Request, ServeConfig, ServingEngine,
                           SnapshotPublisher, SnapshotWatcher)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "benchmarks", "serve.json")


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def _lat(xs: List[float]) -> Dict[str, float]:
    return {"p50": _pct(xs, 50), "p99": _pct(xs, 99)}


def serve_load(arch: str = "qwen2-0.5b", requests: int = 32,
               rate_rps: float = 4.0, batch: int = 4, max_new: int = 16,
               prompt_len: int = 12, poll_every: int = 4,
               seed: int = 0) -> Dict:
    """One open-loop serving run with two mid-stream swaps → metrics dict."""
    cfg = make_reduced(get_config(arch))
    p0 = init_model(cfg, jax.random.PRNGKey(seed))
    scfg = ServeConfig(batch=batch, max_len=256, max_new_tokens=max_new,
                       seed=seed)
    eng = ServingEngine(p0, cfg, scfg, version=0)

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=prompt_len)
               .astype(np.int32) for _ in range(requests)]
    # arrival indices that trigger a snapshot publication; the second
    # waits for the first swap to land so the run always measures two
    # DISTINCT swap events (not one jump to the newest step)
    swap_at = sorted({requests // 3, (2 * requests) // 3})

    with tempfile.TemporaryDirectory(prefix="psp_serve_bench_") as snap_dir:
        pub = SnapshotPublisher(snap_dir, async_write=True)
        watcher = SnapshotWatcher(snap_dir, p0)
        # warm the decode jit cache so compile time doesn't pollute the
        # measured window (one throwaway request end to end)
        warm = ServingEngine(p0, cfg, scfg)
        warm.submit(Request(prompt=prompts[0]))
        warm.drain()

        arrival: Dict[int, float] = {}
        first_tok: Dict[int, float] = {}
        last_tok: Dict[int, float] = {}
        tok_gaps: List[float] = []
        req_lat: List[float] = []
        ft_lat: List[float] = []
        swap_stalls: List[float] = []
        versions: set = set()
        completed = 0
        total_tokens = 0
        next_i, steps, published = 0, 0, 0

        t0 = time.perf_counter()
        while completed < requests:
            now = time.perf_counter() - t0
            # open loop: admit every request whose scheduled arrival
            # passed, regardless of how far behind the server is
            while next_i < requests and next_i / rate_rps <= now:
                rid = eng.submit(Request(prompt=prompts[next_i]))
                arrival[rid] = next_i / rate_rps
                next_i += 1
            if (published < len(swap_at) and next_i >= swap_at[published]
                    and published == len(swap_stalls)):
                pub.publish(published + 1,
                            init_model(cfg, jax.random.PRNGKey(published + 1)))
                published += 1
            if steps % poll_every == 0:
                ts = time.perf_counter()
                loaded = watcher.poll()
                if loaded is not None:
                    eng.set_params(*loaded)
                    swap_stalls.append(time.perf_counter() - ts)
            if not eng.has_pending():
                time.sleep(min(0.005, max(0.0, next_i / rate_rps - now)))
                continue
            res = eng.step()
            steps += 1
            now = time.perf_counter() - t0
            for rid, _tok in res.emitted:
                total_tokens += 1
                if rid in last_tok:
                    tok_gaps.append(now - last_tok[rid])
                else:
                    first_tok[rid] = now
                    ft_lat.append(now - arrival[rid])
                last_tok[rid] = now
            for c in res.completions:
                completed += 1
                versions.add(c.snapshot_version)
                req_lat.append(now - arrival[c.req_id])
        wall = time.perf_counter() - t0
        pub.close()

    return {
        "arch": cfg.name,
        "requests": requests,
        "rate_rps": rate_rps,
        "batch": batch,
        "max_new_tokens": max_new,
        "prompt_len": prompt_len,
        "wall_s": round(wall, 4),
        "total_tokens": total_tokens,
        "tokens_per_s": round(total_tokens / wall, 3),
        "latency_s": {
            "per_token": _lat(tok_gaps),
            "per_request": _lat(req_lat),
            "first_token": _lat(ft_lat),
        },
        "swaps": len(swap_stalls),
        "swap_stall_s": {"max": round(max(swap_stalls), 4)
                         if swap_stalls else 0.0,
                         "events": [round(s, 4) for s in swap_stalls]},
        "snapshots_skipped": watcher.skipped,
        "dropped": requests - completed,
        "versions_served": sorted(versions),
        "decode_steps": steps,
    }


def main(argv=None) -> int:
    """CLI entry: run the open-loop serve benchmark, write the artifact."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="open-loop arrival rate (requests/s)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI: proves schema + both swaps, "
                         "does NOT write the committed artifact")
    a = ap.parse_args(argv)
    if a.smoke:
        res = serve_load(requests=9, rate_rps=16.0, batch=2, max_new=4)
        # a smoke run never clobbers the committed artifact, but an
        # explicit non-default --out (CI handoff to the gate) is written
        if a.out != OUT_PATH:
            with open(a.out, "w") as f:
                json.dump(res, f, indent=1)
            print(f"wrote {a.out}")
    else:
        res = serve_load(requests=a.requests, rate_rps=a.rate,
                         batch=a.batch, max_new=a.max_new)
        os.makedirs(os.path.dirname(a.out), exist_ok=True)
        with open(a.out, "w") as f:
            json.dump(res, f, indent=1)
        print(f"wrote {a.out}")
    lat = res["latency_s"]
    print(f"{res['arch']}: {res['requests']} reqs @ {res['rate_rps']}/s  "
          f"{res['tokens_per_s']:.1f} tok/s  wall {res['wall_s']:.1f}s")
    print(f"  per-token  p50 {lat['per_token']['p50'] * 1e3:7.1f} ms   "
          f"p99 {lat['per_token']['p99'] * 1e3:7.1f} ms")
    print(f"  per-req    p50 {lat['per_request']['p50'] * 1e3:7.1f} ms   "
          f"p99 {lat['per_request']['p99'] * 1e3:7.1f} ms")
    print(f"  first-tok  p50 {lat['first_token']['p50'] * 1e3:7.1f} ms   "
          f"p99 {lat['first_token']['p99'] * 1e3:7.1f} ms")
    print(f"  swaps {res['swaps']} (max stall "
          f"{res['swap_stall_s']['max'] * 1e3:.1f} ms)  "
          f"versions {res['versions_served']}  dropped {res['dropped']}")
    if res["swaps"] < 2 or res["dropped"] != 0:
        print("FAIL: run invariants violated (need >=2 swaps, 0 drops)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
