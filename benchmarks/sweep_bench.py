"""Sweep-engine benchmark: event-driven loop vs vectorized batch engine.

Runs the same Fig-2-style scenario matrix (five barriers × five straggler
fractions, matched seeds) twice — once as a Python loop over the
discrete-event :func:`~repro.core.simulator.run_simulation` (the *before*),
once through the vectorized :func:`~repro.core.vector_sim.run_sweep` (the
*after*) — checks the two engines agree at the distribution level, and
records wall-clock plus speedup in ``BENCH_sweep.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.sweep_bench [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

from repro.core.barriers import make_barrier
from repro.core.simulator import SimConfig, run_simulation
from repro.core.vector_sim import run_sweep

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_sweep.json")

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")
FRACS = (0.0, 0.05, 0.1, 0.2, 0.3)


def _configs(full: bool):
    n, dur, dim = (1000, 40.0, 100) if full else (100, 20.0, 32)
    beta = max(1, n // 100)
    return [SimConfig(n_nodes=n, duration=dur, dim=dim, seed=3,
                      straggler_frac=frac,
                      barrier=make_barrier(name, staleness=4,
                                           sample_size=beta))
            for name in FIVE for frac in FRACS]


def sweep_speedup(full: bool = False) -> Dict:
    """Time the Fig-2 sweep on both engines and dump ``BENCH_sweep.json``."""
    cfgs = _configs(full)
    run_sweep(cfgs[:2])                         # warm-up (BLAS, imports)
    t0 = time.time()
    vec = run_sweep(cfgs)
    vector_s = time.time() - t0
    t0 = time.time()
    ev = [run_simulation(c) for c in cfgs]
    event_s = time.time() - t0
    rel = [v.mean_progress / max(e.mean_progress, 1e-9)
           for e, v in zip(ev, vec)]
    res = {
        "sweep": "fig2_stragglers",
        "n_configs": len(cfgs),
        "n_nodes": cfgs[0].n_nodes,
        "duration_s": cfgs[0].duration,
        "before": {"engine": "event-driven loop", "seconds": event_s},
        "after": {"engine": "vectorized run_sweep", "seconds": vector_s},
        "speedup": event_s / max(vector_s, 1e-9),
        "max_progress_deviation": max(abs(r - 1.0) for r in rel),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args(argv)
    res = sweep_speedup(full=a.full)
    print(f"event={res['before']['seconds']:.2f}s "
          f"vector={res['after']['seconds']:.2f}s "
          f"speedup={res['speedup']:.1f}x "
          f"max_dev={res['max_progress_deviation']:.3f}")


if __name__ == "__main__":
    main()
