"""Sweep-engine benchmark: event-driven loop vs the grid backends.

Runs the same Fig-2-style scenario matrix (nine barrier policies — five
static protocols plus the four adaptive members — × five straggler
fractions, matched seeds) through every engine — a Python loop over the
discrete-event :func:`~repro.core.simulator.run_simulation` (the
*before*), the vectorized NumPy :func:`~repro.core.vector_sim.run_sweep`,
its jax backend (donated chunked scans with the fused full tick, sharded
over the host's device mesh), and the Pallas tick kernel
(``PSP_TICK_IMPL=interpret`` through the Pallas interpreter on CPU; the
real Mosaic kernel when a TPU is attached) — checks the engines agree at
the distribution level, and records wall-clock plus speedups in
``BENCH_sweep.json`` at the repo root.  Grid-engine rows carry separate
**compile** and **run** phases so a compile-time regression can't hide
inside a throughput number (and vice versa).  Schema and regeneration
flags are documented in ``docs/BENCHMARKS.md``.

On CPU hosts the benchmark forces an ``xla_force_host_platform_device_count``
mesh (one device per core, capped at 8) **before jax initialises**, so the
jax row exercises the sharded multi-device path exactly as a TPU pod slice
would; set ``PSP_BENCH_HOST_DEVICES=0`` to disable, or any value to pin
the mesh size.  ``--mesh RxN`` (or ``PSP_SWEEP_MESH``) factorizes those
devices into a 2-D rows × nodes placement for the jax rows; every
jax-family row records its resolved ``mesh`` / ``mesh_axes``.  A
100k-node pBSP-vs-SSP smoke sweep (``jax_100k`` row) always rides along —
the node-sharded regime no event loop could reach, reported as
machine-comparable per-device node-step throughput.

    PYTHONPATH=src python -m benchmarks.sweep_bench [--full] [--no-pallas]
        [--mesh RxN]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

from benchmarks import _host_mesh  # noqa: F401  (must precede jax import)

import jax  # noqa: E402  (after the device-count bootstrap, by design)

from repro.core import env                              # noqa: E402
from repro.core.barriers import make_barrier            # noqa: E402
from repro.core.simulator import SimConfig, run_simulation  # noqa: E402
from repro.core.sweep_plan import parse_mesh, resolve_mesh  # noqa: E402
from repro.core.vector_sim import run_sweep             # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_sweep.json")
CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", ".jax_cache")

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")
ADAPTIVE = ("dssp", "ebsp", "apbsp", "apssp")
NINE = FIVE + ADAPTIVE
FRACS = (0.0, 0.05, 0.1, 0.2, 0.3)


def enable_compile_cache() -> bool:
    """Switch on JAX's persistent compilation cache for benchmark runs.

    ROADMAP: the smoke sweep pays ~10× more compile than run time, so
    repeated benchmark invocations (CI gate, local iteration) should hit
    the on-disk cache instead of re-lowering identical chunk shapes.
    The cache lives in repo-root ``.jax_cache`` (override with
    ``JAX_COMPILATION_CACHE_DIR``); set ``PSP_NO_COMPILE_CACHE=1`` to
    opt out — e.g. when *measuring* cold-compile cost itself.  Returns
    whether the cache is active.

    **CPU hosts default to off.**  The image's jaxlib (0.4.37) corrupts
    the heap when it deserializes the large donated sharded-scan chunk
    executable from the cache on the CPU backend — observed as wrong
    sweep results followed by glibc ``corrupted double-linked list`` /
    ``malloc`` aborts, with or without
    ``jax_persistent_cache_enable_xla_caches``; small executables
    round-trip fine, so this is a size/donation-dependent
    deserialization bug, not a config problem.  Accelerator backends use
    XLA's well-trodden serialization path and keep the cache on.  Set
    ``PSP_COMPILE_CACHE=1`` to force it on anyway (e.g. on a host with a
    newer jaxlib).
    """
    if env.flag("PSP_NO_COMPILE_CACHE"):
        return False
    if jax.default_backend() == "cpu" and not env.flag("PSP_COMPILE_CACHE"):
        return False
    cache_dir = os.environ.get("JAX_COMPILATION_CACHE_DIR",
                               os.path.abspath(CACHE_DIR))
    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # smoke-scale chunks compile in well under the default 1 s
        # threshold — cache everything, whatever its size
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        # don't bundle XLA's own autotune/kernel caches into the entry;
        # the executable alone is what amortizes recompiles
        jax.config.update("jax_persistent_cache_enable_xla_caches", "none")
    except AttributeError:          # ancient jax: no persistent cache
        return False
    os.makedirs(cache_dir, exist_ok=True)
    return True


def _configs(full: bool):
    """The Fig-2 scenario matrix (paper scale under ``--full``).

    Nine barrier rows: the five static protocols plus the four adaptive
    policies (whose per-row state rides in the scanned carry on the grid
    engines), so the gate times the policy-threading overhead too.
    """
    n, dur, dim = (1000, 40.0, 100) if full else (100, 20.0, 32)
    beta = max(1, n // 100)
    return [SimConfig(n_nodes=n, duration=dur, dim=dim, seed=3,
                      straggler_frac=frac,
                      barrier=make_barrier(name, staleness=4,
                                           sample_size=beta))
            for name in NINE for frac in FRACS]


def _mesh_fields(B: int, P: int) -> Dict:
    """Mesh metadata for a jax-engine row: the resolved placement.

    The regression gate (``tools/check_bench.py``) *requires* these on
    every jax-family row and normalizes throughput per device, so
    baselines transfer across mesh shapes/sizes.
    """
    rows, nodes = resolve_mesh(B, P)
    return {"n_devices": rows * nodes,
            "mesh": [rows, nodes],
            "mesh_axes": {"rows": rows, "nodes": nodes}}


def _100k_configs():
    """The 100k-node pBSP-vs-SSP smoke pair — the regime no event loop
    could touch (the paper's §6 "internet scale" claim).

    ``sample_size=1`` keeps the β-sample draw on the O(P) fast path —
    a P×P score matrix at P = 100 000 would be 40 GB — and a 1-second
    horizon bounds the grid at 50 ticks; the point of the row is the
    placement (node-sharded state at P = 100 000), not the physics.
    """
    return [SimConfig(n_nodes=100_000, duration=1.0, dim=4, batch=2,
                      seed=3, straggler_frac=0.1,
                      barrier=make_barrier(name, staleness=4,
                                           sample_size=1))
            for name in ("pbsp", "ssp")]


def hundred_k_row() -> Dict:
    """Time the 100k-node smoke sweep on the jax engine → one bench row.

    Throughput is reported as ``node_steps_per_device_sec`` — completed
    node steps across the sweep, per device, per second — so the number
    is comparable across mesh factorizations of different sizes (the
    numerator is bit-identical across factorizations by the equivalence
    suite's contract; only wall-clock and device count vary).
    """
    from repro.core import vector_sim_jax
    cfgs = _100k_configs()
    # one scenario row per merge group: the rows axis is useless here, so
    # default every device to the nodes axis (an explicit --mesh /
    # PSP_SWEEP_MESH still wins)
    mesh_before = os.environ.get("PSP_SWEEP_MESH")
    if mesh_before is None:
        os.environ["PSP_SWEEP_MESH"] = f"1x{len(jax.devices())}"
    try:
        t0 = time.time()
        run_sweep(cfgs, backend="jax")
        compile_s = time.time() - t0
        best = float("inf")
        for _ in range(2):
            t0 = time.time()
            res = run_sweep(cfgs, backend="jax")
            best = min(best, time.time() - t0)
        steps = int(sum(int(r.steps.sum()) for r in res))
        # merge groups run one scenario row each → B=1 governs the clamp
        row = _mesh_fields(1, cfgs[0].n_nodes)
    finally:
        if mesh_before is None:
            os.environ.pop("PSP_SWEEP_MESH", None)
        vector_sim_jax._compiled_chunk.cache_clear()
    row.update({
        "seconds": best,
        "compile_seconds": max(compile_s - best, 0.0),
        "n_nodes": cfgs[0].n_nodes,
        "n_configs": len(cfgs),
        "barriers": [c.barrier.name for c in cfgs],
        "total_node_steps": steps,
        "node_steps_per_device_sec":
            steps / max(best, 1e-9) / row["n_devices"],
        "mean_progress": {c.barrier.name: r.mean_progress
                          for c, r in zip(cfgs, res)},
    })
    return row


def _timed_grid(cfgs, backend: str, impl: str | None = None):
    """(compile_s, run_s, results) for one grid engine.

    The first full-matrix call pays jit tracing + compilation — recorded
    as the *compile* phase (numpy's is import/BLAS warm-up, ~0).  The
    *run* phase is then timed **best-of-3**: a sweep is ~1–2 s, so one
    stray scheduler hiccup would otherwise dominate the measurement —
    and the CI bench-regression gate (``tools/check_bench.py``) compares
    these numbers across runs.  The 20× longer event-loop reference
    stays single-shot (its relative noise is small).
    """
    from repro.core import vector_sim_jax
    env_before = os.environ.get("PSP_TICK_IMPL")
    if impl is not None:
        os.environ["PSP_TICK_IMPL"] = impl
    try:
        t0 = time.time()
        run_sweep(cfgs, backend=backend)
        compile_s = time.time() - t0
        best = float("inf")
        for _ in range(3):
            t0 = time.time()
            res = run_sweep(cfgs, backend=backend)
            best = min(best, time.time() - t0)
        # first-call total minus steady-state run ≈ trace+compile cost
        return max(compile_s - best, 0.0), best, res
    finally:
        if impl is not None:
            if env_before is None:
                os.environ.pop("PSP_TICK_IMPL", None)
            else:
                os.environ["PSP_TICK_IMPL"] = env_before
        vector_sim_jax._compiled_chunk.cache_clear()


def sweep_speedup(full: bool = False, backend: str | None = None,
                  pallas: bool = True,
                  out_path: str | None = OUT_PATH,
                  mesh: str | None = None) -> Dict:
    """Time the Fig-2 sweep on all engines and dump ``BENCH_sweep.json``.

    ``backend`` is accepted for harness uniformity and ignored — this
    benchmark's whole point is timing every engine against the others.
    ``pallas=False`` skips the Pallas-tick row (it adds an extra
    compile of the interpreted kernel on CPU).  ``out_path`` redirects
    the JSON dump (``None`` skips it) — the CI bench-regression gate
    writes a *fresh* file and compares it against the committed baseline
    with ``tools/check_bench.py``, and the ``benchmarks.run`` harness
    passes ``None`` so a local harness run never overwrites the
    committed baseline; only the standalone CLI (the documented
    baseline-regeneration command) writes ``BENCH_sweep.json``.

    ``mesh`` pins a 2-D ``RxN`` rows × nodes factorization for the jax
    grid rows (exported as ``PSP_SWEEP_MESH`` for the duration of the
    run; see :mod:`repro.core.sweep_plan`).  Every jax-family row — the
    Fig-2 matrix, the Pallas-tick row, and the always-present 100k-node
    ``jax_100k`` smoke row — records the *resolved* placement under
    ``mesh`` / ``mesh_axes``; results are bit-identical across
    factorizations, so the mesh only moves the timings.
    """
    cache_on = enable_compile_cache()
    mesh_before = os.environ.get("PSP_SWEEP_MESH")
    if mesh is not None:
        parse_mesh(mesh)                       # reject typos loudly, now
        os.environ["PSP_SWEEP_MESH"] = mesh
    try:
        return _sweep_speedup(full, pallas, out_path, cache_on)
    finally:
        if mesh is not None:
            if mesh_before is None:
                os.environ.pop("PSP_SWEEP_MESH", None)
            else:
                os.environ["PSP_SWEEP_MESH"] = mesh_before


def _sweep_speedup(full: bool, pallas: bool, out_path: str | None,
                   cache_on: bool) -> Dict:
    cfgs = _configs(full)
    compile_t, timings, per_engine = {}, {}, {}
    compile_t["numpy"], timings["numpy"], per_engine["numpy"] = \
        _timed_grid(cfgs, "numpy")
    # baseline jax row pins the jnp reference tick — on TPU "auto" would
    # dispatch the Pallas kernel and the pallas row would compare the
    # kernel against itself
    compile_t["jax"], timings["jax"], per_engine["jax"] = \
        _timed_grid(cfgs, "jax", impl="ref")
    if pallas:
        # Pallas tick kernel: the interpreter lowers it to XLA on CPU, so
        # this times kernel *semantics* end-to-end; on a TPU host the same
        # row times the real fused Mosaic kernel (impl="auto")
        impl = "auto" if jax.default_backend() == "tpu" else "interpret"
        compile_t["pallas"], timings["pallas"], per_engine["pallas"] = \
            _timed_grid(cfgs, "jax", impl=impl)
    t0 = time.time()
    ev = [run_simulation(c) for c in cfgs]
    timings["event"] = time.time() - t0

    def max_dev(results):
        rel = [v.mean_progress / max(e.mean_progress, 1e-9)
               for e, v in zip(ev, results)]
        return max(abs(r - 1.0) for r in rel)

    def amortized(name):
        # end-to-end speedup *including* the compile paid this run: with a
        # warm persistent cache compile_seconds collapses toward zero and
        # this converges on the steady-state speedup_vs_event — the
        # compile-amortized throughput the ROADMAP item asks for
        return timings["event"] / max(timings[name] + compile_t[name], 1e-9)

    # merge groups shard B = per-group row count; the static five are the
    # largest group, so report the placement that matrix resolved to
    grid_mesh = _mesh_fields(len(FRACS) * len(FIVE), cfgs[0].n_nodes)
    engines = {
        "event": {"seconds": timings["event"]},
        "numpy": {"seconds": timings["numpy"],
                  "compile_seconds": compile_t["numpy"],
                  "speedup_vs_event":
                      timings["event"] / max(timings["numpy"], 1e-9),
                  "amortized_speedup_vs_event": amortized("numpy"),
                  "max_progress_deviation": max_dev(per_engine["numpy"])},
        "jax": {"seconds": timings["jax"],
                "compile_seconds": compile_t["jax"],
                **grid_mesh,
                "speedup_vs_event":
                    timings["event"] / max(timings["jax"], 1e-9),
                "amortized_speedup_vs_event": amortized("jax"),
                "throughput_vs_numpy":
                    timings["numpy"] / max(timings["jax"], 1e-9),
                "max_progress_deviation": max_dev(per_engine["jax"])},
    }
    if pallas:
        engines["pallas"] = {
            "seconds": timings["pallas"],
            "compile_seconds": compile_t["pallas"],
            "tick_impl": ("pallas" if jax.default_backend() == "tpu"
                          else "interpret"),
            **grid_mesh,
            "speedup_vs_event":
                timings["event"] / max(timings["pallas"], 1e-9),
            "amortized_speedup_vs_event": amortized("pallas"),
            "throughput_vs_jax_ref":
                timings["jax"] / max(timings["pallas"], 1e-9),
            "max_progress_deviation": max_dev(per_engine["pallas"]),
        }
    engines["jax_100k"] = hundred_k_row()
    grid = [name for name in ("numpy", "jax", "pallas") if name in engines]
    res = {
        "sweep": "fig2_stragglers",
        "n_configs": len(cfgs),
        "n_nodes": cfgs[0].n_nodes,
        "duration_s": cfgs[0].duration,
        "compile_cache": cache_on,
        "engines": engines,
        # cross-engine summary: every top-level field is an explicit
        # maximum over the grid-engine rows (per-engine values live in
        # the rows themselves) — see docs/BENCHMARKS.md
        "summary": {
            "best_speedup_vs_event": max(
                engines[n]["speedup_vs_event"] for n in grid),
            "max_progress_deviation": max(
                engines[n]["max_progress_deviation"] for n in grid),
        },
    }
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(res, f, indent=1)
    return res


def main(argv=None) -> None:
    """CLI entry: ``python -m benchmarks.sweep_bench [--full] [--no-pallas]
    [--out PATH]``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--no-pallas", action="store_true",
                    help="skip the Pallas-tick engine row")
    ap.add_argument("--mesh", default=None, metavar="RxN",
                    help="rows × nodes device factorization for the jax "
                         "rows (e.g. 1x8; default: all devices on the "
                         "rows axis, PSP_SWEEP_MESH overrides)")
    ap.add_argument("--out", default=OUT_PATH,
                    help="JSON output path (default: repo-root "
                         "BENCH_sweep.json; the CI gate writes a fresh "
                         "file and compares via tools/check_bench.py)")
    a = ap.parse_args(argv)
    res = sweep_speedup(full=a.full, pallas=not a.no_pallas, out_path=a.out,
                        mesh=a.mesh)
    e = res["engines"]
    extra = ""
    if "pallas" in e:
        extra = (f"pallas={e['pallas']['seconds']:.2f}s"
                 f"({e['pallas']['tick_impl']}) ")
    hk = e["jax_100k"]
    print(f"event={e['event']['seconds']:.2f}s "
          f"numpy={e['numpy']['seconds']:.2f}s "
          f"jax={e['jax']['seconds']:.2f}s"
          f"[mesh {e['jax']['mesh'][0]}x{e['jax']['mesh'][1]}] "
          f"{extra}"
          f"jax_vs_numpy={e['jax']['throughput_vs_numpy']:.2f}x "
          f"max_dev={res['summary']['max_progress_deviation']:.3f} "
          f"100k={hk['seconds']:.2f}s"
          f"[mesh {hk['mesh'][0]}x{hk['mesh'][1]}, "
          f"{hk['node_steps_per_device_sec']:.0f} node-steps/dev/s]")


if __name__ == "__main__":
    main()
