"""Sweep-engine benchmark: event-driven loop vs the two grid backends.

Runs the same Fig-2-style scenario matrix (five barriers × five straggler
fractions, matched seeds) three times — once as a Python loop over the
discrete-event :func:`~repro.core.simulator.run_simulation` (the *before*),
once through the vectorized NumPy :func:`~repro.core.vector_sim.run_sweep`
and once through its jax backend (jit + ``lax.scan``) — checks the engines
agree at the distribution level, and records wall-clock plus speedups in
``BENCH_sweep.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.sweep_bench [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

from repro.core.barriers import make_barrier
from repro.core.simulator import SimConfig, run_simulation
from repro.core.vector_sim import run_sweep

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_sweep.json")

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")
FRACS = (0.0, 0.05, 0.1, 0.2, 0.3)


def _configs(full: bool):
    n, dur, dim = (1000, 40.0, 100) if full else (100, 20.0, 32)
    beta = max(1, n // 100)
    return [SimConfig(n_nodes=n, duration=dur, dim=dim, seed=3,
                      straggler_frac=frac,
                      barrier=make_barrier(name, staleness=4,
                                           sample_size=beta))
            for name in FIVE for frac in FRACS]


def sweep_speedup(full: bool = False, backend: str | None = None) -> Dict:
    """Time the Fig-2 sweep on all engines and dump ``BENCH_sweep.json``.

    ``backend`` is accepted for harness uniformity and ignored — this
    benchmark's whole point is timing every engine against the others.
    """
    cfgs = _configs(full)
    timings, per_engine = {}, {}
    for be in ("numpy", "jax"):
        # numpy needs only a BLAS/import warm-up; jax jit-specialises on
        # the batch shape, so its warm-up must run the full config list
        run_sweep(cfgs if be == "jax" else cfgs[:2], backend=be)
        t0 = time.time()
        per_engine[be] = run_sweep(cfgs, backend=be)
        timings[be] = time.time() - t0
    t0 = time.time()
    ev = [run_simulation(c) for c in cfgs]
    timings["event"] = time.time() - t0

    def max_dev(results):
        rel = [v.mean_progress / max(e.mean_progress, 1e-9)
               for e, v in zip(ev, results)]
        return max(abs(r - 1.0) for r in rel)

    res = {
        "sweep": "fig2_stragglers",
        "n_configs": len(cfgs),
        "n_nodes": cfgs[0].n_nodes,
        "duration_s": cfgs[0].duration,
        "engines": {
            "event": {"seconds": timings["event"]},
            "numpy": {"seconds": timings["numpy"],
                      "speedup_vs_event":
                          timings["event"] / max(timings["numpy"], 1e-9),
                      "max_progress_deviation": max_dev(per_engine["numpy"])},
            "jax": {"seconds": timings["jax"],
                    "speedup_vs_event":
                        timings["event"] / max(timings["jax"], 1e-9),
                    "throughput_vs_numpy":
                        timings["numpy"] / max(timings["jax"], 1e-9),
                    "max_progress_deviation": max_dev(per_engine["jax"])},
        },
        # acceptance headline: the jax backend must not trail numpy
        "speedup": timings["event"] / max(timings["jax"], 1e-9),
        "max_progress_deviation": max(max_dev(per_engine["numpy"]),
                                      max_dev(per_engine["jax"])),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(res, f, indent=1)
    return res


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args(argv)
    res = sweep_speedup(full=a.full)
    e = res["engines"]
    print(f"event={e['event']['seconds']:.2f}s "
          f"numpy={e['numpy']['seconds']:.2f}s "
          f"jax={e['jax']['seconds']:.2f}s "
          f"jax_vs_numpy={e['jax']['throughput_vs_numpy']:.2f}x "
          f"max_dev={res['max_progress_deviation']:.3f}")


if __name__ == "__main__":
    main()
