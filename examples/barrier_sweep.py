"""Barrier-control sweep on a real model: the paper's Fig-1 trade-off,
measured on an actual transformer (not the linear-model simulator).

Stage 1 ranks all barriers cheaply with the **vectorized sweep engine**
(:func:`repro.core.vector_sim.run_sweep` — every barrier × seed scenario
advances simultaneously on the linear task); stage 2 then confirms the
trade-off on a live transformer: for each barrier, trains the same reduced
model with 25% injected stragglers and reports loss reached vs virtual
wall-clock — the convergence-speed/accuracy trade-off PSP is designed to
win.

    PYTHONPATH=src python examples/barrier_sweep.py
"""
import dataclasses

import jax

from repro.configs import get_config, reduced
from repro.core.barriers import make_barrier
from repro.core.simulator import SimConfig
from repro.core.spmd_psp import PSPConfig, psp_init, psp_train_step
from repro.core.vector_sim import run_sweep
from repro.data import SyntheticLM
from repro.models import init_model, loss_fn
from repro.optim import adamw, clip_by_norm

W, TICKS = 4, 120
BARRIERS = ("bsp", "ssp", "asp", "pbsp", "pssp")


def simulator_presweep(backend="jax"):
    """One batched run over barriers × seeds on the linear task.

    Runs on the jax grid backend by default — the whole barrier × seed
    matrix advances inside one jitted ``lax.scan``, so stage 1 exercises
    the same jax stack as the stage-2 SPMD trainer.
    """
    seeds = (0, 1, 2)
    cfgs = [SimConfig(n_nodes=64, duration=10.0, dim=32, seed=s,
                      straggler_frac=0.25,
                      barrier=make_barrier(n, staleness=3, sample_size=2))
            for n in BARRIERS for s in seeds]
    results = run_sweep(cfgs, backend=backend)
    print(f"{'barrier':8s} {'steps/node':>10s} {'spread':>7s} {'err':>8s}"
          f"   (simulator, {len(cfgs)} scenarios batched, "
          f"{backend} backend)")
    for i, name in enumerate(BARRIERS):
        rs = results[i * len(seeds):(i + 1) * len(seeds)]
        mean = sum(r.mean_progress for r in rs) / len(rs)
        spread = max(int(r.steps.max() - r.steps.min()) for r in rs)
        err = max(r.final_error for r in rs)
        print(f"{name:8s} {mean:10.1f} {spread:7d} {err:8.4f}")
    print()


def main():
    simulator_presweep()
    cfg = reduced(get_config("qwen2-0.5b"))
    cfg = dataclasses.replace(cfg, vocab_size=256, n_layers=2, d_model=128,
                              remat=False)
    data = iter(SyntheticLM(cfg.vocab_size, 64, W * 4, seed=0))
    batches = [next(data)["tokens"].reshape(W, 4, 64) for _ in range(16)]
    opt = adamw(2e-3)

    def grad_fn(p, toks):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
            p, {"tokens": toks}, cfg)
        return loss, clip_by_norm(g, 1.0)

    print(f"{'barrier':8s} {'loss':>8s} {'vtime':>7s} {'steps':>7s} "
          f"{'spread':>7s} {'steps/s':>8s}")
    for name in ("bsp", "ssp", "asp", "pbsp", "pssp"):
        pcfg = PSPConfig(barrier=name, n_workers=W, sample_size=2,
                         staleness=3, straggler_frac=0.25)
        st = psp_init(pcfg, init_model(cfg, jax.random.PRNGKey(0)),
                      opt.init, jax.random.PRNGKey(1))
        step = jax.jit(lambda s, b, _p=pcfg: psp_train_step(
            _p, grad_fn, opt.update, s, b))
        for t in range(TICKS):
            st, m = step(st, batches[t % len(batches)])
        loss, _ = loss_fn(st.server_params, {"tokens": batches[0][0]}, cfg)
        vt, ms = float(m["virtual_time"]), float(m["mean_step"])
        print(f"{name:8s} {float(loss):8.4f} {vt:7.2f} {ms:7.1f} "
              f"{int(m['step_spread']):7d} {ms / vt:8.2f}")
    print("\n→ probabilistic barriers keep near-ASP step throughput while")
    print("  bounding dispersion — the paper's trade-off, on a live model.")


if __name__ == "__main__":
    main()
