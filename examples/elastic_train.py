"""Elastic PSP training demo: workers leave and join mid-run.

Runs the jittable SPMD trainer with an elastic worker set
(``PSPConfig(churn=ChurnConfig(...))``): Poisson leave/join events shrink
and regrow the worker population while training proceeds, departed
workers contribute zero gradient to the server psum, and joiners restart
from a fresh pull of the server model at the current max alive step.  The
whole run is ONE compiled SPMD program — churn is data (pre-sampled
schedules + an alive mask), not control flow.

With ``--ckpt-dir`` the demo is also kill-and-resume-able: the async
:class:`repro.checkpoint.CheckpointManager` cuts full-``PSPState``
checkpoints every ``--save-every`` ticks, and ``--resume`` restores the
newest one, fast-forwards the minibatch key stream, and continues the
identical trajectory — the process dying is just one more kind of churn.

    PYTHONPATH=src python examples/elastic_train.py
    PYTHONPATH=src python examples/elastic_train.py --barrier bsp --ticks 400
    PYTHONPATH=src python examples/elastic_train.py --barrier ebsp \
        --max-advance 8 --contribution mean-alive
    PYTHONPATH=src python examples/elastic_train.py --ckpt-dir /tmp/elastic \
        --save-every 50      # SIGKILL it, then add --resume
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointManager, CheckpointPolicy,
                              latest_step, restore_checkpoint)
from repro.core.spmd_psp import (ChurnConfig, PSPConfig, elastic_drive,
                                 linear_psp_state, state_from_tree,
                                 state_to_tree)

D = 32


def main():
    """Train the linear task under churn, printing the population live."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--barrier", default="pssp",
                    choices=("bsp", "ssp", "asp", "pbsp", "pssp",
                             "dssp", "ebsp", "apbsp", "apssp"),
                    help="static protocol or adaptive policy "
                         "(dssp / ebsp / annealed p(b|s)sp)")
    ap.add_argument("--ticks", type=int, default=300)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--leave-rate", type=float, default=1.5)
    ap.add_argument("--join-rate", type=float, default=1.5)
    ap.add_argument("--staleness-lo", type=int, default=0,
                    help="dssp: lower end of the dynamic staleness range")
    ap.add_argument("--max-advance", type=int, default=4,
                    help="ebsp: slack budget for EMA-fast workers")
    ap.add_argument("--contribution", default="mean",
                    choices=("mean", "mean-alive", "sum"),
                    help="gradient scaling; mean-alive tracks the EMA "
                         "of the live population in the policy state")
    ap.add_argument("--ckpt-dir", default=None,
                    help="cut async full-state checkpoints here")
    ap.add_argument("--save-every", type=int, default=25,
                    help="ticks between checkpoints (with --ckpt-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint and continue "
                         "(no-op when --ckpt-dir holds none)")
    ap.add_argument("--publish-dir", default=None,
                    help="publish server_params snapshots here every "
                         "--publish-every ticks (trainer→server bus)")
    ap.add_argument("--publish-every", type=int, default=50)
    a = ap.parse_args()

    cfg = PSPConfig(barrier=a.barrier, n_workers=a.workers, sample_size=2,
                    staleness=3, straggler_frac=0.25,
                    staleness_lo=a.staleness_lo, max_advance=a.max_advance,
                    contribution=a.contribution,
                    churn=ChurnConfig(leave_rate=a.leave_rate,
                                      join_rate=a.join_rate,
                                      horizon=60.0, seed=7))
    state, start = None, 0
    if a.resume and a.ckpt_dir and latest_step(a.ckpt_dir) is not None:
        tree, start = restore_checkpoint(a.ckpt_dir,
                                         state_to_tree(linear_psp_state(cfg, D)))
        state = state_from_tree(tree)
        print(f"resumed tick {start} from {a.ckpt_dir}")
    if start >= a.ticks:
        print(f"nothing to do: checkpoint already at tick {start} "
              f">= --ticks {a.ticks}")
        return
    mgr = None
    if a.ckpt_dir:
        mgr = CheckpointManager(a.ckpt_dir,
                                CheckpointPolicy(every_steps=a.save_every))
    pub = None
    if a.publish_dir:
        from repro.serving.snapshot_bus import SnapshotPublisher
        pub = SnapshotPublisher(a.publish_dir, every_steps=a.publish_every)
    w_true, it = elastic_drive(cfg, D, a.ticks, state=state,
                               start_tick=start)
    print(f"{a.barrier} with churn {a.leave_rate}-/s {a.join_rate}+/s "
          f"on {a.workers} workers")
    print(f"{'tick':>5s} {'virt_t':>7s} {'alive':>5s} {'members':>10s} "
          f"{'mean_step':>9s} {'err':>8s}")
    for i, (st, m) in enumerate(it, start=start):
        if i % 25 == 0 or i == a.ticks - 1:
            err = float(jnp.linalg.norm(st.server_params["w"] - w_true)
                        / jnp.linalg.norm(w_true))
            members = "".join("#" if b else "." for b in np.asarray(st.alive))
            print(f"{i:5d} {float(st.now):7.2f} {int(m['alive']):5d} "
                  f"{members:>10s} {float(m['mean_step']):9.1f} {err:8.4f}")
        if mgr:
            mgr.maybe_save(i + 1, state_to_tree(st),
                           {"barrier": a.barrier, "ticks": i + 1})
        if pub:
            pub.maybe_publish(i + 1, st.server_params,
                              {"barrier": a.barrier})
    if pub:
        pub.publish(a.ticks, st.server_params, {"barrier": a.barrier},
                    block=True)
        pub.close()
        print(f"published {pub.published} snapshots to {a.publish_dir}")
    if mgr:
        mgr.save(a.ticks, state_to_tree(st), {"barrier": a.barrier,
                                              "ticks": a.ticks}, block=True)
        mgr.close()
        print(f"checkpoint: tick {mgr.latest_step()} in {a.ckpt_dir}")
    print(f"\n{int(st.leave_cursor)} leave events, "
          f"{int(st.join_cursor)} join events consumed; "
          f"{int(st.total_pushes)} server updates")


if __name__ == "__main__":
    main()
