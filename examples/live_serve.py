"""Live-traffic demo: a PSP trainer feeding a hot-swapping server.

Two processes, one snapshot bus, zero coordination:

* a **trainer subprocess** (``repro.launch.train --barrier pbsp
  --publish-dir``) trains a reduced transformer and publishes versioned
  serving snapshots on its step cadence;
* an **in-process server** (:class:`repro.serving.InferenceServer` over
  the request-lifecycle :class:`ServingEngine`) watches the directory,
  serves synthetic traffic the whole time, and hot-swaps to each new
  snapshot as it lands — in-flight requests always finish on the
  snapshot they started with (the PSP trade at the serving edge:
  bounded staleness, no barrier).

The demo prints per-request completions with the snapshot version each
was decoded on and exits non-zero unless the run saw live traffic span
at least two model versions.  ``--smoke`` shrinks everything for CI.

    PYTHONPATH=src python examples/live_serve.py
    PYTHONPATH=src python examples/live_serve.py --smoke
"""
import argparse
import dataclasses
import os
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint import latest_step  # noqa: E402
from repro.configs import get_config, reduced as make_reduced  # noqa: E402
from repro.models import init_model  # noqa: E402
from repro.serving import (InferenceServer, Request, ServeConfig,  # noqa: E402
                           ServingEngine, SnapshotWatcher)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=40,
                    help="trainer steps")
    ap.add_argument("--publish-every", type=int, default=10)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--throttle", type=float, default=0.2,
                    help="trainer pacing so traffic overlaps training")
    ap.add_argument("--timeout", type=float, default=600.0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer steps/requests)")
    a = ap.parse_args()
    if a.smoke:
        a.steps, a.publish_every, a.requests = 9, 3, 10
        a.max_new, a.throttle = 6, 0.3

    # the same reduced config the trainer subprocess builds (its flag
    # defaults: --d-model 256 --n-layers 2 --vocab 512)
    cfg = dataclasses.replace(
        make_reduced(get_config(a.arch), n_layers=2, d_model=256),
        vocab_size=512)
    params = init_model(cfg, jax.random.PRNGKey(0))

    snap_dir = tempfile.mkdtemp(prefix="psp_snaps_")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    trainer = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", "--arch", a.arch,
         "--reduced", "--barrier", "pbsp", "--steps", str(a.steps),
         "--batch", "4", "--seq", "32", "--workers", "4",
         "--throttle", str(a.throttle),
         "--publish-dir", snap_dir, "--publish-every", str(a.publish_every)],
        env=env)

    eng = ServingEngine(params, cfg, ServeConfig(
        batch=a.batch, max_len=256, max_new_tokens=a.max_new), version=0)
    watcher = SnapshotWatcher(snap_dir, params)
    rng = np.random.default_rng(0)
    deadline = time.monotonic() + a.timeout
    comps = []
    try:
        with InferenceServer(eng, watcher=watcher, poll_every=2) as srv:
            def req():
                return srv.submit(Request(prompt=rng.integers(
                    0, cfg.vocab_size, size=a.prompt_len).astype(np.int32)))

            # steady traffic while the trainer runs (these requests land
            # on v0 and whatever snapshots get published mid-stream)...
            futs = []
            while trainer.poll() is None and time.monotonic() < deadline:
                if len(futs) < a.requests - a.batch:
                    futs.append(req())
                time.sleep(a.throttle / 2)
            # ...then wait for the trainer's final snapshot to swap in so
            # the tail of the traffic provably spans a second version
            final = latest_step(snap_dir)
            while (final is not None and watcher.loaded_step != final
                   and time.monotonic() < deadline):
                time.sleep(0.05)
            while len(futs) < a.requests:
                futs.append(req())
            comps = [f.result(timeout=a.timeout) for f in futs]
    finally:
        if trainer.poll() is None:
            trainer.kill()
        trainer.wait()

    st = srv.stats
    versions = sorted({c.snapshot_version for c in comps})
    print(f"\n{len(comps)} completions, {st.swaps} hot-swaps, "
          f"versions seen in traffic: {versions}")
    for c in comps[:6]:
        print(f"  req{c.req_id}: v{c.snapshot_version} "
              f"{c.tokens[:8].tolist()}... ({c.finish_reason})")
    if trainer.returncode != 0:
        print(f"FAIL: trainer exited {trainer.returncode}")
        return 1
    if len(comps) != a.requests:
        print(f"FAIL: {a.requests - len(comps)} requests dropped")
        return 1
    if st.swaps < 2 or len(versions) < 2:
        print("FAIL: traffic did not span two snapshot versions "
              f"(swaps={st.swaps}, versions={versions})")
        return 1
    stall = max(st.swap_stalls) if st.swap_stalls else 0.0
    print(f"OK: zero drops; max swap stall {stall * 1e3:.1f} ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
