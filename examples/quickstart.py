"""Quickstart: the paper in 60 seconds.

Simulates the paper's evaluation (distributed SGD on a linear model under
five barrier-control strategies) and prints the headline comparison —
progress, step dispersion, model error, server update counts — plus the
Theorem-2 bounds showing why a tiny sample size β is enough.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.barriers import make_barrier
from repro.core.bounds import mean_lag_bound, variance_lag_bound
from repro.core.simulator import SimConfig, run_simulation


def main():
    n, dur = 200, 20.0
    beta = max(1, n // 100)          # β = 1% of system size (paper §5.1)
    print(f"simulating {n} nodes for {dur:.0f}s, sample size β={beta}\n")
    print(f"{'barrier':8s} {'progress':>9s} {'spread':>7s} "
          f"{'error':>8s} {'updates':>8s}")
    for name in ("bsp", "ssp", "asp", "pbsp", "pssp"):
        bar = make_barrier(name, staleness=4, sample_size=beta)
        r = run_simulation(SimConfig(n_nodes=n, duration=dur, dim=100,
                                     barrier=bar, straggler_frac=0.05,
                                     seed=0))
        print(f"{name:8s} {r.mean_progress:9.1f} "
              f"{int(r.steps.max() - r.steps.min()):7d} "
              f"{r.final_error:8.4f} {r.total_updates:8d}")

    print("\nTheorem-2 bounds (r=4, T=10000, a=F(r)^β=0.5): why small β works")
    print(f"{'beta':>6s} {'mean-lag bound':>15s} {'var-lag bound':>15s}")
    a = 0.5
    for b in (1, 2, 5, 16, 100):
        F = a ** (1.0 / b)
        print(f"{b:6d} {mean_lag_bound(F, b, 4, 10_000):15.3f} "
              f"{variance_lag_bound(F, b, 4, 10_000):15.3f}")
    print("\n→ pBSP/pSSP: near-ASP speed, near-BSP dispersion, lowest error;")
    print("  bounds are already near-optimal at β≈5 — the sampling primitive")
    print("  buys distributed barrier control for O(β) messages per step.")


if __name__ == "__main__":
    main()
