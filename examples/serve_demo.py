"""Batched serving demo: prefill + KV-cache decode across architectures.

Serves three very different families through the same engine — full
attention (qwen2), sliding-window (danube ring cache) and attention-free
SSM (mamba2 constant-size state):

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_model
from repro.serving import ServeConfig, ServingEngine


def main():
    rng = np.random.default_rng(0)
    for arch in ("qwen2-0.5b", "h2o-danube-1.8b", "mamba2-780m"):
        cfg = reduced(get_config(arch))
        params = init_model(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(params, cfg,
                            ServeConfig(batch=4, max_new_tokens=16))
        prompts = [rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
                   for _ in range(8)]
        t0 = time.time()
        outs = eng.generate(prompts)
        dt = time.time() - t0
        total = sum(map(len, outs))
        print(f"{arch:18s} [{cfg.family:6s}] {len(prompts)} reqs, "
              f"{total} tokens in {dt:5.1f}s ({total/dt:5.1f} tok/s)  "
              f"first: {outs[0][:8].tolist()}")


if __name__ == "__main__":
    main()
