"""End-to-end driver: train a transformer for a few hundred steps with PSP
barrier control as a first-class feature.

Default: a ~10M-param reduced qwen2 for 200 PSP ticks on CPU (finishes in
minutes).  ``--large`` selects a ~100M-param config (same code path; sized
for a real accelerator or a long CPU run).

Every ``repro.launch.train`` flag passes through — in particular the
fault-tolerance ones: ``--ckpt-dir`` + ``--save-every``/``--save-interval``
cut async full-state checkpoints, and a killed run restarted with
``--resume`` continues bit-for-bit where the latest checkpoint left off.

    PYTHONPATH=src python examples/train_e2e.py
    PYTHONPATH=src python examples/train_e2e.py --barrier bsp --steps 300
    PYTHONPATH=src python examples/train_e2e.py --large --steps 400
    PYTHONPATH=src python examples/train_e2e.py --ckpt-dir /tmp/e2e \
        --save-every 50      # kill -9 it mid-run, then re-run with --resume
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--barrier", default="pbsp")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--large", action="store_true",
                    help="~100M params instead of ~10M")
    a, rest = ap.parse_known_args()
    if a.large:
        dims = ["--d-model", "768", "--n-layers", "12", "--vocab", "8192",
                "--seq", "256", "--batch", "4"]
    else:
        dims = ["--d-model", "256", "--n-layers", "4", "--vocab", "1024",
                "--seq", "128", "--batch", "4"]
    args = (["--arch", "qwen2-0.5b", "--reduced", "--steps", str(a.steps),
             "--barrier", a.barrier, "--workers", "4",
             "--straggler-frac", "0.25", "--log-every", "20"]
            + dims + rest)
    return train_main(args)


if __name__ == "__main__":
    sys.exit(main())
