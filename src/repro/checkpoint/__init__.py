from repro.checkpoint.checkpoint import (latest_step, read_metadata,
                                         restore_checkpoint, save_checkpoint)
from repro.checkpoint.manager import (CheckpointManager, CheckpointPolicy,
                                      host_snapshot)

__all__ = ["CheckpointManager", "CheckpointPolicy", "host_snapshot",
           "latest_step", "read_metadata", "restore_checkpoint",
           "save_checkpoint"]
