"""Pytree checkpointing (dependency-free .npz format).

Layout: ``<dir>/step_<n>.npz`` holding flattened leaves keyed by their
pytree path, plus a tiny JSON sidecar with step metadata.  Writes are
crash-atomic: both files are staged under ``.tmp`` names and the ``.npz``
rename is the *last* publication step, so a discoverable checkpoint always
has its sidecar already in place (``latest_step`` additionally refuses
entries whose sidecar is missing or unparseable — a torn write can never
be selected for restore).  Restore is structural: arrays land back in an
existing template pytree (so dtypes/shardings are preserved by the caller
putting the arrays back on device).

The async writer / save-policy layer lives in
:mod:`repro.checkpoint.manager`; this module is the storage format only.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":   # ml_dtypes (bf16 etc.): store f32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _npz_name(step: int) -> str:
    return f"step_{step:08d}.npz"


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    metadata: Optional[dict] = None) -> str:
    """Atomically write ``tree`` (+ JSON sidecar) as step ``step``.

    Publication order matters for crash safety: the sidecar is renamed
    into place *first* and the ``.npz`` *last*, so the moment a
    checkpoint becomes discoverable (the ``.npz`` exists) its metadata
    is guaranteed to exist too.  A crash between the two renames leaves
    an orphan sidecar, which restore ignores and
    :meth:`repro.checkpoint.manager.CheckpointManager` garbage-collects.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    final = os.path.join(ckpt_dir, _npz_name(step))
    meta = {"step": step, **(metadata or {})}
    fd, mtmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    with open(mtmp, "w") as f:
        json.dump(meta, f)
    os.replace(mtmp, final + ".json")
    os.replace(tmp, final)            # npz rename last: publishes atomically
    return final


def _sidecar_ok(ckpt_dir: str, fn: str) -> bool:
    """Whether ``fn``'s JSON sidecar exists and parses."""
    try:
        with open(os.path.join(ckpt_dir, fn + ".json")) as f:
            json.load(f)
    except (OSError, ValueError):
        return False
    return True


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Largest step with a complete (npz + parseable sidecar) checkpoint.

    Entries whose sidecar is missing or corrupt are skipped — they are
    torn writes from a crashed process, not restorable state.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", fn))
             and _sidecar_ok(ckpt_dir, fn)]
    return max(steps) if steps else None


def read_metadata(ckpt_dir: str, step: int) -> dict:
    """Load the JSON sidecar of checkpoint ``step`` (raises if absent)."""
    with open(os.path.join(ckpt_dir, _npz_name(step)) + ".json") as f:
        return json.load(f)


def restore_checkpoint(ckpt_dir: str, template: PyTree,
                       step: Optional[int] = None) -> Tuple[PyTree, int]:
    """Restore into the structure of ``template`` (shapes must match).

    Raises :class:`ValueError` — never a bare ``assert`` (which vanishes
    under ``python -O``) or a cryptic ``KeyError`` — when a template leaf
    is absent from the archive or stored with a different shape, naming
    the offending key and both shapes so a config/arch mismatch is
    diagnosable from the message alone.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, _npz_name(step))
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in p)
        if key not in data.files:
            raise ValueError(
                f"checkpoint {path} has no entry for template leaf "
                f"'{key}' (archive holds {sorted(data.files)[:8]}...); "
                "was it written by a different config?")
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(
                f"checkpoint {path} leaf '{key}': stored shape "
                f"{arr.shape} != template shape {leaf.shape}")
        # cast through jnp: numpy cannot cast into ml_dtypes (bf16)
        leaves.append(np.asarray(jax.numpy.asarray(arr).astype(leaf.dtype)))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, step
