"""Pytree checkpointing (dependency-free .npz format).

Layout: ``<dir>/step_<n>.npz`` holding flattened leaves keyed by their
pytree path, plus a tiny JSON sidecar with step metadata.  Atomic writes
(tmp + rename), latest-step discovery, and structural restore into an
existing template pytree (so dtypes/shardings are preserved by the caller
putting the arrays back on device).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":   # ml_dtypes (bf16 etc.): store f32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    final = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    os.replace(tmp, final)
    meta = {"step": step, **(metadata or {})}
    with open(final + ".json", "w") as f:
        json.dump(meta, f)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", fn))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template: PyTree,
                       step: Optional[int] = None) -> Tuple[PyTree, int]:
    """Restore into the structure of ``template`` (shapes must match)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        # cast through jnp: numpy cannot cast into ml_dtypes (bf16)
        leaves.append(np.asarray(jax.numpy.asarray(arr).astype(leaf.dtype)))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, step
