"""Checkpoint manager: save policies, async writer, retention.

The storage format (:mod:`repro.checkpoint.checkpoint`) is a dumb atomic
npz writer; this layer decides *when* to save and keeps the write off the
training critical path, Levanter-style:

* **policies** — save every N steps (:attr:`CheckpointPolicy.every_steps`)
  and/or every T wall-clock seconds (:attr:`CheckpointPolicy.every_seconds`);
  either trigger fires a save.  Step policies give the deterministic
  cadence the kill-and-resume equivalence tests pin; time policies bound
  the work lost to a crash on slow configs where a step cadence would be
  hours apart.  Resume correctness never depends on *when* a checkpoint
  was cut — restore is exact for any published step.
* **async writer** — :meth:`CheckpointManager.save` snapshots the state to
  host memory synchronously (cheap: one ``device_get`` of arrays that are
  immutable anyway) and hands the serialization + fsync-rename to a
  single background thread, so training resumes immediately.  A bounded
  queue applies back-pressure instead of accumulating unbounded snapshots
  when the disk is slower than the save cadence.
* **retention / GC** — after each successful write the writer thread keeps
  the newest ``keep`` checkpoints and deletes the rest (npz + sidecar).
* **crash hygiene** — construction removes stale ``*.tmp`` staging files
  and orphan sidecars (a ``.json`` whose ``.npz`` never got published)
  left behind by a killed process, so a resumed run starts from a clean
  directory.

Typical wiring (``repro.launch.train``)::

    with CheckpointManager(dir, CheckpointPolicy(every_steps=50)) as mgr:
        for t in range(start, steps):
            state = step(state)
            mgr.maybe_save(t + 1, state, metadata={"data_step": t + 1})
        mgr.save(steps, state, metadata=..., block=True)
"""
from __future__ import annotations

import dataclasses
import glob
import os
import queue
import re
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import latest_step, save_checkpoint

PyTree = Any

__all__ = ["CheckpointPolicy", "CheckpointManager", "host_snapshot"]


def host_snapshot(tree: PyTree) -> PyTree:
    """Copy every leaf of ``tree`` to a host numpy array.

    This is the synchronous half of an async save: once the snapshot
    exists, the training loop may donate/overwrite its device buffers
    freely while the writer thread serializes at leisure.
    """
    return jax.tree_util.tree_map(np.asarray, tree)


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """When to cut a checkpoint (either trigger suffices).

    ``every_steps=None`` disables the step cadence, ``every_seconds=None``
    the wall-clock cadence; with both ``None`` only explicit
    :meth:`CheckpointManager.save` calls (e.g. the final save) write.
    """

    every_steps: Optional[int] = None      # save when step % every_steps == 0
    every_seconds: Optional[float] = None  # save when this much wall time passed

    def __post_init__(self):
        if self.every_steps is not None and self.every_steps <= 0:
            raise ValueError(f"every_steps must be positive, "
                             f"got {self.every_steps}")
        if self.every_seconds is not None and self.every_seconds <= 0:
            raise ValueError(f"every_seconds must be positive, "
                             f"got {self.every_seconds}")


class CheckpointManager:
    """Policy-driven async checkpointer over one directory.

    Thread model: one daemon writer thread consumes a bounded queue of
    ``(step, host_tree, metadata)`` snapshots; every disk operation
    (write, rename, GC) happens on that thread, so publication order is
    the enqueue order and retention never races a write.  ``wait()``
    drains the queue (tests and final saves); ``close()`` drains and
    joins.  The manager is also a context manager — the ``with`` exit
    closes it.
    """

    def __init__(self, ckpt_dir: str, policy: CheckpointPolicy | None = None,
                 *, keep: int = 3, async_write: bool = True,
                 queue_size: int = 2, write_retries: int = 3,
                 retry_backoff: float = 0.1):
        self.ckpt_dir = ckpt_dir
        self.policy = policy or CheckpointPolicy()
        self.keep = keep
        self._async = async_write
        self.write_retries = write_retries
        self.retry_backoff = retry_backoff
        self.retried_writes = 0
        self._last_save_time = time.monotonic()
        self._last_saved_step: Optional[int] = None
        os.makedirs(ckpt_dir, exist_ok=True)
        self._clean_stale()
        self._queue: "queue.Queue" = queue.Queue(maxsize=queue_size)
        self._error: Optional[BaseException] = None
        self._injected_faults: list = []
        self._thread: Optional[threading.Thread] = None
        if async_write:
            self._thread = threading.Thread(target=self._writer_loop,
                                            name="ckpt-writer", daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------ #
    # policy
    # ------------------------------------------------------------------ #
    def should_save(self, step: int) -> bool:
        """Does the policy call for a checkpoint at ``step``?"""
        if step == self._last_saved_step:
            return False
        p = self.policy
        if p.every_steps is not None and step % p.every_steps == 0:
            return True
        if (p.every_seconds is not None
                and time.monotonic() - self._last_save_time >= p.every_seconds):
            return True
        return False

    def maybe_save(self, step: int, tree: PyTree,
                   metadata: Optional[dict] = None) -> bool:
        """Save iff the policy fires; returns whether a save was enqueued."""
        if not self.should_save(step):
            return False
        self.save(step, tree, metadata)
        return True

    # ------------------------------------------------------------------ #
    # saving
    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: PyTree, metadata: Optional[dict] = None,
             *, block: bool = False) -> None:
        """Snapshot ``tree`` to host and enqueue the write.

        The device→host copy happens here, on the caller's thread — after
        this returns the caller may mutate/donate its buffers.  With
        ``block=True`` (or a sync manager) the write is also drained
        before returning.
        """
        self._raise_writer_error()
        snap = host_snapshot(tree)
        self._last_save_time = time.monotonic()
        self._last_saved_step = step
        if self._thread is None:
            self._write(step, snap, metadata)
        else:
            self._queue.put((step, snap, metadata))
            if block:
                self.wait()

    def wait(self) -> None:
        """Block until every enqueued checkpoint is on disk."""
        if self._thread is not None:
            self._queue.join()
        self._raise_writer_error()

    def close(self) -> None:
        """Drain pending writes and stop the writer thread."""
        if self._thread is not None:
            self._queue.join()
            self._queue.put(None)           # sentinel: writer exits
            self._thread.join()
            self._thread = None
        self._raise_writer_error()

    def latest_step(self) -> Optional[int]:
        """Newest restorable step in this manager's directory."""
        return latest_step(self.ckpt_dir)

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        """Close on exit; surface writer errors without masking the body.

        A clean ``with`` exit drains and raises any pending writer error
        (the regression the shutdown tests pin).  When the body is
        *already* raising, the writer error must not replace it — the
        original exception stays primary and the writer failure is
        attached as its ``__context__`` via an ordinary chained raise
        swallowed here.
        """
        if exc_type is None:
            self.close()
            return
        try:
            self.close()
        except Exception:
            pass                # body exception stays primary

    def inject_write_fault(self, exc: BaseException) -> None:
        """Chaos hook: make the next write attempt raise ``exc`` once.

        Each injected fault consumes exactly one *attempt* (not one
        save), so ``write_retries >= 1`` turns a single injection into a
        transparently retried transient failure — the path the
        disk-full fault plan and the retry regression tests drive.
        """
        self._injected_faults.append(exc)

    # ------------------------------------------------------------------ #
    # writer thread
    # ------------------------------------------------------------------ #
    def _raise_writer_error(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("checkpoint writer thread failed") from err

    def _writer_loop(self):
        while True:
            item = self._queue.get()
            if item is None:
                self._queue.task_done()
                return
            step, snap, metadata = item
            try:
                self._write(step, snap, metadata)
            except BaseException as e:          # surfaced on next save/wait
                self._error = e
            finally:
                self._queue.task_done()

    def _write(self, step, snap, metadata):
        """One write, retried with exponential backoff on transient errors.

        ``write_retries`` extra attempts, sleeping ``retry_backoff * 2^i``
        between them — a full disk or flaky mount heals without losing
        the checkpoint; exhausted retries re-raise the last error (into
        ``self._error`` on the async path).
        """
        for attempt in range(self.write_retries + 1):
            try:
                if self._injected_faults:
                    raise self._injected_faults.pop(0)
                save_checkpoint(self.ckpt_dir, step, snap, metadata)
                self._gc()
                return
            except (OSError, IOError):
                if attempt >= self.write_retries:
                    raise
                self.retried_writes += 1
                time.sleep(self.retry_backoff * (2.0 ** attempt))

    def _gc(self):
        """Keep the newest ``keep`` published checkpoints, delete the rest."""
        if self.keep is None or self.keep <= 0:
            return
        steps = sorted(
            int(m.group(1)) for fn in os.listdir(self.ckpt_dir)
            if (m := re.match(r"step_(\d+)\.npz$", fn)))
        for s in steps[:-self.keep]:
            base = os.path.join(self.ckpt_dir, f"step_{s:08d}.npz")
            for path in (base, base + ".json"):
                try:
                    os.remove(path)
                except OSError:
                    pass

    # ------------------------------------------------------------------ #
    # crash hygiene
    # ------------------------------------------------------------------ #
    def _clean_stale(self):
        """Remove ``*.tmp`` staging files and orphan sidecars.

        Both are leftovers of a process killed mid-save: staging files
        never renamed, and sidecars published whose npz rename (the last
        step) never happened.  Only run at construction — a live writer
        in *this* process always publishes npz-last, so anything matching
        here is garbage from a previous life.
        """
        for tmp in glob.glob(os.path.join(self.ckpt_dir, "*.tmp")):
            try:
                os.remove(tmp)
            except OSError:
                pass
        for side in glob.glob(os.path.join(self.ckpt_dir,
                                           "step_*.npz.json")):
            if not os.path.exists(side[:-len(".json")]):
                try:
                    os.remove(side)
                except OSError:
                    pass
