"""Architecture config registry — one module per assigned architecture.

``get_config("gemma2-27b")`` returns the exact assigned configuration;
``reduced(cfg)`` returns the CPU-smoke variant (≤2 layers, d_model ≤ 512,
≤4 experts) of the same family used by per-arch smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

from repro.configs.h2o_danube_1_8b import CONFIG as _danube
from repro.configs.recurrentgemma_2b import CONFIG as _rg
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.qwen1_5_4b import CONFIG as _qwen15
from repro.configs.qwen2_0_5b import CONFIG as _qwen2
from repro.configs.gemma2_27b import CONFIG as _gemma2
from repro.configs.internvl2_2b import CONFIG as _internvl2

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [_danube, _rg, _qwen3moe, _mamba2, _dbrx, _musicgen, _qwen15,
              _qwen2, _gemma2, _internvl2]
}

#: archs allowed to run long_500k (sub-quadratic / windowed decode state);
#: pure full-attention archs skip it — see DESIGN.md §5.
LONG_CONTEXT_ARCHS = (
    "h2o-danube-1.8b",      # SWA everywhere → window-ring cache
    "recurrentgemma-2b",    # RG-LRU + local attention
    "mamba2-780m",          # constant-size SSM state
    "gemma2-27b",           # alternating local/global (global KV sharded)
)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            seq_ok: bool = True) -> ModelConfig:
    """CPU-smoke variant: same family/flavour, tiny dims.

    Keeps every structural switch (GQA ratio, pattern, softcaps, biases,
    MoE top-k, SSD dims, RG-LRU) while shrinking widths so one forward/train
    step runs on a single CPU device in milliseconds.
    """
    n_heads = max(2, cfg.n_heads // 8)
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_kv = max(1, n_heads // ratio)
    head_dim = min(64, max(16, d_model // n_heads))
    pat = cfg.layer_pattern
    # keep the pattern; give patterns longer than n_layers one full group
    layers = max(n_layers, len(pat)) if len(pat) > 1 else n_layers
    if cfg.name == "recurrentgemma-2b":
        layers = 5                      # one (R,R,A) group + (R,R) tail
    changes = dict(
        n_layers=layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=max(1, min(cfg.d_ff, 4 * d_model)) if cfg.d_ff else 0,
        vocab_size=512,
        sliding_window=(64 if cfg.sliding_window else None),
        lru_width=(d_model if cfg.lru_width else None),
        frontend_tokens=(16 if cfg.frontend_tokens else 0),
    )
    if cfg.is_moe:
        changes.update(n_experts=4, n_experts_per_token=2)
    if cfg.family == "ssm":
        changes.update(ssm_state=32, ssm_head_dim=16)
    return dataclasses.replace(cfg, **changes)


__all__ = ["ARCHS", "LONG_CONTEXT_ARCHS", "INPUT_SHAPES", "InputShape",
           "ModelConfig", "get_config", "reduced"]
