"""Model/arch configuration schema.

One :class:`ModelConfig` describes any architecture in the assigned pool:
dense decoders (optionally GQA / sliding-window / logit-softcap /
local-global alternation), MoE decoders, Mamba-2 SSM stacks, RG-LRU hybrid
stacks, and the audio/VLM variants whose modality frontends are stubbed
(``input_specs`` provides precomputed frame/patch embeddings, per spec).

``layer_pattern`` declares the repeating block cycle, e.g.::

    ("attn",)                       # plain decoder
    ("local", "attn")               # gemma2: alternating local/global
    ("rglru", "rglru", "local")     # recurrentgemma 2:1 pattern
    ("ssd",)                        # mamba2
    ("moe",)                        # MoE decoder

The model is scanned over *pattern groups* so heterogeneous patterns still
compile to a small HLO (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = ["ModelConfig", "InputShape", "INPUT_SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # --- attention flavour --------------------------------------------- #
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    pos_embed: str = "rope"         # rope | sinusoidal
    sliding_window: Optional[int] = None   # window for "local" layers
    layer_pattern: Tuple[str, ...] = ("attn",)
    attn_softcap: Optional[float] = None   # gemma2: 50.0
    logit_softcap: Optional[float] = None  # gemma2: 30.0
    post_norms: bool = False        # gemma2: post-attn/post-mlp norms
    gemma_norm: bool = False        # RMSNorm uses (1 + w) scaling
    embed_scale: bool = False       # multiply embeddings by sqrt(d_model)

    # --- mlp ------------------------------------------------------------ #
    mlp_type: str = "swiglu"        # swiglu | geglu | gelu
    #: fuse gate+up into one (D, F, 2) matmul — one backward all-reduce
    #: instead of two (EXPERIMENTS.md §Perf, collective iteration 2)
    fuse_gateup: bool = True
    #: fuse q/k/v into one blocked (D, 16, w, hd) matmul (requires
    #: n_heads % 16 == 0 and n_kv_heads % 16 == 0 and no qkv bias)
    fuse_qkv: bool = False

    # --- moe ------------------------------------------------------------ #
    n_experts: int = 0
    n_experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- ssm (mamba2) ----------------------------------------------------- #
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1

    # --- rglru (recurrentgemma) ------------------------------------------- #
    lru_width: Optional[int] = None  # default d_model
    conv_width: int = 4

    # --- modality frontend stub ------------------------------------------ #
    frontend: Optional[str] = None  # None | "vision" | "audio"
    frontend_tokens: int = 0        # patch/conditioning positions prepended

    # --- numerics / structure ------------------------------------------ #
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True

    # provenance (model card / paper the exact numbers come from)
    source: str = ""

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.lru_width is None and "rglru" in self.layer_pattern:
            object.__setattr__(self, "lru_width", self.d_model)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        """Mamba-2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def n_groups(self) -> int:
        """Number of scanned pattern groups (+ tail handled separately)."""
        return self.n_layers // len(self.layer_pattern)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        """Layers beyond the last full pattern group (e.g. RG-2b: 26 = 8·3+2)."""
        rem = self.n_layers % len(self.layer_pattern)
        return self.layer_pattern[:rem]

    def layer_kinds(self) -> Tuple[str, ...]:
        """The full per-layer block-kind sequence."""
        reps = self.n_layers // len(self.layer_pattern)
        return self.layer_pattern * reps + self.tail_pattern

    # --- parameter counting (for roofline's 6·N·D model-flops term) ----- #
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.n_layers
        kinds = self.layer_kinds()
        total = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                  # unembed
        for kind in kinds:
            if kind in ("attn", "local"):
                hd = self.head_dim
                total += d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd)
                total += (self.n_heads * hd) * d          # o_proj
                total += self._mlp_params(active_only)
            elif kind == "moe":
                hd = self.head_dim
                total += d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd)
                total += (self.n_heads * hd) * d
                e = (self.n_experts_per_token if active_only else self.n_experts)
                total += e * 3 * d * self.d_ff + d * self.n_experts  # experts+router
            elif kind == "ssd":
                di, ng, st = self.d_inner, self.ssm_groups, self.ssm_state
                nh = self.ssm_heads
                total += d * (2 * di + 2 * ng * st + nh)  # in_proj
                total += (di + 2 * ng * st) * self.ssm_conv  # conv
                total += di * d + 2 * nh + di              # out_proj, A/D/dt, norm
            elif kind == "rglru":
                w = self.lru_width
                total += 2 * d * w + w * self.conv_width + 3 * w + w * d
            total += 2 * d                                 # norms (approx)
        return total

    def _mlp_params(self, active_only: bool) -> int:
        if self.mlp_type in ("swiglu", "geglu"):
            return 3 * self.d_model * self.d_ff
        return 2 * self.d_model * self.d_ff


@dataclasses.dataclass(frozen=True)
class InputShape:
    """An assigned (seq_len, global_batch) workload."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
