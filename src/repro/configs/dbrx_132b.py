"""dbrx-132b — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base] 40L, d_model=6144, 48 heads (GQA kv=8, head 128),
per-expert d_ff=10752, vocab=100352, 16 experts top-4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10_752,
    vocab_size=100_352,
    head_dim=128,
    layer_pattern=("moe",),
    n_experts=16,
    n_experts_per_token=4,
    mlp_type="swiglu",
    rope_theta=500_000.0,
    source="hf:databricks/dbrx-base",
)
