"""gemma2-27b — dense with alternating local/global attention + softcaps.

[arXiv:2408.00118] 46L, d_model=4608, 32 heads (GQA kv=16, head 128),
d_ff=36864 (GeGLU; 2·18432 gate+up), vocab=256000; local window 4096
alternating with global layers; attention softcap 50, final-logit softcap 30;
RMSNorm(1+w) with pre+post norms; embeddings scaled by sqrt(d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36_864,               # per-branch width (gate and up are each d×36864)
    vocab_size=256_000,
    head_dim=128,
    sliding_window=4_096,
    layer_pattern=("local", "attn"),   # alternating local, global
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norms=True,
    gemma_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    mlp_type="geglu",
    rope_theta=10_000.0,
    fuse_qkv=True,
    source="arXiv:2408.00118",
)
