"""h2o-danube-1.8b — dense llama+mistral mix with sliding-window attention.

[arXiv:2401.16818] 24L, d_model=2560, 32 heads (GQA kv=8), d_ff=6912,
vocab=32000, SWA (mistral-style sliding window).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32_000,
    head_dim=80,
    sliding_window=4_096,
    layer_pattern=("local",),       # every layer sliding-window
    mlp_type="swiglu",
    rope_theta=10_000.0,
    source="arXiv:2401.16818",
)
