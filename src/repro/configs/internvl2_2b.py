"""internvl2-2b — VLM: InternViT vision encoder + InternLM2-1.8B LM.

[arXiv:2404.16821] LM backbone: 24L, d_model=2048, 16 heads (GQA kv=8,
head 128), d_ff=8192, vocab=92553.

The InternViT + MLP projector frontend is a STUB per spec: ``input_specs()``
provides 256 precomputed patch embeddings (one tile) prepended to the text
tokens; the assigned backbone (the language model) is implemented in full.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    head_dim=128,
    layer_pattern=("attn",),
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_tokens=256,
    source="arXiv:2404.16821",
)
