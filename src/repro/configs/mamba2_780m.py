"""mamba2-780m — attention-free SSM stack with SSD (state-space duality).

[arXiv:2405.21060] 48L, d_model=1536, attn-free, vocab=50280,
ssm_state=128, expand=2 (d_inner=3072), head_dim=64 (48 SSM heads),
conv width 4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,            # no attention heads; SSM heads below
    n_kv_heads=1,
    d_ff=0,               # mamba blocks have no separate MLP
    vocab_size=50_280,
    head_dim=64,
    layer_pattern=("ssd",),
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
