"""musicgen-large — decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284] 48L, d_model=2048, 32 heads (kv=32, i.e. full MHA),
d_ff=8192 (GELU), vocab=2048 (EnCodec codebook), sinusoidal positions.

The EnCodec codec + text-conditioning frontend is a STUB per spec:
``input_specs()`` provides 64 precomputed conditioning embeddings prepended
to the token sequence; the assigned backbone (the language model over audio
tokens) is implemented in full.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    layer_pattern=("attn",),
    mlp_type="gelu",
    pos_embed="sinusoidal",
    frontend="audio",
    frontend_tokens=64,
    fuse_qkv=True,
    source="arXiv:2306.05284",
)
