"""The paper's own evaluation workload (§5).

1000-node network running SGD on a linear model of 1000 parameters through
the parameter-server engine for 40 simulated seconds, each node sampling 1%
of the system size.  This config drives the simulator-based benchmarks
(Figs 1–3) and the quickstart example.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PSPLinearConfig:
    n_nodes: int = 1000
    dim: int = 1000
    duration: float = 40.0
    sample_frac: float = 0.01      # β = 1% of system size (paper §5.1)
    ssp_staleness: int = 4         # paper: "SSP allows certain staleness (4)"
    base_compute: float = 0.1
    seed: int = 0

    @property
    def sample_size(self) -> int:
        return max(1, int(self.n_nodes * self.sample_frac))


CONFIG = PSPLinearConfig()
