"""qwen1.5-4b — dense decoder with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family card] 40L, d_model=2560, 20 heads (kv=20, MHA,
head 128), d_ff=6912, vocab=151936, QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151_936,
    head_dim=128,
    qkv_bias=True,
    layer_pattern=("attn",),
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)
