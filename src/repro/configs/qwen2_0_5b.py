"""qwen2-0.5b — small dense decoder, GQA with QKV bias.

[arXiv:2407.10671] 24L, d_model=896, 14 heads (GQA kv=2, head 64),
d_ff=4864, vocab=151936, QKV bias, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151_936,
    head_dim=64,
    qkv_bias=True,
    layer_pattern=("attn",),
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="arXiv:2407.10671",
)
