"""qwen3-moe-30b-a3b — fine-grained MoE, 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B] 48L, d_model=2048, 32 heads (GQA kv=4, head 128),
per-expert d_ff=768, vocab=151936, 128 experts top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151_936,
    head_dim=128,
    layer_pattern=("moe",),
    n_experts=128,
    n_experts_per_token=8,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
)
