"""recurrentgemma-2b — Griffin hybrid: RG-LRU recurrence + local attention.

[arXiv:2402.19427] 26 blocks, d_model=2560, 10 heads (MQA kv=1, head 256),
d_ff=7680 (GeGLU), vocab=256000; block pattern 2 recurrent : 1 local-attn
(window 2048); 26 = 8 full (R,R,A) groups + (R,R) tail.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    sliding_window=2_048,
    layer_pattern=("rglru", "rglru", "local"),
    mlp_type="geglu",
    lru_width=2560,
    conv_width=4,
    gemma_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    source="arXiv:2402.19427",
)
