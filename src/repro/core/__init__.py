"""PSP core: barrier controls, the sampling primitive, theory, simulator.

The paper's contribution (Probabilistic Synchronous Parallel) as a composable
library:

* :mod:`repro.core.barriers` — BSP/SSP/ASP/pBSP/pSSP predicates
* :mod:`repro.core.sampling` — the ``sampling`` system primitive
* :mod:`repro.core.overlay` — structured overlay backing distributed sampling
* :mod:`repro.core.bounds` — Theorems 1–3 bounds (Figs 4–5)
* :mod:`repro.core.simulator` — discrete-event Actor-system repro (Figs 1–3)
* :mod:`repro.core.vector_sim` — vectorized batched sweep engine (fast path)
* :mod:`repro.core.engines` — map-reduce / parameter-server / p2p engines
* :mod:`repro.core.spmd_psp` — TPU-native PSP for pjit/shard_map training
"""
from repro.core.barriers import (ASP, BSP, PBSP, PSSP, SSP, BarrierControl,
                                 make_barrier)
from repro.core.bounds import (mean_lag_bound, psp_lag_pmf, regret_tail_bound,
                               variance_lag_bound)
from repro.core.sampling import CentralSampler, OverlaySampler, sample_steps_jax
from repro.core.simulator import SimConfig, SimResult, run_simulation
from repro.core.vector_sim import VectorSimulator, run_sweep

__all__ = [
    "ASP", "BSP", "PBSP", "PSSP", "SSP", "BarrierControl", "make_barrier",
    "mean_lag_bound", "psp_lag_pmf", "regret_tail_bound", "variance_lag_bound",
    "CentralSampler", "OverlaySampler", "sample_steps_jax",
    "SimConfig", "SimResult", "run_simulation",
    "VectorSimulator", "run_sweep",
]
