"""The unified straggler/barrier model shared by every jnp execution path.

Before this module existed the SPMD trainer (:mod:`repro.core.spmd_psp`)
and the vectorized sweep engine's jax backend
(:mod:`repro.core.vector_sim_jax`) each carried their own copy of the two
decisions at the heart of PSP:

* **may a worker advance?** — the barrier predicate, evaluated on the full
  step vector (BSP/SSP), on a β-sample of it (pBSP/pSSP), or not at all
  (ASP);
* **how long does a local step take?** — the straggler model (a jittered
  per-worker duration around a per-worker base speed).

Duplicated models drift (Dynamic-SSP and Elastic-BSP both moved barrier
decisions *into* the training step for exactly this reason), so this module
is now the single source: :func:`full_view_allowed`,
:func:`sampled_allowed` and :func:`step_duration` are the only jnp
implementations of the predicates, and :class:`BarrierKernel` packages them
behind the trainer-facing ``allowed(key, steps)`` call.
``tests/test_barrier_kernel.py`` pins both consumers to these outputs.

The β-sample itself routes through the shared sampling primitive
(:func:`repro.core.sampling.sample_peer_indices_jax` /
``sample_alive_peer_indices_jax``), so "which peers does a worker look at"
also has exactly one definition.  The Pallas tick kernel
(:mod:`repro.kernels.psp_tick`) fuses an algebraically identical rank-based
form of :func:`sampled_allowed` on-device; ``tests/test_kernels.py`` holds
the tick-for-tick equivalence.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sampling import (sample_alive_peer_indices_jax,
                                 sample_peer_indices_jax)

__all__ = ["BarrierKernel", "BarrierPolicy", "BetaAnnealPolicy",
           "DSSPPolicy", "ElasticBSPPolicy", "POLICY_REGISTRY",
           "churn_joiner", "churn_victim", "elastic_slack",
           "full_view_allowed", "make_policy", "progress_gap",
           "sampled_allowed", "step_duration"]

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


def step_duration(u: jax.Array, base: jax.Array,
                  jitter: float = 1.0) -> jax.Array:
    """Duration of one local step: ``base · (1 + jitter·(u − ½))``.

    ``u`` is uniform noise in [0, 1); ``base`` is the per-worker mean step
    time (straggler slowdowns already folded in — the simulator bakes them
    into ``compute_time`` at static-init, the trainer multiplies its
    ``base_compute`` by the slowdown).  The simulator's historical
    ``compute_time · (½ + u)`` is exactly ``jitter = 1``.
    """
    return base * (1.0 + jitter * (u - 0.5))


def full_view_allowed(steps: jax.Array, staleness: jax.Array,
                      alive: Optional[jax.Array] = None) -> jax.Array:
    """Classic (BSP/SSP) predicate: ``step − min(alive steps) ≤ s``.

    ``steps``: i32[..., W]; ``staleness`` broadcastable against it.  The
    minimum is taken over **alive** workers only — a departed straggler's
    frozen counter must never gate waiters (the churn-wake rule).
    """
    masked = steps if alive is None else jnp.where(alive, steps, _I32_MAX)
    return steps - jnp.min(masked, axis=-1, keepdims=True) <= staleness


def sampled_allowed(steps: jax.Array, staleness: jax.Array, k_max: int, *,
                    beta: Optional[jax.Array] = None,
                    key: Optional[jax.Array] = None,
                    scores: Optional[jax.Array] = None,
                    u: Optional[jax.Array] = None,
                    alive: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Probabilistic (pBSP/pSSP) predicate on a β-sample of ``steps``.

    Each worker draws up to ``k_max`` peers (self excluded, dead peers
    excluded) through the shared sampling primitive and advances iff no
    sampled peer lags more than ``staleness`` behind it — the paper's §6.4
    worker-centric rule.

    Args:
      steps: i32[..., W] step counters (a leading scenario-batch dim is
        allowed).
      staleness: bound s, broadcastable against ``steps``.
      k_max: static sample-slot count (≥ 1); the per-row effective β may be
        smaller via ``beta``.
      beta: optional per-row β, broadcastable against ``steps[..., None]``
        slot masks; defaults to ``k_max`` everywhere.
      key: PRNG key used when no pre-drawn noise is supplied.
      scores: optional pre-drawn uniform score matrix ``[..., W, W]``
        (shared-score shapes broadcast); forwarded to the sampling
        primitive so fused kernels can consume the identical draw.
      u: optional pre-drawn uniforms ``[..., W]`` for the β = 1 fast path
        (mutually exclusive with ``scores``).
      alive: optional bool[..., W] membership mask (churn / ragged rows).

    Returns:
      (allowed, n_sampled): bool[..., W] pass mask and i32[..., W] count of
      peers actually consulted (the control-plane cost of the decision).
    """
    W = steps.shape[-1]
    if alive is None:
        take, valid = sample_peer_indices_jax(key, W, k_max, scores=scores,
                                              u=u)
        peer = steps[..., take] if steps.ndim > 1 else steps[take]
        valid = jnp.broadcast_to(valid, peer.shape)
    else:
        take, valid = sample_alive_peer_indices_jax(key, alive, k_max,
                                                    scores=scores)
        peer = jnp.take_along_axis(
            jnp.broadcast_to(steps[..., None, :], take.shape[:-1] + (W,)),
            take, axis=-1)
    if beta is not None:
        valid = valid & (jnp.arange(take.shape[-1]) < beta[..., None])
    lag_ok = steps[..., None] - peer <= staleness[..., None]
    return jnp.all(lag_ok | ~valid, axis=-1), jnp.sum(valid, axis=-1)


def churn_victim(u: jax.Array, alive: jax.Array) -> jax.Array:
    """Index of the node a leave event removes: uniform over alive nodes.

    ``u`` is uniform noise in [0, 1) of the same trailing shape as
    ``alive``; the victim is the argmax of the alive-masked scores, i.e.
    a uniformly random **alive** node (ties cannot occur for continuous
    draws; the dead-node sentinel is −1).  This is the single definition
    of the leave rule — the numpy engine
    (:meth:`repro.core.vector_sim.VectorSimulator._churn_leave`), the
    fused tick reference (:func:`repro.kernels.psp_tick.psp_tick_ref`)
    and the elastic SPMD trainer (:mod:`repro.core.spmd_psp`) all select
    victims by exactly this argmax, pinned by
    ``tests/test_elastic_equiv.py``.
    """
    return jnp.argmax(jnp.where(alive, u, -1.0), axis=-1)


def churn_joiner(u: jax.Array, alive: jax.Array,
                 valid_slot: Optional[jax.Array] = None) -> jax.Array:
    """Index of the slot a join event revives: uniform over dead slots.

    Mirror of :func:`churn_victim` over the dead pool.  ``valid_slot``
    restricts the pool to a row's true population (ragged jax batches pad
    with permanently-dead slots that must never rejoin); the trainer and
    unpadded rows pass ``None``.
    """
    pool = ~alive if valid_slot is None else (~alive & valid_slot)
    return jnp.argmax(jnp.where(pool, u, -1.0), axis=-1)


def progress_gap(steps: jax.Array,
                 alive: Optional[jax.Array] = None) -> jax.Array:
    """Observed alive-step spread ``max − min`` per scenario (i32[...]).

    The single observable every adaptive policy keys off: DSSP clips its
    dynamic threshold to it, β-annealing widens/narrows its sample with
    it.  Rows with no alive worker report a gap of 0 (nothing can be
    observed, so nothing adapts).
    """
    if alive is None:
        return jnp.max(steps, axis=-1) - jnp.min(steps, axis=-1)
    mx = jnp.max(jnp.where(alive, steps, _I32_MIN), axis=-1)
    mn = jnp.min(jnp.where(alive, steps, _I32_MAX), axis=-1)
    return jnp.where(jnp.any(alive, axis=-1), mx - mn, 0)


def elastic_slack(ema: jax.Array, max_advance: jax.Array,
                  alive: Optional[jax.Array] = None) -> jax.Array:
    """Elastic-BSP per-worker step credit from the duration EMA (i32[..., W]).

    ``⌊max_advance · (1 − ema_i / max(alive ema))⌋``: the slowest observed
    worker gets zero slack (it blocks exactly like BSP), an infinitely
    fast one gets ``max_advance`` steps of run-ahead — the grid analogue
    of Elastic BSP's "schedule the next sync point from predicted worker
    speeds".  Workers with no observations yet (EMA 0) get full credit.
    With ``max_advance = 0`` the credit is identically zero, which is
    what makes the constant-schedule reduction to BSP bit-exact.
    """
    live = ema if alive is None else jnp.where(alive, ema, 0.0)
    mx = jnp.max(live, axis=-1, keepdims=True)
    frac = 1.0 - ema / jnp.maximum(mx, 1e-9)
    return jnp.floor(max_advance * frac).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class BarrierKernel:
    """Trainer-facing bundle of the unified barrier + straggler model.

    One instance fixes a barrier policy (name, staleness bound s, sample
    size β); :meth:`allowed` then answers "may each worker advance?" for a
    step vector, and :meth:`step_duration` draws step durations — both pure
    jnp, jit/scan-safe.  :mod:`repro.core.spmd_psp` routes its
    ``_barrier_allowed`` / ``_duration`` through an instance of this class,
    and the sweep engine's reference tick uses the same underlying
    functions, so the two systems cannot silently diverge.
    """

    barrier: str = "pssp"           # bsp | ssp | asp | pbsp | pssp
    staleness: int = 0              # bound s (SSP family)
    beta: int = 0                   # sample slots (probabilistic family)

    @property
    def is_asp(self) -> bool:
        """ASP never blocks (the predicate is ⊤)."""
        return self.barrier == "asp"

    @property
    def is_full_view(self) -> bool:
        """Classic barriers evaluate the full step vector.

        The adaptive full-view members (dssp/ebsp) are included: stripped
        of their state, they degrade to the classic predicate at their
        static bound — the stateful refinement lives in
        :class:`BarrierPolicy`.
        """
        return self.barrier in ("bsp", "ssp", "dssp", "ebsp")

    def allowed(self, key: jax.Array, steps: jax.Array,
                alive: Optional[jax.Array] = None) -> jax.Array:
        """bool[..., W]: may each worker start its next step?"""
        if self.is_asp:
            return jnp.ones(steps.shape, bool)
        s = jnp.asarray(self.staleness, steps.dtype)
        if self.is_full_view:
            return full_view_allowed(steps, s, alive)
        k = min(self.beta, steps.shape[-1] - 1)
        if k <= 0:                  # S = ∅ degenerates to ASP
            return jnp.ones(steps.shape, bool)
        ok, _ = sampled_allowed(steps, jnp.broadcast_to(s, steps.shape), k,
                                key=key, alive=alive)
        return ok

    @staticmethod
    def step_duration(u: jax.Array, base: jax.Array,
                      jitter: float = 1.0) -> jax.Array:
        """See :func:`step_duration` (re-exported for consumers)."""
        return step_duration(u, base, jitter)


# --------------------------------------------------------------------------- #
# BarrierPolicy: the barrier decision as a stateful, jittable object.
#
# A policy owns a (possibly empty) state pytree plus an init/decide pair:
#
#     state = policy.init(W)                                # pytree of arrays
#     allowed, state = policy.decide(state, key, steps, durations, alive)
#
# The five static protocols are trivially-stateless policies (empty state,
# decide delegates to BarrierKernel.allowed — bit-identical to the
# pre-policy dispatch); the adaptive members carry state:
#
#   ============  =====================  ==================================
#   policy        state                  update rule (per decide)
#   ============  =====================  ==================================
#   dssp          thr    i32[]           clip(progress_gap, r, s)
#   ebsp          ema    f32[W]          (1−α)·ema + α·durations (alive)
#   apbsp/apssp   beta   i32[]           clip(β_min + gap − s, β_min, β_max)
#   ============  =====================  ==================================
#
# Contract notes:
# * decide consumes `key` exactly as BarrierKernel.allowed does (full-view
#   and ASP policies consume none) — static policies therefore leave every
#   engine's RNG stream untouched.
# * decide reads/writes only its own state keys and passes any other
#   entries through unchanged, so engines may co-locate extra per-run
#   state (e.g. the trainer's churn-aware contribution denominator) in the
#   same pytree.
# * The sweep engines do not call these objects per tick (a batch row mixes
#   policies); they evaluate the same formulas vectorised per row —
#   progress_gap / elastic_slack above are the shared definitions, and the
#   property suite pins the scalar and batched forms to each other.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BarrierPolicy:
    """A barrier predicate plus its decision state (base: stateless).

    Wraps a :class:`BarrierKernel`; ``decide`` is pure jnp and jit/scan
    safe, so the state pytree can ride in any engine's carry.
    """

    kernel: BarrierKernel

    @property
    def stateful(self) -> bool:
        """Whether :meth:`init` returns a non-empty state pytree."""
        return False

    def init(self, W: int) -> Dict[str, jax.Array]:
        """Initial policy state for a W-worker run (empty when stateless)."""
        del W
        return {}

    def decide(self, state: Dict[str, jax.Array], key: jax.Array,
               steps: jax.Array, durations: Optional[jax.Array] = None,
               alive: Optional[jax.Array] = None,
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """(allowed bool[..., W], new_state): may each worker advance?

        ``durations`` is this round's per-worker step-duration draw
        (f32[..., W]); stateless policies and DSSP ignore it, Elastic-BSP
        folds it into its EMA.  ``None`` skips duration-driven updates.
        """
        del durations
        return self.kernel.allowed(key, steps, alive), state


@dataclasses.dataclass(frozen=True)
class DSSPPolicy(BarrierPolicy):
    """Dynamic SSP (arXiv 1908.11848): staleness searched in ``[lo, hi]``.

    The threshold is the last observed alive-step spread clipped into the
    configured range — the online search collapses to "track the gap".
    ``lo == hi`` pins the threshold, reducing bit-for-bit to SSP at that
    bound.
    """

    lo: int = 0

    @property
    def hi(self) -> int:
        """Upper search bound s (the kernel's static staleness)."""
        return self.kernel.staleness

    @property
    def stateful(self) -> bool:
        """True: carries the ``thr`` scalar."""
        return True

    def init(self, W: int) -> Dict[str, jax.Array]:
        """State ``{"thr": i32[]}`` starting at the upper bound s."""
        del W
        return {"thr": jnp.asarray(self.hi, jnp.int32)}

    def decide(self, state, key, steps, durations=None, alive=None):
        """SSP predicate at the tracked threshold; thr ← clip(gap, lo, hi)."""
        del key, durations                     # full view consumes no RNG
        thr = state["thr"].astype(steps.dtype)
        allowed = full_view_allowed(steps, thr, alive)
        gap = progress_gap(steps, alive)
        new = jnp.clip(gap, self.lo, self.hi).astype(jnp.int32)
        return allowed, {**state, "thr": new}


@dataclasses.dataclass(frozen=True)
class ElasticBSPPolicy(BarrierPolicy):
    """Elastic BSP (arXiv 2001.01347): sync points from a duration EMA.

    Each worker's next sync point is scheduled
    ``elastic_slack(ema, max_advance)`` steps ahead of the global minimum;
    the EMA tracks observed step durations.  ``max_advance == 0``
    schedules a barrier every step — bit-for-bit BSP.
    """

    max_advance: int = 4
    ema_alpha: float = 0.5

    @property
    def stateful(self) -> bool:
        """True: carries the per-worker duration EMA."""
        return True

    def init(self, W: int) -> Dict[str, jax.Array]:
        """State ``{"ema": f32[W]}``, zeros (slack 0 ≡ BSP until observed)."""
        return {"ema": jnp.zeros((W,), jnp.float32)}

    def decide(self, state, key, steps, durations=None, alive=None):
        """SSP-shaped predicate at the elastic slack; EMA folds durations."""
        del key                                # full view consumes no RNG
        ema = state["ema"]
        slack = elastic_slack(ema, float(self.max_advance), alive)
        allowed = full_view_allowed(steps, slack.astype(steps.dtype), alive)
        if durations is not None:
            a = jnp.float32(self.ema_alpha)
            new = (1.0 - a) * ema + a * durations.astype(jnp.float32)
            ema = new if alive is None else jnp.where(alive, new, ema)
        return allowed, {**state, "ema": ema}


@dataclasses.dataclass(frozen=True)
class BetaAnnealPolicy(BarrierPolicy):
    """β-annealing pBSP/pSSP: PSP's sample size tracks the progress spread.

    The effective β is ``clip(β_min + gap − s, β_min, β_max)`` — one extra
    sampled peer per step of spread beyond the staleness bound.  The
    sample itself still routes through the shared sampling primitive with
    ``k_max = β_max`` slots, so the pre-drawn score stream is identical to
    a static pBSP/pSSP row's.  ``β_min == β_max`` reduces to the static
    parent.
    """

    beta_lo: int = 1

    @property
    def beta_hi(self) -> int:
        """Upper annealing bound β_max (the kernel's static β)."""
        return self.kernel.beta

    @property
    def stateful(self) -> bool:
        """True: carries the annealed ``beta`` scalar."""
        return True

    def init(self, W: int) -> Dict[str, jax.Array]:
        """State ``{"beta": i32[]}`` starting at β_min (clipped to W−1)."""
        lo = min(max(self.beta_lo, 0), max(min(self.beta_hi, W - 1), 0))
        return {"beta": jnp.asarray(lo, jnp.int32)}

    def decide(self, state, key, steps, durations=None, alive=None):
        """Sampled predicate at the annealed β; β ← clip(lo + gap − s)."""
        del durations
        W = steps.shape[-1]
        k = min(self.beta_hi, W - 1)
        gap = progress_gap(steps, alive)
        s = jnp.asarray(self.kernel.staleness, steps.dtype)
        lo = min(max(self.beta_lo, 0), max(k, 0))
        new = jnp.clip(lo + gap - s, lo, max(k, 0)).astype(jnp.int32)
        if k <= 0:                  # S = ∅ degenerates to ASP
            return jnp.ones(steps.shape, bool), {**state, "beta": new}
        ok, _ = sampled_allowed(steps, jnp.broadcast_to(s, steps.shape), k,
                                beta=state["beta"], key=key, alive=alive)
        return ok, {**state, "beta": new}


#: adaptive policy names → their static parent's registry entry
POLICY_REGISTRY = ("bsp", "ssp", "asp", "pbsp", "pssp",
                   "dssp", "ebsp", "apbsp", "apssp")


def make_policy(name: str, *, staleness: int = 0, beta: int = 0,
                staleness_lo: int = 0, beta_lo: int = 1,
                max_advance: int = 4,
                ema_alpha: float = 0.5) -> BarrierPolicy:
    """Factory mirroring :func:`repro.core.barriers.make_barrier`.

    Static names yield a stateless :class:`BarrierPolicy` around the
    matching :class:`BarrierKernel`; adaptive names yield the stateful
    subclass with its bounds wired up.
    """
    name = name.lower()
    if name not in POLICY_REGISTRY:
        raise ValueError(
            f"unknown barrier policy {name!r}; options: "
            f"{sorted(POLICY_REGISTRY)}")
    kern = BarrierKernel(barrier=name, staleness=staleness, beta=beta)
    if name == "dssp":
        return DSSPPolicy(kernel=kern, lo=staleness_lo)
    if name == "ebsp":
        return ElasticBSPPolicy(kernel=kern, max_advance=max_advance,
                                ema_alpha=ema_alpha)
    if name in ("apbsp", "apssp"):
        return BetaAnnealPolicy(kernel=kern, beta_lo=beta_lo)
    return BarrierPolicy(kernel=kern)
