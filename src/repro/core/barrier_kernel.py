"""The unified straggler/barrier model shared by every jnp execution path.

Before this module existed the SPMD trainer (:mod:`repro.core.spmd_psp`)
and the vectorized sweep engine's jax backend
(:mod:`repro.core.vector_sim_jax`) each carried their own copy of the two
decisions at the heart of PSP:

* **may a worker advance?** — the barrier predicate, evaluated on the full
  step vector (BSP/SSP), on a β-sample of it (pBSP/pSSP), or not at all
  (ASP);
* **how long does a local step take?** — the straggler model (a jittered
  per-worker duration around a per-worker base speed).

Duplicated models drift (Dynamic-SSP and Elastic-BSP both moved barrier
decisions *into* the training step for exactly this reason), so this module
is now the single source: :func:`full_view_allowed`,
:func:`sampled_allowed` and :func:`step_duration` are the only jnp
implementations of the predicates, and :class:`BarrierKernel` packages them
behind the trainer-facing ``allowed(key, steps)`` call.
``tests/test_barrier_kernel.py`` pins both consumers to these outputs.

The β-sample itself routes through the shared sampling primitive
(:func:`repro.core.sampling.sample_peer_indices_jax` /
``sample_alive_peer_indices_jax``), so "which peers does a worker look at"
also has exactly one definition.  The Pallas tick kernel
(:mod:`repro.kernels.psp_tick`) fuses an algebraically identical rank-based
form of :func:`sampled_allowed` on-device; ``tests/test_kernels.py`` holds
the tick-for-tick equivalence.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sampling import (sample_alive_peer_indices_jax,
                                 sample_peer_indices_jax)

__all__ = ["BarrierKernel", "churn_joiner", "churn_victim",
           "full_view_allowed", "sampled_allowed", "step_duration"]

_I32_MAX = jnp.iinfo(jnp.int32).max


def step_duration(u: jax.Array, base: jax.Array,
                  jitter: float = 1.0) -> jax.Array:
    """Duration of one local step: ``base · (1 + jitter·(u − ½))``.

    ``u`` is uniform noise in [0, 1); ``base`` is the per-worker mean step
    time (straggler slowdowns already folded in — the simulator bakes them
    into ``compute_time`` at static-init, the trainer multiplies its
    ``base_compute`` by the slowdown).  The simulator's historical
    ``compute_time · (½ + u)`` is exactly ``jitter = 1``.
    """
    return base * (1.0 + jitter * (u - 0.5))


def full_view_allowed(steps: jax.Array, staleness: jax.Array,
                      alive: Optional[jax.Array] = None) -> jax.Array:
    """Classic (BSP/SSP) predicate: ``step − min(alive steps) ≤ s``.

    ``steps``: i32[..., W]; ``staleness`` broadcastable against it.  The
    minimum is taken over **alive** workers only — a departed straggler's
    frozen counter must never gate waiters (the churn-wake rule).
    """
    masked = steps if alive is None else jnp.where(alive, steps, _I32_MAX)
    return steps - jnp.min(masked, axis=-1, keepdims=True) <= staleness


def sampled_allowed(steps: jax.Array, staleness: jax.Array, k_max: int, *,
                    beta: Optional[jax.Array] = None,
                    key: Optional[jax.Array] = None,
                    scores: Optional[jax.Array] = None,
                    u: Optional[jax.Array] = None,
                    alive: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Probabilistic (pBSP/pSSP) predicate on a β-sample of ``steps``.

    Each worker draws up to ``k_max`` peers (self excluded, dead peers
    excluded) through the shared sampling primitive and advances iff no
    sampled peer lags more than ``staleness`` behind it — the paper's §6.4
    worker-centric rule.

    Args:
      steps: i32[..., W] step counters (a leading scenario-batch dim is
        allowed).
      staleness: bound s, broadcastable against ``steps``.
      k_max: static sample-slot count (≥ 1); the per-row effective β may be
        smaller via ``beta``.
      beta: optional per-row β, broadcastable against ``steps[..., None]``
        slot masks; defaults to ``k_max`` everywhere.
      key: PRNG key used when no pre-drawn noise is supplied.
      scores: optional pre-drawn uniform score matrix ``[..., W, W]``
        (shared-score shapes broadcast); forwarded to the sampling
        primitive so fused kernels can consume the identical draw.
      u: optional pre-drawn uniforms ``[..., W]`` for the β = 1 fast path
        (mutually exclusive with ``scores``).
      alive: optional bool[..., W] membership mask (churn / ragged rows).

    Returns:
      (allowed, n_sampled): bool[..., W] pass mask and i32[..., W] count of
      peers actually consulted (the control-plane cost of the decision).
    """
    W = steps.shape[-1]
    if alive is None:
        take, valid = sample_peer_indices_jax(key, W, k_max, scores=scores,
                                              u=u)
        peer = steps[..., take] if steps.ndim > 1 else steps[take]
        valid = jnp.broadcast_to(valid, peer.shape)
    else:
        take, valid = sample_alive_peer_indices_jax(key, alive, k_max,
                                                    scores=scores)
        peer = jnp.take_along_axis(
            jnp.broadcast_to(steps[..., None, :], take.shape[:-1] + (W,)),
            take, axis=-1)
    if beta is not None:
        valid = valid & (jnp.arange(take.shape[-1]) < beta[..., None])
    lag_ok = steps[..., None] - peer <= staleness[..., None]
    return jnp.all(lag_ok | ~valid, axis=-1), jnp.sum(valid, axis=-1)


def churn_victim(u: jax.Array, alive: jax.Array) -> jax.Array:
    """Index of the node a leave event removes: uniform over alive nodes.

    ``u`` is uniform noise in [0, 1) of the same trailing shape as
    ``alive``; the victim is the argmax of the alive-masked scores, i.e.
    a uniformly random **alive** node (ties cannot occur for continuous
    draws; the dead-node sentinel is −1).  This is the single definition
    of the leave rule — the numpy engine
    (:meth:`repro.core.vector_sim.VectorSimulator._churn_leave`), the
    fused tick reference (:func:`repro.kernels.psp_tick.psp_tick_ref`)
    and the elastic SPMD trainer (:mod:`repro.core.spmd_psp`) all select
    victims by exactly this argmax, pinned by
    ``tests/test_elastic_equiv.py``.
    """
    return jnp.argmax(jnp.where(alive, u, -1.0), axis=-1)


def churn_joiner(u: jax.Array, alive: jax.Array,
                 valid_slot: Optional[jax.Array] = None) -> jax.Array:
    """Index of the slot a join event revives: uniform over dead slots.

    Mirror of :func:`churn_victim` over the dead pool.  ``valid_slot``
    restricts the pool to a row's true population (ragged jax batches pad
    with permanently-dead slots that must never rejoin); the trainer and
    unpadded rows pass ``None``.
    """
    pool = ~alive if valid_slot is None else (~alive & valid_slot)
    return jnp.argmax(jnp.where(pool, u, -1.0), axis=-1)


@dataclasses.dataclass(frozen=True)
class BarrierKernel:
    """Trainer-facing bundle of the unified barrier + straggler model.

    One instance fixes a barrier policy (name, staleness bound s, sample
    size β); :meth:`allowed` then answers "may each worker advance?" for a
    step vector, and :meth:`step_duration` draws step durations — both pure
    jnp, jit/scan-safe.  :mod:`repro.core.spmd_psp` routes its
    ``_barrier_allowed`` / ``_duration`` through an instance of this class,
    and the sweep engine's reference tick uses the same underlying
    functions, so the two systems cannot silently diverge.
    """

    barrier: str = "pssp"           # bsp | ssp | asp | pbsp | pssp
    staleness: int = 0              # bound s (SSP family)
    beta: int = 0                   # sample slots (probabilistic family)

    @property
    def is_asp(self) -> bool:
        """ASP never blocks (the predicate is ⊤)."""
        return self.barrier == "asp"

    @property
    def is_full_view(self) -> bool:
        """Classic barriers evaluate the full step vector."""
        return self.barrier in ("bsp", "ssp")

    def allowed(self, key: jax.Array, steps: jax.Array,
                alive: Optional[jax.Array] = None) -> jax.Array:
        """bool[..., W]: may each worker start its next step?"""
        if self.is_asp:
            return jnp.ones(steps.shape, bool)
        s = jnp.asarray(self.staleness, steps.dtype)
        if self.is_full_view:
            return full_view_allowed(steps, s, alive)
        k = min(self.beta, steps.shape[-1] - 1)
        if k <= 0:                  # S = ∅ degenerates to ASP
            return jnp.ones(steps.shape, bool)
        ok, _ = sampled_allowed(steps, jnp.broadcast_to(s, steps.shape), k,
                                key=key, alive=alive)
        return ok

    @staticmethod
    def step_duration(u: jax.Array, base: jax.Array,
                      jitter: float = 1.0) -> jax.Array:
        """See :func:`step_duration` (re-exported for consumers)."""
        return step_duration(u, base, jitter)
