"""Barrier control policies (the paper's §4.2 / §6.1).

A barrier control decides whether a worker may advance its local step given
(some view of) the steps of other workers.  The paper's key move is that the
*same* predicate can be evaluated on the full state vector (classic,
centralised BSP/SSP) or on a random sample of it (pBSP/pSSP) — the sampling
primitive composes with any barrier method, which decouples barrier control
from model consistency and makes the policy fully distributable.

Two call styles are provided:

* :meth:`BarrierControl.can_pass` — pure-python, used by the discrete-event
  Actor simulator (``core/simulator.py``).
* :meth:`BarrierControl.can_pass_jax` — ``jnp``-only (no python branching on
  traced values), used by the SPMD trainer (``core/spmd_psp.py``); takes the
  *sampled* step vector and returns a bool array.

Formal definitions (paper §6.1), with ``s_i`` worker i's step and ``S`` the
evaluated subset:

    BSP :  ∀ i,j ∈ V  :  s_i = s_j
    SSP :  ∀ i,j ∈ V  :  |s_i − s_j| ≤ s
    ASP :  ⊤
    pBSP:  ∀ i,j ∈ S⊆V:  s_i = s_j
    pSSP:  ∀ i,j ∈ S⊆V:  |s_i − s_j| ≤ s

pSSP generalises all of the above: S=V ⇒ SSP; s=0 ⇒ pBSP; S=V, s=0 ⇒ BSP;
S=∅ or s=∞ ⇒ ASP.

Note on the *worker-centric* evaluation used at runtime: a worker w deciding
whether to advance from its own step ``s_w`` checks the sampled peers' steps
and waits if any sampled peer lags more than ``staleness`` behind ``s_w``
(paper §6.4: "a worker samples β out of P workers ... if a single one of
these lags more than s steps behind the current worker then it waits").
The pairwise form above is the global invariant the policy maintains; the
worker-centric form is what each node evaluates locally.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BarrierControl",
    "BSP",
    "SSP",
    "ASP",
    "PBSP",
    "PSSP",
    "DSSP",
    "EBSP",
    "APBSP",
    "APSSP",
    "make_barrier",
    "BARRIER_REGISTRY",
]


@dataclasses.dataclass(frozen=True)
class BarrierControl:
    """Base class. ``staleness`` is the bound s; ``sample_size`` is β.

    ``sample_size is None`` means "evaluate on the full state" (classic
    methods); an integer β means "evaluate on a β-sample" (probabilistic
    methods).
    """

    staleness: int = 0
    sample_size: Optional[int] = None

    #: registry name, overridden by subclasses
    name: str = "base"

    #: adaptive-policy kind: "" for the static protocols, else one of
    #: "dssp" / "ebsp" / "anneal".  The engines key their stateful
    #: :class:`~repro.core.barrier_kernel.BarrierPolicy` machinery off
    #: this tag; static barriers keep the zero-state fast paths.
    adaptive: ClassVar[str] = ""

    # ------------------------------------------------------------------ #
    # python path (simulator)
    # ------------------------------------------------------------------ #
    def view(self, steps: Sequence[int], rng: np.random.Generator,
             self_index: Optional[int] = None) -> np.ndarray:
        """Return the subset of ``steps`` this policy evaluates.

        For classic policies this is all of ``steps``; for probabilistic ones
        it is a uniform sample of size β (without replacement), which in the
        real system is produced by the structured overlay
        (:mod:`repro.core.overlay`).

        ``self_index`` is the deciding worker's position in ``steps``.  The
        paper's sampling primitive draws β *other* workers (§6.4: "a worker
        samples β out of P workers"), so when given, the worker is removed
        from the sampling pool before drawing — a worker must never draw
        itself into its own β-sample (it would trivially satisfy the
        predicate).  The full-view policies keep ``steps`` intact: a worker's
        own lag is zero, so its presence is harmless there.
        """
        steps = np.asarray(steps)
        if self.sample_size is None:
            return steps
        if self_index is not None:
            steps = np.delete(steps, self_index)
        beta = min(self.sample_size, len(steps))
        if beta == 0:
            return steps[:0]
        idx = rng.choice(len(steps), size=beta, replace=False)
        return steps[idx]

    def can_pass(self, my_step: int, steps: Sequence[int],
                 rng: np.random.Generator,
                 self_index: Optional[int] = None) -> bool:
        """Worker-centric barrier check: may a worker at ``my_step`` advance?

        ``steps`` is the (full) step vector the policy may sample from;
        ``self_index`` (optional) is the worker's own position in it, which
        probabilistic policies exclude from the sample — matching
        ``sample_steps_jax(..., exclude_self=True)`` on the jnp path.
        """
        sampled = self.view(steps, rng, self_index=self_index)
        if sampled.size == 0:
            return True
        return bool(np.all(my_step - sampled <= self.staleness))

    # ------------------------------------------------------------------ #
    # jnp path (SPMD trainer) — no data-dependent python control flow
    # ------------------------------------------------------------------ #
    def can_pass_jax(self, my_step: jax.Array, sampled_steps: jax.Array,
                     valid: Optional[jax.Array] = None) -> jax.Array:
        """Vectorised barrier check.

        Args:
          my_step: i32[] or i32[W] — the deciding worker's step(s).
          sampled_steps: i32[β] or i32[W, β] — sampled peers' steps (already
            drawn by the sampling primitive).
          valid: optional bool mask matching ``sampled_steps`` (β may exceed
            the population in small tests).

        Returns: bool array, True where the worker may advance.
        """
        lag = my_step[..., None] - sampled_steps
        ok = lag <= self.staleness
        if valid is not None:
            ok = ok | ~valid
        return jnp.all(ok, axis=-1)


@dataclasses.dataclass(frozen=True)
class BSP(BarrierControl):
    """Bulk Synchronous Parallel — lockstep (Algorithm 1)."""

    staleness: int = 0
    sample_size: Optional[int] = None
    name: str = "bsp"


@dataclasses.dataclass(frozen=True)
class SSP(BarrierControl):
    """Stale Synchronous Parallel — bounded staleness (Algorithm 2)."""

    staleness: int = 4
    sample_size: Optional[int] = None
    name: str = "ssp"


@dataclasses.dataclass(frozen=True)
class ASP(BarrierControl):
    """Asynchronous Parallel — no synchronisation (⊤)."""

    staleness: int = 0
    sample_size: Optional[int] = None
    name: str = "asp"

    def view(self, steps, rng, self_index=None):
        """ASP evaluates the empty subset (S = ∅)."""
        return np.asarray(steps)[:0]

    def can_pass(self, my_step, steps, rng, self_index=None):
        """ASP never blocks."""
        return True

    def can_pass_jax(self, my_step, sampled_steps, valid=None):
        """ASP never blocks (jnp path: all-True of the broadcast shape)."""
        lag = my_step[..., None] - sampled_steps
        return jnp.ones(jnp.broadcast_shapes(lag.shape[:-1]), dtype=bool)


@dataclasses.dataclass(frozen=True)
class PBSP(BarrierControl):
    """Probabilistic BSP — BSP composed with the sampling primitive."""

    staleness: int = 0
    sample_size: Optional[int] = 16
    name: str = "pbsp"


@dataclasses.dataclass(frozen=True)
class PSSP(BarrierControl):
    """Probabilistic SSP — the most general PSP method (paper Eq. 5)."""

    staleness: int = 4
    sample_size: Optional[int] = 16
    name: str = "pssp"


# --------------------------------------------------------------------------- #
# adaptive barrier family: the barrier itself becomes a runtime decision.
# These classes only *declare* the policy (bounds + smoothing knobs); the
# per-engine decision state lives in repro.core.barrier_kernel's
# BarrierPolicy objects and in each engine's carried state.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class DSSP(BarrierControl):
    """Dynamic SSP — staleness searched online in ``[staleness_lo, staleness]``.

    After arXiv 1908.11848: instead of a fixed bound s, the threshold
    tracks the *observed* alive-step spread, clipped to the configured
    ``[r, s]`` range — tight synchronisation while workers are level,
    SSP-like slack once stragglers open a gap.  With
    ``staleness_lo == staleness`` the search range is a point and the
    policy reduces bit-for-bit to :class:`SSP` (pinned by the
    cross-engine property suite).
    """

    staleness: int = 4              # upper bound s of the search range
    sample_size: Optional[int] = None
    name: str = "dssp"
    staleness_lo: int = 0           # lower bound r of the search range
    adaptive: ClassVar[str] = "dssp"


@dataclasses.dataclass(frozen=True)
class EBSP(BarrierControl):
    """Elastic BSP — per-worker sync points scheduled from a duration EMA.

    After arXiv 2001.01347 (ZipLine): each worker carries an EMA of its
    observed step durations; the next synchronisation point is scheduled
    so that a worker measured r× faster than the slowest may run up to
    ``⌊max_advance·(1 − ema_i/ema_max)⌋`` steps ahead before blocking.
    ``max_advance = 0`` schedules a sync point every step — bit-for-bit
    :class:`BSP` (the "constant schedule" reduction of the property
    suite).
    """

    staleness: int = 0
    sample_size: Optional[int] = None
    name: str = "ebsp"
    max_advance: int = 4            # step credit of an infinitely-fast worker
    ema_alpha: float = 0.5          # duration-EMA smoothing factor
    adaptive: ClassVar[str] = "ebsp"


@dataclasses.dataclass(frozen=True)
class APBSP(BarrierControl):
    """β-annealing pBSP — PSP's sample size adapted to the observed spread.

    The sample widens towards ``sample_size`` (β_max) while the alive-step
    spread exceeds the staleness bound and narrows back towards
    ``sample_size_lo`` (β_min) as workers level out — cheap probabilistic
    checks in calm phases, near-full-view scrutiny under stragglers.
    """

    staleness: int = 0
    sample_size: Optional[int] = 16  # β_max
    name: str = "apbsp"
    sample_size_lo: int = 1          # β_min
    adaptive: ClassVar[str] = "anneal"


@dataclasses.dataclass(frozen=True)
class APSSP(BarrierControl):
    """β-annealing pSSP — :class:`APBSP` with a nonzero staleness bound."""

    staleness: int = 4
    sample_size: Optional[int] = 16  # β_max
    name: str = "apssp"
    sample_size_lo: int = 1          # β_min
    adaptive: ClassVar[str] = "anneal"


BARRIER_REGISTRY = {
    "bsp": BSP,
    "ssp": SSP,
    "asp": ASP,
    "pbsp": PBSP,
    "pssp": PSSP,
    "dssp": DSSP,
    "ebsp": EBSP,
    "apbsp": APBSP,
    "apssp": APSSP,
}

#: names whose ``staleness`` field is configurable (s > 0 is meaningful)
_STALENESS_NAMES = ("ssp", "pssp", "dssp", "apssp")
#: names whose ``sample_size`` field is configurable (the β knob)
_SAMPLED_NAMES = ("pbsp", "pssp", "apbsp", "apssp")


def make_barrier(name: str, *, staleness: Optional[int] = None,
                 sample_size: Optional[int] = None,
                 staleness_lo: Optional[int] = None,
                 sample_size_lo: Optional[int] = None,
                 max_advance: Optional[int] = None,
                 ema_alpha: Optional[float] = None) -> BarrierControl:
    """Factory: ``make_barrier('pssp', staleness=4, sample_size=16)``.

    The adaptive-family knobs (``staleness_lo`` for dssp,
    ``sample_size_lo`` for apbsp/apssp, ``max_advance``/``ema_alpha`` for
    ebsp) are forwarded only to the policies they parameterise, like the
    classic ``staleness``/``sample_size`` arguments.
    """
    name = name.lower()
    if name not in BARRIER_REGISTRY:
        raise ValueError(
            f"unknown barrier {name!r}; options: {sorted(BARRIER_REGISTRY)}")
    cls = BARRIER_REGISTRY[name]
    kwargs = {}
    # staleness is meaningful only for the SSP family (BSP/pBSP are s=0 by
    # definition; ASP ignores it)
    if staleness is not None and name in _STALENESS_NAMES:
        kwargs["staleness"] = staleness
    if sample_size is not None and name in _SAMPLED_NAMES:
        kwargs["sample_size"] = sample_size
    if staleness_lo is not None and name == "dssp":
        kwargs["staleness_lo"] = staleness_lo
    if sample_size_lo is not None and name in ("apbsp", "apssp"):
        kwargs["sample_size_lo"] = sample_size_lo
    if max_advance is not None and name == "ebsp":
        kwargs["max_advance"] = max_advance
    if ema_alpha is not None and name == "ebsp":
        kwargs["ema_alpha"] = ema_alpha
    return cls(**kwargs)
