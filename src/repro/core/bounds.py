"""Theoretical analysis of PSP (paper §6–§7), in executable form.

Implements:

* :func:`psp_lag_pmf` — Theorem 2: the lag distribution a PSP barrier induces,
    p(s) = α·f(s)                 for s ≤ r
    p(s) = α·(F(r)^β)^{s−r}       for s > r
  with the normalising constant α from Eq. 14–18 (geometric-series closed
  form when F(r)^β < 1, linear form when F(r)^β = 1).

* :func:`mean_lag_bound` — Eq. 54: bound on (1/T)·Σ E(γ_t)
* :func:`variance_lag_bound` — Eq. 55: bound on (1/T)·Σ E(γ_t²)

* :func:`regret_tail_bound` — the one-sided Bernstein tail (Theorem 1/3):
    P( R[X]/T − (σL² + 2F²/σ)/√T − q ≥ δ ) ≤ exp( −Tδ² / (c + bδ/3) )
  with q,c either the ASP constants (4PσLμ, 16P²σ²L²φ) or the PSP bounds
  above — allowing a direct ASP-vs-PSP bound comparison (§7.2).

* empirical helpers used by tests to check the theory against the simulator
  (:func:`empirical_lag_distribution`).

Everything is plain numpy — these are analysis-side functions (they also back
``benchmarks/fig45_bounds.py``, reproducing Figures 4 and 5).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

__all__ = [
    "psp_alpha",
    "psp_lag_pmf",
    "mean_lag_bound",
    "variance_lag_bound",
    "regret_tail_bound",
    "asp_regret_constants",
    "psp_regret_constants",
    "empirical_lag_distribution",
]


def _check(a: float) -> None:
    if not (0.0 <= a <= 1.0):
        raise ValueError(f"a = F(r)^beta must be in [0,1], got {a}")


def psp_alpha(F_r: float, beta: int, T: int, r: int) -> float:
    """Normalising constant α (paper Eq. 41–42).

        α = (1−a) / ( F(r)(1−a) + a − a^{T−r+1} ),   a = F(r)^β,  a < 1
        α ≤ 1/(T−r)                                   when a = 1
    """
    a = F_r ** beta
    _check(a)
    if a >= 1.0 - 1e-12:
        return 1.0 / max(T - r, 1)
    denom = F_r * (1.0 - a) + a - a ** (T - r + 1)
    if denom <= 0:
        raise ValueError("degenerate distribution: no probability mass")
    return (1.0 - a) / denom


def psp_lag_pmf(f: np.ndarray, beta: int, r: int, T: int) -> np.ndarray:
    """Theorem 2: PSP-shaped lag pmf over s = 0..T.

    Args:
      f: pmf of the *underlying* lag distribution over s = 0..T (what workers
         would do with no barrier, i.e. under ASP).
      beta: sample size β.
      r: staleness r (r=0 ⇒ pBSP semantics).
      T: support upper end.

    Returns p: pmf over s = 0..T (sums to 1).
    """
    f = np.asarray(f, dtype=np.float64)
    if f.shape[0] < T + 1:
        f = np.pad(f, (0, T + 1 - f.shape[0]))
    F_r = float(np.sum(f[: r + 1]))
    a = F_r ** beta
    _check(a)
    s = np.arange(T + 1)
    p = np.where(s <= r, f[: T + 1], 0.0).astype(np.float64)
    tail = s > r
    if a > 0:
        p[tail] = a ** (s[tail] - r)
    else:
        p[tail] = 0.0
    z = p.sum()
    if z <= 0:
        raise ValueError("no probability mass (a=0 and empty head)")
    return p / z


def mean_lag_bound(F_r: float, beta: int, r: int, T: int) -> float:
    """Eq. 54: bound on the average of the means of the lags.

        (1/T)·Σ E(γ_t) ≤ α · ( r(r+1)/2 + a(r+2)/(1−a)² ),  a = F(r)^β < 1

    For a = 1 the paper shows the bound is O(T) (no convergence); we return
    that explicit O(T) expression (Eq. 49) so the discontinuity is visible in
    the Fig-4 reproduction.
    """
    a = F_r ** beta
    _check(a)
    if a >= 1.0 - 1e-12:
        # Eq. 49: (1/(T−r)) ( r(r+1)/2 + T² + T + Tr + r )
        return (r * (r + 1) / 2 + T**2 + T + T * r + r) / max(T - r, 1)
    alpha = psp_alpha(F_r, beta, T, r)
    return alpha * (r * (r + 1) / 2.0 + a * (r + 2) / (1.0 - a) ** 2)


def variance_lag_bound(F_r: float, beta: int, r: int, T: int) -> float:
    """Eq. 55: bound on the average of the variances of the lags.

        (1/T)·Σ E(γ_t²) < α · ( r(r+1)(2r+1)/6 + a(r²+4)/(1−a)³ )
    """
    a = F_r ** beta
    _check(a)
    if a >= 1.0 - 1e-12:
        # a=1 case of the squared arithmetico-geometric bound: O(T²)
        return (r * (r + 1) * (2 * r + 1) / 6
                + (T + 1) * (r + 1) ** 2 / 2
                + (T + 1) * (2 * T + 1) / 6
                + T * (T + 1) ** 2 / 12) / max(T - r, 1)
    alpha = psp_alpha(F_r, beta, T, r)
    return alpha * (r * (r + 1) * (2 * r + 1) / 6.0
                    + a * (r**2 + 4) / (1.0 - a) ** 3)


# --------------------------------------------------------------------------- #
# Regret tail bounds (Theorems 1 & 3)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class RegretConstants:
    """(q, c, b) of P( R/T − (σL²+2F²/σ)/√T − q ≥ δ ) ≤ exp(−Tδ²/(c+bδ/3))."""

    q: float
    c: float
    b: float


def asp_regret_constants(P: int, sigma: float, L: float, mu: float,
                         phi: float, T: int) -> RegretConstants:
    """Theorem 1 (ASP): q = 4PσLμ, c = 16P²σ²L²φ, b ≤ 4PTσL."""
    return RegretConstants(q=4 * P * sigma * L * mu,
                           c=16 * P**2 * sigma**2 * L**2 * phi,
                           b=4 * P * T * sigma * L)


def psp_regret_constants(P: int, sigma: float, L: float, F_r: float,
                         beta: int, r: int, T: int) -> RegretConstants:
    """Theorem 3 (PSP): q via Eq. 23 (= 4PσL × Eq. 54's bracket), c via Eq. 24."""
    mean_b = mean_lag_bound(F_r, beta, r, T)
    var_b = variance_lag_bound(F_r, beta, r, T)
    return RegretConstants(q=4 * P * sigma * L * mean_b,
                           c=16 * P**2 * sigma**2 * L**2 * var_b,
                           b=4 * P * T * sigma * L)


def regret_tail_bound(consts: RegretConstants, T: int, delta: float) -> float:
    """exp(−Tδ² / (c + bδ/3)) — the Bernstein tail probability."""
    return float(np.exp(-T * delta**2 / (consts.c + consts.b * delta / 3.0)))


# --------------------------------------------------------------------------- #
# Empirical cross-check against the simulator
# --------------------------------------------------------------------------- #
def empirical_lag_distribution(steps: np.ndarray, T: Optional[int] = None
                               ) -> np.ndarray:
    """Histogram of lags (max-step minus each worker's step), normalised."""
    steps = np.asarray(steps)
    lags = steps.max() - steps
    T = int(T if T is not None else lags.max())
    pmf = np.bincount(lags, minlength=T + 1)[: T + 1].astype(np.float64)
    return pmf / pmf.sum()
