"""The Actor system's three engines (paper §4).

The paper's framework exposes three computation engines that share one
swappable ``barrier`` function (Table 1: "Owl+Actor — BSP, ASP, SSP, PSP"):

* **map-reduce** — BSP-style bulk phases (``map``/``reduce``/``collect``);
* **parameter server** — ``push``/``pull``/``schedule``/``barrier`` with a
  logical central server holding model *and* node states
  (design combination 1: [centralised model, centralised states]);
* **peer-to-peer** — the same four APIs, but barrier state is fully
  distributed: every node samples peers through the structured overlay and
  decides locally (combination 2/4: [*, distributed states]); with PSP the
  server degenerates into a stateless *stream server* for updates.

These engines drive the discrete-event simulator, so all of the paper's
experiments are expressible as engine+barrier combinations.  The SPMD
counterpart for TPU meshes lives in :mod:`repro.core.spmd_psp`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.barriers import ASP, BSP, BarrierControl, make_barrier
from repro.core.simulator import SimConfig, SimResult, run_simulation
from repro.core.vector_sim import run_sweep

__all__ = [
    "Engine",
    "MapReduceEngine",
    "ParameterServerEngine",
    "P2PEngine",
    "valid_combinations",
]


# --------------------------------------------------------------------------- #
# design-combination matrix (paper §4.1)
# --------------------------------------------------------------------------- #
#: barrier-name -> engines that can host it.  BSP/SSP need centralised state;
#: ASP needs none; pBSP/pSSP run anywhere (that is the point of the paper).
_COMBINATIONS = {
    "bsp": ("mapreduce", "ps"),
    "ssp": ("ps",),
    "asp": ("ps", "p2p"),
    "pbsp": ("ps", "p2p"),
    "pssp": ("ps", "p2p"),
}


def valid_combinations(barrier_name: str) -> Sequence[str]:
    """Engines that can host ``barrier_name`` (paper §4.1 matrix)."""
    return _COMBINATIONS[barrier_name.lower()]


class Engine:
    """Common engine machinery: configure a simulation and run it."""

    name = "base"
    distributed_states = False

    def __init__(self, barrier: BarrierControl | str = "bsp", **overrides):
        if isinstance(barrier, str):
            barrier = make_barrier(barrier)
        self._check_combination(barrier)
        self.barrier = barrier
        self.overrides = overrides

    def _check_combination(self, barrier: BarrierControl) -> None:
        if self.name != "base" and self.name not in _COMBINATIONS[barrier.name]:
            raise ValueError(
                f"{barrier.name} cannot run on the {self.name} engine "
                f"(paper §4.1: needs one of {_COMBINATIONS[barrier.name]}); "
                "only ASP and PSP support distributed barrier state")

    # the four shared APIs (paper §4) — semantic no-op hooks that the
    # simulator enacts; exposed so applications can be written against them.
    def schedule(self, step: int, n_params: int) -> np.ndarray:
        """Which model parameters to update this step (here: all)."""
        return np.arange(n_params)

    def pull(self):
        """Fetch the current model (enacted by the simulator)."""
        raise NotImplementedError("driven by the simulator's event loop")

    def push(self):
        """Submit a local update (enacted by the simulator)."""
        raise NotImplementedError("driven by the simulator's event loop")

    def _config(self, **cfg_kwargs) -> SimConfig:
        cfg_kwargs = {**self.overrides, **cfg_kwargs}
        barrier = cfg_kwargs.pop("barrier", self.barrier)
        if isinstance(barrier, str):
            barrier = make_barrier(barrier)
        self._check_combination(barrier)
        return SimConfig(barrier=barrier,
                         distributed_sampling=self.distributed_states,
                         **cfg_kwargs)

    def run(self, **cfg_kwargs) -> SimResult:
        """Run one discrete-event simulation under this engine's barrier."""
        return run_simulation(self._config(**cfg_kwargs))

    def run_sweep(self, sweep: Iterable[dict], *, backend: str = "numpy",
                  **common) -> List[SimResult]:
        """Run a scenario sweep through the vectorized batch engine.

        ``sweep`` is an iterable of per-scenario :class:`SimConfig` override
        dicts (each may also carry a ``barrier`` name or instance);
        ``common`` applies to every scenario.  Scenarios sharing a
        structural shape are advanced simultaneously
        (:func:`repro.core.vector_sim.run_sweep`); ``backend`` selects the
        grid engine — ``"numpy"`` array ops, or ``"jax"``: device-resident
        donated chunk scans, sharded over the host mesh, whose whole tick
        (control + data plane) is the fused kernel of
        :mod:`repro.kernels.psp_tick` (ragged shapes batch into
        pow2-bucketed scans); results come back in sweep order either way.
        """
        cfgs = [self._config(**{**common, **kw}) for kw in sweep]
        return run_sweep(cfgs, backend=backend)


class MapReduceEngine(Engine):
    """Bulk phases: map (local grads) → barrier → reduce (server apply).

    MapReduce "requires map to complete before reducing" (Table 1) — i.e. the
    engine is inherently BSP.
    """

    name = "mapreduce"
    distributed_states = False

    def __init__(self, **overrides):
        super().__init__(BSP(), **overrides)


class ParameterServerEngine(Engine):
    """[centralised model, centralised states] — swappable barrier."""

    name = "ps"
    distributed_states = False


class P2PEngine(Engine):
    """[centralised-or-distributed model, **distributed** states].

    Barrier decisions are taken node-locally from overlay samples; the model
    server (when present) is a stateless stream server.  Only ASP and the
    probabilistic barriers are admissible here — BSP/SSP would need the very
    global view this engine abolishes.
    """

    name = "p2p"
    distributed_states = True
