"""Typed registry for every ``PSP_*`` environment override.

The env-override surface grew one variable at a time (sweep mesh, tick
impl, trace stride, compile cache, hypothesis budget, ...) with each read
site doing its own ``os.environ.get`` + ad-hoc parsing.  This module is
the single source of truth: every override is declared once in
:data:`REGISTRY` with its type, default and one-line description, and
every read site goes through the typed accessors below.  Benefits:

* a mistyped variable name raises ``KeyError`` at the read site instead
  of silently reading the process default;
* the docs table is *generated* from the registry
  (``python -m repro.core.env``), so it cannot drift — the serving-tier
  docs gate (``tests/test_env.py``) pins every registered name into
  ``docs/ARCHITECTURE.md``;
* parsing is uniform: ``int``/``float`` variables reject garbage with a
  message naming the variable, and *flag* variables follow one rule
  (set-and-nonempty = true — ``PSP_REGEN_GOLDEN=1``) everywhere.

Accessors return the registered default when the variable is unset; the
empty string counts as unset (so ``PSP_SWEEP_MESH= python ...`` clears an
ambient override).  Write sites (benchmarks exporting a mesh for child
code) still use ``os.environ`` directly — the registry types *reads*.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional

__all__ = ["EnvVar", "REGISTRY", "get_str", "get_int", "get_float", "flag",
           "markdown_table"]


@dataclasses.dataclass(frozen=True)
class EnvVar:
    """One registered environment override."""

    name: str           #: full variable name (``PSP_...``)
    kind: str           #: "str" | "int" | "float" | "flag"
    default: Any        #: value returned when unset (flags: False)
    help: str           #: one-line description for the generated table


def _reg(*vs: EnvVar) -> Dict[str, EnvVar]:
    return {v.name: v for v in vs}


REGISTRY: Dict[str, EnvVar] = _reg(
    EnvVar("PSP_SWEEP_MESH", "str", None,
           "`RxN` rows×nodes mesh factorization for jax sweeps "
           "(beats `PSP_SWEEP_DEVICES`; e.g. `4x2`)"),
    EnvVar("PSP_SWEEP_DEVICES", "int", None,
           "rows-axis device count for 1-D sweep placement "
           "(default: every local device; `0` = default)"),
    EnvVar("PSP_SWEEP_CHUNK", "int", None,
           "force a uniform sweep scan-chunk length in records "
           "(default: greedy pow2 schedule)"),
    EnvVar("PSP_TRACE_STRIDE", "int", None,
           "force the sweep trace record stride (snapped down to an "
           "admissible divisor of the measurement cadence)"),
    EnvVar("PSP_TICK_IMPL", "str", "auto",
           "PSP tick kernel dispatch: `auto` | `pallas` | `interpret` "
           "| `ref`"),
    EnvVar("PSP_COMPILE_CACHE", "flag", False,
           "force the persistent JAX compile cache ON even on CPU "
           "(default off there: jaxlib 0.4.37 heap corruption)"),
    EnvVar("PSP_NO_COMPILE_CACHE", "flag", False,
           "opt out of the persistent JAX compile cache everywhere "
           "(e.g. when measuring cold-compile cost)"),
    EnvVar("PSP_BENCH_HOST_DEVICES", "int", None,
           "forced host-device count for CPU benchmark runs "
           "(`0` disables the forced mesh; default: one per core, "
           "capped at 8)"),
    EnvVar("PSP_HYP_EXAMPLES", "int", 10,
           "hypothesis example budget for the property suites "
           "(CI fast lanes set 4)"),
    EnvVar("PSP_REGEN_GOLDEN", "flag", False,
           "regenerate committed golden trace files instead of "
           "comparing against them (intentional-change workflow)"),
    EnvVar("PSP_FAULT_PLAN", "str", None,
           "default fault plan for the cluster harness / chaos bench: a "
           "registry spec (`standard:seed=7`) or a plan-JSON path"),
    EnvVar("PSP_BUS_BACKOFF_BASE", "float", 0.25,
           "snapshot-watcher retry backoff base seconds for a bad "
           "step (doubles per failure, jittered)"),
    EnvVar("PSP_BUS_BACKOFF_MAX", "float", 8.0,
           "snapshot-watcher retry backoff ceiling in seconds"),
    EnvVar("PSP_BUS_BLACKLIST_MAX", "int", 64,
           "max bad-step entries the snapshot watcher remembers "
           "(oldest evicted beyond the cap)"),
    EnvVar("PSP_BUS_BLACKLIST_TTL", "float", 300.0,
           "seconds a bad-step entry stays blacklisted before eviction "
           "(the retention window)"),
    EnvVar("PSP_HB_INTERVAL", "float", 0.25,
           "cluster worker heartbeat-sidecar write cadence in seconds"),
    EnvVar("PSP_HB_TIMEOUT", "float", 10.0,
           "heartbeat staleness after which the cluster coordinator "
           "SIGKILLs a hung worker and treats it as departed"),
)


def _raw(name: str) -> Optional[str]:
    """Registered lookup: the raw string, or None when unset/empty."""
    if name not in REGISTRY:
        raise KeyError(f"{name} is not a registered env override "
                       f"(known: {sorted(REGISTRY)})")
    val = os.environ.get(name)
    return val if val else None


def get_str(name: str) -> Optional[str]:
    """String-typed read of a registered override (default when unset)."""
    var = REGISTRY[name] if name in REGISTRY else None
    raw = _raw(name)
    return var.default if raw is None else raw


def get_int(name: str) -> Optional[int]:
    """Int-typed read; garbage raises ``ValueError`` naming the variable."""
    raw = _raw(name)
    if raw is None:
        return REGISTRY[name].default
    try:
        return int(raw)
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not an integer") from None


def get_float(name: str) -> Optional[float]:
    """Float-typed read; garbage raises ``ValueError`` naming the variable."""
    raw = _raw(name)
    if raw is None:
        return REGISTRY[name].default
    try:
        return float(raw)
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not a number") from None


def flag(name: str) -> bool:
    """Flag-typed read: set to any non-empty value = True."""
    return _raw(name) is not None


def markdown_table() -> str:
    """The docs table, generated from :data:`REGISTRY` (one row per var)."""
    rows = ["| variable | type | default | meaning |",
            "|---|---|---|---|"]
    for v in REGISTRY.values():
        default = "unset" if v.default in (None, False) else str(v.default)
        help_ = v.help.replace("|", "\\|")   # keep cell pipes out of the grid
        rows.append(f"| `{v.name}` | {v.kind} | {default} | {help_} |")
    return "\n".join(rows)


if __name__ == "__main__":
    print(markdown_table())
