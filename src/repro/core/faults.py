"""Deterministic fault-plan registry for chaos testing.

A *fault plan* is a seeded, fully materialized schedule of concrete fault
events — "SIGKILL worker 2 at tick 12", "every publish torn for the next
4 versions", "stall worker 0 for 1.5 s at tick 20" — that the chaos
consumers execute verbatim:

* the multi-process cluster harness (:mod:`repro.launch.cluster`)
  executes the **process faults** (``kill`` / ``stall`` / ``hang``) on
  its real worker subprocesses;
* the serving tier's chaos driver (:class:`repro.serving.snapshot_bus.
  ChaosPublisher`, ``benchmarks/chaos_bench.py``) executes the
  **publish faults** (``torn_snapshot`` / ``corrupt_snapshot`` /
  ``delay_publish`` / ``drop_publish`` / ``disk_full``) on the snapshot
  bus.

Plans are *data*, not control flow: a builder draws every target and
time from one seeded ``numpy`` generator at construction, the compiled
event list round-trips through JSON (``to_json`` / ``from_json``), and
re-running the same spec string reproduces the identical plan — which is
what makes a chaos run reproducible and lets the equivalence tests
replay a cluster run's membership trajectory exactly.

Specs are ``name`` or ``name:key=value,key=value`` over the builder
registry (:data:`BUILDERS`): ``none``, ``kill-one``, ``standard``,
``rack``, ``torn-storm``, ``stall-one``.  ``PSP_FAULT_PLAN`` (typed in
:mod:`repro.core.env`) provides an ambient default spec — or a path to
a plan JSON written earlier — for the cluster CLI and the chaos bench.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import env

__all__ = ["FaultEvent", "FaultPlan", "BUILDERS", "make_plan",
           "plan_from_env", "PROCESS_KINDS", "PUBLISH_KINDS"]

#: fault kinds executed on worker processes by the cluster coordinator
PROCESS_KINDS = ("kill", "stall", "hang")
#: fault kinds executed on snapshot-bus publications
PUBLISH_KINDS = ("torn_snapshot", "corrupt_snapshot", "delay_publish",
                 "drop_publish", "disk_full")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One concrete fault.

    ``tick`` is the engine tick for process faults and the *publish
    index* (0-based count of publications) for publish faults.
    ``worker`` targets a worker subprocess (process faults; ``None``
    for the serving tier's single decode worker).  ``seconds`` is the
    stall/hang/delay duration; ``count`` widens publish faults to a
    window of consecutive publications (a *storm*).
    """

    kind: str
    tick: int
    worker: Optional[int] = None
    seconds: float = 0.0
    count: int = 1

    def __post_init__(self):
        if self.kind not in PROCESS_KINDS + PUBLISH_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} (known: "
                             f"{PROCESS_KINDS + PUBLISH_KINDS})")
        if self.tick < 0 or self.count < 1 or self.seconds < 0:
            raise ValueError(f"invalid fault event {self}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A compiled, immutable schedule of :class:`FaultEvent`\\ s.

    The shape parameters (``n_workers``, ``ticks``) are recorded so a
    consumer can refuse a plan built for a different cluster, and so the
    JSON artifact is self-describing.
    """

    name: str
    seed: int
    n_workers: int
    ticks: int
    events: Tuple[FaultEvent, ...]

    def kills_at(self, tick: int) -> List[int]:
        """Worker ids with a ``kill`` event scheduled at ``tick``."""
        return [e.worker for e in self.events
                if e.kind == "kill" and e.tick == tick
                and e.worker is not None]

    def worker_events(self, worker: int) -> List[FaultEvent]:
        """The ``stall``/``hang`` events a worker executes on itself."""
        return [e for e in self.events
                if e.kind in ("stall", "hang") and e.worker == worker]

    def publish_fault(self, index: int) -> Optional[FaultEvent]:
        """The publish fault covering publication ``index``, if any.

        An event with ``count=k`` covers indices ``tick .. tick+k-1``;
        the first matching event in plan order wins.
        """
        for e in self.events:
            if e.kind in PUBLISH_KINDS and e.tick <= index < e.tick + e.count:
                return e
        return None

    def serving_kill_index(self) -> Optional[int]:
        """Request index at which the serving decode worker dies, if any.

        Serving-tier plans encode the decode-worker death as a ``kill``
        with ``worker=None``; ``tick`` is the submitted-request index.
        """
        for e in self.events:
            if e.kind == "kill" and e.worker is None:
                return e.tick
        return None

    def to_json(self) -> str:
        """Serialize the plan (events and shape) to a JSON string."""
        return json.dumps({
            "name": self.name, "seed": self.seed,
            "n_workers": self.n_workers, "ticks": self.ticks,
            "events": [dataclasses.asdict(e) for e in self.events],
        }, indent=1)

    @staticmethod
    def from_json(text: str) -> "FaultPlan":
        """Inverse of :meth:`to_json`."""
        d = json.loads(text)
        return FaultPlan(name=d["name"], seed=int(d["seed"]),
                         n_workers=int(d["n_workers"]),
                         ticks=int(d["ticks"]),
                         events=tuple(FaultEvent(**e) for e in d["events"]))

    def save(self, path: str) -> None:
        """Write the plan JSON to ``path`` (atomic tmp+rename)."""
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.to_json())
        os.replace(tmp, path)


def _build_none(rng, n_workers, ticks, opts) -> Tuple[FaultEvent, ...]:
    """The empty plan: a no-fault control run."""
    return ()


def _build_kill_one(rng, n_workers, ticks, opts) -> Tuple[FaultEvent, ...]:
    """SIGKILL one seeded-random worker one third of the way in.

    Options: ``worker`` / ``at`` pin the victim / tick explicitly.
    """
    worker = int(opts.get("worker", rng.integers(n_workers)))
    at = int(opts.get("at", max(1, ticks // 3)))
    return (FaultEvent("kill", at, worker=worker),)


def _build_stall_one(rng, n_workers, ticks, opts) -> Tuple[FaultEvent, ...]:
    """Stall one seeded-random worker for ``d`` wall seconds mid-run."""
    worker = int(opts.get("worker", rng.integers(n_workers)))
    at = int(opts.get("at", max(1, ticks // 2)))
    d = float(opts.get("d", 1.0))
    return (FaultEvent("stall", at, worker=worker, seconds=d),)


def _build_standard(rng, n_workers, ticks, opts) -> Tuple[FaultEvent, ...]:
    """The acceptance-criteria mix: one kill, one stall, publish chaos.

    One worker SIGKILLed a third of the way in, a *different* worker
    stalled (``d`` seconds, default 0.5) halfway, a torn-snapshot storm
    of ``k`` publications (default 3), and one delayed publication —
    the "torn snapshots + one worker death + delayed publishes" plan the
    serving chaos run and the cluster bench both execute.
    """
    k = int(opts.get("k", 3))
    d = float(opts.get("d", 0.5))
    victim = int(opts.get("worker", rng.integers(n_workers)))
    straggler = int((victim + 1 + rng.integers(max(1, n_workers - 1)))
                    % n_workers) if n_workers > 1 else victim
    return (
        FaultEvent("kill", max(1, ticks // 3), worker=victim),
        FaultEvent("stall", max(1, ticks // 2), worker=straggler, seconds=d),
        FaultEvent("torn_snapshot", int(opts.get("storm_at", 2)), count=k),
        FaultEvent("delay_publish", int(opts.get("delay_at", 2 + k)),
                   seconds=float(opts.get("delay", 0.2))),
    )


def _build_rack(rng, n_workers, ticks, opts) -> Tuple[FaultEvent, ...]:
    """Correlated rack-level kill: one whole rack dies at the same tick.

    Workers are partitioned into racks of ``g`` (default 2) consecutive
    ids; a seeded-random rack is killed at a seeded mid-run tick.  At
    least one worker always survives (the last partial rack is never
    chosen when it would empty the cluster).
    """
    g = max(1, int(opts.get("g", 2)))
    n_racks = max(1, n_workers // g)
    rack = int(opts.get("rack", rng.integers(n_racks)))
    at = int(opts.get("at", max(1, ticks // 3)))
    members = [w for w in range(rack * g, min((rack + 1) * g, n_workers))]
    if len(members) >= n_workers:        # never kill the whole cluster
        members = members[:-1]
    return tuple(FaultEvent("kill", at, worker=w) for w in members)


def _build_torn_storm(rng, n_workers, ticks, opts) -> Tuple[FaultEvent, ...]:
    """Every publication torn for ``k`` versions, then clean again.

    The serving satellite's storm: a watcher must keep serving its last
    good version through the storm and swap on the first complete
    snapshot after it.  ``corrupt=1`` writes discoverable-but-unloadable
    snapshots instead of invisible torn ones.
    """
    k = int(opts.get("k", 4))
    kind = "corrupt_snapshot" if opts.get("corrupt") else "torn_snapshot"
    return (FaultEvent(kind, int(opts.get("at", 1)), count=k),)


#: registered plan builders: ``name -> (rng, n_workers, ticks, opts) -> events``
BUILDERS: Dict[str, Callable] = {
    "none": _build_none,
    "kill-one": _build_kill_one,
    "stall-one": _build_stall_one,
    "standard": _build_standard,
    "rack": _build_rack,
    "torn-storm": _build_torn_storm,
}


def _parse_spec(spec: str) -> Tuple[str, Dict[str, float]]:
    """Split ``name:key=value,...`` into (name, numeric options dict)."""
    name, _, rest = spec.partition(":")
    opts: Dict[str, float] = {}
    for item in filter(None, rest.split(",")):
        k, _, v = item.partition("=")
        if not _ or not k:
            raise ValueError(f"bad fault-plan option {item!r} in {spec!r}")
        opts[k.strip()] = float(v)
    return name.strip(), opts


def make_plan(spec: str, *, n_workers: int, ticks: int) -> FaultPlan:
    """Compile a spec string (or plan-JSON path) into a :class:`FaultPlan`.

    ``spec`` is either a path to a plan JSON (loaded verbatim, shape
    checked against ``n_workers``) or a registry spec like
    ``"standard:seed=7,k=4"``.  The ``seed`` option (default 0) seeds
    the builder's generator; all other options are builder-specific.
    """
    if spec.endswith(".json") or os.path.sep in spec:
        with open(spec) as f:
            plan = FaultPlan.from_json(f.read())
        if plan.n_workers != n_workers:
            raise ValueError(f"plan {plan.name!r} was built for "
                             f"{plan.n_workers} workers, cluster has "
                             f"{n_workers}")
        return plan
    name, opts = _parse_spec(spec)
    if name not in BUILDERS:
        raise ValueError(f"unknown fault plan {name!r} "
                         f"(known: {sorted(BUILDERS)})")
    seed = int(opts.pop("seed", 0))
    rng = np.random.default_rng(seed)
    events = BUILDERS[name](rng, n_workers, ticks, opts)
    for e in events:
        if e.kind in PROCESS_KINDS and e.worker is not None \
                and not 0 <= e.worker < n_workers:
            raise ValueError(f"fault targets worker {e.worker} outside "
                             f"0..{n_workers - 1}: {e}")
    return FaultPlan(name=name, seed=seed, n_workers=n_workers,
                     ticks=ticks, events=tuple(events))


def plan_from_env(*, n_workers: int, ticks: int,
                  default: str = "none") -> FaultPlan:
    """The ambient plan: ``PSP_FAULT_PLAN`` if set, else ``default``."""
    spec = env.get_str("PSP_FAULT_PLAN") or default
    return make_plan(spec, n_workers=n_workers, ticks=ticks)
