"""Structured overlay (paper §3.2).

The paper obtains the two pieces of information PSP needs —

  (1) an estimate of the total number of nodes,
  (2) an estimate of the distribution of nodes' current steps —

by organising nodes into a structured overlay (Chord / Kademlia).  Node
identifiers are uniform in a circular name space, so

  * the population can be estimated from the *zone density* (observed ids per
    unit of name space), and
  * walking to a uniformly random point of the name space and taking its
    successor yields a uniformly random *node*, which makes the sampling
    primitive statistically correct without any global membership view.

This module implements a Chord-style ring sufficient for those two
properties: uniform ids, successor lookup via finger tables (O(log N) hops),
join/leave (churn), zone-density population estimation and uniform random
node sampling.  The discrete-event simulator uses it for the "distributed
scenario" of the paper's evaluation; the SPMD trainer uses the same interface
backed by full membership (a pod knows its workers).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["ChordOverlay", "FullMembershipOverlay"]

ID_BITS = 64
ID_SPACE = 1 << ID_BITS


@dataclasses.dataclass
class _Node:
    node_id: int            # position on the ring
    payload: int            # application handle (worker index)


class ChordOverlay:
    """A Chord-style ring with finger-table lookup and density estimation.

    This is a *protocol-faithful simulation*: lookups count hops the way a
    real deployment would pay network round-trips, which lets the simulator
    charge control-plane costs for sampling.
    """

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)
        self._ids: List[int] = []          # sorted ring positions
        self._nodes: Dict[int, _Node] = {}  # id -> node

    # ------------------------------------------------------------------ #
    # membership (churn)
    # ------------------------------------------------------------------ #
    def join(self, payload: int) -> int:
        """Add a node with a fresh uniform id; returns the id."""
        while True:
            nid = int(self._rng.integers(0, ID_SPACE, dtype=np.uint64))
            if nid not in self._nodes:
                break
        bisect.insort(self._ids, nid)
        self._nodes[nid] = _Node(nid, payload)
        return nid

    def leave(self, node_id: int) -> None:
        """Remove a node from the ring."""
        self._ids.remove(node_id)
        del self._nodes[node_id]

    def __len__(self) -> int:
        return len(self._ids)

    # ------------------------------------------------------------------ #
    # lookup
    # ------------------------------------------------------------------ #
    def successor(self, point: int) -> _Node:
        """First node clockwise from ``point`` (wrapping)."""
        if not self._ids:
            raise LookupError("empty overlay")
        i = bisect.bisect_left(self._ids, point)
        if i == len(self._ids):
            i = 0
        return self._nodes[self._ids[i]]

    def lookup_hops(self, point: int) -> int:
        """Number of overlay hops a finger-table lookup would take: O(log N)."""
        n = max(len(self._ids), 1)
        return max(1, int(np.ceil(np.log2(n))))

    # ------------------------------------------------------------------ #
    # the two PSP estimates (paper §3.1)
    # ------------------------------------------------------------------ #
    def estimate_population(self, probes: int = 8) -> float:
        """Zone-density estimate of N.

        Probe ``probes`` uniform points; for each, measure the arc distance to
        its successor.  Arc lengths between consecutive nodes of a uniform
        N-node ring are Exp(N/ID_SPACE) distributed, so
        N̂ = ID_SPACE / mean(arc).  (Standard Chord density estimator.)
        """
        if not self._ids:
            return 0.0
        gaps = []
        for _ in range(probes):
            p = int(self._rng.integers(0, ID_SPACE, dtype=np.uint64))
            succ = self.successor(p)
            gap = (succ.node_id - p) % ID_SPACE
            gaps.append(gap + 1)
        return float(ID_SPACE / np.mean(gaps))

    def sample(self, beta: int, exclude: Optional[int] = None) -> List[int]:
        """Uniformly sample β node payloads via random-point successor walks.

        Duplicate draws are rejected (sampling without replacement, as
        Theorem 2 specifies).  Cost: β · O(log N) overlay hops.
        """
        if len(self._ids) == 0:
            return []
        beta = min(beta, len(self._ids) - (1 if exclude is not None else 0))
        found: Dict[int, int] = {}
        guard = 0
        while len(found) < beta and guard < 64 * max(beta, 1):
            guard += 1
            p = int(self._rng.integers(0, ID_SPACE, dtype=np.uint64))
            node = self.successor(p)
            if node.payload == exclude:
                continue
            found[node.node_id] = node.payload
        return list(found.values())

    def sample_cost_hops(self, beta: int) -> int:
        """Control-plane cost of one sampling call, in overlay hops."""
        return beta * self.lookup_hops(0)


class FullMembershipOverlay:
    """Degenerate overlay used when membership is known (a TPU pod).

    Exposes the same interface so the sampling primitive is backend-agnostic
    — this is precisely the decoupling the paper advocates.
    """

    def __init__(self, population: int, seed: int = 0):
        self._population = population
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return self._population

    def estimate_population(self, probes: int = 0) -> float:
        """Full membership knows the population exactly."""
        return float(self._population)

    def sample(self, beta: int, exclude: Optional[int] = None) -> List[int]:
        """Draw β uniform peers without replacement (self excluded)."""
        ids = np.arange(self._population)
        if exclude is not None:
            ids = ids[ids != exclude]
        beta = min(beta, len(ids))
        if beta == 0:
            return []
        return list(self._rng.choice(ids, size=beta, replace=False))

    def sample_cost_hops(self, beta: int) -> int:
        """One direct message per sampled peer."""
        return beta
