"""The ``sampling`` system primitive (the paper's central contribution).

The primitive answers: *"give me the current steps of β uniformly random
workers"*.  Composed with any barrier predicate (:mod:`repro.core.barriers`)
it yields the probabilistic variants pBSP/pSSP, and because a β-sample needs
no global state it can be evaluated **by every node independently** — turning
a centralised barrier into a fully distributed one.

Backends:

* :class:`OverlaySampler` — samples through a structured overlay
  (:class:`~repro.core.overlay.ChordOverlay`); charges O(β log N) hops.
  Used by the simulator's *distributed* scenario.
* :class:`CentralSampler` — the *centralised* scenario: the server holds the
  step vector, sampling "is as trivial as a counting process" (paper §5).
* :func:`sample_steps_jax` — jittable sampling of a step vector for the SPMD
  trainer; seeded, without replacement (per-worker independent permutations).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overlay import ChordOverlay, FullMembershipOverlay

__all__ = [
    "StepSample",
    "CentralSampler",
    "OverlaySampler",
    "sample_steps_jax",
]


@dataclasses.dataclass
class StepSample:
    """Result of one sampling call."""

    steps: np.ndarray          # i64[β] — sampled workers' current steps
    worker_ids: np.ndarray     # i64[β]
    cost_hops: int             # control-plane cost charged for this call


class CentralSampler:
    """Server-side sampling: the server already holds all steps."""

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def sample(self, steps: Sequence[int], beta: Optional[int],
               exclude: Optional[int] = None) -> StepSample:
        steps = np.asarray(steps)
        ids = np.arange(len(steps))
        if exclude is not None:
            keep = ids != exclude
            ids, pool = ids[keep], steps[keep]
        else:
            pool = steps
        if beta is None:  # classic barrier: full view
            return StepSample(pool, ids, cost_hops=0)
        beta = min(beta, len(pool))
        if beta == 0:
            return StepSample(pool[:0], ids[:0], cost_hops=0)
        # rejection sampling: O(β) per call instead of rng.choice's O(N)
        # permutation — this is the simulator's hottest path (every poll of
        # every waiting node draws a fresh sample)
        n = len(pool)
        if beta * 4 < n:
            seen: set = set()
            while len(seen) < beta:
                for v in self._rng.integers(0, n, size=beta):
                    seen.add(int(v))
                    if len(seen) == beta:
                        break
            sel = np.fromiter(seen, dtype=np.int64)
        else:
            sel = self._rng.choice(n, size=beta, replace=False)
        # Centralised: zero extra messages — it's a local counting process.
        return StepSample(pool[sel], ids[sel], cost_hops=0)


class OverlaySampler:
    """Node-local sampling through the structured overlay.

    Each call queries β random peers for their step: β lookups of
    O(log N) hops plus β direct step queries.
    """

    def __init__(self, overlay: ChordOverlay | FullMembershipOverlay):
        self.overlay = overlay

    def sample(self, steps: Sequence[int], beta: Optional[int],
               exclude: Optional[int] = None) -> StepSample:
        steps = np.asarray(steps)
        if beta is None:
            beta = len(steps)
        peer_ids = np.asarray(self.overlay.sample(beta, exclude=exclude),
                              dtype=np.int64)
        cost = self.overlay.sample_cost_hops(len(peer_ids)) + len(peer_ids)
        return StepSample(steps[peer_ids], peer_ids, cost_hops=cost)

    def estimate_population(self) -> float:
        return self.overlay.estimate_population()


def sample_steps_jax(
    key: jax.Array,
    steps: jax.Array,
    beta: int,
    *,
    exclude_self: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Jittable sampling primitive for the SPMD trainer.

    For each of the W workers, draws β peers uniformly **without replacement**
    (independent per worker, as each node samples locally in the distributed
    scenario).

    Args:
      key: PRNG key.
      steps: i32[W] — all workers' step counters (cheap to all-gather: 4W
        bytes; this is the *only* globally exchanged control state, and in the
        fully distributed deployment even this is replaced by β point queries).
      beta: sample size β ≥ 0.
      exclude_self: do not let a worker sample itself (it trivially satisfies
        the predicate).

    Returns:
      sampled_steps: i32[W, β]
      valid: bool[W, β] — False where β exceeded the peer population.
    """
    w = steps.shape[0]
    if beta == 0:
        return (jnp.zeros((w, 0), dtype=steps.dtype),
                jnp.zeros((w, 0), dtype=bool))

    keys = jax.random.split(key, w)

    def one(worker_idx, k):
        # Uniform scores; self is pushed to the end when excluded.
        scores = jax.random.uniform(k, (w,))
        if exclude_self:
            scores = scores.at[worker_idx].set(2.0)
        order = jnp.argsort(scores)          # ascending: β smallest = sample
        take = order[:beta]
        pop = w - 1 if exclude_self else w
        valid = jnp.arange(beta) < pop
        return steps[take], valid

    sampled, valid = jax.vmap(one)(jnp.arange(w), keys)
    return sampled, valid
