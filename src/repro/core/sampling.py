"""The ``sampling`` system primitive (the paper's central contribution).

The primitive answers: *"give me the current steps of β uniformly random
workers"*.  Composed with any barrier predicate (:mod:`repro.core.barriers`)
it yields the probabilistic variants pBSP/pSSP, and because a β-sample needs
no global state it can be evaluated **by every node independently** — turning
a centralised barrier into a fully distributed one.

Backends:

* :class:`OverlaySampler` — samples through a structured overlay
  (:class:`~repro.core.overlay.ChordOverlay`); charges O(β log N) hops.
  Used by the simulator's *distributed* scenario.
* :class:`CentralSampler` — the *centralised* scenario: the server holds the
  step vector, sampling "is as trivial as a counting process" (paper §5).
* :func:`sample_steps_jax` — jittable sampling of a step vector; seeded,
  without replacement (per-worker independent draws).  One primitive serves
  the SPMD trainer and the vectorized simulator's jax backend
  (:mod:`repro.core.vector_sim_jax`): the index core is
  :func:`sample_peer_indices_jax`, with
  :func:`sample_alive_peer_indices_jax` as the membership-masked variant
  for churn scenarios — both the sweep engines' churn rows and the
  elastic SPMD trainer (:mod:`repro.core.spmd_psp` with
  ``PSPConfig(churn=...)``) draw their β-samples from alive peers
  through it, so "which peers does a worker look at" has exactly one
  definition across every execution layer.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overlay import ChordOverlay, FullMembershipOverlay

__all__ = [
    "StepSample",
    "CentralSampler",
    "OverlaySampler",
    "sample_alive_peer_indices_jax",
    "sample_peer_indices_jax",
    "sample_steps_jax",
]


@dataclasses.dataclass
class StepSample:
    """Result of one sampling call."""

    steps: np.ndarray          # i64[β] — sampled workers' current steps
    worker_ids: np.ndarray     # i64[β]
    cost_hops: int             # control-plane cost charged for this call


class CentralSampler:
    """Server-side sampling: the server already holds all steps."""

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def sample(self, steps: Sequence[int], beta: Optional[int],
               exclude: Optional[int] = None) -> StepSample:
        """Draw β of ``steps`` uniformly (server-side counting process)."""
        steps = np.asarray(steps)
        ids = np.arange(len(steps))
        if exclude is not None:
            keep = ids != exclude
            ids, pool = ids[keep], steps[keep]
        else:
            pool = steps
        if beta is None:  # classic barrier: full view
            return StepSample(pool, ids, cost_hops=0)
        beta = min(beta, len(pool))
        if beta == 0:
            return StepSample(pool[:0], ids[:0], cost_hops=0)
        # rejection sampling: O(β) per call instead of rng.choice's O(N)
        # permutation — this is the simulator's hottest path (every poll of
        # every waiting node draws a fresh sample)
        n = len(pool)
        if beta * 4 < n:
            seen: set = set()
            while len(seen) < beta:
                for v in self._rng.integers(0, n, size=beta):
                    seen.add(int(v))
                    if len(seen) == beta:
                        break
            sel = np.fromiter(seen, dtype=np.int64)
        else:
            sel = self._rng.choice(n, size=beta, replace=False)
        # Centralised: zero extra messages — it's a local counting process.
        return StepSample(pool[sel], ids[sel], cost_hops=0)


class OverlaySampler:
    """Node-local sampling through the structured overlay.

    Each call queries β random peers for their step: β lookups of
    O(log N) hops plus β direct step queries.
    """

    def __init__(self, overlay: ChordOverlay | FullMembershipOverlay):
        self.overlay = overlay

    def sample(self, steps: Sequence[int], beta: Optional[int],
               exclude: Optional[int] = None) -> StepSample:
        """Draw β peers through the overlay, charging lookup hops."""
        steps = np.asarray(steps)
        if beta is None:
            beta = len(steps)
        peer_ids = np.asarray(self.overlay.sample(beta, exclude=exclude),
                              dtype=np.int64)
        cost = self.overlay.sample_cost_hops(len(peer_ids)) + len(peer_ids)
        return StepSample(steps[peer_ids], peer_ids, cost_hops=cost)

    def estimate_population(self) -> float:
        """Estimate N from overlay density (paper §4.3)."""
        return self.overlay.estimate_population()


def sample_peer_indices_jax(
    key: Optional[jax.Array],
    n: int,
    beta: int,
    *,
    exclude_self: bool = True,
    scores: Optional[jax.Array] = None,
    u: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Jittable peer-index sampling: the index core of the β primitive.

    For each of the ``n`` workers, draws ``k = min(β, n)`` peer *indices*
    uniformly without replacement (independent per worker).  Shared by the
    SPMD trainer (:func:`sample_steps_jax`), the unified barrier model
    (:mod:`repro.core.barrier_kernel`) and the vectorized simulator's
    jax backend (:mod:`repro.core.vector_sim_jax`), so every system
    exercises one sampling primitive.

    β = 1 short-circuits to a single uniform draw per worker (the paper's
    canonical β = 1% regime); larger β takes the k smallest of a uniform
    score matrix (top-k, not a full argsort).

    The uniform noise may be pre-drawn and passed in (``scores`` for the
    top-k path, ``u`` for the β = 1 fast path, leading batch dims allowed)
    — this is how the fused Pallas tick kernel
    (:mod:`repro.kernels.psp_tick`) and this reference are held to the
    *identical* sample: both consume the same draw, one by top-k selection,
    one by an algebraically equivalent rank test.  When no noise is given
    it is drawn from ``key`` exactly as before.

    Returns:
      take: i32[n, k] — sampled peer indices (leading batch dims follow
        the supplied noise).
      valid: bool[n, k] — False where β exceeded the peer population.
    """
    k = min(beta, n)
    pop = n - 1 if exclude_self else n
    if k <= 0:
        z = jnp.zeros((n, 0))
        return z.astype(jnp.int32), z.astype(bool)
    if k == 1 and exclude_self:
        # one uniform over the n−1 non-self slots, shifted past self;
        # clamped so the degenerate n = 1 population (valid = False)
        # still yields an in-range index, like the top-k path
        if u is None:
            u = jax.random.uniform(key, (n,))
        draw = jnp.floor(u * max(n - 1, 1)).astype(jnp.int32)
        take = jnp.minimum(draw + (draw >= jnp.arange(n, dtype=jnp.int32)),
                           n - 1)[..., None]
    else:
        if scores is None:
            scores = jax.random.uniform(key, (n, n))
        if exclude_self:
            scores = jnp.where(jnp.eye(n, dtype=bool), 2.0, scores)
        _, take = jax.lax.top_k(-scores, k)   # k smallest scores = sample
    valid = jnp.broadcast_to(jnp.arange(k) < pop, take.shape)
    return take.astype(jnp.int32), valid


def sample_alive_peer_indices_jax(
    key: Optional[jax.Array],
    alive: jax.Array,
    beta: int,
    *,
    exclude_self: bool = True,
    scores: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Membership-masked variant of :func:`sample_peer_indices_jax`.

    For each worker, draws up to ``min(β, n)`` peers uniformly without
    replacement from the **alive** peer set (churn scenarios: every row of
    a scenario batch has its own alive mask, so indices cannot be shared;
    ragged batches: padded node slots are permanently dead).
    A slot is invalid where β exceeded the row's alive-peer population —
    the jittable analogue of the event engine's
    ``beta = min(beta, len(pool))`` over a compressed alive pool.

    Args:
      key: PRNG key (unused when ``scores`` is supplied).
      alive: bool[..., n] — membership mask(s); leading dims are batched.
      beta: sample size β ≥ 0.
      exclude_self: do not let a worker sample itself.
      scores: optional pre-drawn uniform scores ``[..., n, n]`` — the same
        draw a fused kernel consumes, see :func:`sample_peer_indices_jax`.

    Returns:
      take: i32[..., n, k] peer indices, k = min(β, n).
      valid: bool[..., n, k] — False on dead-peer / exhausted-pool slots.
    """
    *lead, n = alive.shape
    k = min(beta, n)
    if k <= 0:
        z = jnp.zeros((*lead, n, 0))
        return z.astype(jnp.int32), z.astype(bool)
    if scores is None:
        scores = jax.random.uniform(key, (*lead, n, n))
    masked = ~alive[..., None, :]
    if exclude_self:
        masked = masked | jnp.eye(n, dtype=bool)
    scores = jnp.where(masked, 2.0, scores)
    neg, take = jax.lax.top_k(-scores, k)   # k smallest scores = sample
    return take.astype(jnp.int32), -neg < 1.5


def sample_steps_jax(
    key: jax.Array,
    steps: jax.Array,
    beta: int,
    *,
    exclude_self: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Jittable sampling primitive for the SPMD trainer and sweep engine.

    For each of the W workers, draws β peers uniformly **without replacement**
    (independent per worker, as each node samples locally in the distributed
    scenario).

    Args:
      key: PRNG key.
      steps: i32[W] — all workers' step counters (cheap to all-gather: 4W
        bytes; this is the *only* globally exchanged control state, and in the
        fully distributed deployment even this is replaced by β point queries).
        May also be i32[B, W]: a scenario batch (the vectorized sweep
        engine's jax backend); one index draw is shared across the B rows —
        each row's marginal stays an exact uniform β-sample — and the
        sampled steps are gathered per row.
      beta: sample size β ≥ 0.
      exclude_self: do not let a worker sample itself (it trivially satisfies
        the predicate).

    Returns:
      sampled_steps: i32[W, k] (or i32[B, W, k]) with k = min(β, W)
      valid: bool of the same shape — False where β exceeded the peer
        population.
    """
    w = steps.shape[-1]
    take, valid = sample_peer_indices_jax(key, w, beta,
                                          exclude_self=exclude_self)
    if steps.ndim == 2:
        return steps[:, take], jnp.broadcast_to(valid, (steps.shape[0],)
                                                + valid.shape)
    return steps[take], valid
