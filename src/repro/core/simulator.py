"""Discrete-event simulator of the paper's Actor system (§4–§5).

Reproduces the evaluation workload: P heterogeneous nodes collaboratively
training a d-parameter **linear model with SGD** through a parameter server,
under a swappable barrier control (BSP / SSP / ASP / pBSP / pSSP).  The
simulator is seeded and deterministic, and measures exactly what the paper
plots:

* per-node progress in steps at a time horizon (Fig 1a/1b/1c),
* normalized model error ‖w − w*‖₂/‖w*‖₂ over time (Fig 1d),
* number of updates received by the server over time (Fig 1e),
* straggler sweeps — fraction and slowness (Fig 2),
* scalability sweeps — system size (Fig 3).

Faithfulness notes
------------------
* Each node holds an i.i.d. local dataset (paper §5: "every node hold the
  equal-size data and the data is i.i.d.").
* A node's SGD step: pull the current model, compute a minibatch gradient on
  it, push the update.  Updates computed on a stale pull are exactly the
  paper's "delayed updates" noise.
* Barrier evaluation is either **centralised** (server-side counting process)
  or **distributed** (each node samples β peers through the structured
  overlay) — both scenarios of §5.
* Barrier sampling is **worker-centric and self-excluding** (§6.4): a worker
  deciding whether to advance samples β *other* workers — on both paths the
  deciding node is excluded from the pool (centralised via
  ``CentralSampler(exclude=...)`` with the index remapped through the alive
  mask under churn; distributed via the overlay's ``exclude``), matching
  ``sample_steps_jax(..., exclude_self=True)`` on the SPMD path.  A worker
  that could draw itself would trivially satisfy the predicate.
* Control-plane cost is tracked separately from update messages, matching the
  paper's Fig-1e methodology ("we ignore control messages ... negligible
  compared to the size of model updates").

This event-driven simulator is the **semantic reference**; scenario sweeps
should go through the vectorized batch engine
(:func:`repro.core.vector_sim.run_sweep`), which advances many
configurations simultaneously and is equivalence-tested against this one.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.barriers import ASP, BSP, BarrierControl
from repro.core.overlay import ChordOverlay, FullMembershipOverlay
from repro.core.sampling import CentralSampler, OverlaySampler

__all__ = ["SimConfig", "SimResult", "Simulator", "run_simulation",
           "draw_static_state", "sample_poisson_times"]


@dataclasses.dataclass
class SimConfig:
    """Configuration mirroring the paper's experimental setup."""

    n_nodes: int = 100
    duration: float = 40.0          # simulated seconds (paper: 40 s)
    dim: int = 100                  # model dimensionality (paper: 1000)
    batch: int = 8                  # minibatch per local step
    #: learning rate; None ⇒ 0.5/P (server applies P concurrent pushes, so
    #: stability of the quadratic task needs P·lr < 2; see tests)
    lr: Optional[float] = None
    base_compute: float = 0.1       # mean seconds per local SGD step
    compute_jitter: float = 0.5     # U[1−j/2, 1+j/2] multiplicative noise
    straggler_frac: float = 0.0     # fraction of slow nodes (Fig 2)
    straggler_slowdown: float = 4.0  # slow nodes are this many × slower
    barrier: BarrierControl = dataclasses.field(default_factory=BSP)
    distributed_sampling: bool = False  # node-local sampling via overlay
    poll_interval: float = 0.02     # waiting-node recheck cadence (sampled)
    measure_interval: float = 0.5   # error/progress trace cadence
    noise_std: float = 0.1          # label noise of the linear task
    churn_join_rate: float = 0.0    # nodes joining per second
    churn_leave_rate: float = 0.0   # nodes leaving per second
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    """Measured outputs of one simulation (the paper's Fig-1 traces)."""

    steps: np.ndarray               # i64[P] final per-node progress
    times: np.ndarray               # f64[M] measurement grid
    errors: np.ndarray              # f64[M] normalized ‖w−w*‖/‖w*‖
    server_updates: np.ndarray      # i64[M] cumulative updates at server
    control_messages: int           # overlay/sampling control-plane cost
    total_updates: int
    mean_progress: float
    final_error: float

    def lag_pmf(self) -> np.ndarray:
        """Empirical pmf of final step lags behind the leader."""
        lags = self.steps.max() - self.steps
        pmf = np.bincount(lags).astype(np.float64)
        return pmf / pmf.sum()


def draw_static_state(cfg: SimConfig,
                      rng: np.random.Generator) -> Tuple[np.ndarray,
                                                         np.ndarray]:
    """Per-seed static draw: ground truth + per-node mean step times.

    Both the event-driven simulator and the vectorized batch engines
    (:mod:`repro.core.vector_sim`) replay this exact stream per config, so a
    config's ground-truth model, node speeds and straggler assignment are
    identical across engines — only the *dynamics* noise differs (and only
    at the sample-path level).
    """
    w_true = rng.normal(size=cfg.dim) / np.sqrt(cfg.dim)
    speed = 1.0 + cfg.compute_jitter * (rng.random(cfg.n_nodes) - 0.5)
    n_slow = int(round(cfg.straggler_frac * cfg.n_nodes))
    slow_ids = rng.choice(cfg.n_nodes, size=n_slow, replace=False)
    speed[slow_ids] *= cfg.straggler_slowdown
    return w_true, cfg.base_compute * speed


def sample_poisson_times(rng: np.random.Generator, rate: float,
                         duration: float) -> np.ndarray:
    """Event times of a Poisson process on (0, duration]: exponential gaps.

    This is the churn arrival/departure model of the event simulator
    (each ``_on_leave``/``_on_join`` re-arms at an exponential gap, i.e. a
    Poisson process independent of system state); the batched engines
    pre-sample the whole schedule from it.
    """
    if rate <= 0.0:
        return np.empty(0)
    times: List[float] = []
    t = rng.exponential(1.0 / rate)
    while t <= duration:
        times.append(t)
        t += rng.exponential(1.0 / rate)
    return np.asarray(times)


# event kinds
_FINISH, _POLL, _MEASURE, _JOIN, _LEAVE = range(5)


class Simulator:
    """Single-run simulator.  See :func:`run_simulation` for the entry point."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        P, d = cfg.n_nodes, cfg.dim
        self.lr = cfg.lr if cfg.lr is not None else 0.5 / P

        # --- linear-regression ground truth & server model ---------------- #
        self.w_true, self.compute_time = draw_static_state(cfg, self.rng)
        self.w = np.zeros(d)
        self.w_true_norm = float(np.linalg.norm(self.w_true))

        # --- node state ---------------------------------------------------- #
        self.steps = np.zeros(P, dtype=np.int64)
        self.alive = np.ones(P, dtype=bool)
        self._all_alive = (cfg.churn_leave_rate == 0.0
                           and cfg.churn_join_rate == 0.0)
        self.pulled_w: List[np.ndarray] = [self.w.copy() for _ in range(P)]

        # --- barrier / sampling backends ----------------------------------- #
        self.barrier = cfg.barrier
        if cfg.distributed_sampling:
            self.overlay = ChordOverlay(seed=cfg.seed + 1)
            self.node_ids = [self.overlay.join(i) for i in range(P)]
            self.sampler = OverlaySampler(self.overlay)
        else:
            self.overlay = None
            self.sampler = CentralSampler(seed=cfg.seed + 1)

        # --- bookkeeping ---------------------------------------------------- #
        self.now = 0.0
        self.total_updates = 0
        self.control_messages = 0
        self._events: List[Tuple[float, int, int, int]] = []
        self._seq = itertools.count()
        self._waiting: Dict[int, int] = {}   # node -> step it wants to start
        self._trace_t: List[float] = []
        self._trace_err: List[float] = []
        self._trace_upd: List[int] = []
        # fast-path state for full-view (deterministic) barriers
        self._full_view = self.barrier.sample_size is None and \
            not isinstance(self.barrier, ASP)
        # --- adaptive barrier-policy state (dssp / ebsp / β-annealing) --- #
        # Mutable mirrors of the BarrierPolicy state pytree; static
        # barriers have kind "" and never touch them.  Decisions read the
        # current state; observations update it at this engine's natural
        # points (finishes for the step spread, starts for the duration
        # EMA) — the engines are equivalent at the distribution level.
        self._adaptive = getattr(self.barrier, "adaptive", "")
        if self._adaptive:
            cap = max(min(int(self.barrier.sample_size or 0), P - 1), 0)
            self._beta_cap = cap
            self._beta_lo = min(max(int(getattr(
                self.barrier, "sample_size_lo", 0)), 0), cap)
            self._pol_thr = int(self.barrier.staleness)
            self._pol_beta = self._beta_lo if self._adaptive == "anneal" \
                else cap
            self._pol_ema = np.zeros(P)

    # ------------------------------------------------------------------ #
    def _push(self, t: float, kind: int, node: int = -1) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, node))

    def _step_duration(self, node: int) -> float:
        # exponential-ish jitter around the node's mean (heterogeneous net+CPU)
        return float(self.compute_time[node] *
                     (0.5 + self.rng.random()))

    # ------------------------------------------------------------------ #
    # SGD mechanics
    # ------------------------------------------------------------------ #
    def _local_gradient(self, node: int) -> np.ndarray:
        """Minibatch gradient of ½‖Xw−y‖² on node-local i.i.d. data."""
        cfg = self.cfg
        X = self.rng.normal(size=(cfg.batch, cfg.dim))
        y = X @ self.w_true + cfg.noise_std * self.rng.normal(size=cfg.batch)
        w_local = self.pulled_w[node]
        return X.T @ (X @ w_local - y) / cfg.batch

    def _push_update(self, node: int) -> None:
        """Node pushes −η·∇f(w_pulled); the server applies it (data plane)."""
        g = self._local_gradient(node)
        self.w -= self.lr * g
        self.total_updates += 1

    def _pull_model(self, node: int) -> None:
        self.pulled_w[node] = self.w.copy()

    # ------------------------------------------------------------------ #
    # barrier plumbing
    # ------------------------------------------------------------------ #
    def _can_pass(self, node: int) -> bool:
        if isinstance(self.barrier, ASP):
            return True
        beta = self.barrier.sample_size
        staleness = self.barrier.staleness
        if self._adaptive == "dssp":
            # dynamic threshold searched in [staleness_lo, staleness]
            staleness = self._pol_thr
        elif self._adaptive == "ebsp":
            # per-node step credit from the duration EMA (the scalar form
            # of barrier_kernel.elastic_slack); slowest node gets 0 — BSP
            live = np.where(self.alive, self._pol_ema, 0.0)
            frac = 1.0 - self._pol_ema[node] / max(float(live.max()), 1e-9)
            staleness = int(np.floor(self.barrier.max_advance * frac))
        elif self._adaptive == "anneal":
            # annealed sample size; β = 0 samples nobody (degenerate ASP,
            # and CentralSampler draws no RNG for an empty sample)
            beta = self._pol_beta
        # avoid the O(N) alive-mask gather on the hot path when there is
        # no churn (the common case)
        all_alive = self._all_alive if hasattr(self, "_all_alive") else True
        alive_steps = self.steps if all_alive else self.steps[self.alive]
        if self.cfg.distributed_sampling and beta is not None:
            sample = self.sampler.sample(self.steps, beta, exclude=node)
            self.control_messages += sample.cost_hops
            pool = sample.steps
        else:
            # The paper's worker-centric check samples β *other* workers
            # (§6.4), so the deciding node is excluded from the pool.  Under
            # churn ``alive_steps`` is compressed, so remap the node's index
            # through the alive mask.
            self_index = node if all_alive else \
                int(np.count_nonzero(self.alive[:node]))
            sample = self.sampler.sample(alive_steps, beta,
                                         exclude=self_index)
            # centralised: counting process at the server — no extra messages
            pool = sample.steps
        if pool.size == 0:
            return True
        return bool(np.all(self.steps[node] - pool <= staleness))

    def _try_advance(self, node: int, from_poll: bool = False) -> None:
        """Barrier check; on success begin the node's next step."""
        if not self.alive[node]:
            return
        if self._can_pass(node):
            self._waiting.pop(node, None)
            self._pull_model(node)
            dur = self._step_duration(node)
            if self._adaptive == "ebsp":
                # fold the freshly drawn duration into the node's EMA —
                # the event engine's observation point for worker speed
                a = self.barrier.ema_alpha
                self._pol_ema[node] = (1.0 - a) * self._pol_ema[node] \
                    + a * dur
            self._push(self.now + dur, _FINISH, node)
        else:
            newly_waiting = node not in self._waiting
            if newly_waiting:
                self._waiting[node] = int(self.steps[node])
            if not self._full_view and (newly_waiting or from_poll):
                # sampled barriers re-draw a fresh sample after a poll
                # interval; wake-triggered re-checks of an already-waiting
                # node must not spawn a second poll chain
                self._push(self.now + self.cfg.poll_interval, _POLL, node)

    def _wake_waiters(self) -> None:
        """Re-check all waiters (global-min movement or membership change)."""
        if not self._waiting:
            return
        for node in list(self._waiting):
            self._try_advance(node)

    # ------------------------------------------------------------------ #
    # event handlers
    # ------------------------------------------------------------------ #
    def _on_finish(self, node: int) -> None:
        if not self.alive[node]:
            return
        self._push_update(node)
        old_min = int(self.steps[self.alive].min())
        self.steps[node] += 1
        thr_moved = False
        if self._adaptive in ("dssp", "anneal"):
            # observe the post-finish alive-step spread and update the
            # carried threshold / sample size (clip into the configured
            # range — the grid engines' block-3b rule at this engine's
            # per-event granularity)
            a_steps = self.steps[self.alive]
            gap = int(a_steps.max() - a_steps.min())
            if self._adaptive == "dssp":
                new = int(np.clip(gap, self.barrier.staleness_lo,
                                  self.barrier.staleness))
                thr_moved = new != self._pol_thr
                self._pol_thr = new
            else:
                self._pol_beta = int(np.clip(
                    self._beta_lo + gap - self.barrier.staleness,
                    self._beta_lo, self._beta_cap))
        self._try_advance(node)
        # full-view waiters are event-woken: on global-min movement, on a
        # DSSP threshold change, and on every finish for Elastic-BSP
        # (a finisher's restart shifts the EMA, so any waiter's slack may
        # have widened).  Wakes draw no RNG for full-view barriers, so
        # the extra re-checks cannot perturb the stream.
        if self._full_view and (
                int(self.steps[self.alive].min()) != old_min or thr_moved
                or self._adaptive == "ebsp"):
            self._wake_waiters()

    def _on_measure(self) -> None:
        err = float(np.linalg.norm(self.w - self.w_true) / self.w_true_norm)
        self._trace_t.append(self.now)
        self._trace_err.append(err)
        self._trace_upd.append(self.total_updates)
        if self.now + self.cfg.measure_interval <= self.cfg.duration + 1e-9:
            self._push(self.now + self.cfg.measure_interval, _MEASURE)

    def _on_leave(self) -> None:
        alive_ids = np.flatnonzero(self.alive)
        if len(alive_ids) > 2:
            node = int(self.rng.choice(alive_ids))
            was_min = int(self.steps[node]) == int(self.steps[alive_ids].min())
            self.alive[node] = False
            if self.overlay is not None:
                self.overlay.leave(self.node_ids[node])
            self._waiting.pop(node, None)
            # Full-view waiters have no poll chain — they are only woken by
            # the global min *moving* on a finish, which a departed node's
            # step never does, so a leave must wake them or they can block
            # forever.  Sampled waiters re-poll on their own; the eager
            # re-check when the departed node was the global minimum just
            # spares them the remaining poll interval.
            if self._full_view or was_min:
                self._wake_waiters()
        if self.cfg.churn_leave_rate > 0:
            self._push(self.now + self.rng.exponential(
                1.0 / self.cfg.churn_leave_rate), _LEAVE)

    def _on_join(self) -> None:
        # a previously departed node re-joins (bounded population model)
        dead = np.flatnonzero(~self.alive)
        if len(dead):
            node = int(self.rng.choice(dead))
            self.alive[node] = True
            self.steps[node] = int(self.steps[self.alive].max())  # fresh start
            if self.overlay is not None:
                self.node_ids[node] = self.overlay.join(node)
            self._try_advance(node)
        if self.cfg.churn_join_rate > 0:
            self._push(self.now + self.rng.exponential(
                1.0 / self.cfg.churn_join_rate), _JOIN)

    # ------------------------------------------------------------------ #
    def run(self) -> SimResult:
        """Drive the event loop to the horizon and assemble the result."""
        cfg = self.cfg
        for node in range(cfg.n_nodes):
            self._push(self._step_duration(node), _FINISH, node)
        self._push(0.0, _MEASURE)
        if cfg.churn_leave_rate > 0:
            self._push(self.rng.exponential(1.0 / cfg.churn_leave_rate), _LEAVE)
        if cfg.churn_join_rate > 0:
            self._push(self.rng.exponential(1.0 / cfg.churn_join_rate), _JOIN)

        while self._events:
            t, _, kind, node = heapq.heappop(self._events)
            if t > cfg.duration:
                break
            self.now = t
            if kind == _FINISH:
                self._on_finish(node)
            elif kind == _POLL:
                if node in self._waiting:
                    self._try_advance(node, from_poll=True)
            elif kind == _MEASURE:
                self._on_measure()
            elif kind == _LEAVE:
                self._on_leave()
            elif kind == _JOIN:
                self._on_join()

        err = float(np.linalg.norm(self.w - self.w_true) / self.w_true_norm)
        return SimResult(
            steps=self.steps.copy(),
            times=np.asarray(self._trace_t),
            errors=np.asarray(self._trace_err),
            server_updates=np.asarray(self._trace_upd),
            control_messages=self.control_messages,
            total_updates=self.total_updates,
            mean_progress=float(self.steps[self.alive].mean()),
            final_error=err,
        )


def run_simulation(cfg: SimConfig) -> SimResult:
    """Run one seeded simulation."""
    return Simulator(cfg).run()
