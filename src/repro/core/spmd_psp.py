"""TPU-native PSP: barrier control as a first-class SPMD training feature.

The paper's deployment model (WAN actors) does not exist on a TPU pod — an
SPMD program is lockstep by construction.  What transfers is the *semantics*:
workers at heterogeneous speeds, a server model updated by possibly-stale
pushes, and a barrier predicate (evaluated on a β-sample of step counters)
gating when each worker may start its next step.

This module implements those semantics as a single jittable train step
(`lax`-only control flow), so one SPMD program faithfully executes
BSP / SSP / ASP / pBSP / pSSP and their convergence-vs-virtual-wall-clock
trade-offs can be measured on real models — and so the PSP logic itself is
visible to the multi-pod dry-run and the roofline pipeline.

Mapping (DESIGN.md §3/§4):

* **worker** = a data-parallel shard group (the
  :data:`repro.parallel.sharding.PSP_WORKER_AXES` mesh axes carry the
  worker dimension W — ``data``, or (pod, data-row) pairs on a multi-pod
  mesh, resolved by :func:`repro.parallel.sharding.psp_worker_axes`; the
  ``model`` axis shards each worker's compute).  The server ``psum``
  reduces over exactly those axes, and the sweep engines' 2-D mesh
  (:mod:`repro.core.vector_sim_jax`) draws its ``rows``/``nodes`` names
  from the same vocabulary, so trainer and sweeps shard one way.
* **server model** = one replicated parameter pytree, updated by masked
  contributions (`psum` over the worker axis is the only cross-worker
  collective — identical schedule to plain DP, so PSP adds *zero* extra
  collective bytes on the data plane; the control plane is a W-length i32
  vector).
* **worker view** = each worker's stale pull of the server model (leading W
  axis sharded over ``data``), updated by a masked "pull" when the worker
  passes the barrier.  This reproduces read-my-writes staleness exactly.
* **virtual clock** = seeded per-worker step durations (heterogeneity +
  straggler injection, reproducing Fig 2 on-device).  Time advances
  event-style to the next completion.

The per-tick protocol (one call of :func:`psp_train_step`):

  1. every worker computes a gradient on **its own view** (SPMD always
     computes; masks decide what lands),
  2. workers whose virtual clock completed *push*: the server applies the
     masked sum of their gradients through the optimizer,
  3. completed workers evaluate the barrier on a β-sample of the step
     vector; those allowed *pull* the fresh server model, bump their step,
     and draw the duration of their next local step; blocked workers hold
     (they re-sample next tick — the paper's "holds until condition is
     satisfied").

Elastic worker sets (churn)
---------------------------
The paper's scalability claims assume a *dynamic* node population, and the
sweep engines model churn natively; with ``PSPConfig(churn=ChurnConfig(...))``
so does this trainer.  :class:`PSPState` carries a per-worker ``alive`` mask
plus pre-sampled Poisson leave/join schedules (the schedule machinery of
:func:`repro.core.vector_sim.sample_churn_schedules` — churn events are
data, not control flow), and every tick opens with a churn phase, all
``lax``-only so the step stays one SPMD program:

* a due **leave** kills a uniformly random alive worker (only while more
  than two are alive — the event engine's rule; the event is consumed
  either way).  The departed worker's counters freeze; it contributes
  **zero** gradient and zero bytes to the server ``psum`` (the push mask
  is alive-masked) and never gates a waiter (barrier predicates evaluate
  over alive workers only, via the masked
  :class:`~repro.core.barrier_kernel.BarrierKernel` predicates with
  β-samples drawn from alive peers).
* a due **join** revives a uniformly random departed slot: it is
  re-anchored with a *fresh pull* of the server model, restarts at the
  max alive step (the event engine's fresh-start rule), and decides this
  very tick.  Its never-computed gradient is masked out of the push.

At most one leave and one join fire per tick; surplus due events carry to
the next tick (cursor semantics — the Poisson totals are preserved, as
the sweep engines' ``pend_*`` counters).  Victim/joiner selection routes
through the shared :func:`repro.core.barrier_kernel.churn_victim` /
``churn_joiner`` rules, so trainer and simulators cannot silently
diverge; ``tests/test_elastic_equiv.py`` pins the cross-layer semantics
tick-for-tick and ``tests/test_spmd_psp.py`` holds a golden churn trace.
With ``churn=None`` the step consumes the identical RNG stream and
computes bit-for-bit the same numbers as the fixed-worker trainer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.barrier_kernel import (BarrierKernel, BarrierPolicy,
                                       churn_joiner, churn_victim,
                                       make_policy)
from repro.core.barriers import BarrierControl, make_barrier

__all__ = ["ChurnConfig", "PSPConfig", "PSPState", "apply_external_churn",
           "elastic_drive", "external_drive", "linear_psp_state",
           "linear_psp_task", "psp_apply_tick", "psp_init",
           "psp_train_step", "make_psp_step_fn", "state_from_tree",
           "state_to_tree"]

PyTree = Any

_I32_MIN = jnp.iinfo(jnp.int32).min


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Elastic-worker-set (churn) configuration for the SPMD trainer.

    Leave/join events are two independent Poisson processes pre-sampled
    over ``horizon`` virtual seconds at :func:`psp_init` (the schedule
    machinery of :func:`repro.core.vector_sim.sample_churn_schedules`),
    so the jitted train step consumes them as fixed-shape data.  Past the
    horizon the worker set stays frozen at whatever population the
    schedule left behind.
    """

    leave_rate: float = 0.1        # workers leaving per virtual second
    join_rate: float = 0.1         # workers (re)joining per virtual second
    horizon: float = 120.0         # schedule length in virtual seconds
    seed: int = 0                  # schedule RNG seed (independent of init key)


@dataclasses.dataclass(frozen=True)
class PSPConfig:
    """Barrier-control configuration for the SPMD trainer."""

    barrier: str = "pssp"          # bsp|ssp|asp|pbsp|pssp|dssp|ebsp|ap(b|s)sp
    staleness: int = 4             # s (ignored by bsp/asp)
    sample_size: int = 16          # β (ignored by classic barriers)
    n_workers: int = 8             # W — data-parallel worker groups
    # heterogeneity model (virtual seconds per local step)
    base_compute: float = 0.1
    compute_jitter: float = 0.5    # per-step U[1−j/2, 1+j/2] noise
    straggler_frac: float = 0.0
    straggler_slowdown: float = 4.0
    poll_interval: float = 0.02    # blocked-worker re-sample cadence (virtual s)
    #: "mean" (pushing-worker mean), "sum", or "mean-alive" (divide by an
    #: EMA of the alive-worker count — contribution per worker stays
    #: stable when churn shrinks the pushing set; the PR-4 leftover)
    contribution: str = "mean"
    # adaptive-policy knobs (ignored by the five static barriers)
    staleness_lo: int = 0          # DSSP lower search bound r
    sample_size_lo: int = 1        # β-annealing lower bound β_min
    max_advance: int = 4           # Elastic-BSP max run-ahead R
    ema_alpha: float = 0.5         # Elastic-BSP duration-EMA α
    #: elastic worker set: None ⇒ fixed W workers (the pre-elastic trainer,
    #: bit-for-bit); a :class:`ChurnConfig` enables Poisson leave/join churn
    churn: Optional[ChurnConfig] = None

    def make_barrier(self) -> BarrierControl:
        """Instantiate the configured :class:`BarrierControl` policy."""
        return make_barrier(self.barrier, staleness=self.staleness,
                            sample_size=self.sample_size,
                            staleness_lo=self.staleness_lo,
                            sample_size_lo=self.sample_size_lo,
                            max_advance=self.max_advance,
                            ema_alpha=self.ema_alpha)

    @property
    def beta(self) -> int:
        """Effective sample size β (0 for classic/ASP barriers)."""
        b = self.make_barrier()
        return 0 if b.sample_size is None else min(b.sample_size,
                                                   self.n_workers - 1)

    @property
    def effective_staleness(self) -> int:
        """Staleness bound s after barrier-specific defaults apply."""
        b = self.make_barrier()
        return int(b.staleness)

    @property
    def is_classic(self) -> bool:
        """Classic barriers evaluate the full step vector (β = W−1)."""
        return self.barrier in ("bsp", "ssp")

    @property
    def is_asp(self) -> bool:
        """ASP never blocks (the barrier predicate is ⊤)."""
        return self.barrier == "asp"

    @property
    def has_churn(self) -> bool:
        """Whether the elastic churn phase is compiled into the step."""
        return self.churn is not None

    @property
    def barrier_kernel(self) -> BarrierKernel:
        """The unified barrier/straggler model this trainer executes.

        The same :class:`~repro.core.barrier_kernel.BarrierKernel`
        semantics drive the vectorized sweep engine, so trainer and
        simulator cannot silently diverge
        (``tests/test_barrier_kernel.py``).
        """
        return BarrierKernel(barrier=self.barrier,
                             staleness=self.effective_staleness,
                             beta=self.beta)

    @property
    def barrier_policy(self) -> BarrierPolicy:
        """The (possibly stateful) decision policy this trainer executes.

        Static barriers yield a stateless wrapper whose ``decide`` is
        exactly :meth:`barrier_kernel`'s predicate — the pre-policy
        trainer bit-for-bit.  Adaptive names (``dssp`` / ``ebsp`` /
        ``apbsp`` / ``apssp``) yield the stateful policy whose state
        pytree rides in :attr:`PSPState.policy`.
        """
        return make_policy(self.barrier, staleness=self.effective_staleness,
                           beta=self.beta, staleness_lo=self.staleness_lo,
                           beta_lo=self.sample_size_lo,
                           max_advance=self.max_advance,
                           ema_alpha=self.ema_alpha)


class PSPState(NamedTuple):
    """Replicated-or-sharded training state carried across ticks.

    The elastic fields (``alive`` through ``join_cursor``) are carried
    unconditionally so the pytree structure does not depend on the churn
    setting; with ``churn=None`` the mask is all-True and the schedules
    are empty, and the train step compiles to the fixed-worker program.
    """

    server_params: PyTree          # the single server model
    opt_state: PyTree              # optimizer state of the server model
    views: PyTree                  # [W, ...] worker views (stale pulls)
    step: jax.Array                # i32[W] logical step counters
    busy_until: jax.Array          # f32[W] virtual completion times
    pushed: jax.Array              # bool[W] pushed current step's update?
    now: jax.Array                 # f32[] virtual wall clock
    slow: jax.Array                # bool[W] straggler flags (static draw)
    key: jax.Array                 # PRNG key
    tick: jax.Array                # i32[] SPMD tick counter
    total_pushes: jax.Array        # i32[] server update count (Fig 1e)
    # ---- elastic worker set (PSPConfig.churn) ------------------------- #
    alive: jax.Array               # bool[W] current worker membership
    leave_times: jax.Array         # f32[El] pre-sampled leave schedule
    join_times: jax.Array          # f32[Ej] pre-sampled join schedule
    leave_cursor: jax.Array        # i32[] next unconsumed leave event
    join_cursor: jax.Array         # i32[] next unconsumed join event
    #: adaptive barrier-policy state (``cfg.barrier_policy.init``): empty
    #: for the five static barriers, so their pytree — and compiled
    #: program — is unchanged.  ``contribution="mean-alive"`` co-locates
    #: its alive-count EMA here under the ``"denom"`` key (policies pass
    #: unknown keys through untouched).
    policy: PyTree = {}


def _duration(cfg: PSPConfig, key: jax.Array, slow: jax.Array) -> jax.Array:
    """Seeded per-worker duration of one local step (virtual seconds).

    Routed through the unified straggler model
    (:func:`repro.core.barrier_kernel.step_duration`) — the same formula
    the sweep engine's grid tick applies, with the straggler slowdown
    folded into the per-worker base rate.
    """
    w = slow.shape[0]
    base = cfg.base_compute * jnp.where(slow, cfg.straggler_slowdown, 1.0)
    return BarrierKernel.step_duration(jax.random.uniform(key, (w,)), base,
                                       cfg.compute_jitter)


def psp_init(cfg: PSPConfig, params: PyTree, opt_init: Callable[[PyTree], PyTree],
             key: jax.Array) -> PSPState:
    """Build the initial PSP state from server params.

    With ``cfg.churn`` set, the Poisson leave/join schedules are
    pre-sampled here (from ``cfg.churn.seed`` via the shared
    :func:`repro.core.vector_sim.sample_churn_schedules` machinery — a
    numpy-side draw, so the jax init key stream is identical with and
    without churn) and carried in the state as fixed-shape arrays.
    """
    from repro.core.vector_sim import sample_churn_schedules

    w = cfg.n_workers
    views = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (w,) + p.shape),
                         params)
    k_slow, k_dur, k_carry = jax.random.split(key, 3)
    n_slow = int(round(cfg.straggler_frac * w))
    slow = jnp.arange(w) < n_slow  # deterministic placement; permuted below
    slow = jax.random.permutation(k_slow, slow)
    dur = _duration(cfg, k_dur, slow)
    if cfg.has_churn:
        rng = np.random.default_rng(cfg.churn.seed)
        lt, jt = sample_churn_schedules(rng, cfg.churn.leave_rate,
                                        cfg.churn.join_rate,
                                        cfg.churn.horizon)
    else:
        lt = jt = np.empty(0)
    policy = dict(cfg.barrier_policy.init(w))
    if cfg.contribution == "mean-alive":
        policy["denom"] = jnp.asarray(float(w), jnp.float32)
    return PSPState(
        server_params=params,
        opt_state=opt_init(params),
        views=views,
        step=jnp.zeros((w,), jnp.int32),
        busy_until=dur,
        pushed=jnp.zeros((w,), bool),
        now=jnp.zeros((), jnp.float32),
        slow=slow,
        key=k_carry,
        tick=jnp.zeros((), jnp.int32),
        total_pushes=jnp.zeros((), jnp.int32),
        alive=jnp.ones((w,), bool),
        leave_times=jnp.asarray(lt, jnp.float32),
        join_times=jnp.asarray(jt, jnp.float32),
        leave_cursor=jnp.zeros((), jnp.int32),
        join_cursor=jnp.zeros((), jnp.int32),
        policy=policy,
    )


def _barrier_allowed(cfg: PSPConfig, key: jax.Array, step: jax.Array,
                     alive: Optional[jax.Array] = None) -> jax.Array:
    """bool[W]: may each worker start its next step, per the barrier?

    Delegates to the unified barrier model
    (:meth:`PSPConfig.barrier_kernel`): full-view masked-min for BSP/SSP,
    a β-sample through the shared sampling primitive for pBSP/pSSP, ⊤ for
    ASP — exactly the predicate the sweep engine's fused tick evaluates.
    Under churn, ``alive`` masks both the full-view minimum and the
    β-sample pool (``sample_alive_peer_indices_jax``): departed workers'
    frozen counters never gate waiters, and samples draw alive peers only.
    """
    return cfg.barrier_kernel.allowed(key, step, alive)


def _schedule_due(times: jax.Array, cursor: jax.Array,
                  now: jax.Array) -> jax.Array:
    """bool[]: is the next unconsumed schedule event at or before ``now``?"""
    n = times.shape[0]
    if n == 0:
        return jnp.zeros((), bool)
    return (cursor < n) & (times[jnp.minimum(cursor, n - 1)] <= now)


def _membership_update(state: PSPState, leave_sel: jax.Array,
                       join_sel: jax.Array) -> PSPState:
    """Apply membership-change masks to the state (the churn kernel).

    ``leave_sel`` / ``join_sel`` are bool[W] selections of workers leaving
    and (re)joining *this instant*.  Leavers' counters freeze where they
    are.  Joiners follow the engines' fresh-start rule: they are
    re-anchored with a fresh pull of the server model, restart at the max
    alive step (evaluated after both masks land, so a rejoining
    front-runner's own frozen counter participates — the
    :func:`_fire_churn` ordering, preserved bit-for-bit), become
    completed (``busy_until = now``) so they decide this very tick, and
    have ``pushed`` set so a gradient computed while dead can never land.

    This is the single definition of "what a leave/join does to trainer
    state": the schedule-driven Poisson phase (:func:`_fire_churn`) and
    the process-driven cluster harness (:func:`apply_external_churn`)
    both route through it, so simulated and real churn cannot silently
    diverge.  Cursor bookkeeping is the caller's job.
    """
    alive = (state.alive & ~leave_sel) | join_sel
    fresh = jnp.max(jnp.where(alive, state.step, _I32_MIN))
    step = jnp.where(join_sel, fresh, state.step)

    def _reanchor(view, p):
        m = join_sel.reshape((-1,) + (1,) * p.ndim)
        return jnp.where(m, p[None], view)

    return state._replace(
        views=jax.tree.map(_reanchor, state.views, state.server_params),
        step=step,
        busy_until=jnp.where(join_sel, state.now, state.busy_until),
        pushed=state.pushed | join_sel,
        alive=alive,
    )


def _fire_churn(cfg: PSPConfig, state: PSPState,
                k_churn: jax.Array) -> PSPState:
    """Phase 0 of an elastic tick: fire due leave/join events (≤ 1 each).

    Semantics follow the sweep engines' churn rules (pinned by
    ``tests/test_elastic_equiv.py``): a leave kills a uniformly random
    alive worker only while more than two are alive, a join revives a
    uniformly random departed slot at the current max alive step and lets
    it decide this tick.  Due events are consumed (cursor advances) even
    when the population guard skips the effect — Poisson totals are
    preserved; several same-tick events drain one per tick, the fused
    tick's ``pend_*`` carry rule (the numpy grid engine instead drains
    same-tick surpluses within the tick — a timing difference of rare
    multi-event ticks, not a protocol difference).  The membership
    effect itself (joiner fresh-start/re-anchor/push-mask semantics)
    lives in :func:`_membership_update`; this phase only decides *who*.
    """
    w = cfg.n_workers
    iota = jnp.arange(w)
    k_leave, k_join = jax.random.split(k_churn)
    alive = state.alive

    # leave: kill a uniformly random alive worker (population floor: 2)
    due_l = _schedule_due(state.leave_times, state.leave_cursor, state.now)
    do_l = due_l & (jnp.sum(alive) > 2)
    victim = churn_victim(jax.random.uniform(k_leave, (w,)), alive)
    leave_sel = do_l & (iota == victim)
    alive = alive & ~leave_sel

    # join: revive a uniformly random departed slot, fresh-started
    due_j = _schedule_due(state.join_times, state.join_cursor, state.now)
    do_j = due_j & jnp.any(~alive)
    joiner = churn_joiner(jax.random.uniform(k_join, (w,)), alive)
    join_sel = do_j & (iota == joiner)

    state = _membership_update(state, leave_sel, join_sel)
    return state._replace(
        leave_cursor=state.leave_cursor + due_l.astype(jnp.int32),
        join_cursor=state.join_cursor + due_j.astype(jnp.int32),
    )


def apply_external_churn(cfg: PSPConfig, state: PSPState, *,
                         leave: Tuple[int, ...] = (),
                         join: Tuple[int, ...] = ()) -> PSPState:
    """Apply *observed* membership changes (real process churn) to state.

    The cluster harness (:mod:`repro.launch.cluster`) maps actual worker
    deaths and rejoins onto the elastic trainer's alive-mask machinery
    through this function: a SIGKILLed worker is a ``leave``, a respawned
    worker that restored the latest snapshot is a ``join``.  Both apply
    the exact :func:`_membership_update` kernel the Poisson churn phase
    fires, so a real death behaves bit-for-bit like a scheduled one.

    Unlike :func:`_fire_churn` there is no population floor and no
    one-event-per-tick drain: real deaths are observed facts, not
    schedule draws, and a correlated rack-level kill takes several
    workers in one call.  Leaving an already-dead worker and joining an
    already-alive one are no-ops (idempotent re-application).  The churn
    RNG stream is untouched — this is host-driven, between ticks, and
    composes with ``churn=None`` configs (the cluster's case).
    """
    w = cfg.n_workers
    alive = np.asarray(state.alive)
    leave_sel = np.zeros(w, bool)
    for i in leave:
        leave_sel[int(i)] = True
    leave_sel &= alive                       # no-op on dead workers
    join_sel = np.zeros(w, bool)
    for i in join:
        join_sel[int(i)] = True
    join_sel &= ~(alive & ~leave_sel)        # no-op on alive workers
    if not leave_sel.any() and not join_sel.any():
        return state
    return _membership_update(state, jnp.asarray(leave_sel),
                              jnp.asarray(join_sel))


def psp_apply_tick(
    cfg: PSPConfig,
    opt_update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]],
    state: PSPState,
    compute: Callable[[PSPState], Tuple[jax.Array, PyTree]],
) -> Tuple[PSPState, dict]:
    """One SPMD tick of PSP, with the gradient source abstracted out.

    ``compute(state) -> (losses, grads)`` supplies the f32[W] losses and
    [W, ...] gradient pytree, evaluated *after* the churn phase (so a
    same-tick joiner's gradient comes from its re-anchored view, as it
    always did).  :func:`psp_train_step` passes the vmapped in-process
    ``grad_fn``; the multi-process cluster coordinator
    (:mod:`repro.launch.cluster`) passes the gradients its worker
    subprocesses pushed over the bus (zeros in non-pushing rows — the
    push mask discards those columns identically either way, which is
    what makes the cluster bit-exact against the in-process trainer).

    Returns: (new_state, metrics)
    """
    if cfg.has_churn:
        # (0) elastic churn phase: fire due pre-sampled leave/join events.
        # The extra key split is compiled in only when churn is enabled,
        # so the churn=None RNG stream is identical to the fixed-worker
        # trainer (bit-for-bit on golden/regression tests).
        key, k_bar, k_dur, k_churn = jax.random.split(state.key, 4)
        state = _fire_churn(cfg, state, k_churn)
    else:
        key, k_bar, k_dur = jax.random.split(state.key, 3)
    alive = state.alive

    # (1) every worker computes on its own (possibly stale) view
    losses, grads = compute(state)

    # (2) completions push to the server; departed workers are masked out
    # of the psum — zero gradient, zero bytes
    completed = state.busy_until <= state.now
    push_mask = completed & ~state.pushed & alive
    denom = jnp.maximum(jnp.sum(push_mask), 1)
    if cfg.contribution == "mean-alive":
        # churn-aware scaling: divide by the carried alive-count EMA, not
        # by this tick's pushing-set size — per-worker contribution stays
        # stable as churn shrinks/grows the population.  Reads the OLD
        # state (the EMA update lands below with the policy state).
        scale = 1.0 / jnp.maximum(state.policy["denom"], 1.0)
    else:
        scale = jnp.where(cfg.contribution == "mean", 1.0 / denom, 1.0)

    def _masked_sum(g):
        m = push_mask.reshape((-1,) + (1,) * (g.ndim - 1))
        return jnp.sum(jnp.where(m, g, 0), axis=0) * scale

    server_grad = jax.tree.map(_masked_sum, grads)
    any_push = jnp.any(push_mask)
    updates, new_opt = opt_update(server_grad, state.opt_state,
                                  state.server_params)
    new_params = jax.tree.map(
        lambda p, u: jnp.where(any_push, p + u, p),
        state.server_params, updates)
    new_opt = jax.tree.map(
        lambda new, old: jnp.where(any_push, new, old), new_opt,
        state.opt_state)
    pushed = state.pushed | push_mask

    # (3) barrier: completed alive workers try to start their next step.
    # The next-step duration is drawn *before* the decide so adaptive
    # policies (Elastic-BSP's duration EMA) can observe it; k_dur and
    # k_bar are independent splits of the same parent key, so hoisting
    # the draw leaves every RNG stream bit-identical.  For static
    # barriers ``decide`` is exactly the old ``_barrier_allowed``
    # predicate and passes the (empty) policy state through.
    next_dur = _duration(cfg, k_dur, state.slow)
    allowed, new_policy = cfg.barrier_policy.decide(
        state.policy, k_bar, state.step, next_dur,
        alive if cfg.has_churn else None)
    allowed = allowed & completed & alive
    new_step = state.step + allowed.astype(jnp.int32)
    new_busy = jnp.where(allowed, state.now + next_dur, state.busy_until)
    new_pushed = jnp.where(allowed, False, pushed)

    def _pull(view, p):
        m = allowed.reshape((-1,) + (1,) * p.ndim)
        return jnp.where(m, p[None], view)

    new_views = jax.tree.map(_pull, state.views, new_params)

    if cfg.contribution == "mean-alive":
        new_policy = dict(new_policy)
        new_policy["denom"] = (0.9 * state.policy["denom"]
                               + 0.1 * jnp.sum(alive).astype(jnp.float32))

    # (4) event-driven virtual-time advance: jump to the earlier of (a) the
    # next completion of a still-busy alive worker, (b) the next poll of a
    # barrier-blocked worker (the paper's "holds until condition is
    # satisfied" — re-sampling costs a poll interval of virtual time).
    # Departed workers' frozen clocks never hold time back; with at least
    # two alive workers every tick either has someone busy or someone
    # polling, so the clock always advances and pending joins fire.
    blocked = completed & ~allowed & alive
    next_busy = jnp.min(jnp.where((new_busy > state.now) & alive, new_busy,
                                  jnp.inf))
    next_poll = jnp.where(jnp.any(blocked),
                          state.now + cfg.poll_interval, jnp.inf)
    next_time = jnp.minimum(next_busy, next_poll)
    new_now = jnp.where(jnp.isfinite(next_time),
                        jnp.maximum(state.now, next_time), state.now)

    new_state = state._replace(
        server_params=new_params,
        opt_state=new_opt,
        views=new_views,
        step=new_step,
        busy_until=new_busy,
        pushed=new_pushed,
        now=new_now,
        key=key,
        tick=state.tick + 1,
        total_pushes=state.total_pushes + jnp.sum(push_mask),
        policy=new_policy,
    )
    if cfg.has_churn:
        # progress statistics over the *current* worker set only — a
        # departed straggler's frozen counter is not progress
        n_alive = jnp.maximum(jnp.sum(alive), 1)
        mean_step = (jnp.sum(jnp.where(alive, new_step, 0))
                     / n_alive.astype(jnp.float32))
        alive_steps_max = jnp.max(jnp.where(alive, new_step, _I32_MIN))
        alive_steps_min = jnp.min(
            jnp.where(alive, new_step, jnp.iinfo(jnp.int32).max))
        step_spread = alive_steps_max - alive_steps_min
    else:
        mean_step = jnp.mean(new_step.astype(jnp.float32))
        step_spread = jnp.max(new_step) - jnp.min(new_step)
    metrics = {
        # pushed-worker mean; falls back to the all-worker mean on ticks
        # where nobody completed (avoids misleading 0.0 readouts)
        "loss": jnp.where(any_push,
                          jnp.sum(jnp.where(push_mask, losses, 0)) / denom,
                          jnp.mean(losses)),
        "pushes": jnp.sum(push_mask),
        "allowed": jnp.sum(allowed),
        "blocked": jnp.sum(blocked),
        "alive": jnp.sum(alive),
        "mean_step": mean_step,
        "step_spread": step_spread,
        "virtual_time": new_now,
    }
    return new_state, metrics


def psp_train_step(
    cfg: PSPConfig,
    grad_fn: Callable[[PyTree, PyTree], Tuple[jax.Array, PyTree]],
    opt_update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]],
    state: PSPState,
    batch: PyTree,
) -> Tuple[PSPState, dict]:
    """One SPMD tick of PSP training (in-process gradients).

    Args:
      cfg: barrier configuration (static).
      grad_fn: ``(params, microbatch) -> (loss, grads)`` for ONE worker;
        vmapped over the leading W axis of ``state.views`` / ``batch``.
      opt_update: ``(grads, opt_state, params) -> (updates, new_opt_state)``.
      state: carried :class:`PSPState`.
      batch: pytree with leading axis W (per-worker microbatches).

    A thin wrapper over :func:`psp_apply_tick` that computes the
    gradients in-process by vmapping ``grad_fn`` over the worker views —
    pure code motion from the pre-cluster trainer, so every golden trace
    and RNG stream is bit-identical.

    Returns: (new_state, metrics)
    """
    return psp_apply_tick(cfg, opt_update, state,
                          lambda st: jax.vmap(grad_fn)(st.views, batch))


def state_to_tree(state: PSPState) -> dict:
    """The checkpointable pytree of the FULL training state.

    A plain field-name → value dict (``NamedTuple._asdict``), so the
    archive keys read ``server_params/...``, ``opt_state/...``, ``step``,
    ``key`` … — every leaf the trainer carries, including the optimizer
    state, worker views, step/busy/pushed/alive arrays, churn schedules
    and cursors, the adaptive-policy pytree and the RNG key.  Persisting
    this tree (not just ``server_params``) is what makes kill-and-resume
    bit-exact: restoring it and replaying the same minibatch stream
    reproduces the uninterrupted run's numbers leaf for leaf.
    """
    return state._asdict()


def state_from_tree(tree: dict) -> PSPState:
    """Inverse of :func:`state_to_tree` (e.g. on a restored checkpoint)."""
    return PSPState(**tree)


def make_psp_step_fn(cfg: PSPConfig, grad_fn, opt_update):
    """Convenience: partially-applied, jit-ready step function."""
    return functools.partial(psp_train_step, cfg, grad_fn, opt_update)


def linear_psp_task(dim: int, lr: float = 0.1, seed: int = 0):
    """The paper's linear-regression task, packaged for this trainer.

    One definition serves every consumer that trains the trainer on the
    paper's evaluation workload — the churn benchmark
    (:mod:`benchmarks.churn_bench`), the elastic demo
    (``examples/elastic_train.py``) and the trainer/equivalence test
    suites — so "which task do the elastic numbers measure" has exactly
    one answer.

    Returns:
      (w_true, grad_fn, opt_update): the ground-truth vector f32[dim], a
      per-worker ``(params, (x, y)) -> (loss, grads)`` for params pytree
      ``{"w": f32[dim]}``, and a plain-SGD ``opt_update`` with step size
      ``lr``.
    """
    w_true = jax.random.normal(jax.random.PRNGKey(seed), (dim,)) \
        / np.sqrt(dim)

    def grad_fn(params, batch):
        x, y = batch
        return jax.value_and_grad(
            lambda p: jnp.mean((x @ p["w"] - y) ** 2))(params)

    def opt_update(g, s, p):
        return jax.tree.map(lambda gi: -lr * gi, g), s

    return w_true, grad_fn, opt_update


def linear_psp_state(cfg: PSPConfig, dim: int,
                     init_seed: int = 1) -> PSPState:
    """The initial :class:`PSPState` of :func:`elastic_drive`'s run.

    Exposed separately because it doubles as the *restore template*: a
    checkpoint written mid-drive restores into this state's structure
    (same shapes/dtypes by construction), which is how the elastic demo
    and the resume tests rebuild a killed run.
    """
    return psp_init(cfg, {"w": jnp.zeros((dim,))}, lambda p: None,
                    jax.random.PRNGKey(init_seed))


def elastic_drive(cfg: PSPConfig, dim: int, ticks: int, *, batch: int = 16,
                  lr: float = 0.1, task_seed: int = 0, init_seed: int = 1,
                  batch_seed: int = 2, state: Optional[PSPState] = None,
                  start_tick: int = 0):
    """Drive the trainer on the linear task; the canonical tick loop.

    One definition of "init the trainer, jit the step, feed random
    minibatches for N ticks" shared by the churn benchmark
    (:mod:`benchmarks.churn_bench`), the elastic demo
    (``examples/elastic_train.py``) and the trainer test suites, so their
    trajectories are the same run by construction (the golden churn trace
    pins this loop's exact RNG consumption).

    Resume: pass a restored ``state`` plus the ``start_tick`` it was
    checkpointed at and the drive fast-forwards the minibatch key stream
    (``start_tick`` splits, no data materialized) before continuing —
    ticks ``start_tick..ticks-1`` then consume exactly the keys the
    uninterrupted run would have, so the resumed trajectory is
    bit-identical (``tests/test_checkpoint.py``).

    Returns:
      (w_true, it): the task ground truth and an iterator yielding one
      ``(state, metrics)`` pair per tick (the state *after* that tick).
    """
    w_true, grad_fn, opt_update = linear_psp_task(dim, lr=lr, seed=task_seed)
    if state is None:
        state = linear_psp_state(cfg, dim, init_seed)
    step = jax.jit(make_psp_step_fn(cfg, grad_fn, opt_update))

    def _ticks(state, kb):
        for _ in range(start_tick):          # replay the consumed key stream
            kb, _ = jax.random.split(kb)
        for _ in range(start_tick, ticks):
            kb, k1 = jax.random.split(kb)
            x = jax.random.normal(k1, (cfg.n_workers, batch, dim))
            state, m = step(state, (x, x @ w_true))
            yield state, m

    return w_true, _ticks(state, jax.random.PRNGKey(batch_seed))


def external_drive(cfg: PSPConfig, dim: int, ticks: int,
                   events: dict, *, batch: int = 16, lr: float = 0.1,
                   task_seed: int = 0, init_seed: int = 1,
                   batch_seed: int = 2):
    """:func:`elastic_drive` with an *explicit* leave/join schedule.

    ``events`` maps ``tick -> (leave_ids, join_ids)``; each entry is
    applied via :func:`apply_external_churn` immediately before that
    tick's train step, exactly where the cluster coordinator applies
    observed process churn.  With ``cfg.churn=None`` this is the
    single-process reference for a multi-process cluster run: replaying
    the cluster's recorded membership events here must reproduce the
    cluster's server params bit-for-bit (same alive trajectory, same RNG
    stream, same pushes — ``tests/test_cluster_faults.py`` pins it).

    Returns:
      (w_true, it): ground truth and a per-tick ``(state, metrics)``
      iterator, mirroring :func:`elastic_drive`.
    """
    w_true, grad_fn, opt_update = linear_psp_task(dim, lr=lr, seed=task_seed)
    state = linear_psp_state(cfg, dim, init_seed)
    step = jax.jit(make_psp_step_fn(cfg, grad_fn, opt_update))

    def _ticks(state, kb):
        for t in range(ticks):
            if t in events:
                leave, join = events[t]
                state = apply_external_churn(cfg, state, leave=tuple(leave),
                                             join=tuple(join))
            kb, k1 = jax.random.split(kb)
            x = jax.random.normal(k1, (cfg.n_workers, batch, dim))
            state, m = step(state, (x, x @ w_true))
            yield state, m

    return w_true, _ticks(state, jax.random.PRNGKey(batch_seed))
