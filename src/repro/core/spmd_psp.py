"""TPU-native PSP: barrier control as a first-class SPMD training feature.

The paper's deployment model (WAN actors) does not exist on a TPU pod — an
SPMD program is lockstep by construction.  What transfers is the *semantics*:
workers at heterogeneous speeds, a server model updated by possibly-stale
pushes, and a barrier predicate (evaluated on a β-sample of step counters)
gating when each worker may start its next step.

This module implements those semantics as a single jittable train step
(`lax`-only control flow), so one SPMD program faithfully executes
BSP / SSP / ASP / pBSP / pSSP and their convergence-vs-virtual-wall-clock
trade-offs can be measured on real models — and so the PSP logic itself is
visible to the multi-pod dry-run and the roofline pipeline.

Mapping (DESIGN.md §3/§4):

* **worker** = a data-parallel shard group (the ``data`` mesh axis carries the
  worker dimension W; the ``model`` axis shards each worker's compute).  In a
  multi-pod mesh a worker is a (pod, data-row) pair.
* **server model** = one replicated parameter pytree, updated by masked
  contributions (`psum` over the worker axis is the only cross-worker
  collective — identical schedule to plain DP, so PSP adds *zero* extra
  collective bytes on the data plane; the control plane is a W-length i32
  vector).
* **worker view** = each worker's stale pull of the server model (leading W
  axis sharded over ``data``), updated by a masked "pull" when the worker
  passes the barrier.  This reproduces read-my-writes staleness exactly.
* **virtual clock** = seeded per-worker step durations (heterogeneity +
  straggler injection, reproducing Fig 2 on-device).  Time advances
  event-style to the next completion.

The per-tick protocol (one call of :func:`psp_train_step`):

  1. every worker computes a gradient on **its own view** (SPMD always
     computes; masks decide what lands),
  2. workers whose virtual clock completed *push*: the server applies the
     masked sum of their gradients through the optimizer,
  3. completed workers evaluate the barrier on a β-sample of the step
     vector; those allowed *pull* the fresh server model, bump their step,
     and draw the duration of their next local step; blocked workers hold
     (they re-sample next tick — the paper's "holds until condition is
     satisfied").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.barrier_kernel import BarrierKernel
from repro.core.barriers import BarrierControl, make_barrier

__all__ = ["PSPConfig", "PSPState", "psp_init", "psp_train_step",
           "make_psp_step_fn"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class PSPConfig:
    """Barrier-control configuration for the SPMD trainer."""

    barrier: str = "pssp"          # bsp | ssp | asp | pbsp | pssp
    staleness: int = 4             # s (ignored by bsp/asp)
    sample_size: int = 16          # β (ignored by classic barriers)
    n_workers: int = 8             # W — data-parallel worker groups
    # heterogeneity model (virtual seconds per local step)
    base_compute: float = 0.1
    compute_jitter: float = 0.5    # per-step U[1−j/2, 1+j/2] noise
    straggler_frac: float = 0.0
    straggler_slowdown: float = 4.0
    poll_interval: float = 0.02    # blocked-worker re-sample cadence (virtual s)
    contribution: str = "mean"     # "mean" | "sum" over pushing workers

    def make_barrier(self) -> BarrierControl:
        """Instantiate the configured :class:`BarrierControl` policy."""
        return make_barrier(self.barrier, staleness=self.staleness,
                            sample_size=self.sample_size)

    @property
    def beta(self) -> int:
        """Effective sample size β (0 for classic/ASP barriers)."""
        b = self.make_barrier()
        return 0 if b.sample_size is None else min(b.sample_size,
                                                   self.n_workers - 1)

    @property
    def effective_staleness(self) -> int:
        """Staleness bound s after barrier-specific defaults apply."""
        b = self.make_barrier()
        return int(b.staleness)

    @property
    def is_classic(self) -> bool:
        """Classic barriers evaluate the full step vector (β = W−1)."""
        return self.barrier in ("bsp", "ssp")

    @property
    def is_asp(self) -> bool:
        """ASP never blocks (the barrier predicate is ⊤)."""
        return self.barrier == "asp"

    @property
    def barrier_kernel(self) -> BarrierKernel:
        """The unified barrier/straggler model this trainer executes.

        The same :class:`~repro.core.barrier_kernel.BarrierKernel`
        semantics drive the vectorized sweep engine, so trainer and
        simulator cannot silently diverge
        (``tests/test_barrier_kernel.py``).
        """
        return BarrierKernel(barrier=self.barrier,
                             staleness=self.effective_staleness,
                             beta=self.beta)


class PSPState(NamedTuple):
    """Replicated-or-sharded training state carried across ticks."""

    server_params: PyTree          # the single server model
    opt_state: PyTree              # optimizer state of the server model
    views: PyTree                  # [W, ...] worker views (stale pulls)
    step: jax.Array                # i32[W] logical step counters
    busy_until: jax.Array          # f32[W] virtual completion times
    pushed: jax.Array              # bool[W] pushed current step's update?
    now: jax.Array                 # f32[] virtual wall clock
    slow: jax.Array                # bool[W] straggler flags (static draw)
    key: jax.Array                 # PRNG key
    tick: jax.Array                # i32[] SPMD tick counter
    total_pushes: jax.Array        # i32[] server update count (Fig 1e)


def _duration(cfg: PSPConfig, key: jax.Array, slow: jax.Array) -> jax.Array:
    """Seeded per-worker duration of one local step (virtual seconds).

    Routed through the unified straggler model
    (:func:`repro.core.barrier_kernel.step_duration`) — the same formula
    the sweep engine's grid tick applies, with the straggler slowdown
    folded into the per-worker base rate.
    """
    w = slow.shape[0]
    base = cfg.base_compute * jnp.where(slow, cfg.straggler_slowdown, 1.0)
    return BarrierKernel.step_duration(jax.random.uniform(key, (w,)), base,
                                       cfg.compute_jitter)


def psp_init(cfg: PSPConfig, params: PyTree, opt_init: Callable[[PyTree], PyTree],
             key: jax.Array) -> PSPState:
    """Build the initial PSP state from server params."""
    w = cfg.n_workers
    views = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (w,) + p.shape),
                         params)
    k_slow, k_dur, k_carry = jax.random.split(key, 3)
    n_slow = int(round(cfg.straggler_frac * w))
    slow = jnp.arange(w) < n_slow  # deterministic placement; permuted below
    slow = jax.random.permutation(k_slow, slow)
    dur = _duration(cfg, k_dur, slow)
    return PSPState(
        server_params=params,
        opt_state=opt_init(params),
        views=views,
        step=jnp.zeros((w,), jnp.int32),
        busy_until=dur,
        pushed=jnp.zeros((w,), bool),
        now=jnp.zeros((), jnp.float32),
        slow=slow,
        key=k_carry,
        tick=jnp.zeros((), jnp.int32),
        total_pushes=jnp.zeros((), jnp.int32),
    )


def _barrier_allowed(cfg: PSPConfig, key: jax.Array, step: jax.Array
                     ) -> jax.Array:
    """bool[W]: may each worker start its next step, per the barrier?

    Delegates to the unified barrier model
    (:meth:`PSPConfig.barrier_kernel`): full-view masked-min for BSP/SSP,
    a β-sample through the shared sampling primitive for pBSP/pSSP, ⊤ for
    ASP — exactly the predicate the sweep engine's fused tick evaluates.
    """
    return cfg.barrier_kernel.allowed(key, step)


def psp_train_step(
    cfg: PSPConfig,
    grad_fn: Callable[[PyTree, PyTree], Tuple[jax.Array, PyTree]],
    opt_update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]],
    state: PSPState,
    batch: PyTree,
) -> Tuple[PSPState, dict]:
    """One SPMD tick of PSP training.

    Args:
      cfg: barrier configuration (static).
      grad_fn: ``(params, microbatch) -> (loss, grads)`` for ONE worker;
        vmapped over the leading W axis of ``state.views`` / ``batch``.
      opt_update: ``(grads, opt_state, params) -> (updates, new_opt_state)``.
      state: carried :class:`PSPState`.
      batch: pytree with leading axis W (per-worker microbatches).

    Returns: (new_state, metrics)
    """
    key, k_bar, k_dur = jax.random.split(state.key, 3)

    # (1) every worker computes on its own (possibly stale) view
    losses, grads = jax.vmap(grad_fn)(state.views, batch)

    # (2) completions push to the server
    completed = state.busy_until <= state.now
    push_mask = completed & ~state.pushed
    denom = jnp.maximum(jnp.sum(push_mask), 1)
    scale = jnp.where(cfg.contribution == "mean", 1.0 / denom, 1.0)

    def _masked_sum(g):
        m = push_mask.reshape((-1,) + (1,) * (g.ndim - 1))
        return jnp.sum(jnp.where(m, g, 0), axis=0) * scale

    server_grad = jax.tree.map(_masked_sum, grads)
    any_push = jnp.any(push_mask)
    updates, new_opt = opt_update(server_grad, state.opt_state,
                                  state.server_params)
    new_params = jax.tree.map(
        lambda p, u: jnp.where(any_push, p + u, p),
        state.server_params, updates)
    new_opt = jax.tree.map(
        lambda new, old: jnp.where(any_push, new, old), new_opt,
        state.opt_state)
    pushed = state.pushed | push_mask

    # (3) barrier: completed workers try to start their next step
    allowed = _barrier_allowed(cfg, k_bar, state.step) & completed
    new_step = state.step + allowed.astype(jnp.int32)
    next_dur = _duration(cfg, k_dur, state.slow)
    new_busy = jnp.where(allowed, state.now + next_dur, state.busy_until)
    new_pushed = jnp.where(allowed, False, pushed)

    def _pull(view, p):
        m = allowed.reshape((-1,) + (1,) * p.ndim)
        return jnp.where(m, p[None], view)

    new_views = jax.tree.map(_pull, state.views, new_params)

    # (4) event-driven virtual-time advance: jump to the earlier of (a) the
    # next completion of a still-busy worker, (b) the next poll of a
    # barrier-blocked worker (the paper's "holds until condition is
    # satisfied" — re-sampling costs a poll interval of virtual time).
    blocked = completed & ~allowed
    next_busy = jnp.min(jnp.where(new_busy > state.now, new_busy, jnp.inf))
    next_poll = jnp.where(jnp.any(blocked),
                          state.now + cfg.poll_interval, jnp.inf)
    next_time = jnp.minimum(next_busy, next_poll)
    new_now = jnp.where(jnp.isfinite(next_time),
                        jnp.maximum(state.now, next_time), state.now)

    new_state = PSPState(
        server_params=new_params,
        opt_state=new_opt,
        views=new_views,
        step=new_step,
        busy_until=new_busy,
        pushed=new_pushed,
        now=new_now,
        slow=state.slow,
        key=key,
        tick=state.tick + 1,
        total_pushes=state.total_pushes + jnp.sum(push_mask),
    )
    metrics = {
        # pushed-worker mean; falls back to the all-worker mean on ticks
        # where nobody completed (avoids misleading 0.0 readouts)
        "loss": jnp.where(any_push,
                          jnp.sum(jnp.where(push_mask, losses, 0)) / denom,
                          jnp.mean(losses)),
        "pushes": jnp.sum(push_mask),
        "allowed": jnp.sum(allowed),
        "blocked": jnp.sum(blocked),
        "mean_step": jnp.mean(new_step.astype(jnp.float32)),
        "step_spread": (jnp.max(new_step) - jnp.min(new_step)),
        "virtual_time": new_now,
    }
    return new_state, metrics


def make_psp_step_fn(cfg: PSPConfig, grad_fn, opt_update):
    """Convenience: partially-applied, jit-ready step function."""
    return functools.partial(psp_train_step, cfg, grad_fn, opt_update)
