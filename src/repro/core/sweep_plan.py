"""Execution planner for the jax sweep backend's chunked, sharded scans.

The jax grid engine (:mod:`repro.core.vector_sim_jax`) no longer runs one
monolithic ``lax.scan`` over the whole tick grid.  It runs a sequence of
donated *chunk* scans, each advancing a block of **superticks** (``stride``
grid ticks drawn and executed together, one trace record emitted per
supertick), sharded over a 1-D device mesh on the scenario (B) dimension.
This module is the single place where the three free parameters of that
execution are chosen, so the engine itself stays policy-free:

``stride`` — ticks per trace record / noise-draw block
    Traces are only *consumed* on the measurement grid, so recording them
    every tick wastes output bandwidth; and drawing each tick's noise in
    its own tiny ``jax.random`` call wastes RNG dispatch.  The stride is
    the largest divisor of the measurement cadence (every measurement
    index must land exactly on a record) whose per-supertick noise block
    still fits the memory budget — churn/ragged batches carry per-row
    ``P × P`` score matrices, which caps the stride long before the
    no-churn fast path does.

``chunks`` — binary decomposition of the supertick count
    Each chunk length compiles once (the jit cache is keyed on it) and
    pow2 lengths recur across sweeps, so the schedule is the greedy
    binary decomposition of the supertick count, largest block first:
    40 records → 32 + 8.  The last block never over-runs the grid —
    remainder ticks below one stride are padded with *dead* ticks that
    every row ignores (their time lies beyond all row horizons), and a
    chunk whose every row is already past its horizon is skipped by the
    caller's all-rows-done early exit (merged rows may have *different*
    horizons — see :func:`repro.core.vector_sim._merge_key`).

``n_devices`` / padding — mesh placement of the scenario rows
    The B dimension is sharded over a 1-D mesh (rows are independent;
    per-row noise is keyed by *global* row id and shared noise by global
    node id, so results are bit-identical for every mesh size — the
    degenerate 1-device mesh IS the single-device engine).  Rows pad up
    to a multiple of the mesh so each device owns an equal block; padded
    rows carry a negative horizon and never tick.  Node-keyed shared
    draws (the minibatch blob) are likewise split over the mesh and
    all-gathered, so RNG cost shards with the rows.

Env overrides (all optional, for tests and benchmarks):

=====================  ==================================================
``PSP_SWEEP_DEVICES``  mesh size (default: every local device)
``PSP_TRACE_STRIDE``   force the record stride (still snapped to a
                       divisor of the measurement cadence)
``PSP_SWEEP_CHUNK``    force a uniform chunk length in records
=====================  ==================================================
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["SweepPlan", "plan_sweep"]

#: per-supertick noise-block budget (bytes); caps the stride for batches
#: whose per-row score matrices scale with B·P²
_NOISE_BUDGET = 64 << 20

#: chunks smaller than this are not worth their compile (records)
_MIN_CHUNK = 1


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """One sweep's execution schedule (see module docstring)."""

    stride: int                 #: grid ticks per trace record
    n_rec: int                  #: scheduled records (covers the padded grid)
    n_rec_live: int             #: records containing at least one live tick
    chunks: Tuple[int, ...]     #: record-block lengths, in execution order
    n_devices: int              #: 1-D mesh size over the B dimension
    b_pad: int                  #: scenario rows after mesh padding
    node_pad: int               #: node-keyed draw slots after mesh padding

    @property
    def n_ticks(self) -> int:
        """Padded tick-grid length (``n_rec × stride``)."""
        return self.n_rec * self.stride


def _record_stride(n_ticks: int, measure_idx: np.ndarray,
                   noise_bytes_per_tick: int) -> int:
    """Largest stride aligning every measurement index on a record.

    A stride ``s`` records states after global ticks ``s−1, 2s−1, …``; a
    measurement landing on tick index ``m`` is representable iff
    ``s | (m + 1)``, so the admissible strides are exactly the divisors
    of ``gcd{m + 1}`` — and the full grid must land on a record too,
    else the final state would be cut short, so ``n_ticks`` joins the
    gcd.  Among those, take the largest whose supertick noise block
    stays under the budget (``PSP_TRACE_STRIDE`` forces a candidate,
    snapped down to the nearest admissible divisor).
    """
    vals = np.concatenate([measure_idx + 1, [n_ticks]])
    q = int(np.gcd.reduce(vals.astype(np.int64)))
    cap = max(1, _NOISE_BUDGET // max(noise_bytes_per_tick, 1))
    forced = os.environ.get("PSP_TRACE_STRIDE")
    if forced:
        cap = min(cap, max(1, int(forced)))
    best = 1
    for s in range(1, int(math.isqrt(q)) + 1):
        if q % s == 0:
            for cand in (s, q // s):
                if cand <= cap:
                    best = max(best, cand)
    return best


def _binary_chunks(n_rec: int) -> Tuple[int, ...]:
    """Greedy pow2 decomposition of the record count, largest first.

    Pow2 block lengths recur across sweeps of the same structural shape,
    so every block of the schedule hits the jit cache after its first
    compile; the decomposition is exact (no dead records beyond the
    sub-stride grid padding).  ``PSP_SWEEP_CHUNK`` forces a uniform
    length instead — the tail chunk is then *scheduled* past the live
    records and the runner's early exit skips it once every row is done.
    """
    forced = os.environ.get("PSP_SWEEP_CHUNK")
    if forced:
        c = max(1, int(forced))
        return tuple([c] * math.ceil(n_rec / c))
    out, left = [], n_rec
    while left > 0:
        block = 1 << (left.bit_length() - 1)
        block = max(block, _MIN_CHUNK) if left >= _MIN_CHUNK else left
        block = min(block, left)
        out.append(block)
        left -= block
    return tuple(out)


def plan_sweep(n_ticks: int, measure_idx: Sequence[int], B: int, P: int, *,
               batch: int, d: int, k_max: int, masked: bool,
               has_churn: bool, n_devices: Optional[int] = None) -> SweepPlan:
    """Choose stride, chunk schedule and mesh placement for one sweep.

    Args:
      n_ticks: live tick-grid length (before stride padding).
      measure_idx: global tick index of each measurement point (any row —
        merged rows share the cadence, shorter horizons are prefixes).
      B: scenario rows in the batch (before mesh padding).
      P: padded node-slot count of the batch.
      batch / d: data-plane minibatch size and model dimension.
      k_max: static β-sample slot count (0 = no sampled rows).
      masked: per-row alive-masked sampling (churn or ragged padding) —
        the memory-dominant case (B·P² scores per tick).
      has_churn: churn uniforms are drawn per row per tick.
      n_devices: mesh size; default every local device
        (``PSP_SWEEP_DEVICES`` overrides), clamped to B so no device
        owns zero rows.
    """
    if n_devices is None:
        n_devices = int(os.environ.get("PSP_SWEEP_DEVICES", "0")) or None
    import jax
    avail = len(jax.devices())
    if n_devices is None:
        n_devices = avail
    # clamp: no device may own zero rows, and a request beyond the host's
    # devices (e.g. a stale env override) degrades instead of failing
    ndev = max(1, min(int(n_devices), B, avail))
    # each device's row block pads up to the data-plane GEMM width
    # (DATA_PLANE_BLOCK), so neither the fused tick nor the kernel ever
    # pays a per-tick pad copy; padded rows are inert (negative horizon)
    # and the control plane's cost on them is negligible
    from repro.kernels.psp_tick import DATA_PLANE_BLOCK
    b_loc = math.ceil(math.ceil(B / ndev) / DATA_PLANE_BLOCK) \
        * DATA_PLANE_BLOCK
    b_pad = b_loc * ndev
    node_pad = math.ceil(P / ndev) * ndev

    # the engine draws per-row noise for every PADDED row (keys are
    # global row ids, inert rows included), so the memory estimate must
    # use b_pad, not B — a B=1 churn sweep still draws a 16-row block
    noise = P * batch * (d + 1)                     # minibatch blob
    noise += b_pad * P                              # step-duration jitter
    if k_max > 0:
        noise += b_pad * P * P if masked else (P if k_max == 1 else P * P)
    if has_churn:
        noise += 2 * b_pad * P
    stride = _record_stride(n_ticks, np.asarray(measure_idx, np.int64),
                            4 * noise)

    n_rec_live = math.ceil(n_ticks / stride)
    chunks = _binary_chunks(n_rec_live)
    n_rec = sum(chunks)
    return SweepPlan(stride=stride, n_rec=n_rec, n_rec_live=n_rec_live,
                     chunks=chunks, n_devices=ndev, b_pad=b_pad,
                     node_pad=node_pad)
