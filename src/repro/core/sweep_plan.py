"""Execution planner for the jax sweep backend's chunked, sharded scans.

The jax grid engine (:mod:`repro.core.vector_sim_jax`) no longer runs one
monolithic ``lax.scan`` over the whole tick grid.  It runs a sequence of
donated *chunk* scans, each advancing a block of **superticks** (``stride``
grid ticks drawn and executed together, one trace record emitted per
supertick), sharded over a 1-D device mesh on the scenario (B) dimension.
This module is the single place where the three free parameters of that
execution are chosen, so the engine itself stays policy-free:

``stride`` — ticks per trace record / noise-draw block
    Traces are only *consumed* on the measurement grid, so recording them
    every tick wastes output bandwidth; and drawing each tick's noise in
    its own tiny ``jax.random`` call wastes RNG dispatch.  The stride is
    the largest divisor of the measurement cadence (every measurement
    index must land exactly on a record) whose per-supertick noise block
    still fits the memory budget — churn/ragged batches carry per-row
    ``P × P`` score matrices, which caps the stride long before the
    no-churn fast path does.

``chunks`` — binary decomposition of the supertick count
    Each chunk length compiles once (the jit cache is keyed on it) and
    pow2 lengths recur across sweeps, so the schedule is the greedy
    binary decomposition of the supertick count, largest block first:
    40 records → 32 + 8.  The last block never over-runs the grid —
    remainder ticks below one stride are padded with *dead* ticks that
    every row ignores (their time lies beyond all row horizons), and a
    chunk whose every row is already past its horizon is skipped by the
    caller's all-rows-done early exit (merged rows may have *different*
    horizons — see :func:`repro.core.vector_sim._merge_key`).

``mesh`` / padding — 2-D ``(rows, nodes)`` placement
    Devices factorize into a ``rows × nodes`` mesh.  The B dimension is
    sharded over the ``rows`` axis (rows are independent; per-row noise
    is keyed by *global* row id, so results are bit-identical for every
    row count — the degenerate 1-device mesh IS the single-device
    engine).  Rows pad up to a multiple of the rows axis so each device
    owns an equal block; padded rows carry a negative horizon and never
    tick.  The P node slots shard over the ``nodes`` axis: the engine
    keeps the node-dimensioned state and node-keyed draws (minibatch
    blob, shared β-sample scores) sliced per shard and turns the
    cross-node reductions into collectives
    (:mod:`repro.core.vector_sim_jax`).  Bit-identity across
    factorizations requires the node-shard width to be *exact* — a
    padded slot would change the width of the full-view reductions — so
    the nodes-axis size is clamped to the largest divisor of P within
    the request, and the per-shard GEMM alignment lives on the rows
    axis (:data:`~repro.kernels.psp_tick.DATA_PLANE_BLOCK`-padded row
    blocks) where inert padding is free.  The default mesh is
    ``(devices, 1)`` — node sharding is opt-in via ``mesh=`` /
    ``PSP_SWEEP_MESH`` because the 1-D plan is optimal until P outgrows
    a device.

Env overrides (all optional, for tests and benchmarks):

=====================  ==================================================
``PSP_SWEEP_MESH``     ``RxN`` rows × nodes factorization (e.g. ``4x2``)
``PSP_SWEEP_DEVICES``  rows-axis size (default: every local device);
                       ignored when a mesh is given
``PSP_TRACE_STRIDE``   force the record stride (still snapped to a
                       divisor of the measurement cadence)
``PSP_SWEEP_CHUNK``    force a uniform chunk length in records
=====================  ==================================================
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import env

__all__ = ["SweepPlan", "parse_mesh", "plan_sweep", "resolve_mesh"]

#: per-supertick noise-block budget (bytes); caps the stride for batches
#: whose per-row score matrices scale with B·P²
_NOISE_BUDGET = 64 << 20

#: chunks smaller than this are not worth their compile (records)
_MIN_CHUNK = 1


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """One sweep's execution schedule (see module docstring)."""

    stride: int                 #: grid ticks per trace record
    n_rec: int                  #: scheduled records (covers the padded grid)
    n_rec_live: int             #: records containing at least one live tick
    chunks: Tuple[int, ...]     #: record-block lengths, in execution order
    n_devices: int              #: total devices used (= rows · nodes)
    b_pad: int                  #: scenario rows after mesh padding
    node_pad: int               #: node-keyed draw slots after mesh padding
    mesh: Tuple[int, int] = (1, 1)   #: (rows, nodes) device factorization
    p_loc: int = 0              #: node slots per nodes-axis shard (P / nodes)

    @property
    def n_ticks(self) -> int:
        """Padded tick-grid length (``n_rec × stride``)."""
        return self.n_rec * self.stride

    @property
    def rows(self) -> int:
        """Rows-axis size of the device mesh."""
        return self.mesh[0]

    @property
    def nodes(self) -> int:
        """Nodes-axis size of the device mesh."""
        return self.mesh[1]


def _record_stride(n_ticks: int, measure_idx: np.ndarray,
                   noise_bytes_per_tick: int) -> int:
    """Largest stride aligning every measurement index on a record.

    A stride ``s`` records states after global ticks ``s−1, 2s−1, …``; a
    measurement landing on tick index ``m`` is representable iff
    ``s | (m + 1)``, so the admissible strides are exactly the divisors
    of ``gcd{m + 1}`` — and the full grid must land on a record too,
    else the final state would be cut short, so ``n_ticks`` joins the
    gcd.  Among those, take the largest whose supertick noise block
    stays under the budget (``PSP_TRACE_STRIDE`` forces a candidate,
    snapped down to the nearest admissible divisor).
    """
    vals = np.concatenate([measure_idx + 1, [n_ticks]])
    q = int(np.gcd.reduce(vals.astype(np.int64)))
    cap = max(1, _NOISE_BUDGET // max(noise_bytes_per_tick, 1))
    forced = env.get_int("PSP_TRACE_STRIDE")
    if forced:
        cap = min(cap, max(1, forced))
    best = 1
    for s in range(1, int(math.isqrt(q)) + 1):
        if q % s == 0:
            for cand in (s, q // s):
                if cand <= cap:
                    best = max(best, cand)
    return best


def parse_mesh(spec: str) -> Tuple[int, int]:
    """Parse a ``RxN`` mesh spec (``PSP_SWEEP_MESH`` / ``--mesh``).

    Exactly two positive decimal integers joined by a single ``x`` (case
    insensitive): ``"4x2" → (4, 2)``.  Anything else — negative or zero
    sizes, missing factors, stray separators — raises ``ValueError``
    rather than silently running an unintended placement (the override
    exists precisely to pin placements in CI).
    """
    parts = spec.strip().lower().split("x")
    if len(parts) != 2 or not all(p.isdigit() and p for p in parts):
        raise ValueError(
            f"mesh spec {spec!r} is not of the form RxN (two positive "
            "integers, e.g. '4x2')")
    rows, nodes = int(parts[0]), int(parts[1])
    if rows < 1 or nodes < 1:
        raise ValueError(f"mesh spec {spec!r}: sizes must be >= 1")
    return rows, nodes


def _node_axis_size(n: int, P: int, budget: int) -> int:
    """Largest divisor of ``P`` that is ≤ min(n, budget).

    The nodes-axis shard width must be exact (``P / nodes``) — padding a
    node slot would widen the full-view reductions and break the
    cross-factorization bit-identity invariant — so a request that does
    not divide P degrades to the nearest feasible factorization instead
    of failing (e.g. ``nodes=8`` on P = 100 runs 5-way).
    """
    cap = max(1, min(n, P, budget))
    return max(d for d in range(1, cap + 1) if P % d == 0)


def resolve_mesh(B: int, P: int,
                 mesh: Optional[Tuple[int, int]] = None,
                 n_devices: Optional[int] = None) -> Tuple[int, int]:
    """The ``(rows, nodes)`` factorization a sweep of this shape will use.

    Resolution order: explicit ``mesh`` > ``PSP_SWEEP_MESH`` env >
    1-D ``(n_devices, 1)`` (``PSP_SWEEP_DEVICES`` env, default every
    local device).  Clamps exactly as :func:`plan_sweep` does — no
    device may own zero rows, the nodes axis must divide P exactly, and
    a request beyond the host's devices degrades instead of failing —
    so benchmarks can *report* the placement they actually ran.
    """
    import jax
    avail = len(jax.devices())
    if mesh is None:
        env_mesh = env.get_str("PSP_SWEEP_MESH")
        if env_mesh:
            mesh = parse_mesh(env_mesh)
    if mesh is None:
        if n_devices is None:
            n_devices = env.get_int("PSP_SWEEP_DEVICES") or None
        mesh = (avail if n_devices is None else int(n_devices), 1)
    rows = max(1, min(int(mesh[0]), B, avail))
    nodes = _node_axis_size(int(mesh[1]), P, avail // rows)
    return rows, nodes


def _binary_chunks(n_rec: int) -> Tuple[int, ...]:
    """Greedy pow2 decomposition of the record count, largest first.

    Pow2 block lengths recur across sweeps of the same structural shape,
    so every block of the schedule hits the jit cache after its first
    compile; the decomposition is exact (no dead records beyond the
    sub-stride grid padding).  ``PSP_SWEEP_CHUNK`` forces a uniform
    length instead — the tail chunk is then *scheduled* past the live
    records and the runner's early exit skips it once every row is done.
    """
    forced = env.get_int("PSP_SWEEP_CHUNK")
    if forced:
        c = max(1, forced)
        return tuple([c] * math.ceil(n_rec / c))
    out, left = [], n_rec
    while left > 0:
        block = 1 << (left.bit_length() - 1)
        block = max(block, _MIN_CHUNK) if left >= _MIN_CHUNK else left
        block = min(block, left)
        out.append(block)
        left -= block
    return tuple(out)


def plan_sweep(n_ticks: int, measure_idx: Sequence[int], B: int, P: int, *,
               batch: int, d: int, k_max: int, masked: bool,
               has_churn: bool, n_devices: Optional[int] = None,
               mesh: Optional[Tuple[int, int]] = None) -> SweepPlan:
    """Choose stride, chunk schedule and mesh placement for one sweep.

    Args:
      n_ticks: live tick-grid length (before stride padding).
      measure_idx: global tick index of each measurement point (any row —
        merged rows share the cadence, shorter horizons are prefixes).
      B: scenario rows in the batch (before mesh padding).
      P: padded node-slot count of the batch.
      batch / d: data-plane minibatch size and model dimension.
      k_max: static β-sample slot count (0 = no sampled rows).
      masked: per-row alive-masked sampling (churn or ragged padding) —
        the memory-dominant case (B·P² scores per tick).
      n_devices: rows-axis size; default every local device
        (``PSP_SWEEP_DEVICES`` overrides), clamped to B so no device
        owns zero rows.  Ignored when a mesh is requested.
      mesh: explicit ``(rows, nodes)`` factorization
        (``PSP_SWEEP_MESH=RxN`` overrides ``None``).  Clamped like the
        1-D request: rows to B and the host's devices, nodes to the
        largest divisor of P fitting the remaining device budget, so a
        stale override degrades instead of failing.
    """
    rows, nodes = resolve_mesh(B, P, mesh=mesh, n_devices=n_devices)
    ndev = rows * nodes
    # each device's row block pads up to the data-plane GEMM width
    # (DATA_PLANE_BLOCK), so neither the fused tick nor the kernel ever
    # pays a per-tick pad copy; padded rows are inert (negative horizon)
    # and the control plane's cost on them is negligible
    from repro.kernels.psp_tick import DATA_PLANE_BLOCK
    b_loc = math.ceil(math.ceil(B / rows) / DATA_PLANE_BLOCK) \
        * DATA_PLANE_BLOCK
    b_pad = b_loc * rows
    # node-keyed draw slots: each nodes-axis shard owns an exact P/nodes
    # node block, and splits its block's draws over the rows axis (the
    # rows of one node column draw disjoint id ranges and all-gather), so
    # the slot count pads to the rows axis *within* each node column —
    # the 1-D plan's ceil(P/ndev)·ndev, per column
    p_loc = P // nodes
    node_pad = nodes * math.ceil(p_loc / rows) * rows

    # the engine draws per-row noise for every PADDED row (keys are
    # global row ids, inert rows included), so the memory estimate must
    # use b_pad, not B — a B=1 churn sweep still draws a 16-row block
    noise = P * batch * (d + 1)                     # minibatch blob
    noise += b_pad * P                              # step-duration jitter
    if k_max > 0:
        noise += b_pad * P * P if masked else (P if k_max == 1 else P * P)
    if has_churn:
        noise += 2 * b_pad * P
    stride = _record_stride(n_ticks, np.asarray(measure_idx, np.int64),
                            4 * noise)

    n_rec_live = math.ceil(n_ticks / stride)
    chunks = _binary_chunks(n_rec_live)
    n_rec = sum(chunks)
    return SweepPlan(stride=stride, n_rec=n_rec, n_rec_live=n_rec_live,
                     chunks=chunks, n_devices=ndev, b_pad=b_pad,
                     node_pad=node_pad, mesh=(rows, nodes), p_loc=p_loc)
