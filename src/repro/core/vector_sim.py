"""Vectorized batched sweep engine — the evaluation layer's fast path.

The paper's headline results (Figs 1–3) are *sweeps*: barrier policy ×
straggler fraction × slowness × system size × seed.  The discrete-event
:class:`~repro.core.simulator.Simulator` processes one Python event at a
time, so a full scenario matrix costs minutes; this module advances **all P
nodes and a batch of B configurations simultaneously** with NumPy array ops
on a fixed time grid, cutting sweep wall-clock by an order of magnitude
while keeping the event-driven simulator as the semantic reference
(``tests/test_vector_sim.py`` holds the distribution-level equivalence
test).

Sweep API
---------
:func:`run_sweep` is the entry point::

    from repro.core.simulator import SimConfig
    from repro.core.vector_sim import run_sweep

    configs = [SimConfig(barrier=make_barrier(b), straggler_frac=f, seed=s)
               for b in ("bsp", "pbsp") for f in (0.0, 0.1) for s in range(4)]
    results = run_sweep(configs)          # -> list[SimResult], input order

Configurations are grouped by structural key (``n_nodes``, ``dim``,
``batch``, ``duration``, ``measure_interval``, ``poll_interval``); each
group runs as one batched :class:`VectorSimulator`, everything else (seed,
learning rate, straggler settings, barrier policy, noise, distributed
sampling) is batched per-row.  Configs the vector engine cannot express
(churn) transparently fall back to the event-driven reference.

Simulation model (one grid tick of width ``dt``)
------------------------------------------------
1. **Finish** — nodes whose busy-until clock expired push their update
   (gradient of the linear task at their *pulled* model — SGD updates
   commute within a tick because each depends only on the puller's stale
   view), advance their step counter, and become *deciding*.
2. **Decide** — all deciding nodes evaluate their barrier predicate in one
   masked batch: ASP rows always pass; full-view rows (BSP/SSP) pass iff
   ``step − min(steps) ≤ staleness``; sampled rows (pBSP/pSSP) draw β
   peers **without replacement, excluding themselves** (the worker-centric
   semantics of paper §6.4, matching
   ``sample_steps_jax(..., exclude_self=True)``) and pass iff no sampled
   peer lags more than ``staleness`` behind.
3. **Start** — passing nodes pull the server model and draw their next
   step duration, anchored at their *continuous* ready time (not the grid
   tick), so grid quantisation does not systematically slow progress.
   Blocked sampled rows re-poll after ``poll_interval`` exactly like the
   event simulator; blocked full-view rows re-check every tick (the grid
   analogue of the event simulator's min-moved wakeup).
4. **Measure** — error/update traces are recorded on the same
   ``measure_interval`` grid as :class:`SimResult` expects.

Determinism: a sweep is deterministic given the config list (the batch
shares one dynamics RNG seeded from all row seeds), and each row's *static*
draw — ground-truth model, node speeds, straggler assignment — replays the
event simulator's per-seed init stream exactly.  Per-row dynamics noise
(minibatches, step-duration jitter, β-samples) is shared across the batch,
so a row's trajectory matches the event simulator at the distribution level
(mean progress, lag pmf shape, final error), not sample-path level.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.barriers import ASP
from repro.core.simulator import SimConfig, SimResult, run_simulation

__all__ = ["VectorSimulator", "run_sweep"]

_EPS = 1e-9


def _group_key(cfg: SimConfig) -> Tuple:
    """Structural fields that must agree within one vectorized batch."""
    return (cfg.n_nodes, cfg.dim, cfg.batch, float(cfg.duration),
            float(cfg.measure_interval), float(cfg.poll_interval))


def _vectorizable(cfg: SimConfig) -> bool:
    """Churn needs the event-driven membership machinery — fall back."""
    return cfg.churn_join_rate == 0.0 and cfg.churn_leave_rate == 0.0


class VectorSimulator:
    """Batched fixed-grid simulator over B same-shape configurations."""

    def __init__(self, configs: Sequence[SimConfig],
                 dt: Optional[float] = None):
        if not configs:
            raise ValueError("empty config batch")
        keys = {_group_key(c) for c in configs}
        if len(keys) > 1:
            raise ValueError(f"heterogeneous batch: {keys} "
                             "(use run_sweep, which groups automatically)")
        for c in configs:
            if not _vectorizable(c):
                raise ValueError("churn is not vectorizable; use run_sweep "
                                 "(falls back to the event-driven simulator)")
        self.configs = list(configs)
        B = len(configs)
        c0 = configs[0]
        P, d = c0.n_nodes, c0.dim
        self.B, self.P, self.d, self.batch = B, P, d, c0.batch
        self.duration = float(c0.duration)
        self.poll_interval = float(c0.poll_interval)
        self.measure_interval = float(c0.measure_interval)
        self.dt = float(dt) if dt is not None else self.poll_interval
        if self.dt > self.poll_interval + 1e-12:
            # a node can finish/decide at most once per tick, so a coarse
            # grid silently caps throughput and skips poll attempts —
            # results would be wrong, not just coarse
            raise ValueError(
                f"dt={self.dt} must not exceed poll_interval="
                f"{self.poll_interval}")

        # ---- per-row static state: replay the event simulator's init ---- #
        self.w_true = np.empty((B, d))
        self.compute_time = np.empty((B, P))
        self.lr = np.empty(B)
        self.noise_std = np.empty(B)
        self.staleness = np.zeros(B, dtype=np.int64)
        self.beta = np.full(B, -1, dtype=np.int64)    # -1 = full view
        self.is_asp = np.zeros(B, dtype=bool)
        self.distributed = np.zeros(B, dtype=bool)
        for b, cfg in enumerate(configs):
            rng = np.random.default_rng(cfg.seed)
            self.w_true[b] = rng.normal(size=d) / np.sqrt(d)
            speed = 1.0 + cfg.compute_jitter * (rng.random(P) - 0.5)
            n_slow = int(round(cfg.straggler_frac * P))
            slow_ids = rng.choice(P, size=n_slow, replace=False)
            speed[slow_ids] *= cfg.straggler_slowdown
            self.compute_time[b] = cfg.base_compute * speed
            self.lr[b] = cfg.lr if cfg.lr is not None else 0.5 / P
            self.noise_std[b] = cfg.noise_std
            bar = cfg.barrier
            self.staleness[b] = bar.staleness
            self.is_asp[b] = isinstance(bar, ASP)
            if not self.is_asp[b] and bar.sample_size is not None:
                self.beta[b] = bar.sample_size
            self.distributed[b] = cfg.distributed_sampling
        self.full_view = (self.beta < 0) & ~self.is_asp
        self.sampled = self.beta >= 0
        self.w_true_norm = np.linalg.norm(self.w_true, axis=1)

        # one dynamics stream for the whole batch, seeded from all rows;
        # SFC64 because bulk draws are the engine's hottest path
        self.rng = np.random.Generator(np.random.SFC64(
            np.random.SeedSequence([int(c.seed) for c in configs]
                                   + [B, P, d])))

        # ---- dynamic state ---------------------------------------------- #
        self.w = np.zeros((B, d))
        self.pulled = np.zeros((B, P, d))
        self.steps = np.zeros((B, P), dtype=np.int64)
        self.computing = np.ones((B, P), dtype=bool)
        #: finish time while computing / next barrier-check time while not
        self.event_time = self.compute_time * (0.5 + self.rng.random((B, P)))
        #: continuous anchor of the node's current decision attempt
        self.ready = self.event_time.copy()
        self.blocked = np.zeros((B, P), dtype=bool)
        self.total_updates = np.zeros(B, dtype=np.int64)
        self.control_messages = np.zeros(B, dtype=np.int64)
        # per-draw control cost of the structured overlay (β lookups of
        # O(log N) hops + β step queries), matching OverlaySampler
        self._hops_per_peer = max(1, int(np.ceil(np.log2(max(P, 2))))) + 1

        self.m_times = np.arange(0.0, self.duration + 1e-9,
                                 self.measure_interval)
        self._trace_err: List[np.ndarray] = []
        self._trace_upd: List[np.ndarray] = []

    # ------------------------------------------------------------------ #
    def _measure(self) -> None:
        err = (np.linalg.norm(self.w - self.w_true, axis=1)
               / self.w_true_norm)
        self._trace_err.append(err)
        self._trace_upd.append(self.total_updates.copy())

    def _apply_updates(self, b_idx: np.ndarray, p_idx: np.ndarray) -> None:
        """Batched SGD pushes for every node that finished this tick.

        The residual is computed directly as X·(w_pulled − w*) − σ·ε, which
        folds the label draw into one projection; minibatch draws are f32
        (the simulation's noise floor is orders of magnitude above f32 eps).
        """
        K = b_idx.size
        X = self.rng.standard_normal((K, self.batch, self.d),
                                     dtype=np.float32)
        diff = (self.pulled[b_idx, p_idx]
                - self.w_true[b_idx]).astype(np.float32)
        eps = self.rng.standard_normal((K, self.batch), dtype=np.float32)
        resid = (np.einsum("kbd,kd->kb", X, diff)
                 - self.noise_std[b_idx, None].astype(np.float32) * eps)
        grads = np.einsum("kb,kbd->kd", resid, X) / self.batch
        # updates within a tick commute: each gradient depends only on the
        # node's pulled (stale) model, so the server sum is order-free.
        # b_idx comes from np.nonzero and is therefore sorted, so the
        # per-row sums are contiguous segments (reduceat ≫ np.add.at).
        rows, starts = np.unique(b_idx, return_index=True)
        self.w[rows] -= (self.lr[rows, None]
                         * np.add.reduceat(grads.astype(np.float64),
                                           starts, axis=0))
        self.total_updates += np.bincount(b_idx, minlength=self.B)

    def _sample_peers(self, bb: np.ndarray, pp: np.ndarray,
                      k: int) -> np.ndarray:
        """i64[K, k] peer indices: uniform without replacement, self excluded.

        For k ≪ P this is vectorized rejection sampling (draw k iid indices
        over the P−1 non-self slots, redraw rows with within-row collisions)
        — O(K·k) versus the O(K·P) of a full argpartition, which remains the
        fallback for dense samples.
        """
        K = bb.size
        if 3 * k >= self.P:
            scores = self.rng.random((K, self.P))
            scores[np.arange(K), pp] = 2.0
            return np.argpartition(scores, k - 1, axis=1)[:, :k]
        draw = self.rng.integers(0, self.P - 1, size=(K, k))
        draw += draw >= pp[:, None]          # skip over the self slot
        if k > 1:
            for _ in range(16):
                srt = np.sort(draw, axis=1)
                dup = (srt[:, 1:] == srt[:, :-1]).any(axis=1)
                if not dup.any():
                    break
                rows = np.flatnonzero(dup)
                redo = self.rng.integers(0, self.P - 1, size=(rows.size, k))
                redo += redo >= pp[rows, None]
                draw[rows] = redo
        return draw

    def _barrier_pass(self, cand: np.ndarray) -> np.ndarray:
        """Masked barrier predicates; bool[B, P], valid where ``cand``."""
        passed = np.zeros((self.B, self.P), dtype=bool)
        passed[self.is_asp] = True
        if self.full_view.any():
            fv_steps = self.steps[self.full_view]
            lag = fv_steps - fv_steps.min(axis=1, keepdims=True)
            passed[self.full_view] = \
                lag <= self.staleness[self.full_view, None]
        sm = cand & self.sampled[:, None]
        b_idx, p_idx = np.nonzero(sm)
        if b_idx.size:
            betas = self.beta[b_idx]
            for beta in np.unique(betas):
                pick = betas == beta
                bb, pp = b_idx[pick], p_idx[pick]
                k = min(int(beta), self.P - 1)
                if k <= 0:
                    passed[bb, pp] = True   # S = ∅ degenerates to ASP
                    continue
                take = self._sample_peers(bb, pp, k)
                peer_steps = self.steps[bb[:, None], take]
                my = self.steps[bb, pp]
                passed[bb, pp] = np.all(
                    my[:, None] - peer_steps
                    <= self.staleness[bb][:, None], axis=1)
                dist = self.distributed[bb]
                if dist.any():
                    self.control_messages += (
                        k * self._hops_per_peer
                        * np.bincount(bb[dist], minlength=self.B))
        return passed

    # ------------------------------------------------------------------ #
    def run(self) -> List[SimResult]:
        dt = self.dt
        ticks = np.arange(dt, self.duration + 1e-9, dt)
        if ticks.size == 0 or ticks[-1] < self.duration - 1e-9:
            ticks = np.append(ticks, self.duration)
        self._measure()                      # t = 0 trace point
        m_next = 1

        for t in ticks:
            # 1. finishes: push updates, advance steps, become "deciding"
            fin = self.computing & (self.event_time <= t + _EPS)
            # latest finish per row this tick: a full-view waiter unblocked
            # this tick was gated by (at most) that finish, so anchoring
            # there instead of the tick boundary removes the systematic
            # dt/2-per-round quantisation loss for BSP/SSP
            row_unblock = np.full(self.B, t)
            if fin.any():
                b_idx, p_idx = np.nonzero(fin)
                rows, starts = np.unique(b_idx, return_index=True)
                row_last = np.maximum.reduceat(self.event_time[fin], starts)
                row_unblock[rows] = np.minimum(row_last, t)
                self._apply_updates(b_idx, p_idx)
                self.steps[fin] += 1
                self.computing[fin] = False
                self.ready[fin] = self.event_time[fin]  # true finish time
                self.blocked[fin] = False

            # 2. barrier decisions for every due deciding node
            cand = ~self.computing & (self.event_time <= t + _EPS)
            if cand.any():
                passed = self._barrier_pass(cand)
                start = cand & passed
                if start.any():
                    b_idx, p_idx = np.nonzero(start)
                    # anchor at the continuous ready time; a full-view node
                    # unblocked by a peer's finish starts at that finish
                    # (the grid analogue of the event simulator's
                    # min-moved wakeup)
                    t0 = np.where(self.blocked[start]
                                  & self.full_view[b_idx],
                                  np.maximum(row_unblock[b_idx],
                                             self.ready[start]),
                                  self.ready[start])
                    self.pulled[b_idx, p_idx] = self.w[b_idx]
                    dur = (self.compute_time[b_idx, p_idx]
                           * (0.5 + self.rng.random(b_idx.size)))
                    self.event_time[start] = t0 + dur
                    self.computing[start] = True
                    self.blocked[start] = False
                fail = cand & ~passed
                if fail.any():
                    self.blocked[fail] = True
                    # sampled rows re-poll on the poll cadence; full-view
                    # rows stay due and re-check next tick
                    sm_fail = fail & self.sampled[:, None]
                    self.ready[sm_fail] += self.poll_interval
                    self.event_time[sm_fail] = self.ready[sm_fail]

            # 3. error / server-update traces on the measurement grid
            while m_next < self.m_times.size and \
                    self.m_times[m_next] <= t + _EPS:
                self._measure()
                m_next += 1

        errs = np.stack(self._trace_err, axis=1)        # [B, M]
        upds = np.stack(self._trace_upd, axis=1)        # [B, M]
        final_err = (np.linalg.norm(self.w - self.w_true, axis=1)
                     / self.w_true_norm)
        out = []
        for b in range(self.B):
            out.append(SimResult(
                steps=self.steps[b].copy(),
                times=self.m_times[: errs.shape[1]].copy(),
                errors=errs[b].copy(),
                server_updates=upds[b].copy(),
                control_messages=int(self.control_messages[b]),
                total_updates=int(self.total_updates[b]),
                mean_progress=float(self.steps[b].mean()),
                final_error=float(final_err[b]),
            ))
        return out


# --------------------------------------------------------------------------- #
def run_sweep(configs: Sequence[SimConfig], *,
              dt: Optional[float] = None) -> List[SimResult]:
    """Run a batch of simulations, vectorizing wherever possible.

    Configs are grouped by structural shape and each group is advanced as
    one :class:`VectorSimulator`; configs the vector engine cannot express
    (churn) run on the event-driven reference.  Results come back in input
    order.
    """
    results: List[Optional[SimResult]] = [None] * len(configs)
    groups: Dict[Tuple, List[int]] = {}
    for i, cfg in enumerate(configs):
        if _vectorizable(cfg):
            groups.setdefault(_group_key(cfg), []).append(i)
        else:
            results[i] = run_simulation(cfg)
    for idx in groups.values():
        batch = VectorSimulator([configs[i] for i in idx], dt=dt).run()
        for i, res in zip(idx, batch):
            results[i] = res
    return results  # type: ignore[return-value]
