"""Vectorized batched sweep engine — the evaluation layer's fast path.

The paper's headline results (Figs 1–3) are *sweeps*: barrier policy ×
straggler fraction × slowness × system size × seed.  The discrete-event
:class:`~repro.core.simulator.Simulator` processes one Python event at a
time, so a full scenario matrix costs minutes; this module advances **all P
nodes and a batch of B configurations simultaneously** on a fixed time
grid, cutting sweep wall-clock by an order of magnitude while keeping the
event-driven simulator as the semantic reference
(``tests/test_vector_sim.py`` and ``tests/test_vector_sim_jax.py`` hold the
distribution-level equivalence suites).

Sweep API
---------
:func:`run_sweep` is the entry point::

    from repro.core.simulator import SimConfig
    from repro.core.vector_sim import run_sweep

    configs = [SimConfig(barrier=make_barrier(b), straggler_frac=f, seed=s)
               for b in ("bsp", "pbsp") for f in (0.0, 0.1) for s in range(4)]
    results = run_sweep(configs)                  # NumPy grid engine
    results = run_sweep(configs, backend="jax")   # jit + lax.scan engine

Configurations are grouped by structural key (``n_nodes``, ``dim``,
``batch``, ``duration``, ``measure_interval``, ``poll_interval``, churn
on/off); each group runs as one batched :class:`VectorSimulator`,
everything else (seed, learning rate, straggler settings, barrier policy,
noise, distributed sampling, churn rates) is batched per-row.  Results come
back in input order regardless of backend or grouping.

Backend matrix
--------------
===========  ==========================  ==========================
backend      no churn                    churn (alive-masked rows)
===========  ==========================  ==========================
``numpy``    array ops per grid tick     same + per-tick event batch
``jax``      donated chunked scans       same, per-row masked samples
===========  ==========================  ==========================

Both backends handle churn natively — nothing falls back to the event
engine.  The jax backend is device-resident: each grid tick — control
plane (churn, finish bookkeeping, barrier decisions, start/re-poll)
*and* data plane (masked SGD push, model-view pull) — runs as one fused
kernel, the Pallas tick of :mod:`repro.kernels.psp_tick` on TPU, its
jnp twin on CPU, driven by donated chunked scans sharded over a 1-D
device mesh (:mod:`repro.core.vector_sim_jax`, schedule chosen by
:mod:`repro.core.sweep_plan`), with β-samples from the shared
:mod:`repro.core.sampling` primitives and barrier/straggler semantics
single-sourced in :mod:`repro.core.barrier_kernel` (the same model the
SPMD trainer uses).  The jax backend additionally merges structural
groups that differ in ``n_nodes``, churn-ness or duration (ragged P
padded with permanently-dead alive-mask slots; shorter rows freeze at
their own horizon), so a mixed sweep compiles once per
(dim, batch, cadence) shape; see ``docs/ARCHITECTURE.md`` for the full
engine map.

Simulation model (one grid tick of width ``dt``)
------------------------------------------------
0. **Churn** — pre-sampled Poisson leave/join events due this tick fire:
   a leave kills a uniformly random alive node (only while more than two
   are alive, as the event engine), a join revives a dead node at the
   current max alive step and lets it decide this tick.  Departed nodes neither finish nor decide;
   the full-view minimum is re-derived from the alive-masked step matrix
   every tick, so a departed global-min straggler unblocks waiters on the
   next tick — the grid analogue of the event engine's ``_on_leave`` wake.
1. **Finish** — nodes whose busy-until clock expired push their update
   (gradient of the linear task at their *pulled* model — SGD updates
   commute within a tick because each depends only on the puller's stale
   view), advance their step counter, and become *deciding*.
2. **Decide** — all deciding nodes evaluate their barrier predicate in one
   masked batch: ASP rows always pass; full-view rows (BSP/SSP) pass iff
   ``step − min(alive steps) ≤ staleness``; sampled rows (pBSP/pSSP) draw β
   **alive** peers without replacement, excluding themselves (the
   worker-centric semantics of paper §6.4, matching
   ``sample_steps_jax(..., exclude_self=True)``) and pass iff no sampled
   peer lags more than ``staleness`` behind.
3. **Start** — passing nodes pull the server model and draw their next
   step duration, anchored at their *continuous* ready time (not the grid
   tick), so grid quantisation does not systematically slow progress.
   Blocked sampled rows re-poll after ``poll_interval`` exactly like the
   event simulator; blocked full-view rows re-check every tick (the grid
   analogue of the event simulator's min-moved wakeup).
4. **Measure** — error/update traces are recorded on the same
   ``measure_interval`` grid as :class:`SimResult` expects.

Determinism: a sweep is deterministic given the config list and backend
(the batch shares one dynamics RNG seeded from all row seeds), and each
row's *static* draw — ground-truth model, node speeds, straggler
assignment — replays the event simulator's per-seed init stream exactly
(:func:`repro.core.simulator.draw_static_state`) on **both** backends.
Per-row dynamics noise (minibatches, step-duration jitter, β-samples,
churn victims) is shared across the batch, so a row's trajectory matches
the event simulator at the distribution level (mean progress, lag pmf
shape, final error), not sample-path level; the numpy and jax backends
likewise agree at the distribution level (different dynamics streams) —
``tests/test_vector_sim_jax.py`` pins per-backend golden traces.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.barriers import ASP
from repro.core.simulator import (SimConfig, SimResult, draw_static_state,
                                  sample_poisson_times)

__all__ = ["VectorSimulator", "run_sweep", "sample_churn_schedules",
           "BACKENDS"]

_EPS = 1e-9

BACKENDS = ("numpy", "jax")


def _group_key(cfg: SimConfig) -> Tuple:
    """Structural fields that must agree within one numpy batch.

    Churn-ness is structural: churn batches carry alive masks and per-row
    event schedules, and both backends specialise their tick on it
    (per-row masked sampling vs the shared-index fast path).
    """
    has_churn = cfg.churn_join_rate > 0.0 or cfg.churn_leave_rate > 0.0
    return (cfg.n_nodes, cfg.dim, cfg.batch, float(cfg.duration),
            float(cfg.measure_interval), float(cfg.poll_interval), has_churn)


def _merge_key(cfg: SimConfig) -> Tuple:
    """Relaxed jax grouping key: ragged P, churn-ness and duration merge.

    The jax backend pads heterogeneous ``n_nodes`` up to the group max and
    runs the merged batch as **one** chunked scan schedule — padded node
    slots are permanently dead alive-mask entries — so a ragged sweep
    costs one compile per bucket instead of one per structural shape.  P
    is bucketed to the next power of two: that caps the padding waste of
    any row at 2× (4× on the P² sampling terms) while still collapsing
    the near-size shapes a scalability sweep produces.  Durations merge
    too: the tick grid runs to the group maximum and each row freezes at
    its own horizon (the fused tick's ``active`` gate), with the chunk
    runner early-exiting once every row is done.  Only the fields that
    fix the tick/measurement cadence and the data-plane shapes must
    still agree exactly.
    """
    p_bucket = 1 << max(0, cfg.n_nodes - 1).bit_length()
    return (p_bucket, cfg.dim, cfg.batch,
            float(cfg.measure_interval), float(cfg.poll_interval))


def sample_churn_schedules(rng: np.random.Generator, leave_rate: float,
                           join_rate: float, duration: float
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Pre-sample one row's Poisson churn schedule: (leave, join) times.

    The batched engines and the elastic SPMD trainer
    (:mod:`repro.core.spmd_psp`) all consume churn as *pre-sampled*
    schedules rather than on-line exponential re-arming, so churn events
    are data, not control flow — a fixed-shape input a ``lax.scan`` (or a
    jitted train step) can carry.  Both processes are the event
    simulator's model (:func:`repro.core.simulator.sample_poisson_times`),
    drawn leave-first from ``rng`` so a shared generator yields a
    deterministic schedule.
    """
    leaves = sample_poisson_times(rng, leave_rate, duration)
    joins = sample_poisson_times(rng, join_rate, duration)
    return leaves, joins


class VectorSimulator:
    """Batched fixed-grid simulator over B same-shape configurations."""

    def __init__(self, configs: Sequence[SimConfig],
                 dt: Optional[float] = None, backend: str = "numpy"):
        if not configs:
            raise ValueError("empty config batch")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"choose from {BACKENDS}")
        keys = {_group_key(c) for c in configs}
        if len(keys) > 1 and (backend != "jax"
                              or len({_merge_key(c) for c in configs}) > 1):
            raise ValueError(f"heterogeneous batch: {keys} "
                             "(use run_sweep, which groups automatically; "
                             "only the jax backend batches ragged P/churn)")
        self.configs = list(configs)
        self.backend = backend
        B = len(configs)
        c0 = configs[0]
        #: per-row true population; P is the padded batch width (jax only
        #: — the numpy engine always runs structurally homogeneous batches)
        self.n_true = np.array([c.n_nodes for c in configs], dtype=np.int64)
        P, d = int(self.n_true.max()), c0.dim
        self.B, self.P, self.d, self.batch = B, P, d, c0.batch
        #: per-row horizon; the shared grid runs to the batch max and the
        #: jax tick freezes each row past its own duration (merged
        #: durations are a jax-only grouping — numpy batches are strict)
        self.row_duration = np.array([float(c.duration) for c in configs])
        self.duration = float(self.row_duration.max())
        self.poll_interval = float(c0.poll_interval)
        self.measure_interval = float(c0.measure_interval)
        self.dt = float(dt) if dt is not None else self.poll_interval
        if self.dt > self.poll_interval + 1e-12:
            # a node can finish/decide at most once per tick, so a coarse
            # grid silently caps throughput and skips poll attempts —
            # results would be wrong, not just coarse
            raise ValueError(
                f"dt={self.dt} must not exceed poll_interval="
                f"{self.poll_interval}")
        self.has_churn = any(c.churn_join_rate > 0.0
                             or c.churn_leave_rate > 0.0 for c in configs)

        # ---- per-row static state: replay the event simulator's init ---- #
        #: ragged padding mask: slot p exists in row b iff p < n_true[b]
        self.valid_slot = np.arange(P) < self.n_true[:, None]
        self.w_true = np.empty((B, d))
        self.compute_time = np.ones((B, P))
        self.lr = np.empty(B)
        self.noise_std = np.empty(B)
        self.staleness = np.zeros(B, dtype=np.int64)
        self.beta = np.full(B, -1, dtype=np.int64)    # -1 = full view
        self.is_asp = np.zeros(B, dtype=bool)
        self.distributed = np.zeros(B, dtype=bool)
        # adaptive barrier-policy rows (dssp / ebsp / β-annealing): row
        # tags + per-row knobs; the static-policy fast path never reads
        # these (self.adaptive gates every use)
        self.is_dssp = np.zeros(B, dtype=bool)
        self.is_ebsp = np.zeros(B, dtype=bool)
        self.is_anneal = np.zeros(B, dtype=bool)
        self.pol_lo = np.zeros(B, dtype=np.int64)      # DSSP lower bound r
        self.beta_lo = np.zeros(B, dtype=np.int64)     # annealing β_min
        self.ebsp_range = np.zeros(B)                  # Elastic max_advance
        self.ebsp_alpha = np.full(B, 0.5)              # Elastic EMA α
        for b, cfg in enumerate(configs):
            rng = np.random.default_rng(cfg.seed)
            self.w_true[b], ct = draw_static_state(cfg, rng)
            self.compute_time[b, :cfg.n_nodes] = ct
            # default lr scales with the row's TRUE population, not the
            # padded batch width — grouping must not change results
            self.lr[b] = cfg.lr if cfg.lr is not None else 0.5 / cfg.n_nodes
            self.noise_std[b] = cfg.noise_std
            bar = cfg.barrier
            self.staleness[b] = bar.staleness
            self.is_asp[b] = isinstance(bar, ASP)
            if not self.is_asp[b] and bar.sample_size is not None:
                self.beta[b] = bar.sample_size
            self.distributed[b] = cfg.distributed_sampling
            kind = getattr(bar, "adaptive", "")
            if kind == "dssp":
                self.is_dssp[b] = True
                self.pol_lo[b] = bar.staleness_lo
            elif kind == "ebsp":
                self.is_ebsp[b] = True
                self.ebsp_range[b] = bar.max_advance
                self.ebsp_alpha[b] = bar.ema_alpha
            elif kind == "anneal":
                self.is_anneal[b] = True
                self.beta_lo[b] = bar.sample_size_lo
        self.full_view = (self.beta < 0) & ~self.is_asp
        self.sampled = self.beta >= 0
        self.adaptive = bool(self.is_dssp.any() or self.is_ebsp.any()
                             or self.is_anneal.any())
        #: per-row effective sample-slot cap (β clipped to the row's true
        #: peer count) — the annealing bounds live inside it
        self.beta_cap = np.maximum(np.minimum(self.beta, self.n_true - 1), 0)
        self.beta_lo = np.clip(self.beta_lo, 0, self.beta_cap)
        # ---- adaptive policy state (decisions read the OLD state; the
        # ---- end-of-tick update mirrors psp_tick_ref block 3b) ---------- #
        self.pol_thr = self.staleness.copy()           # DSSP threshold
        self.pol_ema = np.zeros((B, P))                # Elastic duration EMA
        self.pol_beta = np.where(self.is_anneal, self.beta_lo,
                                 np.maximum(self.beta, 0))
        self.w_true_norm = np.linalg.norm(self.w_true, axis=1)

        # one dynamics stream for the whole batch, seeded from all rows;
        # SFC64 because bulk draws are the engine's hottest path
        self.rng = np.random.Generator(np.random.SFC64(
            np.random.SeedSequence([int(c.seed) for c in configs]
                                   + [B, P, d])))

        # ---- dynamic state ---------------------------------------------- #
        self.w = np.zeros((B, d))
        self.pulled = np.zeros((B, P, d))
        self.steps = np.zeros((B, P), dtype=np.int64)
        self.alive = self.valid_slot.copy()
        self.computing = np.ones((B, P), dtype=bool)
        #: finish time while computing / next barrier-check time while not
        self.event_time = self.compute_time * (0.5 + self.rng.random((B, P)))
        #: continuous anchor of the node's current decision attempt
        self.ready = self.event_time.copy()
        self.blocked = np.zeros((B, P), dtype=bool)
        self.total_updates = np.zeros(B, dtype=np.int64)
        self.control_messages = np.zeros(B, dtype=np.int64)
        # per-draw control cost of the structured overlay (β lookups of
        # O(log N) hops + β step queries), matching OverlaySampler;
        # per-row because a ragged batch mixes populations
        self.hops_per_peer = np.maximum(
            1, np.ceil(np.log2(np.maximum(self.n_true, 2)))
        ).astype(np.int64) + 1

        # ---- tick grid + measurement grid ------------------------------- #
        ticks = np.arange(self.dt, self.duration + 1e-9, self.dt)
        if ticks.size == 0 or ticks[-1] < self.duration - 1e-9:
            ticks = np.append(ticks, self.duration)
        self.ticks = ticks
        self.m_times = np.arange(0.0, self.duration + 1e-9,
                                 self.measure_interval)
        self._trace_err: List[np.ndarray] = []
        self._trace_upd: List[np.ndarray] = []

        # ---- churn schedules: pre-sampled Poisson processes per row ----- #
        # i64[T, B] event counts per tick (tick i covers (t_{i-1}, t_i]);
        # empty rows for churn-free configs inside a churn batch
        if self.has_churn:
            edges = np.concatenate(([0.0], ticks))
            self.leave_counts = np.zeros((ticks.size, B), dtype=np.int64)
            self.join_counts = np.zeros((ticks.size, B), dtype=np.int64)
            for b, cfg in enumerate(configs):
                # sampled to the ROW's horizon: a merged shorter-duration
                # row must see no churn events past its own freeze point
                lt, jt = sample_churn_schedules(
                    self.rng, cfg.churn_leave_rate, cfg.churn_join_rate,
                    float(cfg.duration))
                self.leave_counts[:, b] = np.histogram(lt, bins=edges)[0]
                self.join_counts[:, b] = np.histogram(jt, bins=edges)[0]

    # ------------------------------------------------------------------ #
    def _measure(self) -> None:
        err = (np.linalg.norm(self.w - self.w_true, axis=1)
               / self.w_true_norm)
        self._trace_err.append(err)
        self._trace_upd.append(self.total_updates.copy())

    def _apply_updates(self, b_idx: np.ndarray, p_idx: np.ndarray) -> None:
        """Batched SGD pushes for every node that finished this tick.

        The residual is computed directly as X·(w_pulled − w*) − σ·ε, which
        folds the label draw into one projection; minibatch draws are f32
        (the simulation's noise floor is orders of magnitude above f32 eps).
        """
        K = b_idx.size
        X = self.rng.standard_normal((K, self.batch, self.d),
                                     dtype=np.float32)
        diff = (self.pulled[b_idx, p_idx]
                - self.w_true[b_idx]).astype(np.float32)
        eps = self.rng.standard_normal((K, self.batch), dtype=np.float32)
        resid = (np.einsum("kbd,kd->kb", X, diff)
                 - self.noise_std[b_idx, None].astype(np.float32) * eps)
        grads = np.einsum("kb,kbd->kd", resid, X) / self.batch
        # updates within a tick commute: each gradient depends only on the
        # node's pulled (stale) model, so the server sum is order-free.
        # b_idx comes from np.nonzero and is therefore sorted, so the
        # per-row sums are contiguous segments (reduceat ≫ np.add.at).
        rows, starts = np.unique(b_idx, return_index=True)
        self.w[rows] -= (self.lr[rows, None]
                         * np.add.reduceat(grads.astype(np.float64),
                                           starts, axis=0))
        self.total_updates += np.bincount(b_idx, minlength=self.B)

    def _sample_peers(self, bb: np.ndarray, pp: np.ndarray,
                      k: int) -> np.ndarray:
        """i64[K, k] peer indices: uniform without replacement, self excluded.

        For k ≪ P this is vectorized rejection sampling (draw k iid indices
        over the P−1 non-self slots, redraw rows with within-row collisions)
        — O(K·k) versus the O(K·P) of a full argpartition, which remains the
        fallback for dense samples.  No-churn path: every peer is alive.
        """
        K = bb.size
        if 3 * k >= self.P:
            scores = self.rng.random((K, self.P))
            scores[np.arange(K), pp] = 2.0
            return np.argpartition(scores, k - 1, axis=1)[:, :k]
        draw = self.rng.integers(0, self.P - 1, size=(K, k))
        draw += draw >= pp[:, None]          # skip over the self slot
        if k > 1:
            for _ in range(16):
                srt = np.sort(draw, axis=1)
                dup = (srt[:, 1:] == srt[:, :-1]).any(axis=1)
                if not dup.any():
                    break
                rows = np.flatnonzero(dup)
                redo = self.rng.integers(0, self.P - 1, size=(rows.size, k))
                redo += redo >= pp[rows, None]
                draw[rows] = redo
        return draw

    def _sample_peers_masked(self, bb: np.ndarray, pp: np.ndarray,
                             k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Churn path: k alive-peer indices + validity, self/dead excluded.

        Masked argpartition over uniform scores; a slot is valid iff its
        score stayed below the dead/self sentinel, which caps the effective
        sample at the row's alive-peer count — exactly the event engine's
        ``beta = min(beta, len(pool))`` under a compressed alive pool.
        """
        K = bb.size
        scores = self.rng.random((K, self.P))
        scores[~self.alive[bb]] = 2.0
        scores[np.arange(K), pp] = 2.0
        take = np.argpartition(scores, min(k, self.P - 1), axis=1)[:, :k]
        valid = np.take_along_axis(scores, take, axis=1) < 1.5
        return take, valid

    def _barrier_pass(self, cand: np.ndarray) -> np.ndarray:
        """Masked barrier predicates; bool[B, P], valid where ``cand``."""
        passed = np.zeros((self.B, self.P), dtype=bool)
        passed[self.is_asp] = True
        if self.full_view.any():
            fv = self.full_view
            fv_steps = self.steps[fv]
            # min over *alive* steps: a departed straggler's frozen counter
            # must not gate waiters (the event engine's churn-wake fix)
            masked = np.where(self.alive[fv], fv_steps,
                              np.iinfo(np.int64).max)
            lag = fv_steps - masked.min(axis=1, keepdims=True)
            thr = np.broadcast_to(self.staleness[fv, None], fv_steps.shape)
            if self.adaptive:
                # adaptive rows swap their effective threshold in: DSSP
                # the carried dynamic bound, Elastic-BSP the per-node
                # EMA step credit (same formulas as psp_tick_ref /
                # barrier_kernel.elastic_slack)
                thr = np.where(self.is_dssp[fv, None],
                               self.pol_thr[fv, None], thr)
                if self.is_ebsp.any():
                    live = np.where(self.alive, self.pol_ema, 0.0)
                    frac = 1.0 - self.pol_ema / np.maximum(
                        live.max(axis=1, keepdims=True), 1e-9)
                    slack = np.floor(self.ebsp_range[:, None] * frac
                                     ).astype(np.int64)
                    thr = np.where(self.is_ebsp[fv, None], slack[fv], thr)
            passed[fv] = lag <= thr
        sm = cand & self.sampled[:, None]
        b_idx, p_idx = np.nonzero(sm)
        if b_idx.size:
            betas = self.beta[b_idx]
            if self.adaptive:
                # β-annealing rows sample with their carried β
                betas = np.where(self.is_anneal[b_idx],
                                 self.pol_beta[b_idx], betas)
            for beta in np.unique(betas):
                pick = betas == beta
                bb, pp = b_idx[pick], p_idx[pick]
                k = min(int(beta), self.P - 1)
                if k <= 0:
                    passed[bb, pp] = True   # S = ∅ degenerates to ASP
                    continue
                if self.has_churn:
                    take, valid = self._sample_peers_masked(bb, pp, k)
                    n_sampled = valid.sum(axis=1)
                else:
                    take = self._sample_peers(bb, pp, k)
                    valid = np.ones_like(take, dtype=bool)
                    n_sampled = np.full(bb.size, k)
                peer_steps = self.steps[bb[:, None], take]
                my = self.steps[bb, pp]
                passed[bb, pp] = np.all(
                    (my[:, None] - peer_steps
                     <= self.staleness[bb][:, None]) | ~valid, axis=1)
                dist = self.distributed[bb]
                if dist.any():
                    self.control_messages += (
                        self.hops_per_peer
                        * np.bincount(bb[dist], weights=n_sampled[dist],
                                      minlength=self.B).astype(np.int64))
        return passed

    # ------------------------------------------------------------------ #
    # churn: batched leave/join event processing
    # ------------------------------------------------------------------ #
    def _churn_leave(self, rows: np.ndarray) -> None:
        """One leave event in each flagged row: kill a random alive node.

        Fires only while more than two nodes are alive (the population can
        drop to two), as the event engine; the event is consumed either
        way (a too-small row just skips the effect).
        """
        rows = rows & (self.alive.sum(axis=1) > 2)
        b = np.flatnonzero(rows)
        if b.size == 0:
            return
        scores = self.rng.random((b.size, self.P))
        scores[~self.alive[b]] = -1.0
        victim = scores.argmax(axis=1)
        self.alive[b, victim] = False

    def _churn_join(self, rows: np.ndarray, t: float) -> None:
        """One join event per flagged row: revive a random dead node.

        The joiner restarts at the current max alive step (the event
        engine's fresh-start rule) and decides this tick.
        """
        rows = rows & ~self.alive.all(axis=1)
        b = np.flatnonzero(rows)
        if b.size == 0:
            return
        scores = self.rng.random((b.size, self.P))
        scores[self.alive[b]] = -1.0
        node = scores.argmax(axis=1)
        self.alive[b, node] = True
        fresh = np.where(self.alive[b], self.steps[b],
                         np.iinfo(np.int64).min).max(axis=1)
        self.steps[b, node] = fresh
        self.computing[b, node] = False
        self.event_time[b, node] = t
        self.ready[b, node] = t
        self.blocked[b, node] = False

    def _process_churn(self, t: float, leave_n: np.ndarray,
                       join_n: np.ndarray) -> None:
        """Fire this tick's pre-sampled leave/join events, batched per round
        (several events per row per tick are possible but rare)."""
        leave_n, join_n = leave_n.copy(), join_n.copy()
        while (leave_n > 0).any() or (join_n > 0).any():
            self._churn_leave(leave_n > 0)
            self._churn_join(join_n > 0, t)
            leave_n -= leave_n > 0
            join_n -= join_n > 0

    # ------------------------------------------------------------------ #
    def _tick(self, t: float, tick_index: int) -> None:
        """Advance the whole batch by one grid tick (phases 0–3)."""
        if self.has_churn:
            self._process_churn(t, self.leave_counts[tick_index],
                                self.join_counts[tick_index])

        # 1. finishes: push updates, advance steps, become "deciding"
        fin = self.computing & self.alive & (self.event_time <= t + _EPS)
        # latest finish per row this tick: a full-view waiter unblocked
        # this tick was gated by (at most) that finish, so anchoring
        # there instead of the tick boundary removes the systematic
        # dt/2-per-round quantisation loss for BSP/SSP
        row_unblock = np.full(self.B, t)
        if fin.any():
            b_idx, p_idx = np.nonzero(fin)
            rows, starts = np.unique(b_idx, return_index=True)
            row_last = np.maximum.reduceat(self.event_time[fin], starts)
            row_unblock[rows] = np.minimum(row_last, t)
            self._apply_updates(b_idx, p_idx)
            self.steps[fin] += 1
            self.computing[fin] = False
            self.ready[fin] = self.event_time[fin]  # true finish time
            self.blocked[fin] = False

        # 2. barrier decisions for every due deciding node
        cand = ~self.computing & self.alive & (self.event_time <= t + _EPS)
        if cand.any():
            passed = self._barrier_pass(cand)
            start = cand & passed
            if start.any():
                b_idx, p_idx = np.nonzero(start)
                # anchor at the continuous ready time; a full-view node
                # unblocked by a peer's finish starts at that finish
                # (the grid analogue of the event simulator's
                # min-moved wakeup)
                t0 = np.where(self.blocked[start]
                              & self.full_view[b_idx],
                              np.maximum(row_unblock[b_idx],
                                         self.ready[start]),
                              self.ready[start])
                self.pulled[b_idx, p_idx] = self.w[b_idx]
                dur = (self.compute_time[b_idx, p_idx]
                       * (0.5 + self.rng.random(b_idx.size)))
                self.event_time[start] = t0 + dur
                self.computing[start] = True
                self.blocked[start] = False
                if self.adaptive and self.is_ebsp.any():
                    # Elastic-BSP folds each starter's freshly drawn
                    # duration into its per-node EMA (the grid engines'
                    # observation point — see psp_tick_ref block 3b)
                    eb = self.is_ebsp[b_idx]
                    if eb.any():
                        al = self.ebsp_alpha[b_idx[eb]]
                        old = self.pol_ema[b_idx[eb], p_idx[eb]]
                        self.pol_ema[b_idx[eb], p_idx[eb]] = \
                            (1.0 - al) * old + al * dur[eb]
            fail = cand & ~passed
            if fail.any():
                self.blocked[fail] = True
                # sampled rows re-poll on the poll cadence; full-view
                # rows stay due and re-check next tick
                sm_fail = fail & self.sampled[:, None]
                self.ready[sm_fail] += self.poll_interval
                self.event_time[sm_fail] = self.ready[sm_fail]

        # 2b. adaptive-policy state updates from this tick's observed
        #     post-finish step spread (decisions above used the OLD state)
        if self.adaptive:
            masked = np.where(self.alive, self.steps,
                              np.iinfo(np.int64).min)
            gap = masked.max(axis=1) - np.where(
                self.alive, self.steps, np.iinfo(np.int64).max).min(axis=1)
            gap = np.where(self.alive.any(axis=1), gap, 0)
            self.pol_thr = np.where(
                self.is_dssp,
                np.clip(gap, self.pol_lo, self.staleness), self.pol_thr)
            self.pol_beta = np.where(
                self.is_anneal,
                np.clip(self.beta_lo + gap - self.staleness,
                        self.beta_lo, self.beta_cap), self.pol_beta)

    def _results(self, errs: np.ndarray, upds: np.ndarray) -> List[SimResult]:
        """Assemble per-row :class:`SimResult`\\ s from [B, M] traces.

        A merged batch can carry rows with different horizons (jax
        backend): each row's traces are cut at its own duration — the
        trailing grid points belong to longer-lived batch mates.
        """
        final_err = (np.linalg.norm(self.w - self.w_true, axis=1)
                     / self.w_true_norm)
        out = []
        for b in range(self.B):
            n = int(self.n_true[b])   # drop ragged padding slots
            mb = min(errs.shape[1],
                     int(np.searchsorted(self.m_times,
                                         self.row_duration[b] + 1e-9)))
            out.append(SimResult(
                steps=self.steps[b, :n].copy(),
                times=self.m_times[:mb].copy(),
                errors=errs[b, :mb].copy(),
                server_updates=upds[b, :mb].copy(),
                control_messages=int(self.control_messages[b]),
                total_updates=int(self.total_updates[b]),
                mean_progress=float(self.steps[b][self.alive[b]].mean()),
                final_error=float(final_err[b]),
            ))
        return out

    def run(self) -> List[SimResult]:
        """Advance the batch over the whole tick grid on this backend."""
        if self.backend == "jax":
            from repro.core import vector_sim_jax
            return vector_sim_jax.run_batch(self)

        self._measure()                      # t = 0 trace point
        m_next = 1
        for i, t in enumerate(self.ticks):
            self._tick(t, i)
            # 3. error / server-update traces on the measurement grid
            while m_next < self.m_times.size and \
                    self.m_times[m_next] <= t + _EPS:
                self._measure()
                m_next += 1

        errs = np.stack(self._trace_err, axis=1)        # [B, M]
        upds = np.stack(self._trace_upd, axis=1)        # [B, M]
        return self._results(errs, upds)


# --------------------------------------------------------------------------- #
def run_sweep(configs: Sequence[SimConfig], *,
              dt: Optional[float] = None,
              backend: str = "numpy") -> List[SimResult]:
    """Run a batch of simulations on the vectorized grid engine.

    Configs are grouped by structural shape and each group is advanced as
    one :class:`VectorSimulator` — churn configs run natively with
    per-row alive masks; nothing falls back to the event-driven reference.
    The numpy backend groups strictly (identical ``n_nodes``, duration
    and churn-ness per batch); the jax backend groups by the relaxed
    :func:`_merge_key`, padding ragged ``n_nodes`` with permanently-dead
    alive-mask slots and freezing shorter-duration rows at their own
    horizon, so mixed sweeps run as one chunk-scan schedule per
    (dim, batch, cadence) shape.  Results come back in input order
    regardless of backend or grouping.

    Args:
      configs: scenario list (any mix of shapes/barriers/churn).
      dt: grid width; defaults to each group's ``poll_interval``.
      backend: ``"numpy"`` (array ops per tick) or ``"jax"`` (donated
        chunked scans over the tick grid with the fused tick of
        :mod:`repro.kernels.psp_tick`, sharded over the device mesh —
        :mod:`repro.core.vector_sim_jax`).
    """
    results: List[Optional[SimResult]] = [None] * len(configs)
    key_fn = _merge_key if backend == "jax" else _group_key
    groups: Dict[Tuple, List[int]] = {}
    for i, cfg in enumerate(configs):
        groups.setdefault(key_fn(cfg), []).append(i)
    for idx in groups.values():
        batch = VectorSimulator([configs[i] for i in idx], dt=dt,
                                backend=backend).run()
        for i, res in zip(idx, batch):
            results[i] = res
    return results  # type: ignore[return-value]
