"""JAX backend of the vectorized sweep engine: one device-resident scan.

One grid tick splits into a *control plane* (churn, finish bookkeeping,
barrier decisions, start/re-poll anchoring over the ``(B, P)`` state
pytree) and a *data plane* (the masked SGD push, a batched einsum).  The
control plane runs as one fused kernel — the Pallas tick
(:mod:`repro.kernels.psp_tick`) on TPU, its pure-jnp twin on CPU, selected
by :func:`repro.kernels.ops.psp_tick` with ``impl="auto"`` (override with
the ``PSP_TICK_IMPL`` env var, e.g. ``interpret`` to exercise the kernel
on CPU).  :func:`run_batch` drives the whole tick grid with ``lax.scan``
under ``jit``: the state pytree never leaves the device during the sweep —
inputs are staged up front, the scan carries everything, and exactly one
``device_get`` at the end fetches traces plus final state
(``tests/test_vector_sim_jax.py`` holds a ``transfer_guard`` test on
this).

Semantics mirror :class:`repro.core.vector_sim.VectorSimulator`'s numpy
tick exactly (same phases, same anchoring, same alive-mask churn rules);
only the dynamics RNG differs (threefry vs SFC64), so the two backends
agree at the distribution level and each is individually deterministic
(golden traces in ``tests/test_vector_sim_jax.py``).

Design notes for the hot path:

* Barrier predicates and the straggler duration model are single-sourced
  in :mod:`repro.core.barrier_kernel` — the same code the SPMD trainer
  (:mod:`repro.core.spmd_psp`) routes through — and β-samples come from
  the shared :mod:`repro.core.sampling` primitives.  All per-tick noise is
  drawn outside the kernel, so every ``impl`` consumes an identical RNG
  stream.
* Without churn, one peer-index draw per tick is shared across the B
  scenario rows (each row's marginal stays an exact uniform β-sample);
  likewise one minibatch draw per (tick, node) is shared across rows.
  Cross-row correlation is irrelevant for per-row statistics — use the
  numpy backend when cross-row independence matters (it decorrelates via
  finisher-ordered stream consumption).
* Ragged batches: scenario groups that differ only in ``n_nodes`` (and
  churn-ness) are padded to a common P and merged into **one** scan —
  padded node slots are permanently dead ``alive``-mask entries that the
  masked-min barrier, the alive-masked β-sample and the join pool all
  ignore (``valid_slot`` guards joins), so ragged sweeps cost one compile
  instead of one per shape.
* Times are f32 (no global x64 flag); the due-comparison epsilon scales
  with ``dt`` to stay above f32 resolution at the horizon.
* The compiled scan is cached by structural signature
  (``P, d, batch, k_max, has_churn, masked, impl``) so repeated sweeps of
  the same shape (the common benchmark/test pattern) compile once.
"""
from __future__ import annotations

import functools
import os
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.simulator import SimResult
from repro.kernels import ops

__all__ = ["run_batch", "tick_impl"]


def tick_impl() -> str:
    """Control-plane tick implementation (``PSP_TICK_IMPL`` env override).

    ``auto`` (default): Pallas kernel on TPU, jnp reference elsewhere;
    ``pallas`` / ``interpret`` / ``ref`` force a path (``interpret`` runs
    the kernel through the Pallas interpreter — the CPU test/bench path).
    """
    return os.environ.get("PSP_TICK_IMPL", "auto")


@functools.lru_cache(maxsize=32)
def _compiled_scan(P: int, d: int, batch: int, k_max: int, has_churn: bool,
                   masked: bool, impl: str):
    """Jitted scan over the tick grid, specialised on structural shape."""

    def tick(params, carry, x):
        t, i, leave_n, join_n = x
        state = {k: carry[k] for k in
                 ("steps", "alive", "computing", "event_time", "ready",
                  "blocked", "pend_leave", "pend_join")}
        B = state["steps"].shape[0]
        tk = jax.random.fold_in(params["key"], i)
        k_mini, k_samp, k_dur, *k_rest = jax.random.split(
            tk, 4 if has_churn else 3)

        # pre-draw this tick's noise (identical stream for every impl)
        rand = {"dur": jax.random.uniform(k_dur, (B, P))}
        if k_max > 0:
            if masked:
                rand["scores"] = jax.random.uniform(k_samp, (B, P, P))
            elif k_max == 1:
                rand["u1"] = jax.random.uniform(k_samp, (P,))
            else:
                rand["scores"] = jax.random.uniform(k_samp, (P, P))
        if has_churn:
            u_l, u_j = jax.random.uniform(k_rest[0], (2, B, P))
            rand["leave"], rand["join"] = u_l, u_j

        # fused control-plane tick: churn → finish → decide → start
        state, out = ops.psp_tick(state, rand, params, t, leave_n, join_n,
                                  k_max=k_max, has_churn=has_churn,
                                  masked=masked, impl=impl)

        # data plane: masked SGD push for every node that finished.
        # One minibatch draw per (tick, node), shared across rows.
        fin = out["fin"]
        w, pulled = carry["w"], carry["pulled"]
        blob = jax.random.normal(k_mini, (P, batch, d + 1),
                                 dtype=jnp.float32)
        X, mb_noise = blob[..., :d], blob[..., d]
        diff = pulled - params["w_true"][:, None, :]
        resid = (jnp.einsum("pbd,kpd->kpb", X, diff)
                 - params["noise_std"][:, None, None] * mb_noise[None])
        grads = jnp.einsum("kpb,pbd->kpd", resid, X) / batch
        gsum = jnp.sum(jnp.where(fin[..., None], grads, 0.0), axis=1)
        w = w - params["lr"][:, None] * gsum
        pulled = jnp.where(out["start"][..., None], w[:, None, :], pulled)

        err = (jnp.linalg.norm(w - params["w_true"], axis=1)
               / params["w_true_norm"])
        total_updates = carry["total_updates"] + out["n_fin"]
        carry = {**state, "w": w, "pulled": pulled,
                 "total_updates": total_updates,
                 "control": carry["control"] + out["ctrl"]}
        return carry, (err, total_updates)

    def scan_fn(params, carry, xs):
        return lax.scan(functools.partial(tick, params), carry, xs)

    return jax.jit(scan_fn)


def _prepare(sim) -> Tuple:
    """Stage a batch: (compiled scan, params, carry, xs) — all device-ready.

    Everything the grid loop touches is materialised here, so the scan
    itself performs zero host transfers; the zero-copy test in
    ``tests/test_vector_sim_jax.py`` runs this staging, then executes the
    scan under ``jax.transfer_guard("disallow")``.
    """
    B, P, d = sim.B, sim.P, sim.d
    f32 = jnp.float32
    k_max = int(min(max(int(sim.beta.max(initial=-1)), 0), P - 1))
    masked = sim.has_churn or bool((sim.n_true < P).any())
    eps = max(1e-9, 1e-3 * sim.dt)   # above f32 resolution at the horizon

    seed = np.random.SeedSequence(
        [int(c.seed) for c in sim.configs] + [B, P, d]).generate_state(1)[0]
    params = {
        "key": jax.random.PRNGKey(int(seed)),
        "eps": jnp.asarray(eps, f32),
        "poll": jnp.asarray(sim.poll_interval, f32),
        "w_true": jnp.asarray(sim.w_true, f32),
        "w_true_norm": jnp.asarray(sim.w_true_norm, f32),
        "compute_time": jnp.asarray(sim.compute_time, f32),
        "lr": jnp.asarray(sim.lr, f32),
        "noise_std": jnp.asarray(sim.noise_std, f32),
        "staleness": jnp.asarray(sim.staleness, jnp.int32),
        "beta_clip": jnp.asarray(
            np.clip(sim.beta, 0, sim.n_true - 1), jnp.int32),
        "is_asp": jnp.asarray(sim.is_asp),
        "full_view": jnp.asarray(sim.full_view),
        "sampled": jnp.asarray(sim.sampled),
        "valid_slot": jnp.asarray(sim.valid_slot),
        "dist_hops": jnp.asarray(
            np.where(sim.distributed & sim.sampled, sim.hops_per_peer, 0),
            jnp.int32),
    }
    carry = {
        "w": jnp.zeros((B, d), f32),
        "pulled": jnp.zeros((B, P, d), f32),
        "steps": jnp.zeros((B, P), jnp.int32),
        "alive": jnp.asarray(sim.alive),
        "computing": jnp.asarray(sim.computing),
        "event_time": jnp.asarray(sim.event_time, f32),
        "ready": jnp.asarray(sim.ready, f32),
        "blocked": jnp.asarray(sim.blocked),
        "total_updates": jnp.zeros(B, jnp.int32),
        "control": jnp.zeros(B, jnp.int32),
        "pend_leave": jnp.zeros(B, jnp.int32),
        "pend_join": jnp.zeros(B, jnp.int32),
    }
    T = sim.ticks.size
    if sim.has_churn:
        lc = jnp.asarray(sim.leave_counts, jnp.int32)
        jc = jnp.asarray(sim.join_counts, jnp.int32)
    else:
        lc = jc = jnp.zeros((T, B), jnp.int32)
    xs = (jnp.asarray(sim.ticks, f32), jnp.arange(T, dtype=jnp.int32),
          lc, jc)
    scan = _compiled_scan(P, d, sim.batch, k_max, sim.has_churn, masked,
                          tick_impl())
    return scan, params, carry, xs


def run_batch(sim) -> List[SimResult]:
    """Run a :class:`~repro.core.vector_sim.VectorSimulator` batch on jax.

    Consumes the simulator's numpy-initialised static state (identical to
    the numpy backend: per-seed init replay, initial busy clocks, churn
    schedules), scans the tick grid under jit with the fused control-plane
    tick, and writes the final state back so result assembly is shared
    with the numpy path.  One ``device_get`` per sweep moves the traces
    and final state to the host together.
    """
    B = sim.B
    scan, params, carry, xs = _prepare(sim)
    final, (err_t, upd_t) = scan(params, carry, xs)
    final, err_t, upd_t = jax.device_get(
        jax.block_until_ready((final, err_t, upd_t)))

    # select the measurement grid: value at m_j = state after the first
    # tick t with m_j ≤ t + eps (the numpy engine's while-loop rule),
    # plus the t = 0 point (w = 0 ⇒ normalized error exactly 1)
    m_idx = np.searchsorted(sim.ticks, sim.m_times[1:] - 1e-9)
    errs = np.concatenate([np.ones((B, 1)),
                           np.asarray(err_t, np.float64).T[:, m_idx]],
                          axis=1)
    upds = np.concatenate([np.zeros((B, 1), np.int64),
                           np.asarray(upd_t, np.int64).T[:, m_idx]], axis=1)

    # write final state back so SimResult assembly is shared with numpy
    sim.w = np.asarray(final["w"], np.float64)
    sim.steps = np.asarray(final["steps"], np.int64)
    sim.alive = np.asarray(final["alive"])
    sim.total_updates = np.asarray(final["total_updates"], np.int64)
    sim.control_messages = np.asarray(final["control"], np.int64)
    return sim._results(errs, upds)
