"""JAX backend of the vectorized sweep engine: donated, sharded chunk scans.

One grid tick — control plane (churn, finish bookkeeping, barrier
decisions, start/re-poll anchoring) *and* data plane (masked SGD push +
model-view pull) — is one fused kernel: the Pallas tick
(:mod:`repro.kernels.psp_tick`) on TPU, its pure-jnp twin on CPU,
selected by :func:`repro.kernels.ops.psp_tick` with ``impl="auto"``
(override with the ``PSP_TICK_IMPL`` env var, e.g. ``interpret`` to
exercise the kernel on CPU).

:func:`run_batch` executes the tick grid as a sequence of **chunked,
donated scans** laid out by :func:`repro.core.sweep_plan.plan_sweep`:

* The grid is blocked into *superticks* of ``stride`` ticks.  Each
  supertick draws its whole noise block in a handful of batched
  ``jax.random`` calls, runs an inner ``lax.scan`` over its ticks, and
  emits **one** trace record — traces are only consumed on the
  measurement grid, so recording every tick is pure waste.
* Superticks are grouped into pow2-length chunks, each a separate call
  into one jitted scan whose ``(B, P)`` carry is **donated** — XLA
  reuses the state pytree's buffers across chunks instead of
  double-buffering them.  The chunk loop early-exits once every row is
  past its horizon, so scheduled-but-dead superticks are never executed.
* The batch is sharded over a 2-D ``(rows, nodes)`` device mesh with
  ``shard_map`` (axis names from :mod:`repro.parallel.sharding`; the
  degenerate 1×1 mesh on an unflagged CPU IS the single-device engine).
  The scenario dimension shards over ``rows``; the P node slots — state,
  node-keyed draws, the minibatch blob — stay **node-sliced** over
  ``nodes`` end-to-end, and the tick's cross-node reductions run as
  collectives (:func:`repro.kernels.psp_tick.psp_tick_sharded`).
  Per-row noise is keyed by *global row id* and node-keyed noise by
  *global node id*, with every draw either sliced from the full-width
  stream or assembled from disjoint global-id blocks, so every mesh
  factorization consumes identical draws and ``run_sweep(backend="jax")``
  is **bit-identical** across device counts *and* factorizations —
  multi-device is transparent (``tests/test_vector_sim_jax.py``'s
  cross-mesh equivalence suite pins this).

The scan itself performs zero host transfers: inputs are staged (and
sharded) once by :func:`_prepare`, chunks hand the donated carry to each
other on device, and exactly one ``device_get`` at the end fetches
traces plus final state (``tests/test_vector_sim_jax.py`` holds
``transfer_guard`` and donation tests on this).

Semantics mirror :class:`repro.core.vector_sim.VectorSimulator`'s numpy
tick exactly (same phases, same anchoring, same alive-mask churn rules);
only the dynamics RNG differs (threefry vs SFC64), so the two backends
agree at the distribution level and each is individually deterministic
(golden traces in ``tests/test_vector_sim_jax.py``).

Design notes for the hot path:

* Barrier predicates and the straggler duration model are single-sourced
  in :mod:`repro.core.barrier_kernel` — the same code the SPMD trainer
  (:mod:`repro.core.spmd_psp`) routes through — and β-samples come from
  the shared :mod:`repro.core.sampling` primitives.  All noise is drawn
  outside the kernel, so every ``impl`` consumes an identical RNG stream.
* Without churn, one peer-score draw per tick is shared across the B
  scenario rows (each row's marginal stays an exact uniform β-sample);
  likewise one minibatch draw per (tick, node) is shared across rows.
  Cross-row correlation is irrelevant for per-row statistics — use the
  numpy backend when cross-row independence matters (it decorrelates via
  finisher-ordered stream consumption).
* Ragged batches: scenario groups that differ in ``n_nodes``, churn-ness
  or **duration** are padded to a common P and merged into one schedule —
  padded node slots are permanently dead ``alive``-mask entries, and a
  row past its own horizon freezes (the fused tick's ``active`` gate), so
  ragged sweeps cost one compile instead of one per shape.
* Times are f32 (no global x64 flag); the due-comparison epsilon scales
  with ``dt`` to stay above f32 resolution at the horizon.
* The compiled chunk scan is cached by structural signature
  (``P, d, batch, k_max, has_churn, masked, adaptive, impl, stride,
  rows, nodes``) so repeated sweeps of the same shape (the common
  benchmark/test pattern) compile once per chunk length.
* Node sharding (``nodes > 1``, opt-in via ``PSP_SWEEP_MESH=RxN``) keeps
  the carried ``(B, P)`` state and the supertick noise blocks sliced to
  ``P_loc = P / nodes`` per shard — the memory that caps system size at
  100k+ nodes — while the tick gathers only one tick's worth of
  transients to full width where bit-identity demands reference shapes
  (the β-sample peer view, the data-plane contraction; see
  ``psp_tick_sharded``).  The nodes axis must divide P exactly — the
  planner clamps to the nearest divisor — because a padded node slot
  would widen the full-view reductions and flip fully-alive batches onto
  the masked sampling path.
* Adaptive barrier policies (dssp / ebsp / β-annealing) ride in the
  scanned carry as the :data:`~repro.kernels.psp_tick.POLICY_STATE_KEYS`
  pytree entries; static batches have ``adaptive=False`` and compile the
  exact pre-policy tick (the keys are simply absent), which is what
  keeps the static golden traces bit-identical.  Adaptive rows draw no
  extra noise — the annealed β consumes the same pre-drawn score slots
  (``k_max`` covers β_max) — so the planner's noise budget is unchanged.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import env
from repro.core.simulator import SimResult
from repro.core.sweep_plan import plan_sweep
from repro.kernels import ops
from repro.kernels.psp_tick import POLICY_STATE_KEYS, STATE_KEYS
from repro.parallel.sharding import (SWEEP_NODES_AXIS, SWEEP_ROWS_AXIS,
                                     sweep_mesh)

__all__ = ["run_batch", "tick_impl"]

#: params entries replicated across the mesh (everything else is per-row
#: or per-node and therefore sharded)
_REPLICATED_PARAMS = frozenset({"key", "eps", "poll"})

#: params entries with a trailing node dimension — sharded over the
#: ``nodes`` mesh axis alongside the node-dimensioned carry
_NODE_PARAMS = frozenset({"compute_time", "valid_slot"})

#: carry entries with a node dimension (axis 1); everything else in the
#: carry is per-row only and rides replicated over the nodes axis
_NODE_CARRY = frozenset({"steps", "alive", "computing", "event_time",
                         "ready", "blocked", "pulled", "pol_ema"})


def tick_impl() -> str:
    """Tick implementation (``PSP_TICK_IMPL`` env override).

    ``auto`` (default): Pallas kernel on TPU, jnp reference elsewhere;
    ``pallas`` / ``interpret`` / ``ref`` force a path (``interpret`` runs
    the kernel through the Pallas interpreter — the CPU test/bench path).
    """
    return env.get_str("PSP_TICK_IMPL")


def _row_spec(ndim: int) -> PartitionSpec:
    """Leading-axis row sharding for an ``ndim``-rank per-row array."""
    return PartitionSpec(*((SWEEP_ROWS_AXIS,) + (None,) * (ndim - 1)))


def _node_spec(ndim: int) -> PartitionSpec:
    """(B, P, ...) sharding: rows on axis 0, node slots on axis 1."""
    return PartitionSpec(*((SWEEP_ROWS_AXIS, SWEEP_NODES_AXIS)
                           + (None,) * (ndim - 2)))


def _specs(params: Dict, carry: Dict, xs: Dict) -> Tuple[Dict, Dict, Dict]:
    """(params, carry, xs) partition-spec pytrees for the chunk scan.

    Per-row arrays shard on their leading (B) axis over ``rows``;
    node-dimensioned arrays (:data:`_NODE_CARRY` / :data:`_NODE_PARAMS`)
    additionally shard their P axis over ``nodes``; ``node_ids`` shards
    its single axis over both (nodes-major: each node column's draw-id
    block splits over the rows axis — see the supertick blob draw); the
    churn schedules shard on their trailing row axis; everything else is
    replicated.  The same trees drive both ``shard_map`` and the input
    staging in :func:`_prepare`, so staged buffers land exactly where the
    compiled scan expects them (no resharding copy on call).
    """
    def p_spec(k, v):
        if k in _REPLICATED_PARAMS:
            return PartitionSpec()
        if k == "node_ids":
            return PartitionSpec((SWEEP_NODES_AXIS, SWEEP_ROWS_AXIS))
        if k in _NODE_PARAMS:
            return _node_spec(np.ndim(v))
        return _row_spec(np.ndim(v))

    p_specs = {k: p_spec(k, v) for k, v in params.items()}
    c_specs = {k: (_node_spec(np.ndim(v)) if k in _NODE_CARRY
                   else _row_spec(np.ndim(v))) for k, v in carry.items()}
    x_specs = {"sup": PartitionSpec(), "t": PartitionSpec(),
               "leave": PartitionSpec(None, None, SWEEP_ROWS_AXIS),
               "join": PartitionSpec(None, None, SWEEP_ROWS_AXIS)}
    return p_specs, c_specs, {k: x_specs[k] for k in xs}


@functools.lru_cache(maxsize=32)
def _compiled_chunk(P: int, d: int, batch: int, k_max: int, has_churn: bool,
                    masked: bool, adaptive: bool, impl: str, stride: int,
                    rows: int, nodes: int):
    """(jitted chunk scan, mesh), specialised on structural shape.

    The returned function maps ``(params, carry, xs) -> (carry', (err,
    upd))`` where ``xs`` covers one chunk of superticks; the carry is
    donated, the B axis is sharded over the ``rows`` mesh axis and the P
    node slots over ``nodes``.  Chunk length only changes input shapes,
    so jit's own cache specialises per pow2 block while this wrapper
    caches the mesh + shard_map plumbing.
    """
    mesh = sweep_mesh(rows, nodes)
    p_loc = P // nodes           # planner guarantees nodes | P
    node_axis = SWEEP_NODES_AXIS if nodes > 1 else None
    kw = dict(k_max=k_max, has_churn=has_churn, masked=masked,
              adaptive=adaptive, impl=impl, node_axis=node_axis)
    state_keys = STATE_KEYS + (POLICY_STATE_KEYS if adaptive else ())

    def tick(params, carry, xt):
        state = {k: carry[k] for k in state_keys}
        rand = {k: xt[k] for k in xt
                if k in ("dur", "scores", "u1", "leave", "join", "X", "mb")}
        state, out = ops.psp_tick(state, rand, params, xt["t"],
                                  xt["lc"], xt["jc"], **kw)
        return {**state,
                "total_updates": carry["total_updates"] + out["n_fin"],
                "control": carry["control"] + out["ctrl"]}, None

    def supertick(params, carry, x):
        # one batched noise block per supertick: a handful of keyed
        # jax.random calls instead of per-tick dispatch.  Per-row noise
        # is keyed by global row id, node-keyed noise by global node id,
        # and every draw reaches the tick either as the shard's slice of
        # the full-width stream (row-keyed draws are drawn full and
        # sliced to the local node columns — the values cannot depend on
        # the factorization) or assembled from disjoint global-id blocks
        # (the blob and shared-score draws below), so every mesh shape
        # consumes identical noise — the cross-mesh bit-identity
        # invariant.  On the 1×1 mesh all slices are identity and this
        # is exactly the single-device draw.
        row_ids, node_ids = params["row_ids"], params["node_ids"]
        nid0 = lax.axis_index(SWEEP_NODES_AXIS) * p_loc
        k_sup = jax.random.fold_in(params["key"], x["sup"])
        k_mini, k_samp, k_dur, k_churn = jax.random.split(k_sup, 4)
        fold = jax.vmap(jax.random.fold_in, (None, 0))
        # minibatch blob keyed per (tick, node): the draw comes out in
        # scan layout directly (stride leading), so no supertick-sized
        # transpose sits between the RNG and the tick loop.  node_ids is
        # nodes-major — each node column's rows-padded id block splits
        # over the rows axis, so the gather over *rows* reassembles the
        # column's global ids [nid0, nid0 + p_loc) in order and the blob
        # stays node-sliced (the 100k-node memory win): no shard ever
        # materialises the (stride, P, m, d+1) block
        kt = fold(k_mini, x["sup"] * stride + jnp.arange(stride))
        blob_loc = jax.vmap(lambda k: jax.vmap(
            lambda kk: jax.random.normal(kk, (batch, d + 1)))(
                fold(k, node_ids)))(kt)               # (stride, ids_loc, ...)
        blob = lax.all_gather(blob_loc, SWEEP_ROWS_AXIS, axis=1,
                              tiled=True)[:, :p_loc]  # (stride, p_loc, ...)
        dur = jnp.moveaxis(jax.vmap(
            lambda k: jax.random.uniform(k, (stride, P)))(
                fold(k_dur, row_ids)), 1, 0)          # (stride, b_loc, P)
        xt = {"t": x["t"], "lc": x["leave"], "jc": x["join"],
              "X": blob[..., :d], "mb": blob[..., d],
              "dur": lax.dynamic_slice_in_dim(dur, nid0, p_loc, 2)}
        if k_max > 0:
            if masked:
                sc = jnp.moveaxis(jax.vmap(
                    lambda k: jax.random.uniform(k, (stride, P, P)))(
                        fold(k_samp, row_ids)), 1, 0)
                # slice the deciding-node axis; peers keep full width
                xt["scores"] = lax.dynamic_slice_in_dim(sc, nid0, p_loc, 2)
            elif k_max == 1:
                u1 = jax.random.uniform(k_samp, (stride, P))
                xt["u1"] = lax.dynamic_slice_in_dim(u1, nid0, p_loc, 1)
            else:
                sc_loc = jax.vmap(
                    lambda k: jax.random.uniform(k, (stride, P)))(
                        fold(k_samp, node_ids))
                sc = lax.all_gather(sc_loc, SWEEP_ROWS_AXIS, tiled=True)
                xt["scores"] = jnp.moveaxis(sc, 1, 0)[:, :p_loc]
        if has_churn:
            cu = jax.vmap(
                lambda k: jax.random.uniform(k, (stride, 2, P)))(
                    fold(k_churn, row_ids))
            xt["leave"] = lax.dynamic_slice_in_dim(
                jnp.moveaxis(cu[:, :, 0], 0, 1), nid0, p_loc, 2)
            xt["join"] = lax.dynamic_slice_in_dim(
                jnp.moveaxis(cu[:, :, 1], 0, 1), nid0, p_loc, 2)
        carry, _ = lax.scan(functools.partial(tick, params), carry, xt)
        err = (jnp.linalg.norm(carry["w"] - params["w_true"], axis=1)
               / params["w_true_norm"])
        return carry, (err, carry["total_updates"])

    def chunk(params, carry, xs):
        return lax.scan(functools.partial(supertick, params), carry, xs)

    def sharded(params, carry, xs):
        specs = _specs(params, carry, xs)
        # check_rep=False: pallas_call (the interpret/TPU tick) has no
        # replication rule; correctness is pinned by the cross-mesh
        # bit-identity suite instead.  Traces (and the per-row carry) are
        # replicated over the nodes axis — their out_specs mention only
        # rows, so shard_map keeps one copy
        return shard_map(chunk, mesh=mesh, in_specs=specs,
                         out_specs=(specs[1],
                                    (PartitionSpec(None, SWEEP_ROWS_AXIS),
                                     PartitionSpec(None, SWEEP_ROWS_AXIS))),
                         check_rep=False)(params, carry, xs)

    return jax.jit(sharded, donate_argnums=(1,)), mesh


def _measure_idx(sim) -> np.ndarray:
    """Global tick index of each measurement point (t > 0).

    Single definition on purpose: the planner aligns the record stride on
    these indices and :func:`run_batch` maps them onto supertick records
    with ``(m_idx + 1) // stride − 1`` — both sides must see the exact
    same epsilon and slicing or traces silently shift by a record.
    """
    return np.searchsorted(sim.ticks, sim.m_times[1:] - 1e-9)


def _prepare(sim):
    """Stage a batch: (chunk fn, plan, params, carry, xs chunks) on device.

    Everything the grid loop touches is materialised and sharded here, so
    the chunk loop itself performs zero host transfers; the zero-copy
    test in ``tests/test_vector_sim_jax.py`` runs this staging, then
    executes the chunks under ``jax.transfer_guard("disallow")``.
    """
    B, P, d = sim.B, sim.P, sim.d
    f32 = jnp.float32
    k_max = int(min(max(int(sim.beta.max(initial=-1)), 0), P - 1))
    masked = sim.has_churn or bool((sim.n_true < P).any())
    eps = max(1e-9, 1e-3 * sim.dt)   # above f32 resolution at the horizon
    T = sim.ticks.size
    plan = plan_sweep(T, _measure_idx(sim), B, P, batch=sim.batch, d=d,
                      k_max=k_max,
                      masked=masked, has_churn=sim.has_churn)
    Bp = plan.b_pad

    def pad_rows(a, fill=0):
        if Bp == B:
            return a
        pad = np.full((Bp - B,) + a.shape[1:], fill, dtype=a.dtype)
        return np.concatenate([a, pad], axis=0)

    seed = np.random.SeedSequence(
        [int(c.seed) for c in sim.configs] + [B, P, d]).generate_state(1)[0]
    # node-keyed draw ids, nodes-major: each node column's global ids
    # [n·p_loc, (n+1)·p_loc) padded up to the rows axis (the pad ids
    # overlap the next column — drawn redundantly, sliced away after the
    # rows gather).  On the 1-D mesh this is exactly arange(node_pad).
    col = plan.node_pad // plan.nodes
    node_ids = (np.arange(col)[None, :]
                + plan.p_loc * np.arange(plan.nodes)[:, None]).reshape(-1)
    params = {
        "key": jax.random.PRNGKey(int(seed)),
        "eps": jnp.asarray(eps, f32),
        "poll": jnp.asarray(sim.poll_interval, f32),
        "row_ids": jnp.arange(Bp, dtype=jnp.int32),
        "node_ids": jnp.asarray(node_ids, jnp.int32),
        "w_true": jnp.asarray(pad_rows(sim.w_true), f32),
        # padded rows never tick; a unit norm keeps their (discarded)
        # error trace finite
        "w_true_norm": jnp.asarray(pad_rows(sim.w_true_norm, 1.0), f32),
        "compute_time": jnp.asarray(pad_rows(sim.compute_time, 1.0), f32),
        "lr": jnp.asarray(pad_rows(sim.lr), f32),
        "noise_std": jnp.asarray(pad_rows(sim.noise_std), f32),
        "horizon": jnp.asarray(pad_rows(sim.row_duration, -1.0), f32),
        "staleness": jnp.asarray(pad_rows(sim.staleness), jnp.int32),
        "beta_clip": jnp.asarray(
            pad_rows(np.clip(sim.beta, 0, sim.n_true - 1)), jnp.int32),
        "is_asp": jnp.asarray(pad_rows(sim.is_asp)),
        "full_view": jnp.asarray(pad_rows(sim.full_view)),
        "sampled": jnp.asarray(pad_rows(sim.sampled)),
        "valid_slot": jnp.asarray(pad_rows(sim.valid_slot)),
        "dist_hops": jnp.asarray(
            pad_rows(np.where(sim.distributed & sim.sampled,
                              sim.hops_per_peer, 0)), jnp.int32),
    }
    adaptive = bool(getattr(sim, "adaptive", False))
    if adaptive:
        # adaptive-policy row tags + knobs; padded rows are tagged static
        # (they are frozen anyway)
        params.update(
            is_dssp=jnp.asarray(pad_rows(sim.is_dssp)),
            is_ebsp=jnp.asarray(pad_rows(sim.is_ebsp)),
            is_anneal=jnp.asarray(pad_rows(sim.is_anneal)),
            pol_lo=jnp.asarray(pad_rows(sim.pol_lo), jnp.int32),
            beta_lo=jnp.asarray(pad_rows(sim.beta_lo), jnp.int32),
            ebsp_range=jnp.asarray(pad_rows(sim.ebsp_range), f32),
            ebsp_alpha=jnp.asarray(pad_rows(sim.ebsp_alpha), f32),
        )
    carry = {
        "w": jnp.zeros((Bp, d), f32),
        "pulled": jnp.zeros((Bp, P, d), f32),
        "steps": jnp.zeros((Bp, P), jnp.int32),
        "alive": jnp.asarray(pad_rows(sim.alive)),
        "computing": jnp.asarray(pad_rows(sim.computing)),
        "event_time": jnp.asarray(pad_rows(sim.event_time.astype(
            np.float32), 1.0)),
        "ready": jnp.asarray(pad_rows(sim.ready.astype(np.float32), 1.0)),
        "blocked": jnp.asarray(pad_rows(sim.blocked)),
        "total_updates": jnp.zeros(Bp, jnp.int32),
        "control": jnp.zeros(Bp, jnp.int32),
        "pend_leave": jnp.zeros(Bp, jnp.int32),
        "pend_join": jnp.zeros(Bp, jnp.int32),
    }
    if adaptive:
        # policy state joins the scanned carry (donated with the rest)
        carry.update(
            pol_thr=jnp.asarray(pad_rows(sim.pol_thr), jnp.int32),
            pol_ema=jnp.asarray(pad_rows(sim.pol_ema.astype(np.float32))),
            pol_beta=jnp.asarray(pad_rows(sim.pol_beta), jnp.int32),
        )

    # scheduled tick grid: live ticks, then dead padding beyond every
    # horizon (the fused tick's active gate makes them no-ops)
    T_sched = plan.n_ticks
    dt = float(sim.dt)
    t_sched = np.concatenate(
        [sim.ticks, sim.ticks[-1] + dt * np.arange(1, T_sched - T + 1)]
    ).astype(np.float32)
    lc = np.zeros((T_sched, Bp), np.int32)
    jc = np.zeros((T_sched, Bp), np.int32)
    if sim.has_churn:
        lc[:T, :B] = sim.leave_counts
        jc[:T, :B] = sim.join_counts

    chunk_fn, mesh = _compiled_chunk(P, d, sim.batch, k_max, sim.has_churn,
                                     masked, adaptive, tick_impl(),
                                     plan.stride, plan.rows, plan.nodes)
    p_specs, c_specs, _ = _specs(params, carry,
                                 {"sup": 0, "t": 0, "leave": 0, "join": 0})
    shard = lambda spec: NamedSharding(mesh, spec)
    params = jax.device_put(params,
                            {k: shard(s) for k, s in p_specs.items()})
    carry = jax.device_put(carry, {k: shard(s) for k, s in c_specs.items()})

    xs_chunks = []
    rec = 0
    for n_rec in plan.chunks:
        lo, hi = rec * plan.stride, (rec + n_rec) * plan.stride
        xs = {
            "sup": jnp.arange(rec, rec + n_rec, dtype=jnp.int32),
            "t": jnp.asarray(
                t_sched[lo:hi].reshape(n_rec, plan.stride)),
            "leave": jnp.asarray(
                lc[lo:hi].reshape(n_rec, plan.stride, Bp)),
            "join": jnp.asarray(
                jc[lo:hi].reshape(n_rec, plan.stride, Bp)),
        }
        _, _, x_specs = _specs(params, carry, xs)
        xs_chunks.append(jax.device_put(
            xs, {k: shard(s) for k, s in x_specs.items()}))
        rec += n_rec
    return chunk_fn, plan, params, carry, xs_chunks


def run_batch(sim) -> List[SimResult]:
    """Run a :class:`~repro.core.vector_sim.VectorSimulator` batch on jax.

    Consumes the simulator's numpy-initialised static state (identical to
    the numpy backend: per-seed init replay, initial busy clocks, churn
    schedules), executes the planned chunk scans with the fused tick —
    donated carry, sharded rows, one trace record per supertick — and
    writes the final state back so result assembly is shared with the
    numpy path.  One ``device_get`` per sweep moves the traces and final
    state to the host together.
    """
    B = sim.B
    chunk_fn, plan, params, carry, xs_chunks = _prepare(sim)
    errs_d, upds_d = [], []
    rec = 0
    for xs in xs_chunks:
        if rec >= plan.n_rec_live:
            break            # every row is past its horizon: dead chunk
        carry, (e, u) = chunk_fn(params, carry, xs)
        errs_d.append(e)
        upds_d.append(u)
        rec += e.shape[0]
    final, errs_rec, upds_rec = jax.device_get(
        jax.block_until_ready((carry, errs_d, upds_d)))
    err_t = np.concatenate(errs_rec)[:plan.n_rec_live, :B]
    upd_t = np.concatenate(upds_rec)[:plan.n_rec_live, :B]

    # select the measurement grid: value at m_j = state after the first
    # tick t with m_j ≤ t + eps (the numpy engine's while-loop rule);
    # the planner guarantees that tick lands on a supertick record.
    # Plus the t = 0 point (w = 0 ⇒ normalized error exactly 1).
    r_idx = (_measure_idx(sim) + 1) // plan.stride - 1
    errs = np.concatenate([np.ones((B, 1)),
                           np.asarray(err_t, np.float64).T[:, r_idx]],
                          axis=1)
    upds = np.concatenate([np.zeros((B, 1), np.int64),
                           np.asarray(upd_t, np.int64).T[:, r_idx]], axis=1)

    # write final state back so SimResult assembly is shared with numpy
    sim.w = np.asarray(final["w"][:B], np.float64)
    sim.steps = np.asarray(final["steps"][:B], np.int64)
    sim.alive = np.asarray(final["alive"][:B])
    sim.total_updates = np.asarray(final["total_updates"][:B], np.int64)
    sim.control_messages = np.asarray(final["control"][:B], np.int64)
    if "pol_thr" in final:
        sim.pol_thr = np.asarray(final["pol_thr"][:B], np.int64)
        sim.pol_ema = np.asarray(final["pol_ema"][:B], np.float64)
        sim.pol_beta = np.asarray(final["pol_beta"][:B], np.int64)
    return sim._results(errs, upds)
