"""JAX backend of the vectorized sweep engine: one jitted ``lax.scan``.

One grid tick (churn → finish → decide → start → measure) is a pure
function over the ``(B, P)`` state pytree; :func:`run_batch` drives it with
``lax.scan`` under ``jit`` over the whole tick grid, so a full scenario
batch advances without touching Python between ticks — the accelerator
(or the XLA CPU loop) stays busy for the entire sweep.

Semantics mirror :class:`repro.core.vector_sim.VectorSimulator`'s numpy
tick exactly (same phases, same anchoring, same alive-mask churn rules);
only the dynamics RNG differs (threefry vs SFC64), so the two backends
agree at the distribution level and each is individually deterministic
(golden traces in ``tests/test_vector_sim_jax.py``).

Design notes for the hot path:

* The β-sample decide step reuses the SPMD trainer's sampling primitive
  (:func:`repro.core.sampling.sample_steps_jax` with ``exclude_self=True``
  over ``[B, W]`` batched steps; the alive-masked
  :func:`repro.core.sampling.sample_alive_peer_indices_jax` under churn) —
  the simulator and the trainer exercise one sampling primitive.
* Without churn, one peer-index draw per tick is shared across the B
  scenario rows (each row's marginal stays an exact uniform β-sample);
  likewise one minibatch draw per (tick, node) is shared across rows.
  Cross-row correlation is irrelevant for per-row statistics — use the
  numpy backend when cross-row independence matters (it decorrelates via
  finisher-ordered stream consumption).
* Times are f32 (no global x64 flag); the due-comparison epsilon scales
  with ``dt`` to stay above f32 resolution at the horizon.
* The compiled scan is cached by structural signature
  (``P, d, batch, k_max, has_churn``) so repeated sweeps of the same shape
  (the common benchmark/test pattern) compile once.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.sampling import (sample_alive_peer_indices_jax,
                                 sample_steps_jax)
from repro.core.simulator import SimResult

__all__ = ["run_batch"]

_I32_MAX = np.iinfo(np.int32).max
_I32_MIN = np.iinfo(np.int32).min


@functools.lru_cache(maxsize=32)
def _compiled_scan(P: int, d: int, batch: int, k_max: int, has_churn: bool):
    """Jitted scan over the tick grid, specialised on structural shape."""

    def tick(params, carry, x):
        t, i, leave_n, join_n = x
        eps, poll = params["eps"], params["poll"]
        alive = carry["alive"]
        steps = carry["steps"]
        computing = carry["computing"]
        event_time = carry["event_time"]
        ready = carry["ready"]
        blocked = carry["blocked"]
        w, pulled = carry["w"], carry["pulled"]
        B = w.shape[0]
        tk = jax.random.fold_in(params["key"], i)
        k_mini, k_samp, k_dur, *k_rest = jax.random.split(
            tk, 4 if has_churn else 3)

        # 0. churn: one pre-sampled leave/join event per row per tick
        #    (multi-event ticks carry the surplus forward — they are rare,
        #    and the event engine's Poisson totals are preserved)
        if has_churn:
            k_churn, = k_rest
            pend_l = carry["pend_leave"] + leave_n
            pend_j = carry["pend_join"] + join_n
            u_l, u_j = jax.random.uniform(k_churn, (2, B, P))
            # leave: kill a uniform alive node (only while > 2 are alive)
            do_l = (pend_l > 0) & (jnp.sum(alive, axis=1) > 2)
            victim = jnp.argmax(jnp.where(alive, u_l, -1.0), axis=1)
            v_oh = victim[:, None] == jnp.arange(P)
            alive = alive & ~(do_l[:, None] & v_oh)
            # join: revive a uniform dead node at the max alive step;
            #       it decides this tick
            do_j = (pend_j > 0) & ~jnp.all(alive, axis=1)
            joiner = jnp.argmax(jnp.where(alive, -1.0, u_j), axis=1)
            sel = do_j[:, None] & (joiner[:, None] == jnp.arange(P))
            alive = alive | sel
            fresh = jnp.max(jnp.where(alive, steps, _I32_MIN), axis=1)
            steps = jnp.where(sel, fresh[:, None], steps)
            computing = computing & ~sel
            event_time = jnp.where(sel, t, event_time)
            ready = jnp.where(sel, t, ready)
            blocked = blocked & ~sel
            carry_churn = {"pend_leave": pend_l - (pend_l > 0),
                           "pend_join": pend_j - (pend_j > 0)}
        else:
            carry_churn = {"pend_leave": carry["pend_leave"],
                           "pend_join": carry["pend_join"]}

        # 1. finishes: push updates, advance steps, become "deciding"
        fin = computing & alive & (event_time <= t + eps)
        any_fin = jnp.any(fin, axis=1)
        row_last = jnp.max(jnp.where(fin, event_time, -jnp.inf), axis=1)
        row_unblock = jnp.where(any_fin, jnp.minimum(row_last, t), t)
        # one minibatch draw per (tick, node), shared across rows
        blob = jax.random.normal(k_mini, (P, batch, d + 1),
                                 dtype=jnp.float32)
        X, mb_noise = blob[..., :d], blob[..., d]
        diff = pulled - params["w_true"][:, None, :]
        resid = (jnp.einsum("pbd,kpd->kpb", X, diff)
                 - params["noise_std"][:, None, None] * mb_noise[None])
        grads = jnp.einsum("kpb,pbd->kpd", resid, X) / batch
        gsum = jnp.sum(jnp.where(fin[..., None], grads, 0.0), axis=1)
        w = w - params["lr"][:, None] * gsum
        total_updates = carry["total_updates"] + jnp.sum(fin, axis=1)
        steps = steps + fin
        computing = computing & ~fin
        ready = jnp.where(fin, event_time, ready)
        blocked = blocked & ~fin

        # 2. barrier decisions for every due deciding node
        cand = ~computing & alive & (event_time <= t + eps)
        min_alive = jnp.min(jnp.where(alive, steps, _I32_MAX), axis=1)
        pass_fv = steps - min_alive[:, None] <= params["staleness"][:, None]
        if k_max > 0:
            if has_churn:
                take, valid = sample_alive_peer_indices_jax(
                    k_samp, alive, k_max, exclude_self=True)
                valid = valid & (jnp.arange(k_max)
                                 < params["beta_clip"][:, None, None])
                peer_steps = jnp.take_along_axis(steps[:, None, :], take,
                                                 axis=-1)
            else:
                # the SPMD trainer's primitive, batched over scenario rows
                # (one index draw shared across B; exact per-row marginals)
                peer_steps, valid = sample_steps_jax(
                    k_samp, steps, k_max, exclude_self=True)
                valid = valid & (jnp.arange(k_max)
                                 < params["beta_clip"][:, None, None])
            lag_ok = (steps[:, :, None] - peer_steps
                      <= params["staleness"][:, None, None])
            pass_sm = jnp.all(lag_ok | ~valid, axis=-1)
            n_sampled = jnp.sum(valid, axis=-1)
        else:
            pass_sm = jnp.ones((B, P), dtype=bool)
            n_sampled = jnp.zeros((B, P), dtype=jnp.int32)
        passed = jnp.where(params["is_asp"][:, None], True,
                           jnp.where(params["full_view"][:, None],
                                     pass_fv, pass_sm))
        # distributed sampled rows pay β lookups per decide attempt
        control = carry["control"] + jnp.sum(
            jnp.where(cand, n_sampled * params["dist_hops"][:, None], 0),
            axis=1)

        # 3. starts / re-polls
        start = cand & passed
        t0 = jnp.where(blocked & params["full_view"][:, None],
                       jnp.maximum(row_unblock[:, None], ready), ready)
        dur = params["compute_time"] * (
            0.5 + jax.random.uniform(k_dur, (B, P)))
        event_time = jnp.where(start, t0 + dur, event_time)
        pulled = jnp.where(start[..., None], w[:, None, :], pulled)
        computing = computing | start
        fail = cand & ~passed
        blocked = (blocked | fail) & ~start
        sm_fail = fail & params["sampled"][:, None]
        ready = jnp.where(sm_fail, ready + poll, ready)
        event_time = jnp.where(sm_fail, ready, event_time)

        # 4. per-tick trace (measurement grid selected by the caller)
        err = (jnp.linalg.norm(w - params["w_true"], axis=1)
               / params["w_true_norm"])
        carry = {"w": w, "pulled": pulled, "steps": steps, "alive": alive,
                 "computing": computing, "event_time": event_time,
                 "ready": ready, "blocked": blocked,
                 "total_updates": total_updates, "control": control,
                 **carry_churn}
        return carry, (err, total_updates)

    def scan_fn(params, carry, xs):
        return lax.scan(functools.partial(tick, params), carry, xs)

    return jax.jit(scan_fn)


def run_batch(sim) -> List[SimResult]:
    """Run a :class:`~repro.core.vector_sim.VectorSimulator` batch on jax.

    Consumes the simulator's numpy-initialised static state (identical to
    the numpy backend: per-seed init replay, initial busy clocks, churn
    schedules), scans the tick grid under jit, and writes the final state
    back so result assembly is shared with the numpy path.
    """
    B, P, d = sim.B, sim.P, sim.d
    f32 = jnp.float32
    k_max = int(min(max(int(sim.beta.max(initial=-1)), 0), P - 1))
    eps = max(1e-9, 1e-3 * sim.dt)   # above f32 resolution at the horizon

    seed = np.random.SeedSequence(
        [int(c.seed) for c in sim.configs] + [B, P, d]).generate_state(1)[0]
    params = {
        "key": jax.random.PRNGKey(int(seed)),
        "eps": jnp.asarray(eps, f32),
        "poll": jnp.asarray(sim.poll_interval, f32),
        "w_true": jnp.asarray(sim.w_true, f32),
        "w_true_norm": jnp.asarray(sim.w_true_norm, f32),
        "compute_time": jnp.asarray(sim.compute_time, f32),
        "lr": jnp.asarray(sim.lr, f32),
        "noise_std": jnp.asarray(sim.noise_std, f32),
        "staleness": jnp.asarray(sim.staleness, jnp.int32),
        "beta_clip": jnp.asarray(np.clip(sim.beta, 0, P - 1), jnp.int32),
        "is_asp": jnp.asarray(sim.is_asp),
        "full_view": jnp.asarray(sim.full_view),
        "sampled": jnp.asarray(sim.sampled),
        "dist_hops": jnp.asarray(
            np.where(sim.distributed & sim.sampled, sim._hops_per_peer, 0),
            jnp.int32),
    }
    carry = {
        "w": jnp.zeros((B, d), f32),
        "pulled": jnp.zeros((B, P, d), f32),
        "steps": jnp.zeros((B, P), jnp.int32),
        "alive": jnp.asarray(sim.alive),
        "computing": jnp.asarray(sim.computing),
        "event_time": jnp.asarray(sim.event_time, f32),
        "ready": jnp.asarray(sim.ready, f32),
        "blocked": jnp.asarray(sim.blocked),
        "total_updates": jnp.zeros(B, jnp.int32),
        "control": jnp.zeros(B, jnp.int32),
        "pend_leave": jnp.zeros(B, jnp.int32),
        "pend_join": jnp.zeros(B, jnp.int32),
    }
    T = sim.ticks.size
    if sim.has_churn:
        lc = jnp.asarray(sim.leave_counts, jnp.int32)
        jc = jnp.asarray(sim.join_counts, jnp.int32)
    else:
        lc = jc = jnp.zeros((T, B), jnp.int32)
    xs = (jnp.asarray(sim.ticks, f32), jnp.arange(T, dtype=jnp.int32),
          lc, jc)

    scan = _compiled_scan(P, d, sim.batch, k_max, sim.has_churn)
    final, (err_t, upd_t) = jax.block_until_ready(scan(params, carry, xs))

    # select the measurement grid: value at m_j = state after the first
    # tick t with m_j ≤ t + eps (the numpy engine's while-loop rule),
    # plus the t = 0 point (w = 0 ⇒ normalized error exactly 1)
    m_idx = np.searchsorted(sim.ticks, sim.m_times[1:] - 1e-9)
    errs = np.concatenate([np.ones((B, 1)),
                           np.asarray(err_t, np.float64).T[:, m_idx]],
                          axis=1)
    upds = np.concatenate([np.zeros((B, 1), np.int64),
                           np.asarray(upd_t, np.int64).T[:, m_idx]], axis=1)

    # write final state back so SimResult assembly is shared with numpy
    sim.w = np.asarray(final["w"], np.float64)
    sim.steps = np.asarray(final["steps"], np.int64)
    sim.alive = np.asarray(final["alive"])
    sim.total_updates = np.asarray(final["total_updates"], np.int64)
    sim.control_messages = np.asarray(final["control"], np.int64)
    return sim._results(errs, upds)
