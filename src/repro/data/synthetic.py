"""Deterministic synthetic LM data pipeline.

Generates learnable token streams (a noisy order-2 Markov process over the
vocabulary) so training losses actually go down in tests/examples, with a
shard-aware iterator: each PSP worker / data shard derives its stream from
``fold_in(seed, shard_index)``, matching the paper's i.i.d.-per-node data
assumption (§5).

``make_batch_specs`` produces the ShapeDtypeStruct stand-ins the dry-run
lowers against (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import AxisRules


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)   # shared task definition
        v = self.vocab_size
        # order-1 transition logits with strong structure + noise
        self._trans = rng.normal(size=(v, v)).astype(np.float32)
        self._trans += 3.0 * np.eye(v, k=1, dtype=np.float32)[
            np.arange(v)[:, None] % v, np.arange(v)[None, :] % v]
        self._rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.shard]))

    def _sample_seq(self) -> np.ndarray:
        v = self.vocab_size
        seq = np.empty(self.seq_len, dtype=np.int32)
        seq[0] = self._rng.integers(v)
        # vectorised Gumbel-max over the transition row
        for i in range(1, self.seq_len):
            logits = self._trans[seq[i - 1]]
            g = self._rng.gumbel(size=v).astype(np.float32)
            seq[i] = int(np.argmax(logits + g))
        return seq

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        while True:
            toks = np.stack([self._sample_seq() for _ in range(self.batch)])
            yield {"tokens": jnp.asarray(toks)}


def make_batch_specs(cfg, shape, rules: Optional[AxisRules] = None,
                     kind: Optional[str] = None) -> Dict:
    """ShapeDtypeStruct batch for (arch cfg, InputShape) — the dry-run input.

    train/prefill: {"tokens": (B, S_tok)[, "embeds": (B, F, D)]}
    decode: {"tokens": (B, 1)} (cache specs come from models.cache_defs).
    """
    kind = kind or shape.kind
    B = shape.global_batch

    def spec(shp, dtype, axes):
        sharding = rules.sharding(axes, shp) if rules else None
        return jax.ShapeDtypeStruct(shp, dtype, sharding=sharding)

    if kind == "decode":
        return {"tokens": spec((B, 1), jnp.int32, ("batch", None))}
    F = cfg.frontend_tokens
    batch = {"tokens": spec((B, shape.seq_len - F), jnp.int32,
                            ("batch", None))}
    if F:
        batch["embeds"] = spec((B, F, cfg.d_model), jnp.bfloat16,
                               ("batch", None, None))
    return batch
