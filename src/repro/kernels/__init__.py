"""Pallas TPU kernels and their dispatch layer.

Hot-spot kernels for the PSP reproduction: flash attention, RMSNorm and
the SSD scan serve the model zoo, and :mod:`repro.kernels.psp_tick` fuses
the sweep engine's per-tick barrier/churn control plane (the paper's
sampling primitive evaluated on-device).  Call through
:mod:`repro.kernels.ops` — ``impl="auto"`` picks the Pallas kernel on TPU
and the pure-jnp reference elsewhere; ``impl="interpret"`` runs the kernel
through the Pallas interpreter for CPU tests.  Oracles live in
:mod:`repro.kernels.ref`.
"""
