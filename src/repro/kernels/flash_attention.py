"""Pallas TPU flash-attention kernel (forward).

TPU-native adaptation (DESIGN.md §3.5): q/k/v tiles live in VMEM via
BlockSpecs; the MXU consumes (block_q × hd)·(hd × block_k) tiles with
hardware-aligned 128-multiples; online softmax state (m, l, acc) sits in
VMEM scratch and is carried across the sequential k-block grid dimension
(TPU grids iterate the last axis innermost and sequentially, which is
exactly the flash accumulation order).  Causal + sliding-window masking is
applied in-tile; fully-masked tiles are skipped with ``pl.when`` so SWA
does O(S·W) work.

Layout: q (B, H, Sq, hd), k/v (B, H, Sk, hd) — MHA (the ops wrapper
repeats GQA KV heads, mirroring the model's XLA path).
Grid: (B·H, nq, nk); block shapes (1, block_q, hd) / (1, block_k, hd).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_tpu"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            block_q: int, block_k: int, nk: int, causal: bool,
            window: Optional[int], softcap: Optional[float], scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, _NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # tile visibility: skip tiles fully outside the causal/window band
    first_q = qi * block_q
    last_q = first_q + block_q - 1
    first_k = ki * block_k
    last_k = first_k + block_k - 1
    visible = True
    if causal:
        visible = jnp.logical_and(visible, first_k <= last_q)
    if window is not None:
        visible = jnp.logical_and(visible, last_k > first_q - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_sc[...]
        l_prev = l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_sc[...] = m_new
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * corr + pv

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_sc[...] /
                    jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_tpu(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        block_q: int = 128,
                        block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q, k, v: (B, H, S, hd) → (B, H, S, hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k
    bh = B * H
    qr = q.reshape(bh, Sq, hd)
    kr = k.reshape(bh, Sk, hd)
    vr = v.reshape(bh, Sk, hd)

    kern = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, nk=nk, causal=causal,
        window=window, softcap=softcap, scale=hd ** -0.5)

    out = pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, hd)
