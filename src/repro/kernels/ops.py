"""Jit'd dispatch wrappers for the Pallas kernels.

``impl="auto"`` picks the Pallas kernel on TPU and the pure-jnp reference on
CPU (where Mosaic kernels cannot lower; interpret mode is for tests).  The
model code calls these wrappers so a TPU deployment gets the kernels without
touching model code.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.psp_tick import (psp_tick_ref, psp_tick_sharded,
                                    psp_tick_tpu)
from repro.kernels.rmsnorm import rmsnorm_tpu
from repro.kernels.ssd_scan import ssd_scan_tpu

__all__ = ["attention", "ssd", "rmsnorm", "psp_tick"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _dispatch(impl: str):
    """(use_kernel, interpret) for an ``impl`` string; typos fail loudly.

    ``ref``/``cpu`` both name the pure-jnp reference; an unknown string
    (e.g. a mistyped ``PSP_TICK_IMPL``) raises instead of silently
    running the reference while claiming to time the kernel.
    """
    if impl not in ("auto", "pallas", "interpret", "ref", "cpu"):
        raise ValueError(f"unknown impl {impl!r}; choose from "
                         "auto|pallas|interpret|ref|cpu")
    return (impl == "pallas" or (impl == "auto" and _on_tpu()),
            impl == "interpret")


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "impl"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None,
              impl: str = "auto") -> jax.Array:
    """q,k,v: (B, S, H, hd) MHA layout → (B, S, H, hd)."""
    use_kernel, interp = _dispatch(impl)
    if use_kernel or interp:
        o = flash_attention_tpu(q.transpose(0, 2, 1, 3),
                                k.transpose(0, 2, 1, 3),
                                v.transpose(0, 2, 1, 3),
                                causal=causal, window=window,
                                softcap=softcap, interpret=interp)
        return o.transpose(0, 2, 1, 3)
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd(xdt: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array, *,
        chunk: int = 128, impl: str = "auto") -> jax.Array:
    """Chunked SSD over (BH, S, ·) tensors (see ssd_scan_tpu)."""
    use_kernel, interp = _dispatch(impl)
    if use_kernel or interp:
        return ssd_scan_tpu(xdt, dA, Bm, Cm, chunk=chunk, interpret=interp)
    # reference path: reconstruct (x·dt, dt·A) → sequential recurrence.
    # ssd_ref wants per-step dt and B/C per head; feed dt=1 with xdt/dA
    # pre-multiplied (algebraically identical).
    BH, S, hd = xdt.shape
    x4 = xdt[:, :, None, :]                     # (BH, S, 1, hd)
    dt4 = jnp.ones((BH, S, 1), xdt.dtype)
    A4 = jnp.zeros((1,), jnp.float32)
    # y_t = C·h_t ; h_t = exp(dA_t)·h + B x·dt — emulate via custom scan
    f32 = jnp.float32

    def step(h, inp):
        xt, dat, bt, ct = inp
        h = h * jnp.exp(dat.astype(f32))[:, None, None] \
            + jnp.einsum("bn,bd->bdn", bt.astype(f32), xt.astype(f32))
        return h, jnp.einsum("bn,bdn->bd", ct.astype(f32), h)

    h0 = jnp.zeros((BH, hd, Bm.shape[-1]), f32)
    xs = (xdt.swapaxes(0, 1), dA.swapaxes(0, 1), Bm.swapaxes(0, 1),
          Cm.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(xdt.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "impl"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            impl: str = "auto") -> jax.Array:
    """RMS-normalise the trailing axis of ``x`` with gain ``w``."""
    use_kernel, interp = _dispatch(impl)
    if use_kernel or interp:
        return rmsnorm_tpu(x, w, eps=eps, interpret=interp)
    return ref.rmsnorm_ref(x, w, eps)


#: state/noise/param entries carrying a node dimension — the pytree slices
#: a node shard owns under a 2-D ``(rows, nodes)`` sweep mesh
_NODE_STATE = ("steps", "alive", "computing", "event_time", "ready",
               "blocked", "pulled", "pol_ema")


def _psp_tick_gathered(state, rand, params, t, leave_n, join_n, *,
                       k_max: int, has_churn: bool, masked: bool,
                       adaptive: bool, interpret: bool, node_axis: str):
    """Kernel path under a node-sharded mesh: gather → full tick → slice.

    The Pallas kernel has no collective form, so each node shard gathers
    the node-dimensioned operands to full width, runs the exact
    single-shard kernel (identical operand shapes ⇒ identical bits to the
    unsharded call), and keeps only its own node slice of the outputs.
    Memory-wise this is the pre-sharding footprint for one tick's
    transients — the *carried* state stays node-sliced — which is the
    honest trade until a collective Mosaic tick exists.
    """
    Pl = state["steps"].shape[1]
    g1 = lambda x: jax.lax.all_gather(x, node_axis, axis=1, tiled=True)
    g0 = lambda x: jax.lax.all_gather(x, node_axis, axis=0, tiled=True)
    st = {k: (g1(v) if k in _NODE_STATE else v) for k, v in state.items()}
    rd = dict(rand)
    rd["dur"] = g1(rd["dur"])
    rd["X"], rd["mb"] = g0(rd["X"]), g0(rd["mb"])
    if "scores" in rd:      # masked scores are (B, Pl, P); shared (Pl, P)
        rd["scores"] = g1(rd["scores"]) if rd["scores"].ndim == 3 \
            else g0(rd["scores"])
    if "u1" in rd:
        rd["u1"] = g0(rd["u1"])
    if has_churn:
        rd["leave"], rd["join"] = g1(rd["leave"]), g1(rd["join"])
    pr = dict(params)
    pr["compute_time"] = g1(pr["compute_time"])
    pr["valid_slot"] = g1(pr["valid_slot"])
    new_state, out = psp_tick_tpu(st, rd, pr, t, leave_n, join_n,
                                  k_max=k_max, has_churn=has_churn,
                                  masked=masked, adaptive=adaptive,
                                  interpret=interpret)
    off = jax.lax.axis_index(node_axis) * Pl
    sl = lambda x: jax.lax.dynamic_slice_in_dim(x, off, Pl, 1)
    for k in _NODE_STATE:
        if k in new_state:
            new_state[k] = sl(new_state[k])
    return new_state, {**out, "fin": sl(out["fin"]),
                       "start": sl(out["start"])}


def psp_tick(state, rand, params, t, leave_n, join_n, *,
             k_max: int, has_churn: bool, masked: bool,
             adaptive: bool = False, impl: str = "auto",
             node_axis: Optional[str] = None):
    """One fused PSP sweep-grid tick — control plane *and* data plane
    (see :mod:`repro.kernels.psp_tick`).

    Dispatch mirrors the other wrappers: ``impl="auto"`` runs the Pallas
    kernel on TPU and the pure-jnp reference elsewhere; ``"pallas"`` /
    ``"interpret"`` / ``"ref"`` force a path.  Both paths consume the same
    pre-drawn noise in ``rand``, so the sweep's RNG stream — and therefore
    its golden traces — are independent of ``impl``.  Not jitted here: the
    caller's ``lax.scan`` (:mod:`repro.core.vector_sim_jax`) traces it.

    ``node_axis`` names the sweep mesh's node axis when the caller runs
    under ``shard_map`` with node-sliced ``(B, P_loc)`` state (the 2-D
    ``(rows, nodes)`` mesh of :mod:`repro.core.sweep_plan`): the reference
    becomes :func:`~repro.kernels.psp_tick.psp_tick_sharded` (cross-node
    reductions as exact collectives) and the kernel paths gather to full
    width, tick, and slice back — both bit-identical to ``node_axis=None``
    on unsharded state.
    """
    use_kernel, interp = _dispatch(impl)
    if node_axis is not None:
        if use_kernel or interp:
            return _psp_tick_gathered(state, rand, params, t, leave_n,
                                      join_n, k_max=k_max,
                                      has_churn=has_churn, masked=masked,
                                      adaptive=adaptive, interpret=interp,
                                      node_axis=node_axis)
        return psp_tick_sharded(state, rand, params, t, leave_n, join_n,
                                k_max=k_max, has_churn=has_churn,
                                masked=masked, adaptive=adaptive,
                                node_axis=node_axis)
    if use_kernel or interp:
        return psp_tick_tpu(state, rand, params, t, leave_n, join_n,
                            k_max=k_max, has_churn=has_churn, masked=masked,
                            adaptive=adaptive, interpret=interp)
    return psp_tick_ref(state, rand, params, t, leave_n, join_n,
                        k_max=k_max, has_churn=has_churn, masked=masked,
                        adaptive=adaptive)
