"""Pallas TPU kernel for one PSP sweep-grid tick (the control plane).

One grid tick of the vectorized sweep engine
(:mod:`repro.core.vector_sim_jax`) is two very different workloads glued
together: a *data-plane* SGD push (a batched matmul XLA already schedules
well) and a *control-plane* update over the ``(B, P)`` scenario state —
churn, finish bookkeeping, the masked-min full-view barrier, the β-sample
barrier predicate, and start/re-poll anchoring.  The control plane is a
swarm of tiny masked element-wise ops and row reductions; left to XLA it
becomes dozens of kernels per tick.  This module fuses it into **one**
Pallas kernel, one grid row per scenario, so a whole tick's barrier logic
runs out of VMEM with no intermediate HBM traffic.

Two implementations, held tick-for-tick identical by
``tests/test_kernels.py``:

* :func:`psp_tick_ref` — pure jnp reference.  β-sampling routes through
  the shared primitives (:func:`repro.core.sampling.sample_peer_indices_jax`
  / ``sample_alive_peer_indices_jax``) and the unified barrier model
  (:mod:`repro.core.barrier_kernel`), i.e. the exact code the SPMD trainer
  uses.  This is what ``impl="auto"`` runs on CPU.
* :func:`psp_tick_tpu` — the Pallas kernel.  Selecting β peers by top-k
  needs a gather, which the TPU vector unit hates; the kernel instead
  consumes the *same* uniform score matrix and evaluates the predicate by
  rank: a lagging peer is inside the β-sample iff fewer than β eligible
  peers precede it in ``(score, index)`` order.  Ties break exactly like
  ``lax.top_k`` (lower index first), so the two paths agree draw-for-draw,
  not just in distribution.

All randomness is drawn *outside* (plain ``jax.random`` on-device) and
passed in, so ref and kernel consume identical noise and the sweep's RNG
stream is independent of ``impl``.

Shapes and state layout (``B`` scenario rows × ``P`` node slots):

========== ============ ==================================================
key         shape        meaning
========== ============ ==================================================
steps       i32[B, P]    logical clock per node
alive       bool[B, P]   membership (churn / ragged padding)
computing   bool[B, P]   node busy with a local step
event_time  f32[B, P]    finish time while computing, else next check time
ready       f32[B, P]    continuous anchor of the current decide attempt
blocked     bool[B, P]   failed its last barrier check
pend_*      i32[B]       carried-over churn events (≤ 1 fires per tick)
========== ============ ==================================================

VMEM budget: the dominant buffer is one ``P × P`` f32 score matrix per
grid row (~4 MB at P = 1024), comfortably resident; P beyond ~1500 would
need a lane-tiled variant.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import barrier_kernel

__all__ = ["psp_tick_ref", "psp_tick_tpu", "STATE_KEYS"]

#: carried control-plane state, in canonical order
STATE_KEYS = ("steps", "alive", "computing", "event_time", "ready",
              "blocked", "pend_leave", "pend_join")

_I32_MAX = np.iinfo(np.int32).max
_I32_MIN = np.iinfo(np.int32).min


# --------------------------------------------------------------------------- #
# pure-jnp reference (the CPU path of ops.psp_tick)
# --------------------------------------------------------------------------- #
def psp_tick_ref(state: Dict[str, jax.Array], rand: Dict[str, jax.Array],
                 params: Dict[str, jax.Array], t: jax.Array,
                 leave_n: jax.Array, join_n: jax.Array, *,
                 k_max: int, has_churn: bool, masked: bool,
                 ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """One control-plane tick, batched over B scenario rows (pure jnp).

    Args:
      state: the ``(B, P)`` control-plane pytree (:data:`STATE_KEYS`).
      rand: pre-drawn uniforms — ``dur`` f32[B, P]; plus ``scores``
        (f32[B, P, P] when ``masked`` else f32[P, P]) or ``u1`` f32[P]
        (β = 1 fast path) when ``k_max > 0``; plus ``leave``/``join``
        f32[B, P] when ``has_churn``.
      params: per-row policy arrays — ``staleness``/``beta_clip``/
        ``dist_hops`` i32[B]; ``is_asp``/``full_view``/``sampled`` bool[B];
        ``compute_time`` f32[B, P]; ``valid_slot`` bool[B, P] (ragged
        padding mask); scalars ``eps``/``poll``.
      t: f32[] — this tick's grid time.
      leave_n / join_n: i32[B] — churn events due this tick.
      k_max: static max sample-slot count over the batch.
      has_churn: static — whether churn state/noise is present.
      masked: static — per-row alive-masked sampling (churn or ragged).

    Returns:
      (new_state, out) where ``out`` holds ``fin``/``start`` bool[B, P]
      node masks and ``n_fin``/``ctrl`` i32[B] row counters.
    """
    steps, alive = state["steps"], state["alive"]
    computing, blocked = state["computing"], state["blocked"]
    event_time, ready = state["event_time"], state["ready"]
    B, P = steps.shape
    eps, poll = params["eps"], params["poll"]
    iota = jnp.arange(P, dtype=jnp.int32)

    # 0. churn: at most one pre-sampled leave/join fires per row per tick
    #    (surplus carries forward in pend_*; Poisson totals are preserved)
    if has_churn:
        pend_l = state["pend_leave"] + leave_n
        pend_j = state["pend_join"] + join_n
        do_l = (pend_l > 0) & (jnp.sum(alive, axis=1) > 2)
        victim = barrier_kernel.churn_victim(rand["leave"], alive)
        v_oh = victim[:, None] == iota
        alive = alive & ~(do_l[:, None] & v_oh)
        pool = ~alive & params["valid_slot"]
        do_j = (pend_j > 0) & jnp.any(pool, axis=1)
        joiner = barrier_kernel.churn_joiner(rand["join"], alive,
                                             params["valid_slot"])
        sel = do_j[:, None] & (joiner[:, None] == iota)
        alive = alive | sel
        fresh = jnp.max(jnp.where(alive, steps, _I32_MIN), axis=1)
        steps = jnp.where(sel, fresh[:, None], steps)
        computing = computing & ~sel
        event_time = jnp.where(sel, t, event_time)
        ready = jnp.where(sel, t, ready)
        blocked = blocked & ~sel
        pend_leave = pend_l - (pend_l > 0)
        pend_join = pend_j - (pend_j > 0)
    else:
        pend_leave, pend_join = state["pend_leave"], state["pend_join"]

    # 1. finishes: advance steps, become "deciding"; the data-plane push
    #    happens outside on the returned fin mask
    fin = computing & alive & (event_time <= t + eps)
    any_fin = jnp.any(fin, axis=1)
    row_last = jnp.max(jnp.where(fin, event_time, -jnp.inf), axis=1)
    row_unblock = jnp.where(any_fin, jnp.minimum(row_last, t), t)
    steps = steps + fin
    computing = computing & ~fin
    ready = jnp.where(fin, event_time, ready)
    blocked = blocked & ~fin

    # 2. barrier decisions for every due deciding node, through the
    #    unified barrier model (single source with the SPMD trainer)
    cand = ~computing & alive & (event_time <= t + eps)
    stal = jnp.broadcast_to(params["staleness"][:, None], (B, P))
    pass_fv = barrier_kernel.full_view_allowed(steps, stal, alive)
    if k_max > 0:
        pass_sm, n_sampled = barrier_kernel.sampled_allowed(
            steps, stal, k_max, beta=params["beta_clip"][:, None],
            scores=rand.get("scores"), u=rand.get("u1"),
            alive=alive if masked else None)
    else:
        pass_sm = jnp.ones((B, P), dtype=bool)
        n_sampled = jnp.zeros((B, P), dtype=jnp.int32)
    passed = jnp.where(params["is_asp"][:, None], True,
                       jnp.where(params["full_view"][:, None],
                                 pass_fv, pass_sm))
    ctrl = jnp.sum(
        jnp.where(cand, n_sampled * params["dist_hops"][:, None], 0),
        axis=1).astype(jnp.int32)

    # 3. starts / re-polls, anchored at continuous ready times
    start = cand & passed
    t0 = jnp.where(blocked & params["full_view"][:, None],
                   jnp.maximum(row_unblock[:, None], ready), ready)
    dur = barrier_kernel.step_duration(rand["dur"], params["compute_time"])
    event_time = jnp.where(start, t0 + dur, event_time)
    computing = computing | start
    fail = cand & ~passed
    blocked = (blocked | fail) & ~start
    sm_fail = fail & params["sampled"][:, None]
    ready = jnp.where(sm_fail, ready + poll, ready)
    event_time = jnp.where(sm_fail, ready, event_time)

    new_state = {"steps": steps, "alive": alive, "computing": computing,
                 "event_time": event_time, "ready": ready,
                 "blocked": blocked, "pend_leave": pend_leave,
                 "pend_join": pend_join}
    out = {"fin": fin, "start": start,
           "n_fin": jnp.sum(fin, axis=1).astype(jnp.int32), "ctrl": ctrl}
    return new_state, out


# --------------------------------------------------------------------------- #
# Pallas kernel (one grid row per scenario)
# --------------------------------------------------------------------------- #
def _first_argmax(scores: jax.Array, mask: jax.Array,
                  jj: jax.Array, P: int) -> jax.Array:
    """Index of the first maximum of ``scores`` under ``mask`` (2D-safe).

    The lowest index attaining the masked maximum — exactly
    ``jnp.argmax(where(mask, scores, -1))`` for scores in [0, 1), written
    with reductions only (no argmax lowering dependence).
    """
    s = jnp.where(mask, scores, -1.0)
    m = jnp.max(s)
    return jnp.min(jnp.where(s == m, jj, P))


def _tick_kernel(*refs, k_max: int, has_churn: bool, masked: bool,
                 use_u1: bool, P: int):
    """Kernel body: one scenario row's full control-plane tick in VMEM."""
    it = iter(refs)
    steps_ref, alive_ref, computing_ref, event_ref, ready_ref, blocked_ref,\
        pl_ref, pj_ref = (next(it) for _ in range(8))
    ln_ref, jn_ref = next(it), next(it)
    u_dur_ref = next(it)
    samp_ref = next(it) if (k_max > 0) else None
    ul_ref = next(it) if has_churn else None
    uj_ref = next(it) if has_churn else None
    ct_ref, vs_ref = next(it), next(it)
    stal_ref, beta_ref, asp_ref, fv_ref, sm_ref, dh_ref = \
        (next(it) for _ in range(6))
    t_ref, eps_ref, poll_ref = next(it), next(it), next(it)
    (o_steps, o_alive, o_comp, o_event, o_ready, o_block, o_pl, o_pj,
     o_fin, o_start, o_nfin, o_ctrl) = (next(it) for _ in range(12))

    i32 = jnp.int32
    steps = steps_ref[...]                      # (1, P) i32
    alive = alive_ref[...] != 0
    computing = computing_ref[...] != 0
    event_time = event_ref[...]
    ready = ready_ref[...]
    blocked = blocked_ref[...] != 0
    valid_slot = vs_ref[...] != 0
    t = t_ref[0, 0]
    eps, poll = eps_ref[0, 0], poll_ref[0, 0]
    stal, beta = stal_ref[0, 0], beta_ref[0, 0]
    iota = jax.lax.broadcasted_iota(i32, (1, P), 1)
    jj = jax.lax.broadcasted_iota(i32, (P, P), 1)

    # 0. churn: one pre-sampled leave/join per row per tick
    if has_churn:
        pend_l = pl_ref[0, 0] + ln_ref[0, 0]
        pend_j = pj_ref[0, 0] + jn_ref[0, 0]
        do_l = (pend_l > 0) & (jnp.sum(alive.astype(i32)) > 2)
        vid = _first_argmax(ul_ref[...], alive, iota, P)
        alive = alive & ~(do_l & (iota == vid))
        pool = ~alive & valid_slot
        do_j = (pend_j > 0) & jnp.any(pool)
        jid = _first_argmax(uj_ref[...], pool, iota, P)
        sel = do_j & (iota == jid)
        alive = alive | sel
        fresh = jnp.max(jnp.where(alive, steps, _I32_MIN))
        steps = jnp.where(sel, fresh, steps)
        computing = computing & ~sel
        event_time = jnp.where(sel, t, event_time)
        ready = jnp.where(sel, t, ready)
        blocked = blocked & ~sel
        o_pl[0, 0] = pend_l - (pend_l > 0)
        o_pj[0, 0] = pend_j - (pend_j > 0)
    else:
        o_pl[0, 0] = pl_ref[0, 0]
        o_pj[0, 0] = pj_ref[0, 0]

    # 1. finishes
    fin = computing & alive & (event_time <= t + eps)
    any_fin = jnp.any(fin)
    row_last = jnp.max(jnp.where(fin, event_time, -jnp.inf))
    row_unblock = jnp.where(any_fin, jnp.minimum(row_last, t), t)
    steps = steps + fin
    computing = computing & ~fin
    ready = jnp.where(fin, event_time, ready)
    blocked = blocked & ~fin

    # 2. barrier decisions
    cand = ~computing & alive & (event_time <= t + eps)
    min_alive = jnp.min(jnp.where(alive, steps, _I32_MAX))
    pass_fv = steps - min_alive <= stal
    if k_max == 0:
        pass_sm = jnp.ones((1, P), dtype=bool)
        n_sampled = jnp.zeros((1, P), dtype=i32)
    elif use_u1:
        # β = 1 fast path: one uniform over the P−1 non-self slots, the
        # exact formula of sample_peer_indices_jax's k == 1 branch
        draw = jnp.floor(samp_ref[...] * max(P - 1, 1)).astype(i32)
        take = jnp.minimum(draw + (draw >= iota), P - 1)       # (1, P)
        oh = jnp.reshape(take, (P, 1)) == jj                   # (P, P)
        step_i = jnp.reshape(steps, (P, 1))
        step_j = jnp.reshape(steps, (1, P))
        lag_bad = jnp.any(oh & (step_i - step_j > stal), axis=1)
        ok = (P - 1 >= 1) & (beta >= 1)
        pass_sm = jnp.reshape(~lag_bad, (1, P)) | ~ok
        n_sampled = jnp.full((1, P), jnp.minimum(beta, P - 1), dtype=i32)
    else:
        # rank form of the top-k β-sample: the lowest-(score, index) bad
        # peer is inside the sample iff fewer than β eligible peers
        # precede it — identical to lax.top_k selection, fused, no gather
        sc = samp_ref[0]                                       # (P, P)
        step_i = jnp.reshape(steps, (P, 1))
        step_j = jnp.reshape(steps, (1, P))
        ii = jax.lax.broadcasted_iota(i32, (P, P), 0)
        # the shared-draw fast path (masked=False) matches the unmasked
        # reference primitive: every non-self peer is in the pool — the
        # sweep engine only takes it when the whole batch is fully alive
        eligible = jj != ii
        if masked:
            eligible = eligible & jnp.reshape(alive, (1, P))
        bad = eligible & (step_i - step_j > stal)
        any_bad = jnp.any(bad, axis=1)
        mbs = jnp.min(jnp.where(bad, sc, 3.0), axis=1, keepdims=True)
        mbi = jnp.min(jnp.where(bad & (sc == mbs), jj, P), axis=1,
                      keepdims=True)
        before = eligible & ((sc < mbs) | ((sc == mbs) & (jj < mbi)))
        cnt = jnp.sum(before.astype(i32), axis=1)
        fail_sm = any_bad & (cnt < beta)
        pass_sm = jnp.reshape(~fail_sm, (1, P))
        n_elig = jnp.sum(eligible.astype(i32), axis=1)
        n_sampled = jnp.reshape(jnp.minimum(beta, n_elig), (1, P))
    is_asp, full_view = asp_ref[0, 0] != 0, fv_ref[0, 0] != 0
    passed = jnp.where(is_asp, True,
                       jnp.where(full_view, pass_fv, pass_sm))
    o_ctrl[0, 0] = jnp.sum(jnp.where(cand, n_sampled * dh_ref[0, 0], 0))

    # 3. starts / re-polls
    start = cand & passed
    t0 = jnp.where(blocked & full_view,
                   jnp.maximum(row_unblock, ready), ready)
    # the single-sourced straggler model, traced into the kernel body
    dur = barrier_kernel.step_duration(u_dur_ref[...], ct_ref[...])
    event_time = jnp.where(start, t0 + dur, event_time)
    computing = computing | start
    fail = cand & ~passed
    blocked = (blocked | fail) & ~start
    sm_fail = fail & (sm_ref[0, 0] != 0)
    ready = jnp.where(sm_fail, ready + poll, ready)
    event_time = jnp.where(sm_fail, ready, event_time)

    o_steps[...] = steps
    o_alive[...] = alive.astype(i32)
    o_comp[...] = computing.astype(i32)
    o_event[...] = event_time
    o_ready[...] = ready
    o_block[...] = blocked.astype(i32)
    o_fin[...] = fin.astype(i32)
    o_start[...] = start.astype(i32)
    o_nfin[0, 0] = jnp.sum(fin.astype(i32))


def psp_tick_tpu(state: Dict[str, jax.Array], rand: Dict[str, jax.Array],
                 params: Dict[str, jax.Array], t: jax.Array,
                 leave_n: jax.Array, join_n: jax.Array, *,
                 k_max: int, has_churn: bool, masked: bool,
                 interpret: bool = False,
                 ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Fused Pallas tick: same contract as :func:`psp_tick_ref`.

    Grid = (B,): each grid step owns one scenario row — its ``(1, P)``
    state slices, its ``P × P`` score tile (or the shared tile when the
    whole batch reuses one draw), and its scalar policy row in SMEM.
    Booleans travel as i32 (TPU-friendly); the wrapper restores dtypes.
    """
    B, P = state["steps"].shape
    i32, f32 = jnp.int32, jnp.float32
    use_u1 = k_max == 1 and not masked

    def row(a, dtype=None):
        a = jnp.asarray(a)
        return (a if dtype is None else a.astype(dtype)), \
            pl.BlockSpec((1, P), lambda b: (b, 0))

    def scalar_col(a, dtype=i32):
        return jnp.asarray(a, dtype).reshape(B, 1), \
            pl.BlockSpec((1, 1), lambda b: (b, 0))

    def scalar(a, dtype=f32):
        return jnp.asarray(a, dtype).reshape(1, 1), \
            pl.BlockSpec((1, 1), lambda b: (0, 0))

    inputs, specs = [], []

    def push(val_spec):
        inputs.append(val_spec[0])
        specs.append(val_spec[1])

    push(row(state["steps"], i32))
    for k in ("alive", "computing"):
        push(row(state[k], i32))
    push(row(state["event_time"], f32))
    push(row(state["ready"], f32))
    push(row(state["blocked"], i32))
    push(scalar_col(state["pend_leave"]))
    push(scalar_col(state["pend_join"]))
    push(scalar_col(leave_n))
    push(scalar_col(join_n))
    push(row(rand["dur"], f32))
    if k_max > 0:
        if use_u1:
            u1 = jnp.asarray(rand["u1"], f32).reshape(1, P)
            inputs.append(u1)
            specs.append(pl.BlockSpec((1, P), lambda b: (0, 0)))
        elif masked:
            inputs.append(jnp.asarray(rand["scores"], f32))
            specs.append(pl.BlockSpec((1, P, P), lambda b: (b, 0, 0)))
        else:
            inputs.append(jnp.asarray(rand["scores"], f32).reshape(1, P, P))
            specs.append(pl.BlockSpec((1, P, P), lambda b: (0, 0, 0)))
    if has_churn:
        push(row(rand["leave"], f32))
        push(row(rand["join"], f32))
    push(row(params["compute_time"], f32))
    push(row(params["valid_slot"], i32))
    push(scalar_col(params["staleness"]))
    push(scalar_col(params["beta_clip"]))
    push(scalar_col(params["is_asp"]))
    push(scalar_col(params["full_view"]))
    push(scalar_col(params["sampled"]))
    push(scalar_col(params["dist_hops"]))
    push(scalar(t))
    push(scalar(params["eps"]))
    push(scalar(params["poll"]))

    rp = lambda dt: jax.ShapeDtypeStruct((B, P), dt)
    cp = lambda: jax.ShapeDtypeStruct((B, 1), i32)
    out_shape = [rp(i32), rp(i32), rp(i32), rp(f32), rp(f32), rp(i32),
                 cp(), cp(), rp(i32), rp(i32), cp(), cp()]
    out_specs = ([pl.BlockSpec((1, P), lambda b: (b, 0))] * 6
                 + [pl.BlockSpec((1, 1), lambda b: (b, 0))] * 2
                 + [pl.BlockSpec((1, P), lambda b: (b, 0))] * 2
                 + [pl.BlockSpec((1, 1), lambda b: (b, 0))] * 2)

    outs = pl.pallas_call(
        functools.partial(_tick_kernel, k_max=k_max, has_churn=has_churn,
                          masked=masked, use_u1=use_u1, P=P),
        grid=(B,),
        in_specs=specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)

    (steps, alive, computing, event_time, ready, blocked, pend_l, pend_j,
     fin, start, n_fin, ctrl) = outs
    new_state = {"steps": steps, "alive": alive != 0,
                 "computing": computing != 0, "event_time": event_time,
                 "ready": ready, "blocked": blocked != 0,
                 "pend_leave": pend_l[:, 0], "pend_join": pend_j[:, 0]}
    out = {"fin": fin != 0, "start": start != 0, "n_fin": n_fin[:, 0],
           "ctrl": ctrl[:, 0]}
    return new_state, out
