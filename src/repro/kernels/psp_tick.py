"""Pallas TPU kernel for one full PSP sweep-grid tick (control + data plane).

One grid tick of the vectorized sweep engine
(:mod:`repro.core.vector_sim_jax`) is two very different workloads glued
together: a *control-plane* update over the ``(B, P)`` scenario state —
churn, finish bookkeeping, the masked-min full-view barrier, the β-sample
barrier predicate, and start/re-poll anchoring — and a *data-plane* SGD
push (minibatch residual + gradient + server update + model-view pull of
the linear task).  The control plane is a swarm of tiny masked
element-wise ops and row reductions; the data plane is two small
contractions per scenario row.  Left to XLA the pair becomes dozens of
kernels per tick; this module fuses the **whole tick** into one Pallas
kernel, one grid step per :data:`DATA_PLANE_BLOCK`-row scenario block,
so a tick runs out of VMEM with no intermediate HBM traffic — the
barrier logic feeds the gradient mask directly, and the updated server
model is pulled into the block's node views without ever leaving the
kernel.

Two implementations, held tick-for-tick identical by
``tests/test_kernels.py``:

* :func:`psp_tick_ref` — pure jnp reference.  β-sampling routes through
  the shared primitives (:func:`repro.core.sampling.sample_peer_indices_jax`
  / ``sample_alive_peer_indices_jax``) and the unified barrier model
  (:mod:`repro.core.barrier_kernel`), i.e. the exact code the SPMD trainer
  uses.  This is what ``impl="auto"`` runs on CPU.
* :func:`psp_tick_tpu` — the Pallas kernel.  Selecting β peers by top-k
  needs a gather, which the TPU vector unit hates; the kernel instead
  consumes the *same* uniform score matrix and evaluates the predicate by
  rank: a lagging peer is inside the β-sample iff fewer than β eligible
  peers precede it in ``(score, index)`` order.  Ties break exactly like
  ``lax.top_k`` (lower index first), so the two paths agree draw-for-draw,
  not just in distribution.

All randomness — step-duration jitter, β-sample scores, churn uniforms
*and* the minibatch blob — is drawn *outside* (plain ``jax.random``
on-device) and passed in, so ref and kernel consume identical noise and
the sweep's RNG stream is independent of ``impl``.

Rows carry a **horizon**: merged sweeps batch scenarios with different
durations, and a row whose horizon lies before this tick's time is
frozen — no churn, no finishes, no decisions, no data-plane update.  The
same gate makes the dead padding ticks of the chunked scan
(:mod:`repro.core.sweep_plan`) semantics-free.

Shapes and state layout (``B`` scenario rows × ``P`` node slots,
``d``-dim model, ``m`` minibatch rows):

========== ============== ================================================
key         shape          meaning
========== ============== ================================================
steps       i32[B, P]      logical clock per node
alive       bool[B, P]     membership (churn / ragged padding)
computing   bool[B, P]     node busy with a local step
event_time  f32[B, P]      finish time while computing, else next check
ready       f32[B, P]      continuous anchor of the current decide attempt
blocked     bool[B, P]     failed its last barrier check
pend_*      i32[B]         carried-over churn events (≤ 1 fires per tick)
w           f32[B, d]      server model (data plane)
pulled      f32[B, P, d]   per-node model view at its last pull
========== ============== ================================================

The data-plane noise is shared across rows (``X`` f32[P, m, d] minibatch
features, ``mb`` f32[P, m] label noise) — each row's marginal is an exact
fresh draw; cross-row correlation is irrelevant for per-row statistics.

VMEM budget: the dominant buffers are one ``P × P`` f32 score matrix per
grid row (~4 MB at P = 1024) and the shared ``P × m × d`` minibatch blob;
both comfortably resident for the paper-scale shapes, P beyond ~1500
would need a lane-tiled variant.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from repro.core import barrier_kernel

__all__ = ["psp_tick_ref", "psp_tick_sharded", "psp_tick_tpu", "STATE_KEYS",
           "POLICY_STATE_KEYS"]


#: data-plane row-block width: the SGD push always runs as GEMMs of
#: exactly this many scenario rows (batches pad up with inert rows).
#: XLA's CPU backend picks its dot strategy — and therefore its f32
#: reduction order — by operand *shape*, so a width that followed the
#: batch (or the per-device shard) would make results depend on how rows
#: are grouped; a constant width makes each row's bits a function of
#: that row alone, which is what keeps sharded sweeps bit-identical to
#: single-device ones.  16 rows amortises the GEMM without inflating
#: small batches too much (measured best of {8, 16, 32} on the Fig-2
#: smoke sweep).
DATA_PLANE_BLOCK = 16


def _data_plane_block(X: jax.Array, diff: jax.Array, fin: jax.Array,
                      start: jax.Array, w: jax.Array, pulled: jax.Array,
                      lr: jax.Array, noise_std: jax.Array, mb: jax.Array,
                      ) -> Tuple[jax.Array, jax.Array]:
    """One fixed-width block of scenario rows' SGD push + model-view pull.

    The whole data plane of :data:`DATA_PLANE_BLOCK` rows in two
    contractions whose shapes depend only on ``(P, m, d)`` — never on the
    batch or shard width (see :data:`DATA_PLANE_BLOCK`).  A GEMM performs
    no cross-row arithmetic, so padded/foreign rows inside a block cannot
    perturb a real row's bits.

    Args:
      X: f32[P, m, d] minibatch features (shared across rows).
      diff: f32[W, P, d] node views minus ground truth.
      fin / start: bool[W, P] finisher and starter masks.
      w: f32[W, d] server models; ``pulled`` f32[W, P, d] node views.
      lr / noise_std: f32[W]; ``mb`` f32[P, m] label noise.

    Returns:
      (w', pulled'): updated server models and node views.
    """
    m = X.shape[1]
    # residual as broadcast-multiply + minor-axis reduce, NOT a batched
    # dot: the per-node (m, d) × (d, W) GEMMs are so small that XLA's
    # batched-dot loop is all dispatch overhead (~1.3× the whole sweep),
    # while the fused multiply-reduce is one flat kernel — and its f32
    # reduction order is width-invariant, which a dot's would not be
    resid = (jnp.sum(X[None] * diff[:, :, None, :], axis=-1)
             - noise_std[:, None, None] * mb[None])
    resid = jnp.where(fin[:, :, None], resid, 0.0)
    gsum = jnp.einsum("kpm,pmd->kd", resid, X) / m
    w_new = w - lr[:, None] * gsum
    pulled_new = jnp.where(start[..., None], w_new[:, None, :], pulled)
    return w_new, pulled_new

#: carried tick state, in canonical order (control plane, then data plane)
STATE_KEYS = ("steps", "alive", "computing", "event_time", "ready",
              "blocked", "pend_leave", "pend_join", "w", "pulled")

#: adaptive barrier-policy state, carried *only* when the batch contains
#: adaptive rows (``adaptive=True``) — static batches pass zero-width
#: policy state (the keys are simply absent) and compile the exact
#: pre-policy tick, so golden traces and kernel paths are unchanged.
#: ``pol_thr`` i32[B] is DSSP's dynamic staleness threshold, ``pol_ema``
#: f32[B, P] Elastic-BSP's per-worker duration EMA, ``pol_beta`` i32[B]
#: the β-annealing rows' current sample size.
POLICY_STATE_KEYS = ("pol_thr", "pol_ema", "pol_beta")

_I32_MAX = np.iinfo(np.int32).max
_I32_MIN = np.iinfo(np.int32).min


# --------------------------------------------------------------------------- #
# pure-jnp reference (the CPU path of ops.psp_tick)
# --------------------------------------------------------------------------- #
def psp_tick_ref(state: Dict[str, jax.Array], rand: Dict[str, jax.Array],
                 params: Dict[str, jax.Array], t: jax.Array,
                 leave_n: jax.Array, join_n: jax.Array, *,
                 k_max: int, has_churn: bool, masked: bool,
                 adaptive: bool = False,
                 ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """One full tick, batched over B scenario rows (pure jnp).

    Args:
      state: the tick-state pytree (:data:`STATE_KEYS`; plus
        :data:`POLICY_STATE_KEYS` when ``adaptive``).
      rand: pre-drawn noise — ``dur`` f32[B, P] step-duration jitter;
        ``X`` f32[P, m, d] / ``mb`` f32[P, m] shared minibatch blob; plus
        ``scores`` (f32[B, P, P] when ``masked`` else f32[P, P]) or
        ``u1`` f32[P] (β = 1 fast path) when ``k_max > 0``; plus
        ``leave``/``join`` f32[B, P] when ``has_churn``.
      params: per-row policy arrays — ``staleness``/``beta_clip``/
        ``dist_hops`` i32[B]; ``is_asp``/``full_view``/``sampled`` bool[B];
        ``compute_time`` f32[B, P]; ``valid_slot`` bool[B, P] (ragged
        padding mask); ``horizon``/``lr``/``noise_std`` f32[B];
        ``w_true`` f32[B, d]; scalars ``eps``/``poll``.  When
        ``adaptive``: ``is_dssp``/``is_ebsp``/``is_anneal`` bool[B] row
        tags plus ``pol_lo``/``beta_lo`` i32[B] lower bounds and
        ``ebsp_range``/``ebsp_alpha`` f32[B] Elastic-BSP knobs (upper
        bounds reuse ``staleness``/``beta_clip``).
      t: f32[] — this tick's grid time; rows with ``horizon < t`` freeze.
      leave_n / join_n: i32[B] — churn events due this tick.
      k_max: static max sample-slot count over the batch.
      has_churn: static — whether churn state/noise is present.
      masked: static — per-row alive-masked sampling (churn or ragged).
      adaptive: static — whether the batch carries adaptive-policy rows
        (and therefore the :data:`POLICY_STATE_KEYS` state/param arrays).

    Returns:
      (new_state, out) where ``out`` holds ``fin``/``start`` bool[B, P]
      node masks and ``n_fin``/``ctrl`` i32[B] row counters.
    """
    steps, alive = state["steps"], state["alive"]
    computing, blocked = state["computing"], state["blocked"]
    event_time, ready = state["event_time"], state["ready"]
    B, P = steps.shape
    eps, poll = params["eps"], params["poll"]
    iota = jnp.arange(P, dtype=jnp.int32)
    #: row liveness: frozen past the row horizon (merged durations and the
    #: chunk scheduler's dead padding ticks both route through this gate)
    active = t <= params["horizon"] + eps

    # 0. churn: at most one pre-sampled leave/join fires per row per tick
    #    (surplus carries forward in pend_*; Poisson totals are preserved)
    if has_churn:
        pend_l = state["pend_leave"] + leave_n
        pend_j = state["pend_join"] + join_n
        do_l = active & (pend_l > 0) & (jnp.sum(alive, axis=1) > 2)
        victim = barrier_kernel.churn_victim(rand["leave"], alive)
        v_oh = victim[:, None] == iota
        alive = alive & ~(do_l[:, None] & v_oh)
        pool = ~alive & params["valid_slot"]
        do_j = active & (pend_j > 0) & jnp.any(pool, axis=1)
        joiner = barrier_kernel.churn_joiner(rand["join"], alive,
                                             params["valid_slot"])
        sel = do_j[:, None] & (joiner[:, None] == iota)
        alive = alive | sel
        fresh = jnp.max(jnp.where(alive, steps, _I32_MIN), axis=1)
        steps = jnp.where(sel, fresh[:, None], steps)
        computing = computing & ~sel
        event_time = jnp.where(sel, t, event_time)
        ready = jnp.where(sel, t, ready)
        blocked = blocked & ~sel
        pend_leave = jnp.where(active, pend_l - (pend_l > 0),
                               state["pend_leave"])
        pend_join = jnp.where(active, pend_j - (pend_j > 0),
                              state["pend_join"])
    else:
        pend_leave, pend_join = state["pend_leave"], state["pend_join"]

    # 1. finishes: advance steps, become "deciding"; the masked data-plane
    #    push at the bottom consumes this fin mask
    fin = computing & alive & (event_time <= t + eps) & active[:, None]
    any_fin = jnp.any(fin, axis=1)
    row_last = jnp.max(jnp.where(fin, event_time, -jnp.inf), axis=1)
    row_unblock = jnp.where(any_fin, jnp.minimum(row_last, t), t)
    steps = steps + fin
    computing = computing & ~fin
    ready = jnp.where(fin, event_time, ready)
    blocked = blocked & ~fin

    # 2. barrier decisions for every due deciding node, through the
    #    unified barrier model (single source with the SPMD trainer)
    cand = ~computing & alive & (event_time <= t + eps) & active[:, None]
    stal = jnp.broadcast_to(params["staleness"][:, None], (B, P))
    beta_eff = params["beta_clip"][:, None]
    if adaptive:
        # adaptive rows swap their *effective* staleness/β in before the
        # (unchanged) predicates run: DSSP rows read the carried dynamic
        # threshold, Elastic-BSP rows their per-worker EMA step credit,
        # β-annealing rows the carried sample size — static rows keep the
        # per-row constants bit-for-bit
        slack = barrier_kernel.elastic_slack(
            state["pol_ema"], params["ebsp_range"][:, None], alive)
        stal = jnp.where(params["is_dssp"][:, None],
                         state["pol_thr"][:, None],
                         jnp.where(params["is_ebsp"][:, None], slack, stal))
        beta_eff = jnp.where(params["is_anneal"], state["pol_beta"],
                             params["beta_clip"])[:, None]
    pass_fv = barrier_kernel.full_view_allowed(steps, stal, alive)
    if k_max > 0:
        pass_sm, n_sampled = barrier_kernel.sampled_allowed(
            steps, stal, k_max, beta=beta_eff,
            scores=rand.get("scores"), u=rand.get("u1"),
            alive=alive if masked else None)
    else:
        pass_sm = jnp.ones((B, P), dtype=bool)
        n_sampled = jnp.zeros((B, P), dtype=jnp.int32)
    passed = jnp.where(params["is_asp"][:, None], True,
                       jnp.where(params["full_view"][:, None],
                                 pass_fv, pass_sm))
    ctrl = jnp.sum(
        jnp.where(cand, n_sampled * params["dist_hops"][:, None], 0),
        axis=1).astype(jnp.int32)

    # 3. starts / re-polls, anchored at continuous ready times
    start = cand & passed
    t0 = jnp.where(blocked & params["full_view"][:, None],
                   jnp.maximum(row_unblock[:, None], ready), ready)
    dur = barrier_kernel.step_duration(rand["dur"], params["compute_time"])
    event_time = jnp.where(start, t0 + dur, event_time)
    computing = computing | start
    fail = cand & ~passed
    blocked = (blocked | fail) & ~start
    sm_fail = fail & params["sampled"][:, None]
    ready = jnp.where(sm_fail, ready + poll, ready)
    event_time = jnp.where(sm_fail, ready, event_time)

    # 3b. adaptive-policy state updates: decisions above used the OLD
    #     state; the new state is a pure function of this tick's
    #     observations (post-finish step spread, starters' drawn
    #     durations) — frozen rows (past horizon) keep their state
    if adaptive:
        gap = barrier_kernel.progress_gap(steps, alive)
        pol_thr = jnp.where(
            params["is_dssp"] & active,
            jnp.clip(gap, params["pol_lo"], params["staleness"]),
            state["pol_thr"]).astype(jnp.int32)
        pol_beta = jnp.where(
            params["is_anneal"] & active,
            jnp.clip(params["beta_lo"] + gap - params["staleness"],
                     params["beta_lo"], params["beta_clip"]),
            state["pol_beta"]).astype(jnp.int32)
        al = params["ebsp_alpha"][:, None]
        pol_ema = jnp.where(
            params["is_ebsp"][:, None] & start,
            (1.0 - al) * state["pol_ema"] + al * dur,
            state["pol_ema"])

    # 4. data plane: masked SGD push of every finisher, then the starters
    #    pull the updated server model into their view.  The fin mask
    #    zeroes non-finisher residuals, so frozen/inactive rows see
    #    w − lr·0 — exactly w.  Executed in fixed-width row blocks
    #    (:data:`DATA_PLANE_BLOCK`): the GEMM shapes never follow the
    #    batch/shard width, so each row's bits are independent of how
    #    rows are grouped — the sharded-sweep bit-identity invariant.
    X, mbn = rand["X"], rand["mb"]
    w, pulled = state["w"], state["pulled"]
    diff = pulled - params["w_true"][:, None, :]
    W = DATA_PLANE_BLOCK
    Bp = -(-B // W) * W

    def pad(a):
        return a if Bp == B else jnp.concatenate(
            [a, jnp.zeros((Bp - B,) + a.shape[1:], a.dtype)], axis=0)

    d_p, f_p, s_p = pad(diff), pad(fin), pad(start)
    w_p, pu_p = pad(w), pad(pulled)
    lr_p, ns_p = pad(params["lr"]), pad(params["noise_std"])
    blocks = [_data_plane_block(X, d_p[i:i + W], f_p[i:i + W],
                                s_p[i:i + W], w_p[i:i + W], pu_p[i:i + W],
                                lr_p[i:i + W], ns_p[i:i + W], mbn)
              for i in range(0, Bp, W)]
    w = jnp.concatenate([b[0] for b in blocks])[:B]
    pulled = jnp.concatenate([b[1] for b in blocks])[:B]

    new_state = {"steps": steps, "alive": alive, "computing": computing,
                 "event_time": event_time, "ready": ready,
                 "blocked": blocked, "pend_leave": pend_leave,
                 "pend_join": pend_join, "w": w, "pulled": pulled}
    if adaptive:
        new_state.update(pol_thr=pol_thr, pol_ema=pol_ema,
                         pol_beta=pol_beta)
    out = {"fin": fin, "start": start,
           "n_fin": jnp.sum(fin, axis=1).astype(jnp.int32), "ctrl": ctrl}
    return new_state, out

# --------------------------------------------------------------------------- #
# node-sharded reference (collectives over the sweep mesh's "nodes" axis)
# --------------------------------------------------------------------------- #
def _arg_first_max(s: jax.Array, gids: jax.Array, sentinel: int,
                   axis_name: str) -> jax.Array:
    """Global index of each row's first maximum, across node shards.

    ``s`` (B, P_loc) is sentinel-masked scores (dead slots −1.0), ``gids``
    the shard's global node ids.  Exactly ``jnp.argmax`` over the full
    row: the maximum is an exact f32 ``pmax`` and the tie-break takes the
    lowest global index (first occurrence — global node order is shard
    order × local order), so the collective form is bit-free of the
    factorization.
    """
    m = lax.pmax(jnp.max(s, axis=1), axis_name)
    i_loc = jnp.min(jnp.where(s == m[:, None], gids[None, :], sentinel),
                    axis=1)
    return lax.pmin(i_loc, axis_name)


def psp_tick_sharded(state: Dict[str, jax.Array], rand: Dict[str, jax.Array],
                     params: Dict[str, jax.Array], t: jax.Array,
                     leave_n: jax.Array, join_n: jax.Array, *,
                     k_max: int, has_churn: bool, masked: bool,
                     adaptive: bool = False, node_axis: str = "nodes",
                     ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """One full tick on node-sharded state: :func:`psp_tick_ref` with the
    cross-node reductions as collectives over ``node_axis``.

    Called under ``shard_map`` on a ``(rows, nodes)`` mesh
    (:mod:`repro.core.vector_sim_jax`): every node-dimensioned operand —
    state (``steps`` … ``pulled``), per-node noise (``dur``, score rows,
    churn uniforms, the minibatch blob) and per-node params
    (``compute_time``, ``valid_slot``) — arrives sliced to the shard's
    contiguous ``P_loc = P / nodes`` node block, and β-sample score rows
    are keyed by global node id so each shard draws exactly its slice.

    **Bit-identity contract** (the reason this function exists instead of
    a generic re-layout): every output element equals
    :func:`psp_tick_ref`'s for *any* nodes-axis size, because each
    cross-node reduction is one of

    * an order-free exact collective — ``pmin``/``pmax`` over step
      counters and event times, integer ``psum`` counts, the
      first-argmax churn victim/joiner selection (:func:`_arg_first_max`);
    * a pure selection over a gathered full-width operand — the β-sample
      ``top_k``/indexing consumes all-gathered ``steps``/``alive`` (bools
      and i32 gather bit-exactly) with the shard's own score rows;
    * the data-plane contraction on gathered full-width inputs — the one
      genuine f32 reduction over P keeps the reference's exact operand
      shapes (:func:`_data_plane_block` at width P), so XLA picks the
      same reduction order for every factorization.  The *stored* blob
      and views stay node-sliced; only one tick's worth is ever
      materialized full-width.

    The nodes axis must divide P exactly (the planner guarantees it) —
    a padded node slot would widen these reductions and void the
    contract.
    """
    steps, alive = state["steps"], state["alive"]
    computing, blocked = state["computing"], state["blocked"]
    event_time, ready = state["event_time"], state["ready"]
    B, Pl = steps.shape
    i32 = jnp.int32
    eps, poll = params["eps"], params["poll"]
    gids = lax.axis_index(node_axis) * Pl + jnp.arange(Pl, dtype=i32)
    active = t <= params["horizon"] + eps

    def nsum(x):
        return lax.psum(jnp.sum(x, axis=1), node_axis)

    def gather(x, axis=1):
        return lax.all_gather(x, node_axis, axis=axis, tiled=True)

    # 0. churn — mirrors psp_tick_ref phase 0 with the row reductions
    #    (alive count, victim/joiner argmax, freshest step) collective
    if has_churn:
        pend_l = state["pend_leave"] + leave_n
        pend_j = state["pend_join"] + join_n
        do_l = active & (pend_l > 0) & (nsum(alive) > 2)
        victim = _arg_first_max(jnp.where(alive, rand["leave"], -1.0),
                                gids, _I32_MAX, node_axis)
        alive = alive & ~(do_l[:, None] & (victim[:, None] == gids[None]))
        pool = ~alive & params["valid_slot"]
        do_j = active & (pend_j > 0) & (nsum(pool) > 0)
        joiner = _arg_first_max(jnp.where(pool, rand["join"], -1.0),
                                gids, _I32_MAX, node_axis)
        sel = do_j[:, None] & (joiner[:, None] == gids[None])
        alive = alive | sel
        fresh = lax.pmax(jnp.max(jnp.where(alive, steps, _I32_MIN), axis=1),
                         node_axis)
        steps = jnp.where(sel, fresh[:, None], steps)
        computing = computing & ~sel
        event_time = jnp.where(sel, t, event_time)
        ready = jnp.where(sel, t, ready)
        blocked = blocked & ~sel
        pend_leave = jnp.where(active, pend_l - (pend_l > 0),
                               state["pend_leave"])
        pend_join = jnp.where(active, pend_j - (pend_j > 0),
                              state["pend_join"])
    else:
        pend_leave, pend_join = state["pend_leave"], state["pend_join"]

    # 1. finishes (elementwise; row_last is an exact f32 max)
    fin = computing & alive & (event_time <= t + eps) & active[:, None]
    any_fin = nsum(fin) > 0
    row_last = lax.pmax(jnp.max(jnp.where(fin, event_time, -jnp.inf),
                                axis=1), node_axis)
    row_unblock = jnp.where(any_fin, jnp.minimum(row_last, t), t)
    steps = steps + fin
    computing = computing & ~fin
    ready = jnp.where(fin, event_time, ready)
    blocked = blocked & ~fin

    # 2. barrier decisions.  The full-view min is a pmin; the β-sample
    #    consults gathered steps/alive (exact) with the shard's own
    #    node-keyed score rows — selection only, no cross-shard f32 math
    cand = ~computing & alive & (event_time <= t + eps) & active[:, None]
    steps_full = gather(steps)
    P = steps_full.shape[1]
    piota = jnp.arange(P, dtype=i32)
    stal = jnp.broadcast_to(params["staleness"][:, None], (B, Pl))
    beta_eff = params["beta_clip"][:, None]
    if adaptive:
        live = jnp.where(alive, state["pol_ema"], 0.0)
        mx = lax.pmax(jnp.max(live, axis=1), node_axis)
        frac = 1.0 - state["pol_ema"] / jnp.maximum(mx[:, None], 1e-9)
        slack = jnp.floor(params["ebsp_range"][:, None] * frac).astype(i32)
        stal = jnp.where(params["is_dssp"][:, None],
                         state["pol_thr"][:, None],
                         jnp.where(params["is_ebsp"][:, None], slack, stal))
        beta_eff = jnp.where(params["is_anneal"], state["pol_beta"],
                             params["beta_clip"])[:, None]
    min_alive = lax.pmin(jnp.min(jnp.where(alive, steps, _I32_MAX), axis=1),
                         node_axis)
    pass_fv = steps - min_alive[:, None] <= stal
    if k_max > 0:
        if masked:
            # sample_alive_peer_indices_jax with the deciding axis
            # sliced: per-node top-k over the full gathered peer width
            alive_full = gather(alive)
            sc = jnp.where(~alive_full[:, None, :]
                           | (gids[None, :, None] == piota[None, None, :]),
                           2.0, rand["scores"])            # (B, Pl, P)
            neg, take = lax.top_k(-sc, k_max)
            valid = -neg < 1.5
            peer = jnp.take_along_axis(
                jnp.broadcast_to(steps_full[:, None, :], (B, Pl, P)),
                take, axis=-1)
        elif k_max == 1:
            # sample_peer_indices_jax's β = 1 branch on global node ids
            draw = jnp.floor(rand["u1"] * max(P - 1, 1)).astype(i32)
            take = jnp.minimum(draw + (draw >= gids), P - 1)   # (Pl,)
            peer = steps_full[:, take][:, :, None]
            valid = jnp.broadcast_to(
                jnp.arange(1) < P - 1, peer.shape)
        else:
            # shared-score top-k: the shard draws its deciding nodes'
            # score rows (global-node keyed), peers span the full width
            sc = jnp.where(gids[:, None] == piota[None, :], 2.0,
                           rand["scores"])                 # (Pl, P)
            _, take = lax.top_k(-sc, k_max)
            peer = steps_full[:, take]                     # (B, Pl, k)
            valid = jnp.broadcast_to(
                jnp.arange(k_max) < P - 1, peer.shape)
        valid = valid & (jnp.arange(peer.shape[-1]) < beta_eff[..., None])
        lag_ok = steps[..., None] - peer <= stal[..., None]
        pass_sm = jnp.all(lag_ok | ~valid, axis=-1)
        n_sampled = jnp.sum(valid, axis=-1)
    else:
        pass_sm = jnp.ones((B, Pl), dtype=bool)
        n_sampled = jnp.zeros((B, Pl), dtype=i32)
    passed = jnp.where(params["is_asp"][:, None], True,
                       jnp.where(params["full_view"][:, None],
                                 pass_fv, pass_sm))
    ctrl = lax.psum(jnp.sum(
        jnp.where(cand, n_sampled * params["dist_hops"][:, None], 0),
        axis=1), node_axis).astype(i32)

    # 3. starts / re-polls (elementwise given the per-row row_unblock)
    start = cand & passed
    t0 = jnp.where(blocked & params["full_view"][:, None],
                   jnp.maximum(row_unblock[:, None], ready), ready)
    dur = barrier_kernel.step_duration(rand["dur"], params["compute_time"])
    event_time = jnp.where(start, t0 + dur, event_time)
    computing = computing | start
    fail = cand & ~passed
    blocked = (blocked | fail) & ~start
    sm_fail = fail & params["sampled"][:, None]
    ready = jnp.where(sm_fail, ready + poll, ready)
    event_time = jnp.where(sm_fail, ready, event_time)

    # 3b. adaptive-policy updates: progress_gap from exact collectives
    if adaptive:
        mxs = lax.pmax(jnp.max(jnp.where(alive, steps, _I32_MIN), axis=1),
                       node_axis)
        mns = lax.pmin(jnp.min(jnp.where(alive, steps, _I32_MAX), axis=1),
                       node_axis)
        gap = jnp.where(nsum(alive) > 0, mxs - mns, 0)
        pol_thr = jnp.where(
            params["is_dssp"] & active,
            jnp.clip(gap, params["pol_lo"], params["staleness"]),
            state["pol_thr"]).astype(i32)
        pol_beta = jnp.where(
            params["is_anneal"] & active,
            jnp.clip(params["beta_lo"] + gap - params["staleness"],
                     params["beta_lo"], params["beta_clip"]),
            state["pol_beta"]).astype(i32)
        al = params["ebsp_alpha"][:, None]
        pol_ema = jnp.where(
            params["is_ebsp"][:, None] & start,
            (1.0 - al) * state["pol_ema"] + al * dur,
            state["pol_ema"])

    # 4. data plane: the one f32 reduction over P.  The contraction runs
    #    on gathered full-width operands at the reference's exact shapes
    #    (any node-sliced partial-sum scheme would change the reduction
    #    order and break cross-factorization bit-identity); the server
    #    model is per-row (replicated over the nodes axis), so every
    #    shard computes the identical w and pulls only its own view slice
    X = gather(rand["X"], axis=0)               # (P, m, d)
    mbn = gather(rand["mb"], axis=0)            # (P, m)
    fin_full = gather(fin)
    pulled_full = gather(state["pulled"])       # (B, P, d)
    w = state["w"]
    diff = pulled_full - params["w_true"][:, None, :]
    W = DATA_PLANE_BLOCK
    Bp = -(-B // W) * W

    def pad(a):
        return a if Bp == B else jnp.concatenate(
            [a, jnp.zeros((Bp - B,) + a.shape[1:], a.dtype)], axis=0)

    d_p, f_p = pad(diff), pad(fin_full)
    w_p = pad(w)
    lr_p, ns_p = pad(params["lr"]), pad(params["noise_std"])
    zero_pull = jnp.zeros((W,) + pulled_full.shape[1:], pulled_full.dtype)
    w_blocks = [_data_plane_block(X, d_p[i:i + W], f_p[i:i + W],
                                  jnp.zeros((W, X.shape[0]), bool),
                                  w_p[i:i + W], zero_pull,
                                  lr_p[i:i + W], ns_p[i:i + W], mbn)[0]
                for i in range(0, Bp, W)]
    w = jnp.concatenate(w_blocks)[:B]
    pulled = jnp.where(start[..., None], w[:, None, :], state["pulled"])

    new_state = {"steps": steps, "alive": alive, "computing": computing,
                 "event_time": event_time, "ready": ready,
                 "blocked": blocked, "pend_leave": pend_leave,
                 "pend_join": pend_join, "w": w, "pulled": pulled}
    if adaptive:
        new_state.update(pol_thr=pol_thr, pol_ema=pol_ema,
                         pol_beta=pol_beta)
    out = {"fin": fin, "start": start,
           "n_fin": nsum(fin).astype(i32), "ctrl": ctrl}
    return new_state, out


# --------------------------------------------------------------------------- #
# Pallas kernel (one grid step per row block)
# --------------------------------------------------------------------------- #
def _first_argmax_rows(scores: jax.Array, mask: jax.Array,
                       iota: jax.Array, P: int) -> jax.Array:
    """Per-row index of the first maximum of ``scores`` under ``mask``.

    The lowest index attaining each row's masked maximum — exactly
    ``jnp.argmax(where(mask, scores, -1), axis=1)`` for scores in [0, 1),
    written with reductions only (no argmax lowering dependence).
    Shapes: ``scores``/``mask`` (W, P), ``iota`` (1, P) → (W, 1).
    """
    s = jnp.where(mask, scores, -1.0)
    mx = jnp.max(s, axis=1, keepdims=True)
    return jnp.min(jnp.where(s == mx, iota, P), axis=1, keepdims=True)


def _tick_kernel(*refs, k_max: int, has_churn: bool, masked: bool,
                 use_u1: bool, adaptive: bool, W: int, P: int, d: int,
                 m: int):
    """Kernel body: one W-row block's full tick in VMEM."""
    it = iter(refs)
    steps_ref, alive_ref, computing_ref, event_ref, ready_ref, blocked_ref,\
        pl_ref, pj_ref = (next(it) for _ in range(8))
    w_ref, pulled_ref = next(it), next(it)
    ln_ref, jn_ref = next(it), next(it)
    u_dur_ref = next(it)
    samp_ref = next(it) if (k_max > 0) else None
    ul_ref = next(it) if has_churn else None
    uj_ref = next(it) if has_churn else None
    x_ref, mb_ref = next(it), next(it)
    ct_ref, vs_ref = next(it), next(it)
    stal_ref, beta_ref, asp_ref, fv_ref, sm_ref, dh_ref = \
        (next(it) for _ in range(6))
    if adaptive:
        # adaptive-policy operands (zero-width for static batches: absent)
        thr_ref, pbeta_ref, ema_ref = (next(it) for _ in range(3))
        dssp_ref, ebsp_ref, ann_ref, lo_ref, blo_ref = \
            (next(it) for _ in range(5))
        ebr_ref, eba_ref = next(it), next(it)
    wt_ref, lr_ref, ns_ref, hz_ref = (next(it) for _ in range(4))
    t_ref, eps_ref, poll_ref = next(it), next(it), next(it)
    (o_steps, o_alive, o_comp, o_event, o_ready, o_block, o_pl, o_pj,
     o_w, o_pulled, o_fin, o_start, o_nfin, o_ctrl) = \
        (next(it) for _ in range(14))
    if adaptive:
        o_thr, o_ema, o_beta = (next(it) for _ in range(3))

    i32 = jnp.int32
    steps = steps_ref[...]                      # (W, P) i32
    alive = alive_ref[...] != 0
    computing = computing_ref[...] != 0
    event_time = event_ref[...]
    ready = ready_ref[...]
    blocked = blocked_ref[...] != 0
    valid_slot = vs_ref[...] != 0
    t = t_ref[0, 0]
    eps, poll = eps_ref[0, 0], poll_ref[0, 0]
    stal, beta = stal_ref[...], beta_ref[...]   # (W, 1) i32
    active = t <= hz_ref[...] + eps             # (W, 1) row liveness
    iota = jax.lax.broadcasted_iota(i32, (1, P), 1)
    jj = jax.lax.broadcasted_iota(i32, (P, P), 1)

    # 0. churn: one pre-sampled leave/join per row per tick
    if has_churn:
        pend_l = pl_ref[...] + ln_ref[...]      # (W, 1)
        pend_j = pj_ref[...] + jn_ref[...]
        n_alive = jnp.sum(alive.astype(i32), axis=1, keepdims=True)
        do_l = active & (pend_l > 0) & (n_alive > 2)
        vid = _first_argmax_rows(ul_ref[...], alive, iota, P)
        alive = alive & ~(do_l & (iota == vid))
        pool = ~alive & valid_slot
        do_j = active & (pend_j > 0) & jnp.any(pool, axis=1, keepdims=True)
        jid = _first_argmax_rows(uj_ref[...], pool, iota, P)
        sel = do_j & (iota == jid)
        alive = alive | sel
        fresh = jnp.max(jnp.where(alive, steps, _I32_MIN), axis=1,
                        keepdims=True)
        steps = jnp.where(sel, fresh, steps)
        computing = computing & ~sel
        event_time = jnp.where(sel, t, event_time)
        ready = jnp.where(sel, t, ready)
        blocked = blocked & ~sel
        o_pl[...] = jnp.where(active, pend_l - (pend_l > 0), pl_ref[...])
        o_pj[...] = jnp.where(active, pend_j - (pend_j > 0), pj_ref[...])
    else:
        o_pl[...] = pl_ref[...]
        o_pj[...] = pj_ref[...]

    # 1. finishes
    fin = computing & alive & (event_time <= t + eps) & active
    any_fin = jnp.any(fin, axis=1, keepdims=True)
    row_last = jnp.max(jnp.where(fin, event_time, -jnp.inf), axis=1,
                       keepdims=True)
    row_unblock = jnp.where(any_fin, jnp.minimum(row_last, t), t)
    steps = steps + fin
    computing = computing & ~fin
    ready = jnp.where(fin, event_time, ready)
    blocked = blocked & ~fin

    # 2. barrier decisions
    cand = ~computing & alive & (event_time <= t + eps) & active
    stal_eff, beta_eff = stal, beta
    if adaptive:
        # effective staleness/β per row, via the same shared helper (and
        # the same op order) as psp_tick_ref — ref ↔ kernel stay
        # bit-identical for adaptive rows too; static rows read the
        # constant columns unchanged
        is_dssp = dssp_ref[...] != 0            # (W, 1)
        is_ebsp = ebsp_ref[...] != 0
        is_ann = ann_ref[...] != 0
        slack = barrier_kernel.elastic_slack(ema_ref[...], ebr_ref[...],
                                             alive)
        stal_eff = jnp.where(is_dssp, thr_ref[...],
                             jnp.where(is_ebsp, slack, stal))   # (W, P)
        beta_eff = jnp.where(is_ann, pbeta_ref[...], beta)      # (W, 1)
    min_alive = jnp.min(jnp.where(alive, steps, _I32_MAX), axis=1,
                        keepdims=True)
    pass_fv = steps - min_alive <= stal_eff
    if k_max == 0:
        pass_sm = jnp.ones((W, P), dtype=bool)
        n_sampled = jnp.zeros((W, P), dtype=i32)
    elif use_u1:
        # β = 1 fast path: one shared uniform over the P−1 non-self
        # slots, the exact formula of sample_peer_indices_jax's k == 1
        # branch.  The peer's step is fetched with a one-hot matmul —
        # exact for counters below 2²⁴, a single small dot instead of a
        # (W, P, P) mask pipeline, and gather-free for the TPU MXU.
        draw = jnp.floor(samp_ref[...] * max(P - 1, 1)).astype(i32)
        take = jnp.minimum(draw + (draw >= iota), P - 1)       # (1, P)
        oh = (jnp.reshape(take, (P, 1)) == jj).astype(jnp.float32)
        step_peer = jax.lax.dot_general(
            steps.astype(jnp.float32), oh,
            (((1,), (1,)), ((), ()))).astype(i32)              # (W, P)
        lag_bad = steps - step_peer > stal_eff
        ok = (P - 1 >= 1) & (beta_eff >= 1)                    # (W, 1)
        pass_sm = ~lag_bad | ~ok
        n_sampled = jnp.broadcast_to(
            jnp.minimum(beta_eff, P - 1), (W, P)).astype(i32)
    else:
        # rank form of the top-k β-sample: the lowest-(score, index) bad
        # peer is inside the sample iff fewer than β eligible peers
        # precede it — identical to lax.top_k selection, fused, no gather
        sc = samp_ref[...]                      # (W, P, P) or (1, P, P)
        ii = jax.lax.broadcasted_iota(i32, (P, P), 0)
        # the shared-draw fast path (masked=False) matches the unmasked
        # reference primitive: every non-self peer is in the pool — the
        # sweep engine only takes it when the whole batch is fully alive
        eligible = (jj != ii)[None]                            # (1, P, P)
        if masked:
            eligible = eligible & alive[:, None, :]            # (W, P, P)
        lag = steps[:, :, None] - steps[:, None, :]
        bad = eligible & (lag > stal_eff[:, :, None])          # (W, P, P)
        any_bad = jnp.any(bad, axis=2)
        mbs = jnp.min(jnp.where(bad, sc, 3.0), axis=2, keepdims=True)
        mbi = jnp.min(jnp.where(bad & (sc == mbs), jj[None], P), axis=2,
                      keepdims=True)
        before = eligible & ((sc < mbs) | ((sc == mbs) & (jj[None] < mbi)))
        cnt = jnp.sum(before.astype(i32), axis=2)              # (W, P)
        fail_sm = any_bad & (cnt < beta_eff)
        pass_sm = ~fail_sm
        n_elig = jnp.sum(
            jnp.broadcast_to(eligible, (W, P, P)).astype(i32), axis=2)
        n_sampled = jnp.minimum(beta_eff, n_elig)
    is_asp, full_view = asp_ref[...] != 0, fv_ref[...] != 0    # (W, 1)
    passed = jnp.where(is_asp, True,
                       jnp.where(full_view, pass_fv, pass_sm))
    o_ctrl[...] = jnp.sum(jnp.where(cand, n_sampled * dh_ref[...], 0),
                          axis=1, keepdims=True)

    # 3. starts / re-polls
    start = cand & passed
    t0 = jnp.where(blocked & full_view,
                   jnp.maximum(row_unblock, ready), ready)
    # the single-sourced straggler model, traced into the kernel body
    dur = barrier_kernel.step_duration(u_dur_ref[...], ct_ref[...])
    event_time = jnp.where(start, t0 + dur, event_time)
    computing = computing | start
    fail = cand & ~passed
    blocked = (blocked | fail) & ~start
    sm_fail = fail & (sm_ref[...] != 0)
    ready = jnp.where(sm_fail, ready + poll, ready)
    event_time = jnp.where(sm_fail, ready, event_time)

    # 3b. adaptive-policy state updates — mirrors psp_tick_ref block 3b
    #     value-for-value (keepdims reductions instead of progress_gap's
    #     flat ones; same inputs, same clip/EMA arithmetic)
    if adaptive:
        mxs = jnp.max(jnp.where(alive, steps, _I32_MIN), axis=1,
                      keepdims=True)
        mns = jnp.min(jnp.where(alive, steps, _I32_MAX), axis=1,
                      keepdims=True)
        gap = jnp.where(jnp.any(alive, axis=1, keepdims=True),
                        mxs - mns, 0)                          # (W, 1)
        o_thr[...] = jnp.where(
            is_dssp & active,
            jnp.clip(gap, lo_ref[...], stal),
            thr_ref[...]).astype(i32)
        o_beta[...] = jnp.where(
            is_ann & active,
            jnp.clip(blo_ref[...] + gap - stal, blo_ref[...], beta),
            pbeta_ref[...]).astype(i32)
        al = eba_ref[...]                                      # (W, 1)
        o_ema[...] = jnp.where(is_ebsp & start,
                               (1.0 - al) * ema_ref[...] + al * dur,
                               ema_ref[...])

    # 4. data plane: the block's SGD push + model-view pull — literally
    #    _data_plane_block, the same code the jnp reference runs, so the
    #    two impls match bit for bit.  All operands are VMEM resident;
    #    the fin/start masks come straight from the phases above.
    X = x_ref[...]                              # (P, m, d)
    pulled = pulled_ref[...]                    # (W, P, d)
    diff = pulled - wt_ref[...][:, None, :]     # view − ground truth
    w_new, pulled_new = _data_plane_block(
        X, diff, fin, start, w_ref[...], pulled,
        jnp.reshape(lr_ref[...], (W,)), jnp.reshape(ns_ref[...], (W,)),
        mb_ref[...])

    o_steps[...] = steps
    o_alive[...] = alive.astype(i32)
    o_comp[...] = computing.astype(i32)
    o_event[...] = event_time
    o_ready[...] = ready
    o_block[...] = blocked.astype(i32)
    o_w[...] = w_new
    o_pulled[...] = pulled_new
    o_fin[...] = fin.astype(i32)
    o_start[...] = start.astype(i32)
    o_nfin[...] = jnp.sum(fin.astype(i32), axis=1, keepdims=True)


def _kernel_block_width(P: int, k_max: int, masked: bool,
                        interpret: bool) -> int:
    """Rows per kernel grid step.

    Interpret/CPU always uses :data:`DATA_PLANE_BLOCK` — that makes the
    kernel's data plane byte-identical to the jnp reference's blocks (and
    keeps the interpreter's grid loop short).  On real TPU hardware the
    (W, P, P) score/lag tiles bound W by VMEM: halve until the dominant
    per-step buffers fit a ~8 MB budget (worst case W = 1, the PR-3
    layout).
    """
    W = DATA_PLANE_BLOCK
    if interpret:
        return W
    # the β = 1 shared-u1 path carries only a W-independent (P, P)
    # one-hot plus (W, P) buffers; per-row P² tiles exist only for the
    # rank form (k_max > 1) and the per-row masked scores
    per_row = 4 * (P * P if k_max > 1 or masked else P)
    while W > 1 and W * per_row > (8 << 20):
        W //= 2
    return W


def psp_tick_tpu(state: Dict[str, jax.Array], rand: Dict[str, jax.Array],
                 params: Dict[str, jax.Array], t: jax.Array,
                 leave_n: jax.Array, join_n: jax.Array, *,
                 k_max: int, has_churn: bool, masked: bool,
                 adaptive: bool = False, interpret: bool = False,
                 ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Fused Pallas tick: same contract as :func:`psp_tick_ref`.

    Grid = (⌈B/W⌉,): each grid step owns one W-row block of scenarios —
    its ``(W, P)`` state slices, its ``(W, P, d)`` model views, its score
    tiles (or the shared tile when the whole batch reuses one draw), the
    shared minibatch blob, and its ``(W, 1)`` policy columns.  W is
    :data:`DATA_PLANE_BLOCK` in interpret mode (bit-identical to the
    reference's data-plane blocks) and VMEM-clamped on real TPUs; batches
    pad up to a W multiple with inert rows (negative horizon).  Booleans
    travel as i32 (TPU-friendly); the wrapper restores dtypes.
    """
    B, P = state["steps"].shape
    d = state["w"].shape[-1]
    m = rand["X"].shape[1]
    i32, f32 = jnp.int32, jnp.float32
    use_u1 = k_max == 1 and not masked
    W = _kernel_block_width(P, k_max, masked, interpret)
    Bp = -(-B // W) * W

    def pad(a, fill=0):
        a = jnp.asarray(a)
        if Bp == B:
            return a
        filler = jnp.full((Bp - B,) + a.shape[1:], fill, a.dtype)
        return jnp.concatenate([a, filler], axis=0)

    def row(a, dtype=None):
        a = pad(jnp.asarray(a) if dtype is None
                else jnp.asarray(a).astype(dtype))
        return a, pl.BlockSpec((W, P), lambda b: (b, 0))

    def col(a, dtype=i32, fill=0):
        return pad(jnp.asarray(a, dtype), fill).reshape(Bp, 1), \
            pl.BlockSpec((W, 1), lambda b: (b, 0))

    def scalar(a, dtype=f32):
        return jnp.asarray(a, dtype).reshape(1, 1), \
            pl.BlockSpec((1, 1), lambda b: (0, 0))

    inputs, specs = [], []

    def push(val_spec):
        inputs.append(val_spec[0])
        specs.append(val_spec[1])

    push(row(state["steps"], i32))
    for k in ("alive", "computing"):
        push(row(state[k], i32))
    push(row(state["event_time"], f32))
    push(row(state["ready"], f32))
    push(row(state["blocked"], i32))
    push(col(state["pend_leave"]))
    push(col(state["pend_join"]))
    inputs.append(pad(jnp.asarray(state["w"], f32)))
    specs.append(pl.BlockSpec((W, d), lambda b: (b, 0)))
    inputs.append(pad(jnp.asarray(state["pulled"], f32)))
    specs.append(pl.BlockSpec((W, P, d), lambda b: (b, 0, 0)))
    push(col(leave_n))
    push(col(join_n))
    push(row(rand["dur"], f32))
    if k_max > 0:
        if use_u1:
            u1 = jnp.asarray(rand["u1"], f32).reshape(1, P)
            inputs.append(u1)
            specs.append(pl.BlockSpec((1, P), lambda b: (0, 0)))
        elif masked:
            inputs.append(pad(jnp.asarray(rand["scores"], f32)))
            specs.append(pl.BlockSpec((W, P, P), lambda b: (b, 0, 0)))
        else:
            inputs.append(jnp.asarray(rand["scores"], f32).reshape(1, P, P))
            specs.append(pl.BlockSpec((1, P, P), lambda b: (0, 0, 0)))
    if has_churn:
        push(row(rand["leave"], f32))
        push(row(rand["join"], f32))
    inputs.append(jnp.asarray(rand["X"], f32))
    specs.append(pl.BlockSpec((P, m, d), lambda b: (0, 0, 0)))
    inputs.append(jnp.asarray(rand["mb"], f32))
    specs.append(pl.BlockSpec((P, m), lambda b: (0, 0)))
    push(row(params["compute_time"], f32))
    push(row(params["valid_slot"], i32))
    push(col(params["staleness"]))
    push(col(params["beta_clip"]))
    push(col(params["is_asp"]))
    push(col(params["full_view"]))
    push(col(params["sampled"]))
    push(col(params["dist_hops"]))
    if adaptive:
        # policy-state/knob operands — pushed like the churn refs:
        # static batches never materialise them, so their kernel is the
        # exact pre-policy trace
        push(col(state["pol_thr"]))
        push(col(state["pol_beta"]))
        push(row(state["pol_ema"], f32))
        push(col(params["is_dssp"]))
        push(col(params["is_ebsp"]))
        push(col(params["is_anneal"]))
        push(col(params["pol_lo"]))
        push(col(params["beta_lo"]))
        push(col(params["ebsp_range"], f32))
        push(col(params["ebsp_alpha"], f32))
    inputs.append(pad(jnp.asarray(params["w_true"], f32)))
    specs.append(pl.BlockSpec((W, d), lambda b: (b, 0)))
    push(col(params["lr"], f32))
    push(col(params["noise_std"], f32))
    # padded rows freeze: a negative horizon keeps them inert forever
    push(col(params["horizon"], f32, fill=-1.0))
    push(scalar(t))
    push(scalar(params["eps"]))
    push(scalar(params["poll"]))

    rp = lambda dt: jax.ShapeDtypeStruct((Bp, P), dt)
    cp = lambda: jax.ShapeDtypeStruct((Bp, 1), i32)
    out_shape = [rp(i32), rp(i32), rp(i32), rp(f32), rp(f32), rp(i32),
                 cp(), cp(),
                 jax.ShapeDtypeStruct((Bp, d), f32),
                 jax.ShapeDtypeStruct((Bp, P, d), f32),
                 rp(i32), rp(i32), cp(), cp()]
    out_specs = ([pl.BlockSpec((W, P), lambda b: (b, 0))] * 6
                 + [pl.BlockSpec((W, 1), lambda b: (b, 0))] * 2
                 + [pl.BlockSpec((W, d), lambda b: (b, 0)),
                    pl.BlockSpec((W, P, d), lambda b: (b, 0, 0))]
                 + [pl.BlockSpec((W, P), lambda b: (b, 0))] * 2
                 + [pl.BlockSpec((W, 1), lambda b: (b, 0))] * 2)
    if adaptive:
        out_shape += [cp(), rp(f32), cp()]      # pol_thr, pol_ema, pol_beta
        out_specs += [pl.BlockSpec((W, 1), lambda b: (b, 0)),
                      pl.BlockSpec((W, P), lambda b: (b, 0)),
                      pl.BlockSpec((W, 1), lambda b: (b, 0))]

    outs = pl.pallas_call(
        functools.partial(_tick_kernel, k_max=k_max, has_churn=has_churn,
                          masked=masked, use_u1=use_u1, adaptive=adaptive,
                          W=W, P=P, d=d, m=m),
        grid=(Bp // W,),
        in_specs=specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)

    outs = [o[:B] for o in outs]
    (steps, alive, computing, event_time, ready, blocked, pend_l, pend_j,
     w, pulled, fin, start, n_fin, ctrl) = outs[:14]
    new_state = {"steps": steps, "alive": alive != 0,
                 "computing": computing != 0, "event_time": event_time,
                 "ready": ready, "blocked": blocked != 0,
                 "pend_leave": pend_l[:, 0], "pend_join": pend_j[:, 0],
                 "w": w, "pulled": pulled}
    if adaptive:
        pol_thr, pol_ema, pol_beta = outs[14:]
        new_state.update(pol_thr=pol_thr[:, 0], pol_ema=pol_ema,
                         pol_beta=pol_beta[:, 0])
    out = {"fin": fin != 0, "start": start != 0, "n_fin": n_fin[:, 0],
           "ctrl": ctrl[:, 0]}
    return new_state, out
