"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernel tests sweep against
(``assert_allclose`` over shapes × dtypes, kernels run in interpret mode on
CPU).  They are deliberately naive — O(S²) attention, direct recurrences —
because obviousness is the point of an oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "ssd_ref", "rmsnorm_ref"]


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jax.Array:
    """Naive attention.  q,k,v: (B, S, H, hd) MHA layout."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qa, ka = jnp.arange(Sq), jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= qa[:, None] >= ka[None, :]
    if window is not None:
        m &= (qa[:, None] - ka[None, :]) < window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
            Cm: jax.Array) -> jax.Array:
    """Sequential SSD recurrence (the definition, not the dual form).

    x: (B, S, nh, hd); dt: (B, S, nh); A: (nh,) (negative);
    Bm, Cm: (B, S, nh, N) (already broadcast to heads).
    Returns y: (B, S, nh, hd) where
        h_t = exp(dt_t A) h_{t−1} + dt_t B_t ⊗ x_t ;  y_t = C_t · h_t
    """
    B, S, nh, hd = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def step(h, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt.astype(f32) * A.astype(f32))        # (B,nh)
        upd = jnp.einsum("bhn,bhd,bh->bhdn", bt.astype(f32),
                         xt.astype(f32), dtt.astype(f32))
        h = h * decay[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhdn->bhd", ct.astype(f32), h)
        return h, y

    h0 = jnp.zeros((B, nh, hd, N), f32)
    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1), Bm.swapaxes(0, 1),
          Cm.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Naive RMSNorm over the trailing axis (f32 accumulation)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)) \
        .astype(x.dtype)
