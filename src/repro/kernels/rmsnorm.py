"""Pallas TPU RMSNorm kernel.

Row-tiled: each grid step normalises a (block_rows × D) tile held in VMEM
with f32 accumulation; D stays whole per tile (the reduction axis must be
resident), which is fine for every assigned arch (D ≤ 6144 → ≤ 3 MB/tile
in f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_tpu"]


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_tpu(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    D = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, D)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = x2.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
