"""Pallas TPU kernel for the Mamba-2 chunked SSD scan.

Grid: (B·nh, n_chunks) — the chunk axis is the innermost (sequential) grid
dimension, so the inter-chunk state recurrence is carried in a VMEM scratch
buffer (hd × N f32), exactly like the flash kernel carries softmax state.
Per chunk the kernel computes, entirely in VMEM:

    cum   = cumsum(dt·A)                       (Q,)
    Lmask = exp(cum_i − cum_j) · [i ≥ j]       (Q, Q)   intra-chunk decay
    y     = ((C Bᵀ) ⊙ Lmask) (x·dt)            MXU (Q,N)(N,Q) + (Q,Q)(Q,hd)
          + (C · state) ⊙ exp(cum)             MXU (Q,N)(N,hd)
    state = state · exp(cum_Q) + (x·dt·decay)ᵀ B

Chunk length Q=128 aligns the MXU; N (state) = 128 for mamba2-780m.
Inputs are pre-projected (the surrounding block handles conv/gating), so
the kernel is the pure sequence-mixing hot spot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_tpu"]


def _kernel(xdt_ref, dA_ref, b_ref, c_ref, y_ref, h_sc, *, chunk: int,
            nchunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_sc[...] = jnp.zeros_like(h_sc)

    xdt = xdt_ref[0].astype(jnp.float32)          # (Q, hd)
    dA = dA_ref[0].astype(jnp.float32)            # (Q,)
    Bm = b_ref[0].astype(jnp.float32)             # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)             # (Q, N)

    cum = jnp.cumsum(dA)                          # (Q,)
    seg = cum[-1]

    # intra-chunk dual form
    li = cum[:, None]
    lj = cum[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(iq >= jq, jnp.exp(li - lj), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state (h: (hd, N))
    h = h_sc[...]
    y += jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]

    # state update: h ← h·exp(seg) + (xdt ⊙ decay_to_end)ᵀ B
    decay_end = jnp.exp(seg - cum)                # (Q,)
    xw = xdt * decay_end[:, None]                 # (Q, hd)
    h_sc[...] = h * jnp.exp(seg) + jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_tpu(xdt: jax.Array, dA: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, *, chunk: int = 128,
                 interpret: bool = False) -> jax.Array:
    """Chunked SSD.

    xdt: (BH, S, hd) — x·dt per head (BH = batch·heads)
    dA:  (BH, S)     — dt·A (negative log-decay per step)
    Bm, Cm: (BH, S, N)
    Returns y: (BH, S, hd).
    """
    BH, S, hd = xdt.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kern = functools.partial(_kernel, chunk=chunk, nchunks=nc)
    return pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(xdt, dA, Bm, Cm)
