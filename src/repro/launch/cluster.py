"""Multi-process PSP cluster: coordinator + worker subprocesses over the bus.

This is the real-process counterpart of the in-process elastic trainer: a
coordinator owns the :class:`~repro.core.spmd_psp.PSPState` and drives
the tick loop, while N worker subprocesses compute gradients on their
own (possibly stale) snapshot views.  Everything rides the snapshot-bus
file protocol — no sockets, no RPC:

* ``server/``   — the coordinator's :class:`SnapshotPublisher` output:
  version ``v`` is the server params after ``v`` ticks (``v=0`` is the
  init), published every tick with GC disabled so any version a worker
  is told to compute on stays addressable (version-addressed pulls are
  what make the run race-free *and* bit-exact).
* ``ticks/current.json`` — the coordinator's work order (atomic
  replace): tick number, the pushing worker set, and the exact snapshot
  version each pusher's view must be at.  Workers poll it.
* ``pushes/push_t<t>_w<w>.npz`` — a pusher's gradient + loss for one
  tick (atomic tmp+rename).
* ``hb/worker_<w>.json`` — per-worker heartbeat sidecar, written by a
  background thread in the worker on a ``PSP_HB_INTERVAL`` cadence.
  The coordinator detects *death* by ``proc.poll()`` and *hangs* by
  heartbeat staleness (``PSP_HB_TIMEOUT``), escalating a hang to
  SIGKILL.  A fault-injected ``stall`` keeps heartbeating — a stalled
  worker is a straggler to wait for, not a corpse.

Real churn maps onto the elastic trainer's own machinery
(:func:`repro.core.spmd_psp.apply_external_churn`): an observed death is
a *leave* at the current tick; a supervisor respawn that has restored
the latest published snapshot and heartbeats ready is a *join* — the
coordinator re-anchors it exactly like a churn joiner (fresh pull of the
server model, restart at the max alive step, same-tick decide, gradient
masked out of this tick's push).  Live workers are never restarted.

Determinism: with ``churn=None`` the coordinator's
:func:`~repro.core.spmd_psp.psp_apply_tick` consumes the identical RNG
stream as the single-process trainer, worker minibatches replicate
:func:`~repro.core.spmd_psp.elastic_drive`'s key-split stream, and a
solo ``jax.jit(grad_fn)`` on a restored view is bit-identical to the
corresponding ``vmap`` row — so replaying a cluster run's recorded
membership events through :func:`~repro.core.spmd_psp.external_drive`
reproduces the final server params bit-for-bit
(``tests/test_cluster_faults.py`` pins it, fault plan and all).

Fault injection: a :class:`~repro.core.faults.FaultPlan` (CLI ``--plan``
or the ``PSP_FAULT_PLAN`` env knob) schedules SIGKILLs (executed by the
coordinator at tick boundaries, including correlated rack groups) and
stalls/hangs (executed by the targeted worker on itself).

CLI::

    python -m repro.launch.cluster --workers 4 --ticks 40 \\
        --plan kill-one:seed=3 --dir /tmp/psp_cluster
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import env
from repro.core.faults import FaultPlan, plan_from_env
from repro.core.spmd_psp import (PSPConfig, apply_external_churn,
                                 linear_psp_state, linear_psp_task,
                                 psp_apply_tick)

__all__ = ["run_cluster", "main"]

_POLL = 0.005                   # file-poll cadence (seconds)


# --------------------------------------------------------------------------- #
# small atomic-file helpers (the bus idiom: tmp + rename)
# --------------------------------------------------------------------------- #
def _atomic_json(path: str, obj: dict) -> None:
    """Write ``obj`` as JSON atomically (tmp + rename)."""
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def _read_json(path: str) -> Optional[dict]:
    """Read a JSON file, returning ``None`` when absent or mid-replace."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _atomic_npz(path: str, **arrays) -> None:
    """Write an npz atomically (tmp + rename)."""
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


# --------------------------------------------------------------------------- #
# worker side
# --------------------------------------------------------------------------- #
class _Heartbeat(threading.Thread):
    """Daemon thread writing the worker's heartbeat sidecar.

    ``state`` advances ``boot`` → ``ready`` (snapshot restored) → worker
    progress is visible via ``tick``.  ``suspended`` silences the beat —
    the ``hang`` fault uses it so the coordinator's staleness detector
    has something real to catch (a ``stall`` keeps beating).
    """

    def __init__(self, path: str, worker: int, epoch: int, interval: float):
        super().__init__(name=f"hb-{worker}", daemon=True)
        self.path = path
        self.worker = worker
        self.epoch = epoch
        self.interval = interval
        self.state = "boot"
        self.tick = -1
        self.suspended = False
        self._stop = threading.Event()

    def beat(self) -> None:
        """Write one heartbeat record now (atomic)."""
        _atomic_json(self.path, {
            "pid": os.getpid(), "worker": self.worker, "epoch": self.epoch,
            "time": time.time(), "state": self.state, "tick": self.tick})

    def run(self):
        while not self._stop.is_set():
            if not self.suspended:
                try:
                    self.beat()
                except OSError:
                    pass                    # workdir vanished: dying anyway
            self._stop.wait(self.interval)

    def stop(self) -> None:
        """Stop the beat (worker exit)."""
        self._stop.set()


def _wait_restore(server_dir: str, template, step: Optional[int],
                  timeout: float):
    """Restore a (possibly not-yet-published) snapshot, waiting for it.

    ``step=None`` waits for *any* version (worker warm start), otherwise
    for that exact version — the coordinator publishes asynchronously,
    so a pusher may be told to compute on a version still in the writer
    queue.  Raises ``TimeoutError`` past ``timeout`` seconds.
    """
    from repro.checkpoint import latest_step, restore_checkpoint
    deadline = time.monotonic() + timeout
    while True:
        try:
            have = latest_step(server_dir)
            if have is not None and (step is None or
                                     os.path.exists(os.path.join(
                                         server_dir,
                                         f"step_{step:08d}.npz"))):
                return restore_checkpoint(server_dir, template, step)
        except (OSError, ValueError, KeyError):
            pass                            # racing the publisher: retry
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"version {step} never appeared in {server_dir}")
        time.sleep(_POLL)


def _worker_main(a: argparse.Namespace) -> int:
    """Worker subprocess entry: poll orders, compute, push, heartbeat.

    The worker replicates the coordinator's deterministic minibatch
    stream (the :func:`~repro.core.spmd_psp.elastic_drive` key splits,
    fast-forwarded to the ordered tick), restores its view at exactly
    the version the order names, computes a solo gradient (bit-identical
    to the vmap row of the in-process trainer) and pushes it atomically.
    Non-pusher ticks are acknowledged by heartbeat only.
    """
    import jax
    import jax.numpy as jnp

    hb_int = a.hb_interval or env.get_float("PSP_HB_INTERVAL")
    hb = _Heartbeat(os.path.join(a.dir, "hb", f"worker_{a.worker}.json"),
                    a.worker, a.epoch, hb_int)
    hb.beat()
    hb.start()

    plan = None
    plan_path = os.path.join(a.dir, "plan.json")
    if os.path.exists(plan_path):
        with open(plan_path) as f:
            plan = FaultPlan.from_json(f.read())
    my_events = sorted(plan.worker_events(a.worker),
                       key=lambda e: e.tick) if plan else []
    fired: set = set()

    template = {"w": jnp.zeros((a.dim,), jnp.float32)}
    w_true, grad_fn, _ = linear_psp_task(a.dim, lr=a.lr, seed=a.task_seed)
    solo = jax.jit(grad_fn)
    server_dir = os.path.join(a.dir, "server")
    order_path = os.path.join(a.dir, "ticks", "current.json")

    # warm start: the churn-joiner restore path (latest published snapshot)
    view, _ = _wait_restore(server_dir, template, None, a.io_timeout)
    view = jax.tree_util.tree_map(jnp.asarray, view)
    view_version = -1                       # authoritative version per order
    hb.state = "ready"
    hb.beat()

    kb, kb_tick = jax.random.PRNGKey(a.batch_seed), 0
    last_done = -1
    while True:
        order = _read_json(order_path)
        if order is None:
            time.sleep(_POLL)
            continue
        if order.get("stop"):
            break
        t = int(order["tick"])
        if t <= last_done:
            time.sleep(_POLL)
            continue
        for i, ev in enumerate(my_events):  # due self-faults (stall/hang)
            if i in fired or ev.tick > t:
                continue
            fired.add(i)
            if ev.kind == "hang":
                hb.suspended = True         # go dark: hb staleness fires
                time.sleep(ev.seconds)
                hb.suspended = False
            else:
                time.sleep(ev.seconds)      # stall: keep heartbeating
        if a.worker in order["pushers"]:
            need = int(order["views"][str(a.worker)])
            out = os.path.join(a.dir, "pushes", f"push_t{t}_w{a.worker}.npz")
            if not os.path.exists(out):
                if need != view_version:
                    view, _ = _wait_restore(server_dir, template, need,
                                            a.io_timeout)
                    view = jax.tree_util.tree_map(jnp.asarray, view)
                    view_version = need
                while kb_tick < t:          # fast-forward the batch stream
                    kb, _ = jax.random.split(kb)
                    kb_tick += 1
                kb, k1 = jax.random.split(kb)
                kb_tick += 1
                x = jax.random.normal(k1, (a.workers, a.batch, a.dim))
                y = x @ w_true              # full draw, slice my row: the
                loss, grads = solo(view, (x[a.worker], y[a.worker]))
                leaves = jax.tree_util.tree_leaves(grads)
                _atomic_npz(out, loss=np.asarray(loss),
                            **{f"g{i}": np.asarray(l)
                               for i, l in enumerate(leaves)})
        last_done = t
        hb.tick = t
        hb.beat()
    hb.stop()
    return 0


# --------------------------------------------------------------------------- #
# coordinator side
# --------------------------------------------------------------------------- #
class _Supervisor:
    """Spawns, kills, and respawns worker subprocesses.

    One entry per worker slot: the live ``Popen`` (or ``None``), its
    spawn ``epoch`` (0 = original process; bumped per respawn), and the
    respawn timer.  Only *dead* workers are ever (re)spawned — the
    no-restart-of-live-workers property the kill-one test asserts via
    the recorded epochs.
    """

    def __init__(self, workdir: str, args: List[str], *,
                 restart_delay: float, max_respawns: int):
        self.workdir = workdir
        self.args = args
        self.restart_delay = restart_delay
        self.max_respawns = max_respawns
        self.procs: Dict[int, subprocess.Popen] = {}
        self.epochs: Dict[int, int] = {}
        self.respawns: Dict[int, int] = {}
        self.due: Dict[int, float] = {}     # worker -> respawn wall time
        self.logs: List = []

    def spawn(self, w: int) -> None:
        """Start worker ``w`` at its current epoch."""
        e = self.epochs.setdefault(w, 0)
        log = open(os.path.join(self.workdir, "logs",
                                f"worker_{w}.e{e}.log"), "ab")
        self.logs.append(log)
        child_env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        child_env["PYTHONPATH"] = src + os.pathsep + \
            child_env.get("PYTHONPATH", "")
        child_env.setdefault("JAX_PLATFORMS", "cpu")
        self.procs[w] = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.cluster", "--role",
             "worker", "--worker", str(w), "--epoch", str(e)] + self.args,
            stdout=log, stderr=subprocess.STDOUT, env=child_env)

    def kill(self, w: int) -> None:
        """SIGKILL worker ``w`` (fault execution / hang escalation)."""
        p = self.procs.get(w)
        if p is not None and p.poll() is None:
            os.kill(p.pid, signal.SIGKILL)

    def reap_deaths(self, known_dead: set) -> List[int]:
        """Worker slots whose process exited since last asked."""
        out = []
        for w, p in self.procs.items():
            if w not in known_dead and p.poll() is not None:
                out.append(w)
        return out

    def schedule_respawn(self, w: int, now: float) -> bool:
        """Queue a respawn of dead worker ``w``; False when exhausted."""
        if self.respawns.get(w, 0) >= self.max_respawns:
            return False
        self.due[w] = now + self.restart_delay
        return True

    def fire_respawns(self, now: float) -> List[int]:
        """Respawn every due worker; returns the slots respawned."""
        fired = [w for w, at in self.due.items() if at <= now]
        for w in fired:
            del self.due[w]
            self.respawns[w] = self.respawns.get(w, 0) + 1
            self.epochs[w] = self.epochs.get(w, 0) + 1
            self.spawn(w)
        return fired

    def shutdown(self, grace: float = 5.0) -> None:
        """Reap everything: wait ``grace`` for clean exits, then kill."""
        deadline = time.monotonic() + grace
        for p in self.procs.values():
            while p.poll() is None and time.monotonic() < deadline:
                time.sleep(_POLL)
            if p.poll() is None:
                os.kill(p.pid, signal.SIGKILL)
                p.wait()
        for log in self.logs:
            log.close()


def _hb_age(workdir: str, w: int, now_wall: float) -> Optional[float]:
    """Seconds since worker ``w`` last heartbeat (None = no beat yet)."""
    hb = _read_json(os.path.join(workdir, "hb", f"worker_{w}.json"))
    if hb is None:
        return None
    return now_wall - float(hb.get("time", 0.0))


def _hb_ready(workdir: str, w: int, epoch: int) -> bool:
    """Has worker ``w``'s *current-epoch* process restored and reported?"""
    hb = _read_json(os.path.join(workdir, "hb", f"worker_{w}.json"))
    return (hb is not None and int(hb.get("epoch", -1)) == epoch
            and hb.get("state") in ("ready", "run"))


def run_cluster(cfg: PSPConfig, dim: int, ticks: int, workdir: str, *,
                batch: int = 16, lr: float = 0.1, task_seed: int = 0,
                init_seed: int = 1, batch_seed: int = 2,
                plan: Optional[FaultPlan] = None,
                hb_timeout: Optional[float] = None,
                restart_delay: float = 0.0, max_respawns: int = 1,
                tick_timeout: float = 120.0,
                tick_min_wall: float = 0.0) -> dict:
    """Drive a full multi-process cluster run; returns the outcome record.

    The coordinator publishes version 0, spawns ``cfg.n_workers`` worker
    subprocesses, and runs ``ticks`` lockstep ticks: observe membership
    changes (deaths → leaves, ready respawns → joins, via
    :func:`apply_external_churn`), issue the work order, execute due
    ``kill`` faults, collect pusher gradients (reissuing the order when
    a pusher dies mid-tick), apply the tick, publish the new version.
    ``cfg.churn`` must be ``None`` — process churn *is* the churn.

    ``tick_min_wall`` throttles the tick rate (seconds of wall clock per
    tick) so short test runs leave a respawned worker time to rejoin
    before the run ends.  The returned dict (also written to
    ``result.json``) carries the recorded membership ``events`` —
    ``[tick, "leave"|"join", worker]`` — whose replay through
    :func:`~repro.core.spmd_psp.external_drive` must reproduce
    ``final_params`` bit-for-bit, plus per-victim recovery records and
    the spawn ``epochs`` proving live workers were never restarted.
    """
    if cfg.has_churn:
        raise ValueError("run_cluster drives real process churn; pass a "
                         "churn=None PSPConfig")
    import jax
    import jax.numpy as jnp
    from repro.serving.snapshot_bus import SnapshotPublisher

    W = cfg.n_workers
    for sub in ("server", "ticks", "pushes", "hb", "logs"):
        os.makedirs(os.path.join(workdir, sub), exist_ok=True)
    plan = plan or plan_from_env(n_workers=W, ticks=ticks)
    plan.save(os.path.join(workdir, "plan.json"))
    hb_timeout = hb_timeout if hb_timeout is not None \
        else env.get_float("PSP_HB_TIMEOUT")

    w_true, grad_fn, opt_update = linear_psp_task(dim, lr=lr, seed=task_seed)
    state = linear_psp_state(cfg, dim, init_seed)
    grad_leaves_tpl, grads_treedef = jax.tree_util.tree_flatten(
        jax.tree_util.tree_map(lambda p: np.zeros((W,) + np.shape(p),
                                                  np.float32),
                               state.server_params))
    apply_fn = jax.jit(lambda st, losses, grads: psp_apply_tick(
        cfg, opt_update, st, lambda _: (losses, grads)))

    pub = SnapshotPublisher(os.path.join(workdir, "server"), keep=0,
                            async_write=True)
    pub.publish(0, state.server_params, block=True)

    worker_args = ["--dir", workdir, "--workers", str(W), "--dim", str(dim),
                   "--batch", str(batch), "--lr", str(lr),
                   "--task-seed", str(task_seed),
                   "--batch-seed", str(batch_seed),
                   "--io-timeout", str(tick_timeout)]
    sup = _Supervisor(workdir, worker_args, restart_delay=restart_delay,
                      max_respawns=max_respawns)
    for w in range(W):
        sup.spawn(w)

    v_view = {w: 0 for w in range(W)}
    dead: set = set()
    events: List[Tuple[int, str, int]] = []
    recovery: Dict[int, dict] = {}
    order_path = os.path.join(workdir, "ticks", "current.json")
    wall0 = time.monotonic()
    issue = 0

    def observe_leaves(t: int) -> List[int]:
        """Newly dead workers → leave events at tick ``t``."""
        newly = sup.reap_deaths(dead)
        now = time.monotonic()
        for w in newly:
            dead.add(w)
            events.append((t, "leave", w))
            rec = recovery.setdefault(w, {})
            rec.setdefault("t_kill", now - wall0)
            if sup.schedule_respawn(w, now):
                rec["respawn_scheduled"] = True
        return newly

    try:
        for t in range(ticks):
            t_wall0 = time.monotonic()
            # (a) execute scheduled kill faults for this tick
            for w in plan.kills_at(t):
                if w not in dead:
                    recovery.setdefault(w, {})["t_kill"] = \
                        time.monotonic() - wall0
                    sup.kill(w)
                    while sup.procs[w].poll() is None:
                        time.sleep(_POLL)   # SIGKILL: exit is imminent
            # (b) membership: deaths since last tick → leaves; ready
            # respawns → joins (the churn-joiner re-anchor, version t)
            leaves = observe_leaves(t)
            sup.fire_respawns(time.monotonic())
            joins = [w for w in sorted(dead)
                     if sup.procs[w].poll() is None
                     and _hb_ready(workdir, w, sup.epochs[w])]
            for w in joins:
                dead.discard(w)
                events.append((t, "join", w))
                v_view[w] = t               # fresh pull = current server
                recovery.setdefault(w, {})["t_rejoin"] = \
                    time.monotonic() - wall0
            if leaves or joins:
                state = apply_external_churn(cfg, state,
                                             leave=tuple(leaves),
                                             join=tuple(joins))

            # (c) who pushes this tick (host-readable, deterministic)
            def pushers_of(st) -> List[int]:
                m = (np.asarray(st.busy_until) <= float(st.now)) \
                    & ~np.asarray(st.pushed) & np.asarray(st.alive)
                return [int(i) for i in np.flatnonzero(m)]

            pushers = pushers_of(state)
            issue += 1
            _atomic_json(order_path, {
                "tick": t, "issue": issue, "pushers": pushers,
                "views": {str(w): v_view[w] for w in pushers}})

            # (d) collect pushes; mid-tick deaths shrink the set
            deadline = time.monotonic() + tick_timeout
            while True:
                missing = [w for w in pushers if not os.path.exists(
                    os.path.join(workdir, "pushes", f"push_t{t}_w{w}.npz"))]
                if not missing:
                    break
                newly = observe_leaves(t)
                if newly:
                    state = apply_external_churn(cfg, state,
                                                 leave=tuple(newly))
                    pushers = pushers_of(state)
                    issue += 1
                    _atomic_json(order_path, {
                        "tick": t, "issue": issue, "pushers": pushers,
                        "views": {str(w): v_view[w] for w in pushers}})
                    continue
                now_wall = time.time()
                for w in missing:           # hang detection: stale beat
                    age = _hb_age(workdir, w, now_wall)
                    if age is not None and age > hb_timeout:
                        sup.kill(w)
                if time.monotonic() > deadline:
                    raise RuntimeError(
                        f"tick {t}: pushers {missing} never pushed "
                        f"within {tick_timeout}s")
                time.sleep(_POLL)

            # (e) stack pusher grads (zeros elsewhere) and apply the tick
            losses = np.zeros((W,), np.float32)
            leaves_acc = [l.copy() for l in grad_leaves_tpl]
            for w in pushers:
                with np.load(os.path.join(
                        workdir, "pushes", f"push_t{t}_w{w}.npz")) as z:
                    losses[w] = z["loss"]
                    for i in range(len(leaves_acc)):
                        leaves_acc[i][w] = z[f"g{i}"]
                rec = recovery.get(w)
                if rec and "t_rejoin" in rec and "t_push" not in rec:
                    rec["t_push"] = time.monotonic() - wall0
            grads = jax.tree_util.tree_unflatten(
                grads_treedef, [jnp.asarray(l) for l in leaves_acc])
            prev_step = np.asarray(state.step)
            state, _ = apply_fn(state, jnp.asarray(losses), grads)

            # (f) pulls: a bumped step counter means the barrier let the
            # worker pull the fresh server model = version t+1
            for w in np.flatnonzero(np.asarray(state.step) > prev_step):
                v_view[int(w)] = t + 1
            pub.publish(t + 1, state.server_params)
            lag = tick_min_wall - (time.monotonic() - t_wall0)
            if lag > 0:
                time.sleep(lag)
        _atomic_json(order_path, {"stop": True, "tick": ticks, "issue": -1})
        sup.shutdown()
    finally:
        try:
            _atomic_json(order_path,
                         {"stop": True, "tick": ticks, "issue": -1})
        except OSError:
            pass
        sup.shutdown(grace=0.5)
        pub.wait()
        pub.close()

    wall = time.monotonic() - wall0
    for rec in recovery.values():
        if "t_kill" in rec and "t_push" in rec:
            rec["latency_s"] = rec["t_push"] - rec["t_kill"]
    result = {
        "workers": W, "ticks": ticks, "dim": dim, "batch": batch,
        "barrier": cfg.barrier, "plan": plan.name, "plan_seed": plan.seed,
        "events": [[t, kind, w] for (t, kind, w) in events],
        "epochs": {str(w): sup.epochs.get(w, 0) for w in range(W)},
        "total_pushes": int(state.total_pushes),
        "virtual_time": float(state.now),
        "wall_s": wall,
        "pushes_per_s": int(state.total_pushes) / max(wall, 1e-9),
        "recovery": {str(w): rec for w, rec in recovery.items()},
        "completed": True,
    }
    _atomic_json(os.path.join(workdir, "result.json"), result)
    result["final_params"] = {
        k: np.asarray(v) for k, v in state.server_params.items()}
    result["alive"] = np.asarray(state.alive).tolist()
    return result


def _coordinator_main(a: argparse.Namespace) -> int:
    """Coordinator CLI entry: build cfg + plan, run, print the record."""
    cfg = PSPConfig(barrier=a.barrier, n_workers=a.workers,
                    staleness=a.staleness, sample_size=a.sample_size,
                    straggler_frac=a.straggler_frac)
    if a.plan:
        from repro.core.faults import make_plan
        plan = make_plan(a.plan, n_workers=a.workers, ticks=a.ticks)
    else:
        plan = plan_from_env(n_workers=a.workers, ticks=a.ticks)
    res = run_cluster(cfg, a.dim, a.ticks, a.dir, batch=a.batch, lr=a.lr,
                      task_seed=a.task_seed, batch_seed=a.batch_seed,
                      plan=plan, restart_delay=a.restart_delay,
                      max_respawns=a.max_respawns,
                      tick_timeout=a.io_timeout,
                      tick_min_wall=a.tick_min_wall)
    res.pop("final_params", None)
    print(json.dumps(res, indent=1))
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    """CLI dispatcher: ``--role coordinator`` (default) or ``worker``."""
    p = argparse.ArgumentParser(
        description="multi-process PSP cluster over the snapshot bus")
    p.add_argument("--role", choices=("coordinator", "worker"),
                   default="coordinator")
    p.add_argument("--dir", required=True, help="shared working directory")
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--ticks", type=int, default=40)
    p.add_argument("--dim", type=int, default=32)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--lr", type=float, default=0.1)
    p.add_argument("--barrier", default="pbsp")
    p.add_argument("--staleness", type=int, default=3)
    p.add_argument("--sample-size", type=int, default=2)
    p.add_argument("--straggler-frac", type=float, default=0.0)
    p.add_argument("--task-seed", type=int, default=0)
    p.add_argument("--batch-seed", type=int, default=2)
    p.add_argument("--plan", default=None,
                   help="fault-plan spec or JSON path (default: "
                        "PSP_FAULT_PLAN, else none)")
    p.add_argument("--restart-delay", type=float, default=0.0)
    p.add_argument("--max-respawns", type=int, default=1)
    p.add_argument("--tick-min-wall", type=float, default=0.0)
    p.add_argument("--io-timeout", type=float, default=120.0)
    # worker-only
    p.add_argument("--worker", type=int, default=None)
    p.add_argument("--epoch", type=int, default=0)
    p.add_argument("--hb-interval", type=float, default=None)
    a = p.parse_args(argv)
    if a.role == "worker":
        if a.worker is None:
            p.error("--worker is required for --role worker")
        return _worker_main(a)
    return _coordinator_main(a)


if __name__ == "__main__":
    sys.exit(main())
