import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove every (arch × input-shape × mesh) combination
lowers, SPMD-partitions and compiles on the production mesh — and extract
the memory/cost/collective artifacts the roofline analysis consumes.

MUST be imported/run before anything else initialises jax (the device count
is locked at first backend init) — hence the XLA_FLAGS lines above all other
imports.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch gemma2-27b --shape train_4k --mesh single \
        --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single,multi

Each combo writes ``results/dryrun/<arch>__<shape>__<mesh>.json`` with
memory_analysis, cost_analysis, parsed collective bytes and wall times —
idempotent (skips existing files unless --force).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, INPUT_SHAPES, LONG_CONTEXT_ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import dryrun_inputs
from repro.parallel.sharding import make_rules, psp_worker_axes, use_rules
from repro.roofline.analysis import (HW, collective_bytes, model_flops,
                                     roofline_report)
from repro.roofline.hlo_cost import analyze_hlo


def should_skip(arch: str, shape_name: str) -> bool:
    return shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS


def run_psp_combo(arch: str, mesh_kind: str, out_dir: str,
                  workers: int = 0, force: bool = False) -> dict:
    """Lower + compile the PSP train step (the paper's technique as the
    trainer) on the production mesh — §Perf pair 3 artifact."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.spmd_psp import PSPConfig, PSPState
    from repro.launch.steps import abstract_opt_state, make_psp_train_step
    from repro.models import model_defs
    from repro.models.params import ParamDef, abstract_params
    from repro.optim import adamw

    tag = f"{arch}__train_4k_psp__{mesh_kind}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = INPUT_SHAPES["train_4k"]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    rules = make_rules(cfg, shape, mesh)
    rules.table["psp_workers"] = psp_worker_axes(mesh)
    # default: one PSP worker per (pod × data) shard group
    W = workers or (32 if mesh_kind == "multi" else 16)
    rec = {"arch": arch, "shape": "train_4k_psp", "mesh": mesh_kind,
           "chips": int(chips), "workers": W, "status": "error"}
    t0 = time.time()
    try:
        defs = model_defs(cfg)
        aparams = abstract_params(defs, jnp.float32, rules)

        def stack(d):
            return jax.tree.map(
                lambda pd: ParamDef((W,) + pd.shape,
                                    ("psp_workers",) + pd.axes,
                                    init=pd.init, scale=pd.scale,
                                    dtype=pd.dtype),
                d, is_leaf=lambda x: isinstance(x, ParamDef))

        aviews = abstract_params(stack(defs), jnp.float32, rules)
        aopt = abstract_opt_state("adamw", defs, rules)

        def rep(shp, dt):
            return jax.ShapeDtypeStruct(
                shp, dt, sharding=NamedSharding(mesh, P(*([None] * len(shp)))))

        state = PSPState(
            server_params=aparams, opt_state=aopt, views=aviews,
            step=rep((W,), jnp.int32), busy_until=rep((W,), jnp.float32),
            pushed=rep((W,), jnp.bool_), now=rep((), jnp.float32),
            slow=rep((W,), jnp.bool_),
            key=rep((2,), jnp.uint32),
            tick=rep((), jnp.int32), total_pushes=rep((), jnp.int32),
            # fixed worker set in the dry-run: all-alive mask, empty
            # churn schedules (churn=None compiles the same program)
            alive=rep((W,), jnp.bool_),
            leave_times=rep((0,), jnp.float32),
            join_times=rep((0,), jnp.float32),
            leave_cursor=rep((), jnp.int32),
            join_cursor=rep((), jnp.int32))
        gb = shape.global_batch
        spec = P(psp_worker_axes(mesh), None, None)
        batch = {"tokens": jax.ShapeDtypeStruct(
            (W, gb // W, shape.seq_len), jnp.int32,
            sharding=NamedSharding(mesh, spec))}
        pcfg = PSPConfig(barrier="pssp", n_workers=W, sample_size=2,
                         staleness=3, straggler_frac=0.25)
        step = make_psp_train_step(cfg, pcfg, adamw(1e-4), rules)
        with use_rules(rules):
            with mesh:
                compiled = jax.jit(step).lower(state, batch).compile()
        hc = analyze_hlo(compiled.as_text())
        ma = compiled.memory_analysis()
        rec.update({
            "status": "ok",
            "wall_s": round(time.time() - t0, 2),
            "cost": {"flops": hc.flops, "bytes_accessed": hc.bytes_min},
            "collectives": {**{k: float(v) for k, v in hc.coll.items()},
                            "total": hc.coll_total},
            "memory": {"temp_bytes": int(ma.temp_size_in_bytes),
                       "argument_bytes": int(ma.argument_size_in_bytes)},
        })
        print(f"[ok] {tag}: flops/dev {hc.flops:.3e} "
              f"coll/dev {hc.coll_total:.3e}B "
              f"temp {ma.temp_size_in_bytes/1e9:.1f}GB")
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {tag}: {rec['error'][:200]}")
    _write(path, rec)
    return rec


def run_combo(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
              force: bool = False, verbose: bool = True) -> dict:
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    if should_skip(arch, shape_name):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped",
               "reason": "pure full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md §5)"}
        _write(path, rec)
        return rec

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    rules = make_rules(cfg, shape, mesh)

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": int(chips), "status": "error"}
    t0 = time.time()
    try:
        with use_rules(rules):
            args, step, donate = dryrun_inputs(cfg, shape, rules)
            with mesh:
                lowered = jax.jit(step, donate_argnums=donate).lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # per-device list on newer jax
            cost = cost[0]
        cost = dict(cost)
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        # trip-count-aware analysis (cost_analysis counts while bodies once)
        hc = analyze_hlo(hlo)
        mf = model_flops(cfg, shape)
        # memory term from the fusion-optimistic byte count (TPU-grade
        # fuser assumption); the naive count is recorded alongside
        rep = roofline_report(
            {"flops": hc.flops, "bytes accessed": hc.bytes_min},
            "", chips=chips, model_flops_total=mf)
        rep.coll_bytes = hc.coll_total
        rep.coll_detail = dict(hc.coll)
        rep.collective_s = hc.coll_total / HW().ici_bw
        terms = {"compute": rep.compute_s, "memory": rep.memory_s,
                 "collective": rep.collective_s}
        rep.bottleneck = max(terms, key=terms.get)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "generated_code_bytes": int(ma.generated_code_size_in_bytes),
                "peak_bytes": int(ma.argument_size_in_bytes
                                  + ma.temp_size_in_bytes),
                "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            },
            # raw cost_analysis values (while-loop bodies counted ONCE —
            # kept for reference only)
            "cost_counted_once": {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
            "collectives_counted_once": coll,
            # trip-count-corrected per-device totals (roofline inputs)
            "cost": {"flops": hc.flops, "bytes_accessed": hc.bytes_min,
                     "bytes_accessed_naive": hc.bytes},
            "collectives": {**{k: float(v) for k, v in hc.coll.items()},
                            "total": hc.coll_total},
            "while_trips": hc.while_trips,
            "roofline": {
                "compute_s": rep.compute_s,
                "memory_s": rep.memory_s,
                "collective_s": rep.collective_s,
                "bottleneck": rep.bottleneck,
                "useful_ratio": rep.useful_ratio,
            },
            "model_flops": mf,
            "hlo_bytes": len(hlo),
        })
        if verbose:
            print(f"[ok] {tag}: lower {t_lower:.1f}s compile {t_compile:.1f}s"
                  f" flops/dev {rec['cost']['flops']:.3e}"
                  f" coll/dev {coll['total']:.3e}B")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[FAIL] {tag}: {rec['error'].splitlines()[0][:200]}")
    _write(path, rec)
    return rec


def _write(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--psp", action="store_true",
                    help="lower the PSP train step (paper's technique) "
                         "instead of the plain pipeline")
    ap.add_argument("--workers", type=int, default=0)
    a = ap.parse_args()
    if a.psp:
        archs = ["qwen2-0.5b"] if a.arch == "all" else a.arch.split(",")
        failures = 0
        for arch in archs:
            for mesh in a.mesh.split(","):
                rec = run_psp_combo(arch, mesh, a.out, a.workers, a.force)
                failures += rec["status"] == "error"
        print(f"done; {failures} failure(s)")
        return 1 if failures else 0
    archs = list(ARCHS) if a.arch == "all" else a.arch.split(",")
    shapes = list(INPUT_SHAPES) if a.shape == "all" else a.shape.split(",")
    meshes = a.mesh.split(",")
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                rec = run_combo(arch, shape, mesh, a.out, a.force)
                failures += rec["status"] == "error"
    print(f"done; {failures} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
