"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing 1 device.

Mesh geometry (v5e pods):
  single-pod: (data=16, model=16)          — 256 chips
  multi-pod:  (pod=2, data=16, model=16)   — 512 chips
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2,
                   pod: Optional[int] = None) -> Mesh:
    """Small mesh over however many (host) devices exist — for tests."""
    n = len(jax.devices())
    assert n >= data * model * (pod or 1), (n, data, model, pod)
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
