"""Serving launcher: batched decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --reduced \
        --requests 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.models import init_model
from repro.serving import ServeConfig, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args(argv)

    cfg = get_config(a.arch)
    if a.reduced:
        cfg = make_reduced(cfg)
    params = init_model(cfg, jax.random.PRNGKey(a.seed))
    eng = ServingEngine(params, cfg, ServeConfig(
        batch=a.batch, max_new_tokens=a.max_new,
        temperature=a.temperature, seed=a.seed))

    rng = np.random.default_rng(a.seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=a.prompt_len)
               .astype(np.int32) for _ in range(a.requests)]
    t0 = time.time()
    outs = eng.generate(prompts)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    print(f"arch={cfg.name} requests={a.requests} new_tokens={total_new} "
          f"wall={dt:.2f}s ({total_new/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o[:12].tolist()}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
