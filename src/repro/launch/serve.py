"""Serving launcher: one-shot batched decode, or a live hot-swapping server.

One-shot (legacy wave mode)::

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --reduced \
        --requests 16 --max-new 32 --top-k 50 --temperature 0.8

Live mode — watch a snapshot directory a trainer publishes into
(``repro.launch.train --publish-dir``) and hot-swap params mid-traffic::

    PYTHONPATH=src python -m repro.launch.serve --reduced \
        --watch-dir /tmp/snaps --requests 32

In live mode requests flow through the :class:`InferenceServer` admission
queue and every completion reports the snapshot version it was decoded
on; in-flight requests are never disturbed by a swap.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.models import init_model
from repro.serving import (InferenceServer, Request, ServeConfig,
                           ServingEngine, SnapshotWatcher)


def _serve_live(a, cfg, params, scfg) -> int:
    watcher = SnapshotWatcher(a.watch_dir, params)
    loaded = watcher.poll()
    version = 0
    if loaded is not None:
        params, version = loaded
        print(f"loaded snapshot v{version} from {a.watch_dir}")
    eng = ServingEngine(params, cfg, scfg, version=version)
    rng = np.random.default_rng(a.seed)
    t0 = time.time()
    with InferenceServer(eng, watcher=watcher,
                         poll_every=a.poll_every) as srv:
        futs = [srv.submit(Request(prompt=rng.integers(
            0, cfg.vocab_size, size=a.prompt_len).astype(np.int32)))
            for _ in range(a.requests)]
        comps = [f.result(timeout=a.timeout) for f in futs]
    dt = time.time() - t0
    total_new = sum(len(c.tokens) for c in comps)
    versions = sorted({c.snapshot_version for c in comps})
    st = srv.stats
    print(f"arch={cfg.name} requests={a.requests} new_tokens={total_new} "
          f"wall={dt:.2f}s ({total_new / dt:.1f} tok/s) "
          f"swaps={st.swaps} versions={versions}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=512,
                    help="per-group cache capacity")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None,
                    help="top-k sampling cutoff (with --temperature > 0)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop decoding a request at this token id")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--watch-dir", default=None,
                    help="serve live: hot-swap snapshots published here")
    ap.add_argument("--poll-every", type=int, default=8,
                    help="live mode: poll --watch-dir every N decode ticks")
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="live mode: per-request completion timeout (s)")
    a = ap.parse_args(argv)

    cfg = get_config(a.arch)
    if a.reduced:
        cfg = make_reduced(cfg)
    params = init_model(cfg, jax.random.PRNGKey(a.seed))
    scfg = ServeConfig(batch=a.batch, max_len=a.max_len,
                       max_new_tokens=a.max_new, temperature=a.temperature,
                       top_k=a.top_k, eos_id=a.eos_id, seed=a.seed)
    if a.watch_dir:
        return _serve_live(a, cfg, params, scfg)

    eng = ServingEngine(params, cfg, scfg)
    rng = np.random.default_rng(a.seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=a.prompt_len)
               .astype(np.int32) for _ in range(a.requests)]
    t0 = time.time()
    outs = eng.generate(prompts)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    print(f"arch={cfg.name} requests={a.requests} new_tokens={total_new} "
          f"wall={dt:.2f}s ({total_new/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o[:12].tolist()}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
