"""Step-function assembly shared by the trainer, server and dry-run.

Builds jit-ready ``train_step`` / ``prefill_step`` / ``serve_step`` (and the
PSP-barrier train step) for a (ModelConfig, InputShape, Mesh) combination,
together with the abstract (ShapeDtypeStruct + sharding) input trees the
dry-run lowers against.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.core.spmd_psp import PSPConfig, PSPState, psp_train_step
from repro.data.synthetic import make_batch_specs
from repro.models import (cache_defs, decode_step, loss_fn, model_defs,
                          prefill)
from repro.models.params import ParamDef, abstract_params, spec_tree
from repro.optim import Optimizer, apply_updates, clip_by_norm
from repro.parallel.sharding import AxisRules, make_rules, use_rules

PyTree = Any


# --------------------------------------------------------------------------- #
# step functions
# --------------------------------------------------------------------------- #
def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    rules: Optional[AxisRules] = None,
                    clip_norm: Optional[float] = 1.0) -> Callable:
    def train_step(params, opt_state, batch):
        with use_rules(rules):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg)
            if clip_norm is not None:
                grads = clip_by_norm(grads, clip_norm)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            params = apply_updates(params, updates)
        return params, opt_state, loss, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig,
                      rules: Optional[AxisRules] = None) -> Callable:
    def prefill_step(params, batch):
        with use_rules(rules):
            logits, cache = prefill(params, batch["tokens"], cfg,
                                    embeds=batch.get("embeds"))
        return logits, cache
    return prefill_step


def make_serve_step(cfg: ModelConfig,
                    rules: Optional[AxisRules] = None) -> Callable:
    def serve_step(params, cache, batch):
        with use_rules(rules):
            logits, new_cache = decode_step(params, cache, batch["tokens"],
                                            cfg)
        return logits, new_cache
    return serve_step


def make_psp_train_step(cfg: ModelConfig, psp_cfg: PSPConfig,
                        optimizer: Optimizer,
                        rules: Optional[AxisRules] = None,
                        clip_norm: Optional[float] = 1.0) -> Callable:
    """PSP-barrier training: W worker views, masked server aggregation."""
    def grad_fn(params, microbatch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, microbatch, cfg)
        if clip_norm is not None:
            grads = clip_by_norm(grads, clip_norm)
        return loss, grads

    def step(state: PSPState, batch):
        with use_rules(rules):
            return psp_train_step(psp_cfg, grad_fn, optimizer.update,
                                  state, batch)
    return step


# --------------------------------------------------------------------------- #
# abstract inputs for the dry-run
# --------------------------------------------------------------------------- #
def abstract_opt_state(optimizer_name: str, defs: Dict,
                       rules: Optional[AxisRules]) -> Dict:
    step = jax.ShapeDtypeStruct((), jnp.int32)
    if optimizer_name == "sgd":
        return {"step": step}
    mu = abstract_params(defs, jnp.float32, rules)
    if optimizer_name == "momentum":
        return {"step": step, "mu": mu}
    nu = abstract_params(defs, jnp.float32, rules)
    return {"step": step, "mu": mu, "nu": nu}


def abstract_cache(cfg: ModelConfig, shape: InputShape,
                   rules: Optional[AxisRules]) -> Dict:
    """Decode-shape cache: capacity seq_len, holding seq_len−1 tokens."""
    cdefs = cache_defs(cfg, shape.global_batch, shape.seq_len)
    return abstract_params(cdefs, jnp.bfloat16, rules)


def dryrun_inputs(cfg: ModelConfig, shape: InputShape, rules: AxisRules,
                  optimizer_name: str = "adamw"
                  ) -> Tuple[tuple, Callable, Tuple[int, ...]]:
    """(abstract_args, step_fn, donate_argnums) for one dry-run combo.

    Donation mirrors production: train donates (params, opt_state); decode
    donates the KV cache (without it XLA double-buffers the cache and the
    32k-decode combos of the big-KV archs exceed the 16 GB chip).
    """
    defs = model_defs(cfg)
    aparams = abstract_params(defs, jnp.dtype(cfg.param_dtype), rules)
    if shape.kind == "train":
        from repro.optim import adamw
        opt = adamw(1e-4)
        astate = abstract_opt_state(optimizer_name, defs, rules)
        batch = make_batch_specs(cfg, shape, rules)
        return (aparams, astate, batch), make_train_step(cfg, opt, rules),             (0, 1)
    if shape.kind == "prefill":
        batch = make_batch_specs(cfg, shape, rules)
        return (aparams, batch), make_prefill_step(cfg, rules), ()
    # decode
    cache = abstract_cache(cfg, shape, rules)
    batch = make_batch_specs(cfg, shape, rules, kind="decode")
    return (aparams, cache, batch), make_serve_step(cfg, rules), (1,)
