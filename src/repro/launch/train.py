"""Training launcher.

Two modes:

* ``--barrier none``  — plain synchronous (pjit) training: the classical
  data+tensor-parallel path used by the dry-run.
* ``--barrier {bsp,ssp,asp,pbsp,pssp}`` — PSP training (the paper's
  technique as a first-class feature): W worker views, seeded virtual-clock
  heterogeneity, masked server aggregation (core/spmd_psp.py).

Fault tolerance: with ``--ckpt-dir`` the run cuts *full-state* checkpoints
through the async :class:`repro.checkpoint.CheckpointManager` — every
``--save-every`` steps and/or ``--save-interval`` wall-clock seconds, plus
one at the final step.  The PSP mode persists the entire
:class:`~repro.core.spmd_psp.PSPState` (server params, optimizer state,
worker views, step/busy/pushed/alive arrays, churn cursors, policy pytree,
RNG key), the pjit mode persists ``{params, opt_state}``.  ``--resume``
restores the newest checkpoint and fast-forwards the synthetic data
stream to the restored step, so a SIGKILL'd run resumed with the same
flags reproduces the uninterrupted run bit-for-bit
(``tests/test_checkpoint.py`` pins this with a real subprocess kill).

Live serving: ``--publish-dir`` additionally publishes *serving
snapshots* (params only — ``server_params`` in PSP mode) every
``--publish-every`` steps over the trainer→server snapshot bus
(:mod:`repro.serving.snapshot_bus`), plus one final snapshot; a live
server (``repro.launch.serve --watch-dir``) hot-swaps them mid-traffic.

CPU example (used by examples/train_e2e.py):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
        --steps 200 --batch 8 --seq 128 --barrier pbsp --workers 4 \
        --ckpt-dir /tmp/ck --save-every 50
    # ... SIGKILL mid-run, then:
    PYTHONPATH=src python -m repro.launch.train ... --ckpt-dir /tmp/ck --resume
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.checkpoint import (CheckpointManager, CheckpointPolicy,
                              latest_step, restore_checkpoint)
from repro.configs import get_config, reduced as make_reduced
from repro.core.spmd_psp import (PSPConfig, psp_init, psp_train_step,
                                 state_from_tree, state_to_tree)
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import init_model, loss_fn
from repro.optim import adamw, clip_by_norm, warmup_cosine
from repro.serving.snapshot_bus import SnapshotPublisher


def _make_manager(a) -> CheckpointManager | None:
    """The run's async checkpointer (None when ``--ckpt-dir`` is unset)."""
    if not a.ckpt_dir:
        return None
    return CheckpointManager(
        a.ckpt_dir,
        CheckpointPolicy(every_steps=a.save_every or None,
                         every_seconds=a.save_interval or None),
        keep=a.keep)


def _maybe_resume(a, template):
    """Restore the newest checkpoint into ``template`` if ``--resume``.

    Returns ``(tree, start_step)`` — the template itself and 0 when there
    is nothing to resume from (first launch with ``--resume`` is legal:
    the flag means "continue if a checkpoint exists", so crash-loop
    supervisors can pass it unconditionally).
    """
    if not (a.resume and a.ckpt_dir) or latest_step(a.ckpt_dir) is None:
        return template, 0
    tree, step = restore_checkpoint(a.ckpt_dir, template)
    print(f"resumed step {step} from {a.ckpt_dir}")
    return tree, step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--barrier", default="none",
                    choices=["none", "bsp", "ssp", "asp", "pbsp", "pssp"])
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--sample-size", type=int, default=2)
    ap.add_argument("--staleness", type=int, default=3)
    ap.add_argument("--straggler-frac", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=0,
                    help="checkpoint every N steps (0: final step only)")
    ap.add_argument("--save-interval", type=float, default=0.0,
                    help="checkpoint every T wall-clock seconds (0: off)")
    ap.add_argument("--keep", type=int, default=3,
                    help="checkpoints retained by GC (older are deleted)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint in --ckpt-dir "
                         "(no-op when none exists) and continue")
    ap.add_argument("--throttle", type=float, default=0.0,
                    help="sleep per step; paces the run so kill-and-resume "
                         "tests get a deterministic mid-run kill window")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--publish-dir", default=None,
                    help="publish serving snapshots (params only) here "
                         "for a live server (repro.launch.serve "
                         "--watch-dir) to hot-swap")
    ap.add_argument("--publish-every", type=int, default=25,
                    help="snapshot-publication step cadence")
    a = ap.parse_args(argv)

    cfg = get_config(a.arch)
    if a.reduced:
        cfg = make_reduced(cfg, n_layers=a.n_layers, d_model=a.d_model)
        cfg = dataclasses.replace(cfg, vocab_size=a.vocab)
    opt = adamw(warmup_cosine(a.lr, a.steps // 10 + 1, a.steps))
    key = jax.random.PRNGKey(a.seed)
    params = init_model(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} barrier={a.barrier}")

    mgr = _make_manager(a)
    pub = (SnapshotPublisher(a.publish_dir, every_steps=a.publish_every)
           if a.publish_dir else None)
    meta = {"arch": cfg.name, "barrier": a.barrier}
    t0 = time.time()
    if a.barrier == "none":
        data = iter(SyntheticLM(cfg.vocab_size, a.seq, a.batch, seed=a.seed))
        state = opt.init(params)
        tree, start = _maybe_resume(a, {"params": params,
                                        "opt_state": state})
        params, state = tree["params"], tree["opt_state"]
        for _ in range(start):       # replay the consumed data stream
            next(data)
        step_fn = jax.jit(make_train_step(cfg, opt))
        for t in range(start, a.steps):
            batch = next(data)
            params, state, loss, _ = step_fn(params, state, batch)
            if t % a.log_every == 0 or t == a.steps - 1:
                print(f"step {t:5d} loss {float(loss):.4f} "
                      f"({time.time()-t0:.1f}s)")
            if mgr:
                mgr.maybe_save(t + 1, {"params": params, "opt_state": state},
                               {**meta, "data_step": t + 1})
            if pub:
                pub.maybe_publish(t + 1, params, meta)
            if a.throttle:
                time.sleep(a.throttle)
        final_tree = {"params": params, "opt_state": state}
    else:
        W = a.workers
        data = iter(SyntheticLM(cfg.vocab_size, a.seq, W * a.batch,
                                seed=a.seed))
        pcfg = PSPConfig(barrier=a.barrier, n_workers=W,
                         sample_size=a.sample_size, staleness=a.staleness,
                         straggler_frac=a.straggler_frac)

        def grad_fn(p, tokens):
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                p, {"tokens": tokens}, cfg)
            return loss, clip_by_norm(g, 1.0)

        st = psp_init(pcfg, params, opt.init, jax.random.fold_in(key, 1))
        tree, start = _maybe_resume(a, state_to_tree(st))
        st = state_from_tree(tree)
        for _ in range(start):       # replay the consumed data stream
            next(data)
        step_fn = jax.jit(lambda s, b: psp_train_step(
            pcfg, grad_fn, opt.update, s, b))
        for t in range(start, a.steps):
            toks = next(data)["tokens"].reshape(W, a.batch, a.seq)
            st, m = step_fn(st, toks)
            if t % a.log_every == 0 or t == a.steps - 1:
                print(f"tick {t:5d} loss {float(m['loss']):.4f} "
                      f"vtime {float(m['virtual_time']):.2f}s "
                      f"mean_step {float(m['mean_step']):.1f} "
                      f"spread {int(m['step_spread'])} "
                      f"({time.time()-t0:.1f}s)")
            if mgr:
                mgr.maybe_save(t + 1, state_to_tree(st),
                               {**meta, "data_step": t + 1})
            if pub:
                pub.maybe_publish(t + 1, st.server_params, meta)
            if a.throttle:
                time.sleep(a.throttle)
        params = st.server_params
        final_tree = state_to_tree(st)
    if mgr:
        if a.steps > start:
            mgr.save(a.steps, final_tree, {**meta, "data_step": a.steps},
                     block=True)
        mgr.close()
        print(f"checkpoint: step {mgr.latest_step()} in {a.ckpt_dir}")
    if pub:
        if a.steps > start:
            pub.publish(a.steps, params, meta, block=True)
        pub.close()
        print(f"published {pub.published} snapshots to {a.publish_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
