"""Training launcher.

Two modes:

* ``--barrier none``  — plain synchronous (pjit) training: the classical
  data+tensor-parallel path used by the dry-run.
* ``--barrier {bsp,ssp,asp,pbsp,pssp}`` — PSP training (the paper's
  technique as a first-class feature): W worker views, seeded virtual-clock
  heterogeneity, masked server aggregation (core/spmd_psp.py).

CPU example (used by examples/train_e2e.py):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
        --steps 200 --batch 8 --seq 128 --barrier pbsp --workers 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config, reduced as make_reduced
from repro.core.spmd_psp import PSPConfig, psp_init, psp_train_step
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import init_model, loss_fn
from repro.optim import adamw, apply_updates, clip_by_norm, warmup_cosine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--barrier", default="none",
                    choices=["none", "bsp", "ssp", "asp", "pbsp", "pssp"])
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--sample-size", type=int, default=2)
    ap.add_argument("--staleness", type=int, default=3)
    ap.add_argument("--straggler-frac", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--vocab", type=int, default=512)
    a = ap.parse_args(argv)

    cfg = get_config(a.arch)
    if a.reduced:
        cfg = make_reduced(cfg, n_layers=a.n_layers, d_model=a.d_model)
        cfg = dataclasses.replace(cfg, vocab_size=a.vocab)
    opt = adamw(warmup_cosine(a.lr, a.steps // 10 + 1, a.steps))
    key = jax.random.PRNGKey(a.seed)
    params = init_model(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} barrier={a.barrier}")

    t0 = time.time()
    if a.barrier == "none":
        data = iter(SyntheticLM(cfg.vocab_size, a.seq, a.batch, seed=a.seed))
        state = opt.init(params)
        step_fn = jax.jit(make_train_step(cfg, opt))
        for t in range(a.steps):
            batch = next(data)
            params, state, loss, _ = step_fn(params, state, batch)
            if t % a.log_every == 0 or t == a.steps - 1:
                print(f"step {t:5d} loss {float(loss):.4f} "
                      f"({time.time()-t0:.1f}s)")
    else:
        W = a.workers
        data = iter(SyntheticLM(cfg.vocab_size, a.seq, W * a.batch,
                                seed=a.seed))
        pcfg = PSPConfig(barrier=a.barrier, n_workers=W,
                         sample_size=a.sample_size, staleness=a.staleness,
                         straggler_frac=a.straggler_frac)

        def grad_fn(p, tokens):
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                p, {"tokens": tokens}, cfg)
            return loss, clip_by_norm(g, 1.0)

        st = psp_init(pcfg, params, opt.init, jax.random.fold_in(key, 1))
        step_fn = jax.jit(lambda s, b: psp_train_step(
            pcfg, grad_fn, opt.update, s, b))
        for t in range(a.steps):
            toks = next(data)["tokens"].reshape(W, a.batch, a.seq)
            st, m = step_fn(st, toks)
            if t % a.log_every == 0 or t == a.steps - 1:
                print(f"tick {t:5d} loss {float(m['loss']):.4f} "
                      f"vtime {float(m['virtual_time']):.2f}s "
                      f"mean_step {float(m['mean_step']):.1f} "
                      f"spread {int(m['step_spread'])} "
                      f"({time.time()-t0:.1f}s)")
        params = st.server_params
    if a.ckpt_dir:
        path = save_checkpoint(a.ckpt_dir, a.steps, params,
                               {"arch": cfg.name, "barrier": a.barrier})
        print("checkpoint:", path)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
