"""Model zoo: composable decoder covering all assigned architectures."""
from repro.models.transformer import (cache_defs, decode_step, forward,
                                      init_cache, init_model, loss_fn,
                                      model_defs, prefill, unembed_matrix)

__all__ = ["cache_defs", "decode_step", "forward", "init_cache", "init_model",
           "loss_fn", "model_defs", "prefill", "unembed_matrix"]
