"""Attention: GQA projections + chunked (flash-style) train/prefill path +
single-token decode path.

The train/prefill core is a two-level ``lax.scan`` with online softmax —
algorithmically identical to the Pallas flash kernel
(:mod:`repro.kernels.flash_attention`), so peak memory is O(block²) instead
of O(S²) and the pure-XLA path stays compile-friendly at 512 partitions.
On TPU the Pallas kernel replaces the inner loops; on CPU (tests, dry-run
lowering) the scan path is used.

Sliding-window layers slice a static (window + block) band of K/V per query
block, so SWA FLOPs scale as O(S·W) rather than O(S²) — this is what makes
``long_500k`` viable for the SWA archs and keeps prefill_32k honest in the
roofline.

Tensor-parallel head padding: the production mesh has a 16-way `model`
axis; archs whose head count doesn't divide it (qwen1.5: 20H, qwen2: 14H,
recurrentgemma: 10H) pad the *activation* head axis to the next multiple
(q padded with zero queries, K/V repeated to full MHA layout and padded
with zero keys, and the output projection padded with zero rows).  Dummy
heads therefore contribute exactly zero to the output and receive zero
gradient — semantics are unchanged, while the attention core shards evenly
across `model` with no resharding of the residual stream (the alternative,
batch-resharding per layer, triggered XLA "involuntary full
rematerialization" — see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.flash import flash_attention
from repro.models.layers import _gathered, rope, softcap
from repro.models.params import ParamDef
from repro.parallel.sharding import constrain, current_rules

__all__ = ["attn_defs", "attn_apply", "chunked_attention", "decode_attention"]

_NEG = -1e30


#: fixed block count of the fused-QKV layout: one block per shard of the
#: production 16-way `model` axis (works for any model size dividing 16)
_QKV_BLOCKS = 16


def _fusable_qkv(cfg) -> bool:
    return (cfg.fuse_qkv and not cfg.qkv_bias
            and cfg.n_heads % _QKV_BLOCKS == 0
            and cfg.n_kv_heads % _QKV_BLOCKS == 0)


def attn_defs(cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if _fusable_qkv(cfg):
        # blocked fused projection: per model-shard block [q…, k…, v…] so
        # q/k/v extraction slices an UNSHARDED dim (no resharding), and the
        # backward dx needs ONE all-reduce instead of three
        width = h // _QKV_BLOCKS + 2 * (kv // _QKV_BLOCKS)
        defs = {
            "wqkv": ParamDef((d, _QKV_BLOCKS, width, hd),
                             ("d_model_w", "heads_w", None, None)),
            "wo": ParamDef((h, hd, d), ("heads_w", None, "d_model_w")),
        }
        return defs
    defs = {
        "wq": ParamDef((d, h, hd), ("d_model_w", "heads_w", None)),
        "wk": ParamDef((d, kv, hd), ("d_model_w", "kv_heads_w", None)),
        "wv": ParamDef((d, kv, hd), ("d_model_w", "kv_heads_w", None)),
        "wo": ParamDef((h, hd, d), ("heads_w", None, "d_model_w")),
    }
    if cfg.qkv_bias:
        defs.update({
            "bq": ParamDef((h, hd), ("heads_w", None), init="zeros"),
            "bk": ParamDef((kv, hd), ("kv_heads_w", None), init="zeros"),
            "bv": ParamDef((kv, hd), ("kv_heads_w", None), init="zeros"),
        })
    return defs


# --------------------------------------------------------------------------- #
# train / prefill core (MHA layout: K/V pre-repeated to H heads)
# --------------------------------------------------------------------------- #
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      window: Optional[int] = None,
                      attn_softcap: Optional[float] = None,
                      block_q: int = 512,
                      block_k: int = 512) -> jax.Array:
    """Online-softmax blocked attention (MHA layout).

    q, k, v: (B, S, H, hd).  Query i attends keys ≤ i (+ window bound).
    Returns (B, Sq, H, hd) in q.dtype.
    """
    B, Sq, H, hd = q.shape
    _, Sk, _, _ = k.shape
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq = Sq // block_q
    scale = hd ** -0.5
    qr = q * scale

    if window is not None:
        # static K/V band per query block: the window plus the query block,
        # rounded up to whole K blocks
        span = min(Sk, int(np.ceil((window + block_q) / block_k)) * block_k)
    else:
        span = Sk
    nk = span // block_k

    def q_block(carry, qi):
        del carry
        q_start = qi * block_q
        qb = jax.lax.dynamic_slice_in_dim(qr, q_start, block_q, axis=1)
        q_pos = q_start + jnp.arange(block_q)

        if window is not None and span < Sk:
            k_start = jnp.clip(q_start + block_q - span, 0, Sk - span)
        else:
            k_start = jnp.zeros((), jnp.int32)
        kb_all = jax.lax.dynamic_slice_in_dim(k, k_start, span, axis=1)
        vb_all = jax.lax.dynamic_slice_in_dim(v, k_start, span, axis=1)

        m0 = jnp.full((B, H, block_q), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        a0 = jnp.zeros((B, H, block_q, hd), jnp.float32)

        def k_block(kcarry, ki):
            m, l, acc = kcarry
            kb = jax.lax.dynamic_slice_in_dim(kb_all, ki * block_k, block_k, 1)
            vb = jax.lax.dynamic_slice_in_dim(vb_all, ki * block_k, block_k, 1)
            k_pos = k_start + ki * block_k + jnp.arange(block_k)
            s = jnp.einsum("bqhd,bshd->bhqs", qb, kb,
                           preferred_element_type=jnp.float32)
            s = softcap(s, attn_softcap)
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask, s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(k_block, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,H,bq,hd)
        return None, out.transpose(0, 2, 1, 3).astype(q.dtype)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(nq))
    # (nq, B, bq, H, hd) → (B, Sq, H, hd)
    return blocks.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


# --------------------------------------------------------------------------- #
# decode core (GQA layout against the compact KV cache)
# --------------------------------------------------------------------------- #
def decode_attention(q: jax.Array, ck: jax.Array, cv: jax.Array,
                     length: jax.Array, *,
                     ring: bool = False,
                     attn_softcap: Optional[float] = None) -> jax.Array:
    """One-token attention against a KV cache.

    q: (B, 1, H, hd); ck/cv: (B, S, KV, hd); length: i32[] — number of valid
    cache entries (for ring buffers, valid = min(length, S); slot order is
    irrelevant to softmax).
    """
    B, _, H, hd = q.shape
    _, S, KV, _ = ck.shape
    G = H // KV
    qr = (q[:, 0] * hd ** -0.5).reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qr, ck,
                   preferred_element_type=jnp.float32)
    s = softcap(s, attn_softcap)
    valid = jnp.arange(S) < jnp.minimum(length, S)
    s = jnp.where(valid[None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, cv.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------- #
# head padding for tensor parallelism
# --------------------------------------------------------------------------- #
def _model_axis_size() -> int:
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return 1
    return int(rules.mesh.shape.get("model", 1))


def _padded_heads(H: int, model: int) -> int:
    if model <= 1 or H % model == 0:
        return H
    return int(np.ceil(H / model)) * model


def _repeat_pad_kv(k: jax.Array, H: int, H_pad: int) -> jax.Array:
    """(B,S,KV,hd) → MHA layout (B,S,H_pad,hd): repeat per group, zero-pad."""
    B, S, KV, hd = k.shape
    G = H // KV
    k = jnp.repeat(k, G, axis=2)                           # (B,S,H,hd)
    if H_pad > H:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, H_pad - H), (0, 0)))
    return k


# --------------------------------------------------------------------------- #
# full module
# --------------------------------------------------------------------------- #
def attn_apply(p: dict, x: jax.Array, *, cfg, window: Optional[int],
               positions: jax.Array, cache: Optional[dict] = None,
               mode: str = "train",
               max_len: Optional[int] = None
               ) -> Tuple[jax.Array, Optional[dict]]:
    """GQA attention with RoPE.

    mode: "train" (no cache), "prefill" (returns cache), "decode"
    (reads/updates cache; x is (B, 1, D); ``positions[0]`` is the write
    position == current length).
    """
    dtype = x.dtype
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if _fusable_qkv(cfg):
        nq, nkv = H // _QKV_BLOCKS, KV // _QKV_BLOCKS
        proj = jnp.einsum(
            "bsd,dnwk->bsnwk", x,
            _gathered(p["wqkv"], dtype, (None, "heads_w", None, None)))
        q = proj[:, :, :, :nq].reshape(B, S, H, hd)
        k = proj[:, :, :, nq:nq + nkv].reshape(B, S, KV, hd)
        v = proj[:, :, :, nq + nkv:].reshape(B, S, KV, hd)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x,
                       _gathered(p["wq"], dtype, (None, "heads_w", None)))
        k = jnp.einsum("bsd,dhk->bshk", x,
                       _gathered(p["wk"], dtype, (None, "kv_heads_w", None)))
        v = jnp.einsum("bsd,dhk->bshk", x,
                       _gathered(p["wv"], dtype, (None, "kv_heads_w", None)))
        if cfg.qkv_bias:
            q = q + p["bq"].astype(dtype)
            k = k + p["bk"].astype(dtype)
            v = v + p["bv"].astype(dtype)

    if cfg.pos_embed == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    if mode == "decode":
        assert cache is not None
        length = positions[0]
        ck, cv = cache["k"], cache["v"]
        s_max = ck.shape[1]
        slot = (length % s_max) if window is not None else length
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, 1)
        ck = constrain(ck, ("cache_batch", "cache_seq", "kv_heads", None))
        cv = constrain(cv, ("cache_batch", "cache_seq", "kv_heads", None))
        o = decode_attention(q, ck, cv, length + 1, ring=window is not None,
                             attn_softcap=cfg.attn_softcap)
        new_cache = {"k": ck, "v": cv}
        wo = _gathered(p["wo"], dtype, ("heads_w", None, None))
    else:
        model = _model_axis_size()
        H_pad = _padded_heads(H, model)
        if H_pad > H:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, H_pad - H), (0, 0)))
        kf = _repeat_pad_kv(k, H, H_pad)
        vf = _repeat_pad_kv(v, H, H_pad)
        q = constrain(q, ("attn_batch", "qseq", "heads", None))
        kf = constrain(kf, ("attn_batch", "seq", "heads", None))
        vf = constrain(vf, ("attn_batch", "seq", "heads", None))
        o = flash_attention(q, kf, vf, True, window, cfg.attn_softcap)
        o = constrain(o, ("attn_batch", "qseq", "heads", None))
        wo = _gathered(p["wo"], dtype, ("heads_w", None, None))
        if H_pad > H:
            wo = jnp.pad(wo, ((0, H_pad - H), (0, 0), (0, 0)))
        new_cache = None
        if mode == "prefill":
            if window is not None:
                w = window
                if S >= w:
                    # ring layout: absolute position p lives at slot p % w
                    ck = jnp.roll(k[:, S - w:], S % w, axis=1)
                    cv = jnp.roll(v[:, S - w:], S % w, axis=1)
                else:
                    pad = ((0, 0), (0, w - S), (0, 0), (0, 0))
                    ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
            else:
                ck, cv = k, v
                if max_len is not None and max_len > S:
                    pad = ((0, 0), (0, max_len - S), (0, 0), (0, 0))
                    ck, cv = jnp.pad(ck, pad), jnp.pad(cv, pad)
            ck = constrain(ck, ("cache_batch", "cache_seq", "kv_heads", None))
            cv = constrain(cv, ("cache_batch", "cache_seq", "kv_heads", None))
            new_cache = {"k": ck.astype(jnp.bfloat16),
                         "v": cv.astype(jnp.bfloat16)}

    out = jnp.einsum("bshk,hkd->bsd", o, wo)
    return constrain(out, ("batch", "seq", None)), new_cache
