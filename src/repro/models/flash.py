"""Flash attention with a memory-correct custom VJP (pure-XLA path).

Differentiating naively through the blocked-softmax scans makes JAX save
every (block_q × block_k) probability/mask tile as a scan residual —
O(S²/chips) bytes, which dominated the dry-run temp memory (see
EXPERIMENTS.md §Perf).  The fix is the standard flash-attention backward:
save only (q, k, v, o, lse), recompute tile scores/probabilities in the
backward sweep, and accumulate dq/dk/dv blockwise.

Forward:  o = softmax(mask(τ·tanh(qkᵀ/τ) if softcap else qkᵀ)) v
Backward: p  = exp(s − lse)
          dv = pᵀ · do
          dp = do · vᵀ ;  ds = p ⊙ (dp − Δ),  Δ = rowsum(do ⊙ o)
          (softcap chain: ds ← ds ⊙ (1 − tanh²(s_raw/τ)))
          dq = ds · k ;  dk = dsᵀ · q

Both sweeps are q-block scans with k-block inner scans over a static
sliding-window band, so SWA keeps O(S·W) work in the backward as well.

Causal global attention uses a **triangular pair scan**: instead of
sweeping the full (nq × nk) tile rectangle and masking the upper half
(≈2× wasted FLOPs — visible in the roofline useful_ratio), both sweeps
iterate a static list of the nq·(nq+1)/2 visible (qi, ki) tile pairs and
scatter-accumulate per-q-block softmax state (EXPERIMENTS.md §Perf,
compute-term iteration).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_NEG = -1e30


def _tri_pairs(nq: int):
    """Static (qi, ki) lists covering the causal lower-triangle of tiles."""
    qis, kis = [], []
    for qi in range(nq):
        for ki in range(qi + 1):
            qis.append(qi)
            kis.append(ki)
    return jnp.asarray(qis, jnp.int32), jnp.asarray(kis, jnp.int32)


def _band(window: Optional[int], block_q: int, block_k: int,
          s_k: int) -> int:
    if window is None:
        return s_k
    return min(s_k, int(np.ceil((window + block_q) / block_k)) * block_k)


def _mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True,
                    window: Optional[int] = None,
                    attn_softcap: Optional[float] = None,
                    block_q: int = 512,
                    block_k: int = 512) -> jax.Array:
    """q, k, v: (B, S, H, hd) MHA layout → (B, Sq, H, hd)."""
    o, _ = _fwd(q, k, v, causal, window, attn_softcap, block_q, block_k)
    return o


def _use_triangular(causal, window, Sq, Sk, block_q, block_k):
    return (causal and window is None and Sq == Sk
            and block_q == block_k and Sq % block_q == 0)


def _fwd_triangular(q, k, v, cap, blk):
    """Causal forward over the visible tile pairs only (no masked tiles
    except the diagonal)."""
    B, Sq, H, hd = q.shape
    nq = Sq // blk
    scale = hd ** -0.5
    qis, kis = _tri_pairs(nq)

    m0 = jnp.full((B, H, Sq), _NEG, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)

    def step(c, qk):
        m, l, acc = c
        qi, ki = qk
        qb = jax.lax.dynamic_slice_in_dim(q, qi * blk, blk, 1) * scale
        kb = jax.lax.dynamic_slice_in_dim(k, ki * blk, blk, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, ki * blk, blk, 1)
        s = jnp.einsum("bqhd,bshd->bhqs", qb, kb,
                       preferred_element_type=jnp.float32)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        diag = qi == ki
        pos = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0) >=             jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
        s = jnp.where(jnp.logical_or(~diag, pos)[None, None], s, _NEG)
        mb = jax.lax.dynamic_slice_in_dim(m, qi * blk, blk, 2)
        lb = jax.lax.dynamic_slice_in_dim(l, qi * blk, blk, 2)
        ab = jax.lax.dynamic_slice_in_dim(acc, qi * blk, blk, 2)
        m_new = jnp.maximum(mb, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mb - m_new)
        l_new = lb * corr + jnp.sum(p, axis=-1)
        a_new = ab * corr[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", p, vb.astype(jnp.float32))
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, qi * blk, 2)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, qi * blk, 2)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, qi * blk, 2)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (qis, kis))
    o = (acc / jnp.maximum(l, 1e-30)[..., None]).transpose(0, 2, 1, 3)         .astype(q.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return o, (q, k, v, o, lse)


def _bwd_triangular(cap, blk, res, do):
    q, k, v, o, lse = res
    B, Sq, H, hd = q.shape
    nq = Sq // blk
    scale = hd ** -0.5
    qis, kis = _tri_pairs(nq)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dq0 = jnp.zeros(q.shape, jnp.float32)
    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)

    def step(c, qk):
        dq, dk, dv = c
        qi, ki = qk
        qb = jax.lax.dynamic_slice_in_dim(q, qi * blk, blk, 1) * scale
        kb = jax.lax.dynamic_slice_in_dim(k, ki * blk, blk, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, ki * blk, blk, 1)
        dob = jax.lax.dynamic_slice_in_dim(do, qi * blk, blk, 1)             .astype(jnp.float32)
        deltab = jax.lax.dynamic_slice_in_dim(delta, qi * blk, blk, 1)
        lseb = jax.lax.dynamic_slice_in_dim(lse, qi * blk, blk, 2)
        s_raw = jnp.einsum("bqhd,bshd->bhqs", qb, kb,
                           preferred_element_type=jnp.float32)
        if cap is not None:
            t = jnp.tanh(s_raw / cap)
            s = cap * t
        else:
            s = s_raw
        diag = qi == ki
        pos = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0) >=             jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
        mask = jnp.logical_or(~diag, pos)[None, None]
        s = jnp.where(mask, s, _NEG)
        p = jnp.exp(s - lseb[..., None])
        dv_blk = jnp.einsum("bhqs,bqhd->bshd", p, dob)
        dp = jnp.einsum("bqhd,bshd->bhqs", dob.astype(v.dtype), vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - deltab.transpose(0, 2, 1)[..., None])
        if cap is not None:
            ds = ds * (1.0 - t * t)
        ds = jnp.where(mask, ds, 0.0)
        dq_blk = jnp.einsum("bhqs,bshd->bqhd", ds,
                            kb.astype(jnp.float32)) * scale
        dk_blk = jnp.einsum("bhqs,bqhd->bshd", ds, qb.astype(jnp.float32))
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, qi * blk, blk, 1) + dq_blk,
            qi * blk, 1)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, ki * blk, blk, 1) + dk_blk,
            ki * blk, 1)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, ki * blk, blk, 1) + dv_blk,
            ki * blk, 1)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), (qis, kis))
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _fwd(q, k, v, causal, window, cap, block_q, block_k):
    B, Sq, H, hd = q.shape
    _, Sk, _, _ = k.shape
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if _use_triangular(causal, window, Sq, Sk, block_q, block_k):
        return _fwd_triangular(q, k, v, cap, block_q)
    nq = Sq // block_q
    scale = hd ** -0.5
    span = _band(window, block_q, block_k, Sk)
    nk = span // block_k

    def q_block(_, qi):
        q_start = qi * block_q
        qb = jax.lax.dynamic_slice_in_dim(q, q_start, block_q, 1) * scale
        q_pos = q_start + jnp.arange(block_q)
        k_start = (jnp.clip(q_start + block_q - span, 0, Sk - span)
                   if (window is not None and span < Sk)
                   else jnp.zeros((), jnp.int32))
        kb_all = jax.lax.dynamic_slice_in_dim(k, k_start, span, 1)
        vb_all = jax.lax.dynamic_slice_in_dim(v, k_start, span, 1)

        m0 = jnp.full((B, H, block_q), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        a0 = jnp.zeros((B, H, block_q, hd), jnp.float32)

        def k_block(c, ki):
            m, l, acc = c
            kb = jax.lax.dynamic_slice_in_dim(kb_all, ki * block_k, block_k, 1)
            vb = jax.lax.dynamic_slice_in_dim(vb_all, ki * block_k, block_k, 1)
            k_pos = k_start + ki * block_k + jnp.arange(block_k)
            s = jnp.einsum("bqhd,bshd->bhqs", qb, kb,
                           preferred_element_type=jnp.float32)
            if cap is not None:
                s = cap * jnp.tanh(s / cap)
            s = jnp.where(_mask(q_pos, k_pos, causal, window), s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(k_block, (m0, l0, a0), jnp.arange(nk))
        ob = (acc / jnp.maximum(l, 1e-30)[..., None])
        lse = m + jnp.log(jnp.maximum(l, 1e-30))           # (B,H,bq)
        return None, (ob.transpose(0, 2, 1, 3).astype(q.dtype), lse)

    _, (blocks, lses) = jax.lax.scan(q_block, None, jnp.arange(nq))
    o = blocks.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
    lse = lses.transpose(1, 2, 0, 3).reshape(B, H, Sq)
    return o, (q, k, v, o, lse)


def _bwd(causal, window, cap, block_q, block_k, res, do):
    q, k, v, o, lse = res
    B, Sq, H, hd = q.shape
    _, Sk, _, _ = k.shape
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if _use_triangular(causal, window, Sq, Sk, block_q, block_k):
        return _bwd_triangular(cap, block_q, res, do)
    nq = Sq // block_q
    scale = hd ** -0.5
    span = _band(window, block_q, block_k, Sk)
    nk = span // block_k

    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                                # (B,Sq,H)
    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)

    def q_block(carry, qi):
        dk_acc, dv_acc = carry
        q_start = qi * block_q
        qb = jax.lax.dynamic_slice_in_dim(q, q_start, block_q, 1) * scale
        dob = jax.lax.dynamic_slice_in_dim(do, q_start, block_q, 1) \
            .astype(jnp.float32)
        deltab = jax.lax.dynamic_slice_in_dim(delta, q_start, block_q, 1)
        lseb = jax.lax.dynamic_slice_in_dim(lse, q_start, block_q, 2)
        q_pos = q_start + jnp.arange(block_q)
        k_start = (jnp.clip(q_start + block_q - span, 0, Sk - span)
                   if (window is not None and span < Sk)
                   else jnp.zeros((), jnp.int32))

        dq0 = jnp.zeros((B, block_q, H, hd), jnp.float32)

        def k_block(c, ki):
            dqb, dk_acc, dv_acc = c
            ks = k_start + ki * block_k
            kb = jax.lax.dynamic_slice_in_dim(k, ks, block_k, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, ks, block_k, 1)
            k_pos = ks + jnp.arange(block_k)
            s_raw = jnp.einsum("bqhd,bshd->bhqs", qb, kb,
                               preferred_element_type=jnp.float32)
            if cap is not None:
                t = jnp.tanh(s_raw / cap)
                s = cap * t
            else:
                s = s_raw
            mask = _mask(q_pos, k_pos, causal, window)
            s = jnp.where(mask, s, _NEG)
            p = jnp.exp(s - lseb[..., None])                # (B,H,bq,bk)
            dv_blk = jnp.einsum("bhqs,bqhd->bshd", p, dob)
            dp = jnp.einsum("bqhd,bshd->bhqs", dob.astype(v.dtype),
                            vb, preferred_element_type=jnp.float32)
            ds = p * (dp - deltab.transpose(0, 2, 1)[..., None])
            if cap is not None:
                ds = ds * (1.0 - t * t)
            ds = jnp.where(mask, ds, 0.0)
            dq_blk = jnp.einsum("bhqs,bshd->bqhd", ds,
                                kb.astype(jnp.float32)) * scale
            dk_blk = jnp.einsum("bhqs,bqhd->bshd", ds,
                                (qb).astype(jnp.float32))
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(dk_acc, ks, block_k, 1)
                + dk_blk, ks, 1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(dv_acc, ks, block_k, 1)
                + dv_blk, ks, 1)
            return (dqb + dq_blk, dk_acc, dv_acc), None

        (dqb, dk_acc, dv_acc), _ = jax.lax.scan(
            k_block, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dqb.astype(q.dtype)

    (dk, dv), dq_blocks = jax.lax.scan(q_block, (dk0, dv0), jnp.arange(nq))
    dq = dq_blocks.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd, _bwd)
