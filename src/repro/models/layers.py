"""Shared neural layers: norms, RoPE/sinusoidal positions, MLPs, losses."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import ParamDef
from repro.parallel.sharding import constrain

__all__ = ["rmsnorm", "rope", "sinusoidal_pos", "mlp_defs", "mlp_apply",
           "softcap", "chunked_cross_entropy", "embed_tokens"]


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
            gemma: bool = False) -> jax.Array:
    """RMSNorm with f32 statistics; ``gemma=True`` scales by (1 + w).

    Custom VJP for two memory-critical reasons (EXPERIMENTS.md §Perf,
    memory-term iterations):

    1. the forward accumulates the variance through an f32 dot instead of
       upcasting x — a wholesale ``x.astype(f32)`` at the top of every
       block makes XLA hoist the conversion out of the layer scan,
       materialising an f32 copy of the whole (L, B, S, D)
       residual-checkpoint stack;
    2. the backward returns dx in **x's dtype** — the plain autodiff rule
       emits an f32 cotangent (bf16 primal × f32 multiplier), and once one
       f32 cotangent enters the residual stream the entire backward
       activation traffic doubles.
    """
    return _rms_fwd(x, w, eps, gemma)[0]


def _rms_stats(x, eps):
    d = x.shape[-1]
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / d
    return jax.lax.rsqrt(var + eps)                        # f32 (...,)


def _rms_fwd(x, w, eps, gemma):
    m = _rms_stats(x, eps)
    scale = (1.0 + w.astype(jnp.float32)) if gemma else w.astype(jnp.float32)
    if x.dtype == jnp.float32:
        y = x * m[..., None] * scale
    else:
        y = (x * (m[..., None] * scale).astype(x.dtype)).astype(x.dtype)
    return y, (x, w, m)


def _rms_bwd(eps, gemma, res, g):
    """dx_j = m·s_j·g_j − (m³ x_j / d)·Σ_i g_i s_i x_i.

    Every consumption of the *saved* x happens through a bf16-native op
    (f32-accumulating dot or a bf16 multiply) — an elementwise
    ``x.astype(f32)`` here would be commuted past the scan's
    dynamic-slice by XLA and materialise an f32 twin of the whole
    residual-checkpoint stack (measured: +27.8 GB/device on gemma2-27b).
    """
    x, w, m = res
    d = x.shape[-1]
    scale = (1.0 + w.astype(jnp.float32)) if gemma else w.astype(jnp.float32)
    gs = g.astype(jnp.float32) * scale                     # transient f32
    gs_x = gs.astype(x.dtype)
    inner = jnp.einsum("...d,...d->...", gs_x, x,
                       preferred_element_type=jnp.float32)
    coeff = (m ** 3 / d) * inner                           # f32 (...,)
    dx = (m[..., None] * gs).astype(x.dtype) \
        - coeff[..., None].astype(x.dtype) * x
    # dw_i = Σ_rows g_i·x_i·m  (einsum keeps x in its own dtype)
    t = (g.astype(jnp.float32) * m[..., None]).astype(x.dtype)
    tr = t.reshape(-1, d)
    xr = x.reshape(-1, d)
    dw = jnp.einsum("rd,rd->d", tr, xr,
                    preferred_element_type=jnp.float32).astype(w.dtype)
    return dx, dw


rmsnorm.defvjp(_rms_fwd, _rms_bwd)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: cap · tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------------------- #
# positions
# --------------------------------------------------------------------------- #
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, half-rotation convention.

    x: (..., S, H, hd); positions: (S,) or scalar broadcastable.
    """
    hd = x.shape[-1]
    half = hd // 2
    freq = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freq  # (S, half)
    cos = jnp.cos(angles)[..., None, :]   # (S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions: jax.Array, d_model: int) -> jax.Array:
    """Classic transformer sinusoidal embedding; positions (S,) → (S, D)."""
    half = d_model // 2
    freq = np.exp(-np.log(10_000.0) * np.arange(half, dtype=np.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #
def mlp_defs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_type in ("swiglu", "geglu"):
        if cfg.fuse_gateup:
            # gate and up interleaved on a trailing axis of 2 so the split
            # after the matmul slices an UNSHARDED dim (no resharding)
            return {
                "w_gu": ParamDef((d, f, 2), ("d_model_w", "d_ff_w", None)),
                "w_down": ParamDef((f, d), ("d_ff_w", "d_model_w")),
            }
        return {
            "w_gate": ParamDef((d, f), ("d_model_w", "d_ff_w")),
            "w_up": ParamDef((d, f), ("d_model_w", "d_ff_w")),
            "w_down": ParamDef((f, d), ("d_ff_w", "d_model_w")),
        }
    return {   # plain gelu MLP (musicgen)
        "w_up": ParamDef((d, f), ("d_model_w", "d_ff_w")),
        "w_down": ParamDef((f, d), ("d_ff_w", "d_model_w")),
    }


def _gathered(w: jax.Array, dtype, axes) -> jax.Array:
    """Cast a weight to compute dtype and make it whole along the FSDP
    (`data`) axis before the matmul.

    Without this, XLA executes the contraction with the d_model dim sharded
    and ALL-REDUCES the (B, S, d_ff)-sized f32 partials — ~300 MB per matmul
    — instead of all-gathering the ~20 MB bf16 weight.  Measured 40× drop in
    per-device collective bytes on gemma2-27b train_4k (EXPERIMENTS.md
    §Perf, collective-term iteration 1).
    """
    return constrain(w.astype(dtype), axes)


def mlp_apply(p: dict, x: jax.Array, cfg) -> jax.Array:
    dtype = x.dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        if cfg.fuse_gateup:
            gu = jnp.einsum(
                "bsd,dft->bsft", x,
                _gathered(p["w_gu"], dtype, (None, "d_ff_w", None)))
            g, u = gu[..., 0], gu[..., 1]
        else:
            g = x @ _gathered(p["w_gate"], dtype, (None, "d_ff_w"))
            u = x @ _gathered(p["w_up"], dtype, (None, "d_ff_w"))
        h = act(g) * u
        h = constrain(h, ("batch", "seq", "d_ff_act"))
        return h @ _gathered(p["w_down"], dtype, ("d_ff_w", None))
    h = jax.nn.gelu(x @ _gathered(p["w_up"], dtype, (None, "d_ff_w")),
                    approximate=True)
    h = constrain(h, ("batch", "seq", "d_ff_act"))
    return h @ _gathered(p["w_down"], dtype, ("d_ff_w", None))


# --------------------------------------------------------------------------- #
# embedding & loss
# --------------------------------------------------------------------------- #
def embed_tokens(embed: jax.Array, tokens: jax.Array, cfg) -> jax.Array:
    x = jnp.take(embed, tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    return x


def chunked_cross_entropy(h: jax.Array, labels: jax.Array, unembed: jax.Array,
                          cfg, chunk: int = 512) -> jax.Array:
    """Causal-LM loss without materialising full (B, S, V) logits.

    h: (B, S, D) hidden states aligned so h[:, i] predicts labels[:, i];
    unembed: (D, V).  Scans over seq chunks; each chunk's logits are
    (B, chunk, V)-sized, optionally soft-capped (gemma2).  S is padded to a
    chunk multiple; padded positions carry label −1 and are masked out.
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (S + pad) // chunk
    hc = h.reshape(B, n, chunk, D).swapaxes(0, 1)          # (n, B, c, D)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)        # (n, B, c)

    @jax.checkpoint   # recompute chunk logits in backward: O(B·c·V) peak
    def step(tot, xs):
        hb, lb = xs
        logits = hb @ unembed.astype(hb.dtype)             # (B, c, V)
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        logits = constrain(logits, ("batch", "seq", "vocab_act"))
        valid = lb >= 0
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(jnp.where(valid, logz - gold, 0.0)), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (B * S)
