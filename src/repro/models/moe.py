"""Mixture-of-Experts block: top-k router + expert-parallel FFN.

Design (DESIGN.md §7, TPU adaptation):

* **Routing** is computed locally per data shard (the router weight is
  replicated — it is tiny).
* **Experts are sharded over the `model` axis** (expert parallelism).  Inside
  a ``shard_map`` over the full mesh, every model shard sees its data row's
  tokens (tokens are *replicated* across the model axis), computes only its
  local experts at fixed capacity, and the outputs are combined with a single
  ``psum`` over `model` — the same collective shape as ordinary tensor
  parallelism, i.e. **no all-to-all is needed** in this scheme.  (An a2a
  dispatch variant is evaluated in EXPERIMENTS.md §Perf.)
* **Capacity**: per (data-shard × expert) capacity C = ceil(T_loc·k/E · cf);
  over-capacity tokens are dropped (standard Switch-style behaviour) and the
  drop fraction is part of the aux metrics.
* The per-expert FF dim is FSDP-sharded over `data` at rest and
  all-gathered per layer (see ``transformer._gather_moe``).

The same ``_moe_core`` runs unsharded for CPU smoke tests (≤4 experts).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:                                   # top-level since jax 0.5
    from jax import shard_map as _shard_map
except ImportError:                    # jax ≤ 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.models.params import ParamDef
from repro.parallel.sharding import constrain, current_rules

__all__ = ["moe_defs", "moe_apply"]


def moe_defs(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, e), (None, None)),   # replicated (tiny)
        "w_gate": ParamDef((e, d, f), ("experts_w", None, "expert_ff_w")),
        "w_up": ParamDef((e, d, f), ("experts_w", None, "expert_ff_w")),
        "w_down": ParamDef((e, f, d), ("experts_w", "expert_ff_w", None)),
    }


def _capacity(tokens_local: int, cfg) -> int:
    c = int(np.ceil(tokens_local * cfg.n_experts_per_token / cfg.n_experts
                    * cfg.moe_capacity_factor))
    return max(8, ((c + 7) // 8) * 8)   # pad to 8 for TPU-friendly layout


def _moe_core(x: jax.Array, router: jax.Array, w_gate: jax.Array,
              w_up: jax.Array, w_down: jax.Array, cfg,
              first_expert, capacity: int) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE over the local expert slice.

    x: (T, D) local tokens; w_*: (E_loc, ...) local experts;
    first_expert: global index of the first local expert.
    Returns (y: (T, D) partial output over local experts, aux_loss: f32[]).
    """
    T, D = x.shape
    E = cfg.n_experts
    E_loc = w_gate.shape[0]
    k = cfg.n_experts_per_token
    dtype = x.dtype

    logits = (x @ router.astype(dtype)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                    # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)    # renormalise

    # --- aux load-balance loss (Switch-style) -------------------------- #
    counts = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    frac_tokens = counts / (T * k)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)

    # --- sort-based dispatch ------------------------------------------- #
    flat_e = top_i.reshape(-1)                                # (T·k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    group_start = jnp.searchsorted(se, jnp.arange(E))         # (E,)
    pos = jnp.arange(T * k) - group_start[se]
    loc = se - first_expert
    ok = (loc >= 0) & (loc < E_loc) & (pos < capacity)
    slot = jnp.where(ok, loc * capacity + pos, E_loc * capacity)

    buf = jnp.zeros((E_loc * capacity + 1, D), dtype)
    buf = buf.at[slot].set(x[st])
    h = buf[: E_loc * capacity].reshape(E_loc, capacity, D)

    g = jnp.einsum("ecd,edf->ecf", h, w_gate.astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", h, w_up.astype(dtype))
    a = jax.nn.silu(g) * u
    o = jnp.einsum("ecf,efd->ecd", a, w_down.astype(dtype))
    o_flat = o.reshape(E_loc * capacity, D)

    contrib = jnp.where(ok, sw, 0.0).astype(dtype)[:, None] * \
        o_flat[jnp.minimum(slot, E_loc * capacity - 1)]
    y = jnp.zeros((T, D), dtype).at[st].add(
        jnp.where(ok[:, None], contrib, 0))
    return y, aux


def moe_apply(p: dict, x: jax.Array, cfg,
              mesh=None) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN. x: (B, S, D) → (y, aux_loss).

    With a mesh: expert-parallel shard_map (experts over `model`, tokens over
    `pod`×`data`).  Without: single-shard fallback (smoke tests).
    """
    B, S, D = x.shape
    rules = current_rules()
    mesh = mesh or (rules.mesh if rules is not None else None)
    use_shmap = (mesh is not None and "model" in mesh.axis_names
                 and int(mesh.shape["model"]) > 1
                 and cfg.n_experts % int(mesh.shape["model"]) == 0)

    if not use_shmap:
        cap = _capacity(B * S, cfg)
        y, aux = _moe_core(x.reshape(B * S, D), p["router"], p["w_gate"],
                           p["w_up"], p["w_down"], cfg, 0, cap)
        return y.reshape(B, S, D), aux

    # make the per-layer expert weights whole along the FSDP dim before
    # entering shard_map (XLA inserts the all-gather over `data`)
    wg = constrain(p["w_gate"], ("experts_w", None, None))
    wu = constrain(p["w_up"], ("experts_w", None, None))
    wd = constrain(p["w_down"], ("experts_w", None, None))

    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    dp = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    t_loc = (B // dp) * S
    cap = _capacity(t_loc, cfg)

    def local(xl, router, wgl, wul, wdl):
        # xl: (B_loc, S, D) — replicated over `model` within a data row
        bl = xl.shape[0]
        first = jax.lax.axis_index("model") * (cfg.n_experts //
                                               int(mesh.shape["model"]))
        y, aux = _moe_core(xl.reshape(bl * S, D), router, wgl, wul, wdl,
                           cfg, first, cap)
        y = jax.lax.psum(y, "model")
        # aux is identical across `model` (same tokens, replicated router) —
        # average it over the data axes only
        if batch_axes:
            aux = jax.lax.psum(aux, axis_name=batch_axes) / dp
        return y.reshape(bl, S, D), aux

    y, aux = _shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_axes or None, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(batch_axes or None, None, None), P()),
    )(x, p["router"], wg, wu, wd)
    return y, aux
