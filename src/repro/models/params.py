"""Parameter definition machinery.

A model is described once as a nested dict of :class:`ParamDef` (shape +
logical axes + initializer).  From that single description we derive:

* materialised parameters (:func:`init_params`) for real runs,
* ``ShapeDtypeStruct`` stand-ins (:func:`abstract_params`) for the dry-run,
* ``PartitionSpec`` trees (:func:`spec_tree`) for pjit in/out shardings.

Keeping shapes, shardings and init in one place is what lets the dry-run and
the real trainer agree by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import AxisRules

__all__ = ["ParamDef", "init_params", "abstract_params", "spec_tree",
           "tree_size_bytes"]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones
    scale: float = 0.02
    dtype: Optional[str] = None     # override the tree-wide dtype (caches)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs: Dict, key: jax.Array, dtype=jnp.float32) -> Dict:
    """Materialise a ParamDef tree into arrays (deterministic per path)."""
    flat, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    paths = jax.tree_util.tree_leaves_with_path(defs, is_leaf=_is_def)
    out = []
    for i, ((path, d), _) in enumerate(zip(paths, flat)):
        k = jax.random.fold_in(key, i)
        dt = jnp.dtype(d.dtype) if d.dtype else dtype
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dt)
        else:
            arr = (d.scale * jax.random.normal(k, d.shape)).astype(dt)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs: Dict, dtype=jnp.float32,
                    rules: Optional[AxisRules] = None) -> Dict:
    """ShapeDtypeStruct tree (with shardings when rules are given)."""
    def one(d: ParamDef):
        sharding = rules.sharding(d.axes, d.shape) if rules else None
        dt = jnp.dtype(d.dtype) if d.dtype else dtype
        return jax.ShapeDtypeStruct(d.shape, dt, sharding=sharding)
    return jax.tree.map(one, defs, is_leaf=_is_def)


def spec_tree(defs: Dict, rules: AxisRules) -> Dict:
    return jax.tree.map(lambda d: rules.spec(d.axes, d.shape), defs,
                        is_leaf=_is_def)


def tree_size_bytes(defs: Dict, bytes_per_el: int = 4) -> int:
    """Total parameter bytes of a ParamDef tree (for memory napkin math)."""
    leaves = jax.tree.leaves(defs, is_leaf=_is_def)
    return sum(int(np.prod(d.shape)) * bytes_per_el for d in leaves)
