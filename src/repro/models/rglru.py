"""RG-LRU recurrent block (RecurrentGemma / Griffin).

[arXiv:2402.19427]  Temporal-mixing block:

    x ─▶ gate branch: GeLU(x·W_y)
      ─▶ x branch:    x·W_x ─ causal-conv(4) ─ RG-LRU ─┐
    out = (h ⊙ gate) · W_out                            ┘

RG-LRU recurrence (per channel):

    r_t = σ(blockdiag(W_a)·x_t + b_a)        recurrence gate
    i_t = σ(blockdiag(W_x)·x_t + b_x)        input gate
    log a_t = −c · softplus(Λ) · r_t          (c = 8)
    h_t = a_t · h_{t−1} + sqrt(1 − a_t²) · (i_t ⊙ x_t)

Training uses ``jax.lax.associative_scan`` over the sequence (parallel
prefix, TPU-friendly — this is the recurrent-scan sharding mentioned in the
assignment); decode is the O(1) single-step recurrence.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _gathered
from repro.models.params import ParamDef
from repro.parallel.sharding import constrain

__all__ = ["rglru_defs", "rglru_apply"]

_C = 8.0            # Griffin's fixed gate sharpness constant
_MAX_SQRT_ARG = 1.0


def rglru_defs(cfg) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    nb = max(1, cfg.n_heads)            # block-diagonal gate blocks
    assert w % nb == 0, (w, nb)
    bw = w // nb
    return {
        "w_y": ParamDef((d, w), ("d_model_w", "lru_w")),
        "w_x": ParamDef((d, w), ("d_model_w", "lru_w")),
        "conv_w": ParamDef((cfg.conv_width, w), ("conv", "lru_w"), scale=0.1),
        "conv_b": ParamDef((w,), ("lru_w",), init="zeros"),
        "a_gate_w": ParamDef((nb, bw, bw), ("ssm_heads_w", None, None)),
        "a_gate_b": ParamDef((w,), ("lru_w",), init="zeros"),
        "i_gate_w": ParamDef((nb, bw, bw), ("ssm_heads_w", None, None)),
        "i_gate_b": ParamDef((w,), ("lru_w",), init="zeros"),
        "Lambda": ParamDef((w,), ("lru_w",), init="ones"),
        "w_out": ParamDef((w, d), ("lru_w", "d_model_w")),
    }


def _block_diag(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, W) with W = nb·bw; w: (nb, bw, bw) → (B, S, W)."""
    B, S, W = x.shape
    nb, bw, _ = w.shape
    xr = x.reshape(B, S, nb, bw)
    y = jnp.einsum("bsnw,nwv->bsnv", xr, w.astype(x.dtype))
    return y.reshape(B, S, W) + b.astype(x.dtype)


def _causal_conv(x, w, b, state=None):
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return y + b.astype(x.dtype), new_state


def rglru_apply(p: dict, x: jax.Array, *, cfg,
                cache: Optional[dict] = None, mode: str = "train"
                ) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, S, D) → (out, new_cache). Residual/norm handled by caller."""
    dtype = x.dtype
    B, S, D = x.shape

    gate = jax.nn.gelu(x @ _gathered(p["w_y"], dtype, (None, "lru_w")),
                       approximate=True)
    xb = x @ _gathered(p["w_x"], dtype, (None, "lru_w"))
    xb = constrain(xb, ("batch", "seq", "lru_act"))
    conv_state = cache.get("conv") if cache else None
    xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid(_block_diag(xb, p["a_gate_w"], p["a_gate_b"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(xb, p["i_gate_w"], p["i_gate_b"])
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["Lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)                                       # (B,S,W) f32
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0, _MAX_SQRT_ARG))
    bterm = mult * i * xb.astype(jnp.float32)

    if mode == "decode":
        h0 = cache["h"]                                      # (B, W) f32
        h = a[:, 0] * h0 + bterm[:, 0]
        hs = h[:, None]
        new_cache = {"conv": new_conv, "h": h}
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        a_sc, b_sc = jax.lax.associative_scan(combine, (a, bterm), axis=1)
        hs = b_sc                                            # h0 = 0
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": new_conv, "h": hs[:, -1]}

    out = (hs.astype(dtype) * gate) @ _gathered(p["w_out"], dtype,
                                                ("lru_w", None))
    return constrain(out, ("batch", "seq", None)), new_cache
