"""Mamba-2 block with SSD (state-space duality) sequence mixing.

[arXiv:2405.21060]  The block:

    x ─ RMSNorm ─ in_proj ─▶ [z | x_in | B | C | dt]   (blocked layout)
                  x_in,B,C ─ causal-conv(4) ─ SiLU
                  y = SSD(x_in, dt, A, B, C) + D·x_in
                  y = RMSNorm(y · SiLU(z)) ─ out_proj ─▶ (+residual)

SSD is computed in the **chunked dual form** (chunk length Q): an
intra-chunk quadratic term (attention-like, MXU-friendly) plus an
inter-chunk linear recurrence over per-chunk states (nh, hd, N) carried by a
``lax.scan`` — O(S·Q + S·N·hd) work instead of O(S²).  The Pallas kernel in
:mod:`repro.kernels.ssd_scan` implements the same chunking for TPU; this
module is the pure-JAX path (CPU tests, dry-run lowering) and the kernel's
oracle counterpart lives in :mod:`repro.kernels.ref`.

**Blocked projection layout** (EXPERIMENTS.md §Perf, mamba2 collective
iteration): the fused in_proj output is laid out as 16 shard-blocks of
``[z | x | B | C | dt]`` so every component extraction slices an UNSHARDED
dim.  A flat ``[z…|x…|B…|C…|dt…]`` layout splits at offsets that are not
multiples of the per-shard width, and XLA reshards every split with
collective-permute/all-to-all — measured 85 GB/device/step on
mamba2-780m train_4k.  The layout is a fixed column permutation of the
weight (training from scratch is unaffected; loading external checkpoints
would need a one-time permutation).  The depthwise convs run per component
(channelwise, so exactly equivalent).

Decode is the classic O(1) recurrence: h ← h·exp(dt·A) + dt·B⊗x.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _gathered, rmsnorm
from repro.models.params import ParamDef
from repro.parallel.sharding import constrain

__all__ = ["ssd_defs", "ssd_apply", "ssd_chunked"]

#: shard-block count of the projection layout (production model-axis size)
_BLOCKS = 16


def _widths(cfg) -> Tuple[int, int, int]:
    """Per-block widths of (z or x, B or C, dt)."""
    di, gs, nh = cfg.d_inner, cfg.ssm_groups * cfg.ssm_state, cfg.ssm_heads
    assert di % _BLOCKS == 0 and nh % _BLOCKS == 0, (di, nh)
    assert gs % _BLOCKS == 0, gs
    return di // _BLOCKS, gs // _BLOCKS, nh // _BLOCKS


def ssd_defs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    gs = cfg.ssm_groups * cfg.ssm_state
    nh = cfg.ssm_heads
    wz, wg, wn = _widths(cfg)
    width = 2 * wz + 2 * wg + wn          # [z | x | B | C | dt] per block
    return {
        "ln": ParamDef((d,), (None,), init="ones"),
        "in_proj": ParamDef((d, _BLOCKS, width),
                            ("d_model_w", "d_inner_w", None)),
        "conv_x_w": ParamDef((cfg.ssm_conv, di), ("conv", "d_inner_act"),
                             scale=0.1),
        "conv_x_b": ParamDef((di,), ("d_inner_act",), init="zeros"),
        "conv_b_w": ParamDef((cfg.ssm_conv, gs), ("conv", None), scale=0.1),
        "conv_b_b": ParamDef((gs,), (None,), init="zeros"),
        "conv_c_w": ParamDef((cfg.ssm_conv, gs), ("conv", None), scale=0.1),
        "conv_c_b": ParamDef((gs,), (None,), init="zeros"),
        "A_log": ParamDef((nh,), ("ssm_heads_w",), init="zeros"),
        "D": ParamDef((nh,), ("ssm_heads_w",), init="ones"),
        "dt_bias": ParamDef((nh,), ("ssm_heads_w",), init="zeros"),
        "norm": ParamDef((di,), ("d_inner_w",), init="ones"),
        "out_proj": ParamDef((di, d), ("d_inner_w", "d_model_w")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  x: (B, S, C), w: (K, C).

    Returns (y, new_state) where state is the trailing K−1 inputs.
    """
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return y, new_state


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int = 128,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x:  (B, S, nh, hd)   inputs per SSM head
    dt: (B, S, nh)       post-softplus step sizes
    A:  (nh,)            negative decay rates (A = −exp(A_log))
    Bm: (B, S, ng, N)    input projections (shared across heads per group)
    Cm: (B, S, ng, N)    output projections
    h0: optional initial state (B, nh, hd, N)

    Returns (y: (B, S, nh, hd), h_final: (B, nh, hd, N)).
    """
    B, S, nh, hd = x.shape
    ng, N = Bm.shape[2], Bm.shape[3]
    rep = nh // ng
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    f32 = jnp.float32
    xdt = (x.astype(f32) * dt.astype(f32)[..., None])        # (B,S,nh,hd)
    dA = dt.astype(f32) * A.astype(f32)                      # (B,S,nh) ≤ 0

    def ch(a, extra):
        return a.reshape((B, nc, Q) + extra)
    xdt_c = ch(xdt, (nh, hd))
    dA_c = ch(dA, (nh,))
    B_c = ch(Bm.astype(f32), (ng, N))
    C_c = ch(Cm.astype(f32), (ng, N))
    B_h = jnp.repeat(B_c, rep, axis=3)                       # (B,nc,Q,nh,N)
    C_h = jnp.repeat(C_c, rep, axis=3)

    cum = jnp.cumsum(dA_c, axis=2)                           # (B,nc,Q,nh)
    seg_total = cum[:, :, -1]                                # (B,nc,nh)

    # --- intra-chunk (quadratic dual form) ------------------------------ #
    li = cum[:, :, :, None, :]                               # (B,nc,Q,1,nh)
    lj = cum[:, :, None, :, :]                               # (B,nc,1,Q,nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(li - lj), 0.0)             # (B,nc,Q,Q,nh)
    scores = jnp.einsum("bcqhn,bcshn->bcqsh", C_h, B_h) * L
    y_intra = jnp.einsum("bcqsh,bcshd->bcqhd", scores, xdt_c)

    # --- per-chunk input states ----------------------------------------- #
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)   # (B,nc,Q,nh)
    chunk_state = jnp.einsum("bcqhn,bcqhd,bcqh->bchdn",
                             B_h, xdt_c, decay_to_end)       # (B,nc,nh,hd,N)

    # --- inter-chunk recurrence over states ------------------------------ #
    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, N), f32)

    def step(h, inp):
        seg, st8 = inp                                       # (B,nh), (B,nh,hd,N)
        h_new = h * jnp.exp(seg)[:, :, None, None] + st8
        return h_new, h                                      # emit PREVIOUS

    seg_t = seg_total.swapaxes(0, 1)                         # (nc,B,nh)
    st_t = chunk_state.swapaxes(0, 1)                        # (nc,B,nh,hd,N)
    h_final, h_prevs = jax.lax.scan(step, h0.astype(f32), (seg_t, st_t))
    h_prev = h_prevs.swapaxes(0, 1)                          # (B,nc,nh,hd,N)

    y_inter = jnp.einsum("bcqhn,bchdn,bcqh->bcqhd",
                         C_h, h_prev, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, S, nh, hd)
    return y.astype(x.dtype), h_final


def ssd_apply(p: dict, x: jax.Array, *, cfg,
              cache: Optional[dict] = None, mode: str = "train",
              skip_norm: bool = False
              ) -> Tuple[jax.Array, Optional[dict]]:
    """Full Mamba-2 block (norm + projections + SSD + gate + out)."""
    dtype = x.dtype
    B, S, D = x.shape
    di, ng, st = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    nh, hd = cfg.ssm_heads, cfg.ssm_head_dim
    gs = ng * st
    wz, wg, wn = _widths(cfg)

    h_in = x if skip_norm else rmsnorm(x, p["ln"], cfg.norm_eps,
                                       gemma=cfg.gemma_norm)
    proj = jnp.einsum(
        "bsd,dnw->bsnw", h_in,
        _gathered(p["in_proj"], dtype, (None, "d_inner_w", None)))
    proj = constrain(proj, ("batch", "seq", "d_inner_act", None))
    # blocked extraction: every slice cuts the UNSHARDED trailing dim
    z = proj[..., :wz].reshape(B, S, di)
    x_in = proj[..., wz:2 * wz].reshape(B, S, di)
    Bm = proj[..., 2 * wz:2 * wz + wg].reshape(B, S, gs)
    Cm = proj[..., 2 * wz + wg:2 * wz + 2 * wg].reshape(B, S, gs)
    dt = proj[..., 2 * wz + 2 * wg:].reshape(B, S, nh)

    # B/C are shared across all heads → replicate over `model` (tiny gather)
    Bm = constrain(Bm, ("batch", "seq", None))
    Cm = constrain(Cm, ("batch", "seq", None))

    cs = cache or {}
    x_in, new_cx = _causal_conv(x_in, p["conv_x_w"], p["conv_x_b"],
                                cs.get("conv_x"))
    Bm, new_cb = _causal_conv(Bm, p["conv_b_w"], p["conv_b_b"],
                              cs.get("conv_b"))
    Cm, new_cc = _causal_conv(Cm, p["conv_c_w"], p["conv_c_b"],
                              cs.get("conv_c"))
    x_in = jax.nn.silu(x_in).reshape(B, S, nh, hd)
    Bm = jax.nn.silu(Bm).reshape(B, S, ng, st)
    Cm = jax.nn.silu(Cm).reshape(B, S, ng, st)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)

    if mode == "decode":
        h0 = cache["ssm"]                                    # (B,nh,hd,N)
        rep = nh // ng
        Bh = jnp.repeat(Bm[:, 0], rep, axis=1).astype(jnp.float32)
        Ch = jnp.repeat(Cm[:, 0], rep, axis=1).astype(jnp.float32)
        dA = jnp.exp(dt[:, 0] * A)                           # (B,nh)
        upd = jnp.einsum("bhn,bhd,bh->bhdn", Bh,
                         x_in[:, 0].astype(jnp.float32), dt[:, 0])
        h_new = h0 * dA[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhdn->bhd", Ch, h_new)
        y = y[:, None].astype(dtype)                         # (B,1,nh,hd)
        new_cache = {"conv_x": new_cx, "conv_b": new_cb, "conv_c": new_cc,
                     "ssm": h_new}
    else:
        y, h_final = ssd_chunked(x_in, dt, A, Bm, Cm)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv_x": new_cx, "conv_b": new_cb,
                         "conv_c": new_cc, "ssm": h_final}

    y = y + x_in * p["D"].astype(dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ _gathered(p["out_proj"], dtype, ("d_inner_w", None))
    return constrain(out, ("batch", "seq", None)), new_cache
