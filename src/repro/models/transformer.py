"""The composable decoder: pattern-grouped blocks, scan-over-layers,
train / prefill / decode paths for every assigned architecture family.

Structure (DESIGN.md §5): a model is ``embed → scan(pattern groups) → tail
blocks → final norm → unembed``.  A *pattern group* is one repetition of
``cfg.layer_pattern`` (e.g. gemma2 ``("local","attn")``); all groups share a
block structure, so their parameters are stacked with a leading G axis and
the stack is consumed by ``jax.lax.scan`` — keeping the lowered HLO small
enough to compile 80 (arch × shape × mesh) dry-run combinations on CPU.
Layers past the last full group (RecurrentGemma's trailing R,R) live in
``params["tail"]`` and run unscanned.

Caches follow the same grouping: ``cache["groups"]`` leaves are stacked over
G and fed to the scan as xs; decode emits the updated stack as ys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, moe as moe_mod, rglru as rglru_mod, \
    ssm as ssm_mod
from repro.models.layers import (chunked_cross_entropy, embed_tokens,
                                 mlp_apply, mlp_defs, rmsnorm, sinusoidal_pos,
                                 softcap)
from repro.models.params import ParamDef, abstract_params, init_params
from repro.parallel.sharding import constrain

__all__ = ["model_defs", "init_model", "forward", "loss_fn", "prefill",
           "decode_step", "cache_defs", "init_cache", "unembed_matrix"]

PyTree = Any


# --------------------------------------------------------------------------- #
# parameter definitions
# --------------------------------------------------------------------------- #
def _norm_def(cfg) -> ParamDef:
    init = "zeros" if cfg.gemma_norm else "ones"   # gemma scales by (1 + w)
    return ParamDef((cfg.d_model,), (None,), init=init)


def block_defs(cfg, kind: str) -> Dict:
    if kind in ("attn", "local"):
        d = {"ln1": _norm_def(cfg), "attn": attention.attn_defs(cfg),
             "ln2": _norm_def(cfg), "mlp": mlp_defs(cfg)}
        if cfg.post_norms:
            d["ln1_post"] = _norm_def(cfg)
            d["ln2_post"] = _norm_def(cfg)
        return d
    if kind == "moe":
        return {"ln1": _norm_def(cfg), "attn": attention.attn_defs(cfg),
                "ln2": _norm_def(cfg), "moe": moe_mod.moe_defs(cfg)}
    if kind == "ssd":
        return {"ssd": ssm_mod.ssd_defs(cfg)}
    if kind == "rglru":
        return {"ln1": _norm_def(cfg), "rglru": rglru_mod.rglru_defs(cfg),
                "ln2": _norm_def(cfg), "mlp": mlp_defs(cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def _stack_defs(defs: Dict, n: int) -> Dict:
    """Prepend a scanned `layers` axis of length n to every ParamDef."""
    def one(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + d.shape, ("layers",) + d.axes, init=d.init,
                        scale=d.scale, dtype=d.dtype)
    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def model_defs(cfg) -> Dict:
    d: Dict = {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model),
                          ("vocab_w", "d_model_w"), scale=0.02),
        "final_norm": _norm_def(cfg),
    }
    group = {str(i): block_defs(cfg, k)
             for i, k in enumerate(cfg.layer_pattern)}
    d["groups"] = _stack_defs(group, cfg.n_groups)
    if cfg.tail_pattern:
        d["tail"] = {str(i): block_defs(cfg, k)
                     for i, k in enumerate(cfg.tail_pattern)}
    if not cfg.tie_embeddings:
        d["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                ("d_model_w", "vocab_w"), scale=0.02)
    return d


def init_model(cfg, key: jax.Array) -> PyTree:
    return init_params(model_defs(cfg), key,
                       dtype=jnp.dtype(cfg.param_dtype))


def unembed_matrix(params: PyTree, cfg) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# --------------------------------------------------------------------------- #
# cache definitions
# --------------------------------------------------------------------------- #
def _block_cache_defs(cfg, kind: str, batch: int, max_len: int) -> Optional[Dict]:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if kind == "moe":
        kind = "attn"   # MoE blocks carry an ordinary attention cache
    if kind == "attn" or (kind == "local" and cfg.sliding_window is None):
        return {"attn": {
            "k": ParamDef((batch, max_len, kv, hd),
                          ("cache_batch", "cache_seq", "kv_heads", None),
                          init="zeros", dtype="bfloat16"),
            "v": ParamDef((batch, max_len, kv, hd),
                          ("cache_batch", "cache_seq", "kv_heads", None),
                          init="zeros", dtype="bfloat16")}}
    if kind == "local":
        w = min(cfg.sliding_window, max_len)
        return {"attn": {
            "k": ParamDef((batch, w, kv, hd),
                          ("cache_batch", "cache_seq", "kv_heads", None),
                          init="zeros", dtype="bfloat16"),
            "v": ParamDef((batch, w, kv, hd),
                          ("cache_batch", "cache_seq", "kv_heads", None),
                          init="zeros", dtype="bfloat16")}}
    if kind == "ssd":
        gs = cfg.ssm_groups * cfg.ssm_state
        return {"ssd": {
            "conv_x": ParamDef((batch, cfg.ssm_conv - 1, cfg.d_inner),
                               ("cache_batch", None, "d_inner_act"),
                               init="zeros", dtype="bfloat16"),
            "conv_b": ParamDef((batch, cfg.ssm_conv - 1, gs),
                               ("cache_batch", None, None),
                               init="zeros", dtype="bfloat16"),
            "conv_c": ParamDef((batch, cfg.ssm_conv - 1, gs),
                               ("cache_batch", None, None),
                               init="zeros", dtype="bfloat16"),
            "ssm": ParamDef((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                             cfg.ssm_state),
                            ("cache_batch", "ssm_heads_act", None, None),
                            init="zeros", dtype="float32")}}
    if kind == "rglru":
        return {"rglru": {
            "conv": ParamDef((batch, cfg.conv_width - 1, cfg.lru_width),
                             ("cache_batch", None, "lru_act"),
                             init="zeros", dtype="bfloat16"),
            "h": ParamDef((batch, cfg.lru_width),
                          ("cache_batch", "lru_act"),
                          init="zeros", dtype="float32")}}
    raise ValueError(kind)


def cache_defs(cfg, batch: int, max_len: int) -> Dict:
    group = {str(i): _block_cache_defs(cfg, k, batch, max_len)
             for i, k in enumerate(cfg.layer_pattern)}
    d: Dict = {"groups": _stack_defs(group, cfg.n_groups),
               "length": ParamDef((), (), init="zeros", dtype="int32")}
    if cfg.tail_pattern:
        d["tail"] = {str(i): _block_cache_defs(cfg, k, batch, max_len)
                     for i, k in enumerate(cfg.tail_pattern)}
    return d


def init_cache(cfg, batch: int, max_len: int) -> PyTree:
    return init_params(cache_defs(cfg, batch, max_len), jax.random.PRNGKey(0))


# --------------------------------------------------------------------------- #
# block application
# --------------------------------------------------------------------------- #
def _apply_block(kind: str, bp: Dict, x: jax.Array, *, cfg,
                 positions: jax.Array, cache: Optional[Dict],
                 mode: str, max_len: Optional[int] = None
                 ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    eps, gn = cfg.norm_eps, cfg.gemma_norm
    window = cfg.sliding_window if kind == "local" else None

    if kind in ("attn", "local", "moe"):
        h = rmsnorm(x, bp["ln1"], eps, gn)
        a, attn_cache = attention.attn_apply(
            bp["attn"], h, cfg=cfg, window=window, positions=positions,
            cache=cache.get("attn") if cache else None, mode=mode,
            max_len=max_len)
        if cfg.post_norms:
            a = rmsnorm(a, bp["ln1_post"], eps, gn)
        x = x + a
        h = rmsnorm(x, bp["ln2"], eps, gn)
        if kind == "moe":
            m, aux = moe_mod.moe_apply(bp["moe"], h, cfg)
        else:
            m = mlp_apply(bp["mlp"], h, cfg)
        if cfg.post_norms:
            m = rmsnorm(m, bp["ln2_post"], eps, gn)
        x = x + m
        new_cache = {"attn": attn_cache} if attn_cache is not None else None
        return x, new_cache, aux

    if kind == "ssd":
        o, c = ssm_mod.ssd_apply(bp["ssd"], x, cfg=cfg,
                                 cache=cache.get("ssd") if cache else None,
                                 mode=mode)
        return x + o, ({"ssd": c} if c is not None else None), aux

    if kind == "rglru":
        h = rmsnorm(x, bp["ln1"], eps, gn)
        o, c = rglru_mod.rglru_apply(
            bp["rglru"], h, cfg=cfg,
            cache=cache.get("rglru") if cache else None, mode=mode)
        x = x + o
        h = rmsnorm(x, bp["ln2"], eps, gn)
        x = x + mlp_apply(bp["mlp"], h, cfg)
        return x, ({"rglru": c} if c is not None else None), aux

    raise ValueError(kind)


# --------------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------------- #
def forward(params: PyTree, tokens: jax.Array, cfg, *,
            embeds: Optional[jax.Array] = None,
            cache: Optional[PyTree] = None,
            mode: str = "train",
            max_len: Optional[int] = None
            ) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
    """Run the decoder stack.

    Returns (h: (B, T, D) final hidden states, new_cache, aux_loss).
    ``embeds`` are the stub-frontend embeddings ([vlm]/[audio]) prepended to
    the token embeddings (train/prefill only).
    """
    dtype = jnp.dtype(cfg.dtype)
    offset = cache["length"] if cache is not None and mode == "decode" else 0
    x = embed_tokens(params["embed"], tokens, cfg)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(dtype), x], axis=1)
    S = x.shape[1]
    positions = offset + jnp.arange(S)
    if cfg.pos_embed == "sinusoidal":
        x = x + sinusoidal_pos(positions, cfg.d_model)[None].astype(dtype)
    x = constrain(x, ("batch", "seq", None))

    pattern = cfg.layer_pattern
    aux0 = jnp.zeros((), jnp.float32)

    def group_body(x, gp, gcache):
        new_c: Dict = {}
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pattern):
            x, c, a = _apply_block(
                kind, gp[str(i)], x, cfg=cfg, positions=positions,
                cache=(gcache[str(i)] if gcache is not None else None),
                mode=mode, max_len=max_len)
            aux += a
            if c is not None:
                new_c[str(i)] = c
        return x, (new_c if new_c else None), aux

    if mode == "train":
        def body(carry, gp):
            x, aux = carry
            x, _, a = group_body(x, gp, None)
            return (x, aux + a), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["groups"])
        new_cache = None
    elif mode == "prefill":
        def body(carry, gp):
            x, aux = carry
            x, c, a = group_body(x, gp, None)
            return (x, aux + a), c
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), gcaches = jax.lax.scan(body, (x, aux0), params["groups"])
        new_cache = {"groups": gcaches, "length": jnp.asarray(S, jnp.int32)}
    else:  # decode
        def body(x, xs):
            gp, gc = xs
            x, c, _ = group_body(x, gp, gc)
            return x, c
        x, gcaches = jax.lax.scan(body, x, (params["groups"],
                                            cache["groups"]))
        new_cache = {"groups": gcaches, "length": offset + S}
        aux = aux0

    # tail blocks (unscanned remainder of the pattern, e.g. RG-2b's R,R)
    if cfg.tail_pattern:
        tail_cache: Dict = {}
        for i, kind in enumerate(cfg.tail_pattern):
            tc = cache["tail"][str(i)] if (cache is not None and
                                           mode == "decode") else None
            x, c, a = _apply_block(kind, params["tail"][str(i)], x, cfg=cfg,
                                   positions=positions, cache=tc, mode=mode,
                                   max_len=max_len)
            aux += a
            if c is not None:
                tail_cache[str(i)] = c
        if new_cache is not None and tail_cache:
            new_cache["tail"] = tail_cache

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps, cfg.gemma_norm)
    return x, new_cache, aux


# --------------------------------------------------------------------------- #
# entry points: train loss / prefill / decode
# --------------------------------------------------------------------------- #
def loss_fn(params: PyTree, batch: Dict, cfg) -> Tuple[jax.Array, Dict]:
    """Causal-LM loss.  batch = {"tokens": (B, S_tok)[, "embeds": (B,F,D)]}"""
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    h, _, aux = forward(params, tokens, cfg, embeds=embeds, mode="train")
    F = cfg.frontend_tokens if embeds is not None else 0
    if F > 0:
        hp = h[:, F - 1:-1]
        labels = tokens
    else:
        hp = h[:, :-1]
        labels = tokens[:, 1:]
    ce = chunked_cross_entropy(hp, labels, unembed_matrix(params, cfg), cfg)
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


def _head(h_last: jax.Array, params: PyTree, cfg) -> jax.Array:
    logits = h_last @ unembed_matrix(params, cfg).astype(h_last.dtype)
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def prefill(params: PyTree, tokens: jax.Array, cfg, *,
            embeds: Optional[jax.Array] = None,
            max_len: Optional[int] = None
            ) -> Tuple[jax.Array, PyTree]:
    """Process a prompt; returns (last-position logits (B, V), cache).

    ``max_len`` pre-sizes full-attention caches so decode can append."""
    h, cache, _ = forward(params, tokens, cfg, embeds=embeds, mode="prefill",
                          max_len=max_len)
    return _head(h[:, -1], params, cfg), cache


def decode_step(params: PyTree, cache: PyTree, tokens: jax.Array, cfg
                ) -> Tuple[jax.Array, PyTree]:
    """One decode step.  tokens: (B, 1) → (logits (B, V), new cache)."""
    h, new_cache, _ = forward(params, tokens, cfg, cache=cache, mode="decode")
    return _head(h[:, -1], params, cfg), new_cache
