from repro.optim.optimizers import (Optimizer, adamw, momentum, sgd,
                                    apply_updates, global_norm, clip_by_norm)
from repro.optim.schedules import constant, cosine, warmup_cosine

__all__ = ["Optimizer", "adamw", "momentum", "sgd", "apply_updates",
           "global_norm", "clip_by_norm", "constant", "cosine",
           "warmup_cosine"]
