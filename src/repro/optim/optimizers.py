"""Optimizers as pure pytree transforms (no external deps).

API shape mirrors optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, new_state)``;
``apply_updates(params, updates)``.  All states live in f32 master copies so
bf16-param training still accumulates exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]


def _lr_at(lr: Union[float, Schedule], step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_norm(grads: PyTree, max_norm: float) -> PyTree:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), grads)


def sgd(lr: Union[float, Schedule]) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        eta = _lr_at(lr, state["step"])
        upd = jax.tree.map(lambda g: (-eta * g.astype(jnp.float32))
                           .astype(g.dtype), grads)
        return upd, {"step": state["step"] + 1}

    return Optimizer(init, update)


def momentum(lr: Union[float, Schedule], beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                   params)}

    def update(grads, state, params=None):
        mu = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32),
                          state["mu"], grads)
        eta = _lr_at(lr, state["step"])
        upd = jax.tree.map(lambda m, g: (-eta * m).astype(g.dtype), mu, grads)
        return upd, {"step": state["step"] + 1, "mu": mu}

    return Optimizer(init, update)


def adamw(lr: Union[float, Schedule], b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(z, params),
                "nu": jax.tree.map(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) *
                          g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                          jnp.square(g.astype(jnp.float32)), state["nu"],
                          grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)
        eta = _lr_at(lr, state["step"])

        def upd(m, v, p):
            mhat = m / c1
            vhat = v / c2
            u = -eta * (mhat / (jnp.sqrt(vhat) + eps)
                        + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        return jax.tree.map(upd, mu, nu, params), \
            {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
