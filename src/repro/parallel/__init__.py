from repro.parallel.sharding import (AxisRules, constrain, make_rules,
                                     spec_for, use_rules, current_rules)

__all__ = ["AxisRules", "constrain", "make_rules", "spec_for", "use_rules",
           "current_rules"]
