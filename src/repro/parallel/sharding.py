"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Arrays in the model are annotated with *logical* axis names; a rules table
maps each logical name to an ordered preference of mesh axes.  At constraint
time, mesh axes that (a) don't exist in the current mesh, (b) don't divide
the dimension, or (c) were already consumed by an earlier dim of the same
array, are dropped — so a single rules table covers every architecture
(e.g. ``heads→model`` silently degrades to replicated for archs whose head
count doesn't divide the 16-way model axis, and the rules table then routes
attention balance through ``attn_batch``/``qseq`` instead; see
DESIGN.md §7 and the per-arch notes in EXPERIMENTS.md).

The table is built per (ModelConfig, InputShape, Mesh) by :func:`make_rules`.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisRules", "PSP_WORKER_AXES", "SWEEP_NODES_AXIS",
           "SWEEP_ROWS_AXIS", "constrain", "current_rules", "make_rules",
           "psp_worker_axes", "spec_for", "sweep_mesh", "use_rules"]

MeshAxes = Tuple[str, ...]

# --------------------------------------------------------------------------- #
# shared mesh-axis vocabulary
#
# Every engine that lays PSP state over devices names its axes from this
# table, so the trainer and the sweep engines cannot drift into
# incompatible sharding conventions:
#
# * the sweep engines (:mod:`repro.core.vector_sim_jax`) run a 2-D
#   ``(rows, nodes)`` mesh — scenario rows over SWEEP_ROWS_AXIS, each
#   scenario's P node slots over SWEEP_NODES_AXIS;
# * the SPMD trainer (:mod:`repro.core.spmd_psp`) carries its worker
#   dimension W on PSP_WORKER_AXES (the server psum reduces over exactly
#   these axes), resolved against the production mesh by
#   :func:`psp_worker_axes`.
# --------------------------------------------------------------------------- #

#: scenario-row axis of the sweep engines' 2-D mesh
SWEEP_ROWS_AXIS = "rows"

#: node-slot axis of the sweep engines' 2-D mesh (the P dimension)
SWEEP_NODES_AXIS = "nodes"

#: mesh axes that may carry the SPMD trainer's worker dimension, in
#: major-to-minor order (a multi-pod worker is a (pod, data-row) pair)
PSP_WORKER_AXES: MeshAxes = ("pod", "data")


def sweep_mesh(rows: int, nodes: int = 1) -> Mesh:
    """The sweep engines' ``(rows, nodes)`` device mesh.

    The first ``rows × nodes`` local devices, rows-major — the planner
    (:mod:`repro.core.sweep_plan`) guarantees the product fits the host.
    The degenerate ``(1, 1)`` mesh is the single-device engine.
    """
    dev = np.array(jax.devices()[:rows * nodes]).reshape(rows, nodes)
    return Mesh(dev, (SWEEP_ROWS_AXIS, SWEEP_NODES_AXIS))


def psp_worker_axes(mesh: Optional[Mesh]) -> MeshAxes:
    """The mesh axes carrying the trainer's worker dimension W.

    :data:`PSP_WORKER_AXES` filtered to the axes the mesh actually has —
    the single definition both the dry-run's ``psp_workers`` rules entry
    and batch specs resolve through.
    """
    if mesh is None:
        return ()
    return tuple(a for a in PSP_WORKER_AXES if a in mesh.axis_names)


class AxisRules:
    """Logical-name → mesh-axes mapping with divisibility-aware resolution."""

    def __init__(self, table: Dict[str, MeshAxes], mesh: Optional[Mesh]):
        self.table = dict(table)
        self.mesh = mesh

    # ------------------------------------------------------------------ #
    def mesh_axis_size(self, axis: str) -> int:
        if self.mesh is None or axis not in self.mesh.shape:
            return 0
        return int(self.mesh.shape[axis])

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
        """Resolve logical axes to a PartitionSpec for a concrete shape."""
        used: set = set()
        out = []
        for dim, name in zip(shape, logical_axes):
            if name is None or name not in self.table:
                out.append(None)
                continue
            picked = []
            prod = 1
            for ax in self.table[name]:
                size = self.mesh_axis_size(ax)
                if size == 0 or ax in used:
                    continue
                if dim % (prod * size) == 0:
                    picked.append(ax)
                    prod *= size
            used.update(picked)
            out.append(tuple(picked) if picked else None)
        return P(*out)

    def sharding(self, logical_axes, shape) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


# --------------------------------------------------------------------------- #
# thread-local active rules (so model code can annotate without plumbing)
# --------------------------------------------------------------------------- #
_state = threading.local()


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def current_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


def spec_for(logical_axes, shape) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return rules.spec(logical_axes, shape)


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """``with_sharding_constraint`` by logical names; no-op without rules."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


# --------------------------------------------------------------------------- #
# rules tables
# --------------------------------------------------------------------------- #
def make_rules(cfg, shape, mesh: Optional[Mesh]) -> AxisRules:
    """Build the rules table for one (arch, input-shape, mesh) combination.

    Arguments may be None-ish duck types in tests; ``cfg`` needs
    ``n_heads``/``n_kv_heads``; ``shape`` needs ``kind``/``global_batch``.
    """
    data_axes: MeshAxes = ()
    model = 16
    if mesh is not None:
        names = mesh.axis_names
        data_axes = tuple(a for a in ("pod", "data") if a in names)
        model = int(mesh.shape.get("model", 1))

    heads_divisible = (cfg.n_heads % max(model, 1) == 0)

    table: Dict[str, MeshAxes] = {
        # activations
        "batch": data_axes,
        "seq": (),
        "qseq": (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "d_model": (),              # activations keep d_model unsharded
        "d_ff_act": ("model",),
        "experts_act": ("model",),
        "vocab_act": ("model",),
        "d_inner_act": ("model",),
        "ssm_heads_act": ("model",),
        "lru_act": ("model",),
        # weights (FSDP dim = 'data'; tensor dim = 'model')
        "d_model_w": ("data",),
        "heads_w": ("model",),
        "kv_heads_w": ("model",),
        "d_ff_w": ("model",),
        "vocab_w": ("model",),
        "experts_w": ("model",),
        "expert_ff_w": ("data",),   # FSDP the per-expert FF dim (see moe.py)
        "d_inner_w": ("model",),
        "ssm_heads_w": ("model",),
        "lru_w": ("model",),
        "layers": (),
        "conv": (),
        "state": (),
        # kv-cache layout (decode)
        "cache_seq": (),
        "cache_batch": data_axes,
    }

    kind = getattr(shape, "kind", "train")
    gbatch = getattr(shape, "global_batch", 0)

    # attention activations: batch over data axes; heads over model (archs
    # whose head count doesn't divide the model axis are zero-padded to the
    # next multiple inside attn_apply, so `heads` is always shardable)
    table["attn_batch"] = data_axes

    if kind == "decode":
        if gbatch == 1:
            # long_500k: batch unshardable — spread the cache over everything
            table["cache_seq"] = data_axes + ("model",)
        else:
            table["cache_seq"] = ("model",)

    return AxisRules(table, mesh)


def _sz(mesh, axis):
    if mesh is None:
        return 1
    return int(mesh.shape.get(axis, 1))
