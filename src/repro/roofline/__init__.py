from repro.roofline.analysis import (HW, collective_bytes, roofline_report,
                                     RooflineReport)

__all__ = ["HW", "collective_bytes", "roofline_report", "RooflineReport"]
