"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

``cost_analysis()`` on the *partitioned* module reports per-device FLOPs and
bytes, so the spec's ``/chips`` division is already applied.  Collective
bytes are not in cost_analysis; we parse the partitioned HLO text and sum
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute (per spec), and also keep a ring-model estimate per op
kind for the §Perf napkin math.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Optional

__all__ = ["HW", "collective_bytes", "roofline_report", "RooflineReport"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link
    hbm_bytes: float = 16e9         # v5e capacity


_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL = r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
_LINE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([\d,]*)\][^=]*?\s" + _COLL +
    r"(?:-start)?\(", re.M)
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum collective bytes (per device) from partitioned HLO text.

    Returns {op_kind: operand_bytes, ..., "total": Σ, "ring_estimate": Σ'}.
    ``ring_estimate`` weights op kinds by their ring-algorithm traffic:
    all-reduce 2×, others 1× (all-gather counted on its output).
    """
    per_kind: Dict[str, float] = defaultdict(float)
    ring = 0.0
    for m in re.finditer(
            r"^\s*(?:%[\w.\-]+|ROOT [\w.\-%]*)\s*=\s*(.+)$", hlo_text, re.M):
        line = m.group(1)
        cm = re.search(_COLL + r"(?:-start)?\(", line)
        if not cm:
            continue
        kind = cm.group(1)
        # result shape(s): everything before the op name
        head = line[: cm.start()]
        out_bytes = sum(_nbytes(d, s) for d, s in _SHAPE.findall(head))
        # operand shapes: inside the parens
        tail = line[cm.end():]
        op_bytes = sum(_nbytes(d, s) for d, s in _SHAPE.findall(tail))
        if op_bytes == 0:
            op_bytes = out_bytes
        per_kind[kind] += op_bytes
        if kind == "all-reduce":
            ring += 2 * op_bytes
        elif kind == "all-gather":
            ring += out_bytes
        else:
            ring += op_bytes
    total = float(sum(per_kind.values()))
    out = dict(per_kind)
    out["total"] = total
    out["ring_estimate"] = ring
    return out


@dataclasses.dataclass
class RooflineReport:
    flops: float                    # per-device HLO FLOPs
    hbm_bytes: float                # per-device HLO bytes accessed
    coll_bytes: float               # per-device collective operand bytes
    coll_detail: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: float        # 6·N·D (global)
    useful_ratio: float             # model_flops / (HLO flops × chips)
    chips: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_report(cost: dict, hlo_text: str, *, chips: int,
                    model_flops_total: float, hw: HW = HW(),
                    train: bool = True) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    compute_s = flops / hw.peak_flops
    memory_s = hbm / hw.hbm_bw
    collective_s = coll["total"] / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = (model_flops_total / (flops * chips)) if flops else 0.0
    return RooflineReport(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll["total"],
        coll_detail=coll, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bottleneck=bottleneck,
        model_flops_total=model_flops_total, useful_ratio=useful,
        chips=chips)


def model_flops(cfg, shape) -> float:
    """6·N·D (training) or 2·N·D (inference) with N = active params."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
