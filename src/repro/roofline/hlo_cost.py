"""Trip-count-aware cost extraction from compiled HLO text.

Why this exists: ``compiled.cost_analysis()`` counts each while-loop *body*
ONCE — it does not multiply by trip count.  Every model here scans over
layer groups (and over attention/CE/SSD chunks), so the built-in numbers
undercount FLOPs, bytes and collective traffic by 20–50×.  This module
parses the partitioned HLO text, builds the computation call graph
(fusion ``calls=``, while ``body=/condition=``, ``to_apply=``, conditional
branches), extracts per-computation dot FLOPs / byte traffic / collective
operand bytes, recovers while trip counts from their condition computations
(scan bounds appear as integer constants), and aggregates recursively from
ENTRY with bodies multiplied by their trip counts.

Approximations (documented in EXPERIMENTS.md §Roofline):
* FLOPs counts dots only (2·|out|·|contracted|) — elementwise/transcendental
  FLOPs are negligible for these models;
* byte traffic counts each instruction's operands+outputs at fusion
  granularity (reads of a stacked scan weight through an in-fusion
  dynamic-slice are charged at slice size, not full-stack size);
* conditional branches are charged at the max across branches;
* a while condition with no parseable integer bound gets trip=1.

Validated against hand-computable cases in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
#: ops that must touch HBM even under a perfect fuser
_HEAVY = {"dot", "convolution", "reduce", "sort", "scatter", "gather",
          "dynamic-slice", "dynamic-update-slice", "copy", "concatenate",
          "reduce-window", "select-and-scatter", "cholesky",
          "triangular-solve", "rng", "fft"}
#: `copy` is excluded from the optimistic count (alias-removable)
_HEAVY_MIN = _HEAVY - {"copy"}
#: tensors ≤ this that are produced AND consumed inside one computation are
#: assumed VMEM-resident on TPU (v5e VMEM ≈ 128 MB; keep headroom)
_VMEM_CAP = 64 * 1024 * 1024


def _charge_operand(comp: "_Computation", arg: str) -> int:
    """HBM read model: parameters/GTEs come from HBM; small locally-produced
    tensors stay in VMEM; big locals spill."""
    o = comp.instrs.get(arg)
    if o is None:
        return 0
    b = _instr_out_bytes(o)
    if o.opcode in ("parameter", "get-tuple-element"):
        return b
    return b if b > _VMEM_CAP else 0


def _charge_output(comp: "_Computation", instr: "_Instr") -> int:
    """HBM write model: roots leave the computation; big tensors spill."""
    b = _instr_out_bytes(instr)
    is_root = comp.order and comp.order[-1] == instr.name
    return b if (is_root or b > _VMEM_CAP) else 0
# opcodes whose call-site byte traffic we skip
_NO_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "while", "conditional", "call", "after-all", "custom-call",
             "partition-id", "replica-id", "iota"}


def _shape_bytes(text: str) -> int:
    """Total bytes of all array shapes mentioned in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(dt_dims: Tuple[str, str]) -> int:
    n = 1
    for d in dt_dims[1].split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class _Instr:
    name: str
    shape_text: str                 # full result-shape text
    opcode: str
    args: List[str]                 # operand instruction names
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: Dict[str, _Instr]
    order: List[str]


_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")


def _parse_computations(text: str) -> Tuple[Dict[str, _Computation], str]:
    comps: Dict[str, _Computation] = {}
    entry = ""
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        if not line:
            continue
        if line[0] in "%E" and "{" in line:
            m = _HEADER_RE.match(line)
            if m:
                cur = _Computation(m.group(1), {}, [])
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_text, opcode, rest = m.groups()
        args = re.findall(r"%([\w.\-]+)", rest.split("),")[0] + ")")
        instr = _Instr(name, shape_text, opcode, args, line.rstrip())
        cur.instrs[name] = instr
        cur.order.append(name)
    return comps, entry


def _instr_out_bytes(instr: _Instr) -> int:
    return _shape_bytes(instr.shape_text)


def _dot_flops(instr: _Instr, comp: _Computation) -> float:
    out_elems = sum(_shape_elems(s) for s in
                    _SHAPE_RE.findall(instr.shape_text)) or 1
    mcon = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    if not mcon or not instr.args:
        return 0.0
    lhs = comp.instrs.get(instr.args[0])
    if lhs is None:
        return 0.0
    lhs_shapes = _SHAPE_RE.findall(lhs.shape_text)
    if not lhs_shapes:
        return 0.0
    dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
    contract = 1
    for idx in mcon.group(1).split(","):
        if idx and int(idx) < len(dims):
            contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def _trip_count(cond: _Computation) -> int:
    """Scan bound heuristic: max integer constant in the condition block."""
    best = 1
    for instr in cond.instrs.values():
        if instr.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", instr.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _attr(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%([\w.\-]+)", line)
    return m.group(1) if m else None


@dataclasses.dataclass
class HloCost:
    """``bytes`` is the fusion-naive upper bound (every instruction charged
    at the granularity the CPU backend happened to fuse); ``bytes_min`` is
    the fusion-optimistic lower bound assuming a TPU-grade fuser folds all
    elementwise chains into their producers/consumers — only dots, reduces,
    data movement (slice/DUS/gather/scatter/sort/copy/concat) and
    collectives touch HBM.  Real traffic lies in between; the roofline
    memory term uses ``bytes_min`` (hardware constants are TPU's) and
    reports both."""

    flops: float = 0.0
    bytes: float = 0.0
    bytes_min: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    while_trips: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def coll_total(self) -> float:
        return float(sum(self.coll.values()))


def _param_number(ci: _Instr) -> Optional[int]:
    m = re.search(r"parameter\((\d+)\)", ci.line)
    return int(m.group(1)) if m else None


def _fusion_operand_bytes(instr: _Instr, comp: _Computation,
                          callee: _Computation) -> int:
    """Operand bytes of a fusion, charging slice-accessed params at slice
    size.

    Scan bodies read stacked weights/checkpoint buffers through
    ``dynamic-slice(param)`` and write them through
    ``dynamic-update-slice(param, update, ...)`` — the real per-iteration
    traffic is the slice, not the whole (L, …) stack, and XLA aliases the
    buffer in place.  Charging the full stack per trip overcounted memory
    traffic ~100× (see EXPERIMENTS.md §Perf iteration log).
    """
    sliced_params: Dict[int, int] = {}
    for ci in callee.instrs.values():
        if ci.opcode == "dynamic-slice" and ci.args:
            src = callee.instrs.get(ci.args[0])
            if src is not None and src.opcode == "parameter":
                pn = _param_number(src)
                if pn is not None:
                    sliced_params[pn] = min(
                        sliced_params.get(pn, 1 << 62),
                        _instr_out_bytes(ci))
        if ci.opcode == "dynamic-update-slice" and len(ci.args) >= 2:
            src = callee.instrs.get(ci.args[0])
            upd = callee.instrs.get(ci.args[1])
            if src is not None and src.opcode == "parameter" and upd is not None:
                pn = _param_number(src)
                if pn is not None:
                    sliced_params[pn] = min(
                        sliced_params.get(pn, 1 << 62),
                        _instr_out_bytes(upd))
    total = 0
    for pos, arg in enumerate(instr.args):
        if pos in sliced_params:
            total += sliced_params[pos]
            continue
        op = comp.instrs.get(arg)
        if op is not None:
            total += _instr_out_bytes(op)
    return total


def _fusion_is_heavy(callee: _Computation) -> bool:
    """True if the fused computation contains HBM-mandatory work."""
    return any(ci.opcode in _HEAVY for ci in callee.instrs.values())


def _fusion_min_bytes(callee: _Computation) -> int:
    """Fusion-optimistic traffic: only the HBM-mandatory internal ops.

    Per op kind: dynamic-slice → its output (the buffer is read at slice
    granularity); dynamic-update-slice → its update (in-place alias);
    gather → output + indices (table reads are output-sized);
    dot/reduce/sort/... → operands + output.  Pure elementwise work is
    assumed to fuse into producers/consumers (TPU-grade fuser).
    """
    total = 0
    for ci in callee.instrs.values():
        op = ci.opcode
        if op not in _HEAVY_MIN:
            continue
        if op == "dynamic-slice":
            total += _charge_output(callee, ci) or _instr_out_bytes(ci)
        elif op == "dynamic-update-slice":
            upd = callee.instrs.get(ci.args[1]) if len(ci.args) >= 2 else None
            total += _instr_out_bytes(upd) if upd is not None else 0
        elif op == "gather":
            total += 2 * _instr_out_bytes(ci)
        else:
            total += _charge_output(callee, ci)
            for a in ci.args:
                total += _charge_operand(callee, a)
    return total


def _fusion_output_bytes(instr: _Instr, callee: _Computation) -> int:
    """Output bytes of a fusion, charging DUS roots at update size.

    A fusion whose root is ``dynamic-update-slice`` (or a tuple containing
    them) writes only the updated slices — the enclosing buffer is aliased.
    """
    root = callee.instrs.get(callee.order[-1]) if callee.order else None
    if root is None:
        return _instr_out_bytes(instr)

    def one(ci: Optional[_Instr]) -> Optional[int]:
        if ci is None:
            return None
        if ci.opcode == "dynamic-update-slice" and len(ci.args) >= 2:
            upd = callee.instrs.get(ci.args[1])
            if upd is not None:
                return _instr_out_bytes(upd)
        return None

    if root.opcode == "tuple":
        total = 0
        for a in root.args:
            ci = callee.instrs.get(a)
            alt = one(ci)
            total += alt if alt is not None else (
                _instr_out_bytes(ci) if ci is not None else 0)
        return total
    alt = one(root)
    return alt if alt is not None else _instr_out_bytes(instr)


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    memo: Dict[str, HloCost] = {}

    def visit(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        out = HloCost(coll={})
        if comp is None:
            memo[name] = out
            return out
        memo[name] = out   # guard (no true recursion in HLO)
        for iname in comp.order:
            instr = comp.instrs[iname]
            op = instr.opcode
            # --- flops ------------------------------------------------- #
            if op == "dot":
                out.flops += _dot_flops(instr, comp)
            # --- collectives ------------------------------------------- #
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLL_KINDS:
                operand_bytes = 0
                for a in instr.args:
                    o = comp.instrs.get(a)
                    if o is not None:
                        operand_bytes += _instr_out_bytes(o)
                if operand_bytes == 0:
                    operand_bytes = _instr_out_bytes(instr)
                out.coll[base] = out.coll.get(base, 0.0) + operand_bytes
                out.bytes += operand_bytes
                out.bytes_min += operand_bytes
            # --- bytes -------------------------------------------------- #
            if op == "fusion":
                callee_name = _attr(instr.line, "calls")
                callee = comps.get(callee_name or "")
                if callee is not None:
                    sub = visit(callee_name)
                    out.flops += sub.flops
                    for k, v in sub.coll.items():
                        out.coll[k] = out.coll.get(k, 0.0) + v
                    out.bytes += _fusion_operand_bytes(instr, comp, callee) \
                        + _fusion_output_bytes(instr, callee)
                    out.bytes_min += _fusion_min_bytes(callee)
                continue
            if op == "while":
                body = _attr(instr.line, "body")
                cond = _attr(instr.line, "condition")
                trips = _trip_count(comps[cond]) if cond in comps else 1
                out.while_trips[body or "?"] = trips
                if body in comps:
                    sub = visit(body)
                    out.flops += trips * sub.flops
                    out.bytes += trips * sub.bytes
                    out.bytes_min += trips * sub.bytes_min
                    for k, v in sub.coll.items():
                        out.coll[k] = out.coll.get(k, 0.0) + trips * v
                    for k, v in sub.while_trips.items():
                        out.while_trips[k] = v
                continue
            if op == "conditional":
                branches = re.findall(r"%([\w.\-]+)",
                                      instr.line.split("branch_computations")
                                      [-1]) if "branch_computations" in \
                    instr.line else \
                    [b for b in (_attr(instr.line, "true_computation"),
                                 _attr(instr.line, "false_computation")) if b]
                subs = [visit(b) for b in branches if b in comps]
                if subs:
                    best = max(subs, key=lambda s: s.flops + s.bytes)
                    out.flops += best.flops
                    out.bytes += best.bytes
                    out.bytes_min += best.bytes_min
                    for k, v in best.coll.items():
                        out.coll[k] = out.coll.get(k, 0.0) + v
                continue
            if op in ("call", "async-start"):
                callee_name = _attr(instr.line, "to_apply")
                if callee_name in comps:
                    sub = visit(callee_name)
                    out.flops += sub.flops
                    out.bytes += sub.bytes
                    out.bytes_min += sub.bytes_min
                    for k, v in sub.coll.items():
                        out.coll[k] = out.coll.get(k, 0.0) + v
                continue
            if op in ("reduce", "sort", "scatter", "map", "reduce-window",
                      "select-and-scatter"):
                # scalar to_apply bodies: negligible flops; charge bytes
                pass
            if op == "dot":
                pass  # bytes charged below like any instruction
            if op not in _NO_BYTES:
                b = _instr_out_bytes(instr)
                for a in instr.args:
                    o = comp.instrs.get(a)
                    if o is not None:
                        b += _instr_out_bytes(o)
                out.bytes += b
                if op in _HEAVY_MIN:
                    if op == "dynamic-slice":
                        out.bytes_min += _instr_out_bytes(instr)
                    elif op == "dynamic-update-slice":
                        upd = comp.instrs.get(instr.args[1]) \
                            if len(instr.args) >= 2 else None
                        out.bytes_min += (_instr_out_bytes(upd)
                                          if upd is not None else 0)
                    elif op == "gather":
                        out.bytes_min += 2 * _instr_out_bytes(instr)
                    else:
                        out.bytes_min += _charge_output(comp, instr)
                        for a in instr.args:
                            out.bytes_min += _charge_operand(comp, a)
        return out

    return visit(entry)
