from repro.serving.engine import (Completion, Request, ServeConfig,
                                  ServingEngine, StepResult, sample_token)
from repro.serving.server import InferenceServer, ServerStats
from repro.serving.snapshot_bus import (ChaosPublisher, SnapshotPublisher,
                                        SnapshotWatcher)

__all__ = [
    "ChaosPublisher",
    "Completion",
    "InferenceServer",
    "Request",
    "ServeConfig",
    "ServerStats",
    "ServingEngine",
    "SnapshotPublisher",
    "SnapshotWatcher",
    "StepResult",
    "sample_token",
]
