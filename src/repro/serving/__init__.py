from repro.serving.engine import ServeConfig, ServingEngine, sample_token

__all__ = ["ServeConfig", "ServingEngine", "sample_token"]
