"""Batched serving loop: continuous batched decode over a KV cache.

A thin production-shaped engine: requests (prompts) are admitted into a
fixed-size batch; prefill builds the cache (per-request in this CPU build;
batched prefill when prompts share a length); decode steps run batched with
per-slot completion (EOS or token budget) and slot recycling.  ``serve_step``
— one token for the whole batch against the cache — is exactly what the
decode input shapes lower in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill


def sample_token(logits: jax.Array, key: jax.Array, temperature: float = 1.0,
                 top_k: Optional[int] = None) -> jax.Array:
    """logits (B, V) → token ids (B,)."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k is not None:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[:, -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1)


@dataclasses.dataclass
class ServeConfig:
    batch: int = 8
    max_len: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0
    top_k: Optional[int] = None
    eos_id: Optional[int] = None
    seed: int = 0


class ServingEngine:
    """Synchronous batched decoder (single host, any number of devices)."""

    def __init__(self, params, cfg, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, c, t, cfg),
            donate_argnums=(1,))   # the cache is consumed each step

    def generate(self, prompts: List[np.ndarray],
                 embeds: Optional[np.ndarray] = None
                 ) -> List[np.ndarray]:
        """Greedy/sampled continuation for a list of token prompts.

        Prompts are left-padded to a common length and processed in
        batch-sized waves (prefill once per wave, then batched decode).
        ``embeds``, when given, is aligned with ``prompts`` — one
        frontend-embedding row per request, sliced per wave.
        """
        out: List[np.ndarray] = []
        for start in range(0, len(prompts), self.scfg.batch):
            wave = prompts[start:start + self.scfg.batch]
            # each wave decodes against ITS requests' frontend embeddings —
            # slicing here (not `embeds[:B]` inside the wave) is what keeps
            # wave 2+ from silently reusing wave 1's conditioning
            emb = None if embeds is None else embeds[start:start + len(wave)]
            out.extend(self._generate_wave(wave, emb))
        return out

    def _generate_wave(self, wave, embeds) -> List[np.ndarray]:
        cfg, scfg = self.cfg, self.scfg
        # pad prompts to a common length (left-pad with token 0)
        L = max(len(p) for p in wave)
        B = len(wave)
        toks = np.zeros((B, L), np.int32)
        for i, p in enumerate(wave):
            toks[i, L - len(p):] = p
        emb = None
        if cfg.frontend_tokens:
            if embeds is None:
                emb = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model),
                                jnp.bfloat16)
            else:
                if len(embeds) != B:
                    raise ValueError(
                        f"wave of {B} prompts got {len(embeds)} embeddings")
                emb = jnp.asarray(embeds, jnp.bfloat16)
        logits, cache = prefill(
            self.params, jnp.asarray(toks), cfg, embeds=emb,
            max_len=L + (cfg.frontend_tokens or 0) + scfg.max_new_tokens)
        done = np.zeros(B, bool)
        outs: List[List[int]] = [[] for _ in range(B)]
        tok = None
        for _ in range(scfg.max_new_tokens):
            self._key, k = jax.random.split(self._key)
            tok = sample_token(logits, k, scfg.temperature, scfg.top_k)
            t = np.asarray(tok)
            for i in range(B):
                if not done[i]:
                    outs[i].append(int(t[i]))
                    if scfg.eos_id is not None and t[i] == scfg.eos_id:
                        done[i] = True
            if done.all():
                break
            logits, cache = self._decode(self.params, cache, tok[:, None])
        return [np.asarray(o, np.int32) for o in outs]
