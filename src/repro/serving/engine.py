"""Serving engine: request-lifecycle API over continuously batched decode.

The primary surface is JetStream-shaped — requests go in one at a time
and the engine is *stepped*:

* :meth:`ServingEngine.submit` — enqueue a :class:`Request`, get a
  request id back immediately;
* :meth:`ServingEngine.step` — one batched decode tick: admit queued
  requests into free slots (batched prefill), sample one token for every
  active slot, retire slots that finished (EOS / token budget / cache
  capacity) as :class:`Completion`\\ s, then advance the KV caches one
  decode step;
* :meth:`ServingEngine.drain` — step until queue and slots are empty;
* :meth:`ServingEngine.set_params` — hot-swap the model between decode
  steps.  Swaps NEVER touch in-flight requests: each decode group pins
  the params (and snapshot version) it started with, finishes on them,
  and only newly admitted work sees the new snapshot.  This is PSP's
  staleness tolerance applied at the serving edge — the trainer keeps
  publishing, the server keeps decoding, nobody waits at a barrier.

Slots live in fixed-width *decode groups* (``ServeConfig.batch`` slots,
``ServeConfig.max_len`` cache capacity).  All slots of a group share one
scalar cache clock, so admission into a running group left-pads the new
prompt to the group's current length — exactly the padding semantics the
wave engine always had (pads are attended), and the decode mask
(``models/attention.py``) makes unused cache capacity numerically
invisible, so a group's fixed-capacity cache decodes bit-identically to
the old exact-fit wave cache.  A group whose snapshot is stale stops
admitting and drains; a group with no active slots is dropped.

``generate(prompts, embeds)`` remains as a thin compatibility wrapper:
it submits one wave at a time and drains, which reproduces the legacy
blocking wave-batch engine token-for-token (pinned by
``tests/test_substrates.py``, incl. the per-wave-embeds regression).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill


def sample_token(logits: jax.Array, key: jax.Array, temperature: float = 1.0,
                 top_k: Optional[int] = None) -> jax.Array:
    """logits (B, V) → token ids (B,)."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k is not None:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[:, -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1)


@dataclasses.dataclass
class ServeConfig:
    """Engine knobs.  ``max_len`` is the per-group cache capacity: every
    request must satisfy ``prompt + frontend + max_new_tokens <= max_len``
    and a slot whose group clock reaches it finishes with reason
    ``"capacity"``.  ``max_groups`` bounds concurrently decoding groups
    (admission back-pressure: excess requests wait in the queue)."""

    batch: int = 8
    max_len: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0
    top_k: Optional[int] = None
    eos_id: Optional[int] = None
    seed: int = 0
    max_groups: int = 4


@dataclasses.dataclass
class Request:
    """One generation request.

    ``embed`` is the per-request frontend embedding row ``(F, d_model)``
    for architectures with ``cfg.frontend_tokens`` (zeros when omitted);
    ``max_new_tokens=None`` takes the engine default.  ``req_id`` is
    assigned by :meth:`ServingEngine.submit`.  ``deadline_s`` is a
    per-request wall-clock budget measured from submission; the engine
    ignores it (deadlines are a server concern —
    :class:`repro.serving.server.InferenceServer` fails the future with
    ``TimeoutError`` and cancels the slot when it expires).
    """

    prompt: np.ndarray
    embed: Optional[np.ndarray] = None
    max_new_tokens: Optional[int] = None
    req_id: Optional[int] = None
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Completion:
    """A finished request: generated ``tokens``, the ``snapshot_version``
    it was served on (pinned at admission — never the mid-flight swap
    target), and why it stopped (``"eos"`` | ``"length"`` |
    ``"capacity"``)."""

    req_id: int
    tokens: np.ndarray
    snapshot_version: int
    prompt_len: int
    finish_reason: str


@dataclasses.dataclass
class StepResult:
    """One tick's outcome: finished requests plus every ``(req_id,
    token)`` emitted this tick (for per-token latency accounting)."""

    completions: List[Completion]
    emitted: List[Tuple[int, int]]


@dataclasses.dataclass
class _Slot:
    req_id: int
    prompt_len: int
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)


class _Group:
    """A fixed-width decode group: ``batch`` slots sharing one cache
    clock and one pinned ``(params, version)`` snapshot."""

    def __init__(self, params, version: int, cache, logits, batch: int):
        self.params = params
        self.version = version
        self.cache = cache
        self.logits = logits                       # (batch, V) f32
        self.slots: List[Optional[_Slot]] = [None] * batch
        self.length: Optional[int] = None          # shared cache clock

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def free(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]


class ServingEngine:
    """Continuously batched decoder with snapshot hot-swap (single host).

    Not thread-safe: one thread drives ``submit``/``step``/``drain``/
    ``set_params`` (``serving/server.py`` wraps it in an admission queue
    + worker thread for concurrent callers).
    """

    def __init__(self, params, cfg, serve_cfg: ServeConfig, *,
                 version: int = 0):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        self.version = version
        self._F = cfg.frontend_tokens or 0
        if cfg.sliding_window and serve_cfg.max_len < cfg.sliding_window:
            raise ValueError(
                f"max_len {serve_cfg.max_len} < sliding_window "
                f"{cfg.sliding_window}: the prefill ring cache would not "
                "fit the group cache")
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, c, t, cfg),
            donate_argnums=(1,))   # the cache is consumed each step
        self._queue: Deque[Request] = collections.deque()
        self._groups: List[_Group] = []
        self._next_id = 0
        self.swaps = 0

    # ------------------------------------------------------------------ #
    # lifecycle API
    # ------------------------------------------------------------------ #
    def set_params(self, params, version: Optional[int] = None) -> int:
        """Swap the serving snapshot between decode steps.

        Groups already decoding keep the snapshot they pinned at
        creation and stop admitting; new admissions build groups on the
        new params.  Returns the (auto-incremented) new version.
        """
        self.params = params
        self.version = self.version + 1 if version is None else version
        self.swaps += 1
        return self.version

    def submit(self, req: Request) -> int:
        """Validate + enqueue a request; returns its assigned id."""
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(f"prompt must be a non-empty 1-D token array, "
                             f"got shape {prompt.shape}")
        mn = req.max_new_tokens or self.scfg.max_new_tokens
        need = prompt.size + self._F + mn
        if need > self.scfg.max_len:
            raise ValueError(
                f"request needs {need} cache slots (prompt {prompt.size} + "
                f"frontend {self._F} + max_new {mn}) > max_len "
                f"{self.scfg.max_len}")
        if self._F and req.embed is not None:
            emb = np.asarray(req.embed)
            if emb.shape != (self._F, self.cfg.d_model):
                raise ValueError(
                    f"embed shape {emb.shape} != "
                    f"({self._F}, {self.cfg.d_model})")
        req = dataclasses.replace(req, prompt=prompt.astype(np.int32),
                                  max_new_tokens=mn, req_id=self._next_id)
        self._next_id += 1
        self._queue.append(req)
        return req.req_id

    def has_pending(self) -> bool:
        """Queued or in-flight work remains."""
        return bool(self._queue) or any(g.active() for g in self._groups)

    def cancel(self, req_id: int) -> bool:
        """Remove a queued or in-flight request without completing it.

        Returns whether the request was found.  A cancelled slot frees
        immediately (its group keeps decoding for the remaining slots;
        an emptied group is dropped at the next :meth:`step`).  The
        server uses this to enforce per-request deadlines — the future,
        not the engine, reports the timeout.
        """
        for i, r in enumerate(self._queue):
            if r.req_id == req_id:
                del self._queue[i]
                return True
        for g in self._groups:
            for i, s in enumerate(g.slots):
                if s is not None and s.req_id == req_id:
                    g.slots[i] = None
                    return True
        return False

    def request_versions(self) -> Dict[int, Optional[int]]:
        """Map every live request id to its pinned snapshot version.

        In-flight requests report the version their decode group pinned
        at admission; still-queued requests report ``None`` (they have
        not pinned anything yet).  This is the book the server's
        worker-death re-admission reads to rebuild version cohorts.
        """
        out: Dict[int, Optional[int]] = {r.req_id: None for r in self._queue}
        for g in self._groups:
            for s in g.slots:
                if s is not None:
                    out[s.req_id] = g.version
        return out

    def live_versions(self) -> List[int]:
        """Snapshot versions still pinned by some decode group."""
        return sorted({g.version for g in self._groups if g.active()})

    def reset(self) -> List[int]:
        """Drop every queued and in-flight request; returns their ids.

        Recovery primitive: after a decode-worker crash the engine's
        groups may be mid-step inconsistent, so the server resets and
        re-submits from its own request book.  Request-id assignment is
        *not* reset — re-admitted requests get fresh ids and stale ids
        can never collide.
        """
        ids = [r.req_id for r in self._queue]
        ids += [s.req_id for g in self._groups for s in g.slots
                if s is not None]
        self._queue.clear()
        self._groups = []
        return ids

    def admit_queued(self) -> None:
        """Admit queued requests into decode groups *now*, no decode step.

        Group formation pins ``(params, version)``, so calling this
        between a :meth:`set_params` pair lets the server rebuild a
        version cohort on its original snapshot before switching the
        engine back to the latest one (worker-death re-admission).
        """
        self._admit()

    def step(self) -> StepResult:
        """One batched decode tick (admit → sample/retire → decode)."""
        self._admit()
        completions: List[Completion] = []
        emitted: List[Tuple[int, int]] = []
        scfg = self.scfg
        for g in self._groups:
            active = g.active()
            if not active:
                continue
            self._key, k = jax.random.split(self._key)
            tok = sample_token(g.logits, k, scfg.temperature, scfg.top_k)
            t = np.asarray(tok)
            for i in active:
                s = g.slots[i]
                s.out.append(int(t[i]))
                emitted.append((s.req_id, int(t[i])))
                reason = None
                if scfg.eos_id is not None and t[i] == scfg.eos_id:
                    reason = "eos"
                elif len(s.out) >= s.max_new:
                    reason = "length"
                elif g.length >= scfg.max_len:
                    reason = "capacity"   # cache full: no further decode
                if reason is not None:
                    completions.append(Completion(
                        req_id=s.req_id,
                        tokens=np.asarray(s.out, np.int32),
                        snapshot_version=g.version,
                        prompt_len=s.prompt_len,
                        finish_reason=reason))
                    g.slots[i] = None
            if g.active():
                g.logits, g.cache = self._decode(g.params, g.cache,
                                                 tok[:, None])
                g.length += 1
        self._groups = [g for g in self._groups if g.active()]
        return StepResult(completions, emitted)

    def drain(self) -> List[Completion]:
        """Step until every queued and in-flight request completed."""
        out: List[Completion] = []
        while self.has_pending():
            out.extend(self.step().completions)
        return out

    # ------------------------------------------------------------------ #
    # admission
    # ------------------------------------------------------------------ #
    def _fits_running(self, req: Request, g: _Group) -> bool:
        """Left-pad admission into a running group's shared clock."""
        return (g.version == self.version and g.free()
                and req.prompt.size + self._F <= g.length
                and g.length + req.max_new_tokens <= self.scfg.max_len)

    def _admit(self):
        """FIFO admission: fill running same-version groups first, then
        open fresh groups up to ``max_groups``; head-of-line blocking is
        deliberate (no reordering → deterministic, fair)."""
        while self._queue:
            head = self._queue[0]
            target = next((g for g in self._groups
                           if self._fits_running(head, g)), None)
            if target is not None:
                block = []
                while (self._queue and len(block) < len(target.free())
                       and self._fits_running(self._queue[0], target)):
                    block.append(self._queue.popleft())
                self._admit_block(target, block)
                continue
            if len(self._groups) >= self.scfg.max_groups:
                return
            block, L, mn = [], 0, 0
            while self._queue and len(block) < self.scfg.batch:
                r = self._queue[0]
                L2 = max(L, r.prompt.size)
                mn2 = max(mn, r.max_new_tokens)
                if block and L2 + self._F + mn2 > self.scfg.max_len:
                    break           # would overflow a co-admitted slot
                L, mn = L2, mn2
                block.append(self._queue.popleft())
            self._groups.append(self._new_group())
            self._admit_block(self._groups[-1], block)

    def _new_group(self) -> _Group:
        cache = init_cache(self.cfg, self.scfg.batch, self.scfg.max_len)
        logits = jnp.zeros((self.scfg.batch, self.cfg.vocab_size),
                           jnp.float32)
        return _Group(self.params, self.version, cache, logits,
                      self.scfg.batch)

    def _admit_block(self, g: _Group, reqs: List[Request]):
        """Prefill ``reqs`` together and scatter them into ``g``'s free
        slots.  A fresh group's clock starts at the block's padded
        length; a running group left-pads every prompt to its clock so
        all slots stay on one cache offset."""
        cfg, F = self.cfg, self._F
        if g.length is None:
            L_tok = max(r.prompt.size for r in reqs)
            g.length = L_tok + F
        else:
            L_tok = g.length - F
        k = len(reqs)
        toks = np.zeros((k, L_tok), np.int32)
        for i, r in enumerate(reqs):
            toks[i, L_tok - r.prompt.size:] = r.prompt
        emb = None
        if F:
            emb = np.zeros((k, F, cfg.d_model), np.float32)
            for i, r in enumerate(reqs):
                if r.embed is not None:
                    emb[i] = np.asarray(r.embed, np.float32)
            emb = jnp.asarray(emb, jnp.bfloat16)
        logits, cache = prefill(g.params, jnp.asarray(toks), cfg,
                                embeds=emb, max_len=self.scfg.max_len)
        assert int(cache["length"]) == g.length
        slots = g.free()[:k]
        self._scatter(g, cache, logits, slots)
        for slot, r in zip(slots, reqs):
            g.slots[slot] = _Slot(req_id=r.req_id, prompt_len=r.prompt.size,
                                  max_new=r.max_new_tokens)

    def _scatter(self, g: _Group, cache, logits, slots: List[int]):
        """Write a k-row prefill (cache rows + logits rows) into group
        slot rows.  Group cache leaves carry the batch axis at position
        1 under ``groups`` (scan-stacked over G) and 0 under ``tail``;
        the scalar ``length`` clock is shared and already equal."""
        idx = jnp.asarray(slots)

        def rows(axis):
            def one(dst, src):
                sel = (slice(None),) * axis + (idx,)
                return dst.at[sel].set(src.astype(dst.dtype))
            return one

        new = {"groups": jax.tree.map(rows(1), g.cache["groups"],
                                      cache["groups"]),
               "length": cache["length"]}
        if "tail" in g.cache:
            new["tail"] = jax.tree.map(rows(0), g.cache["tail"],
                                       cache["tail"])
        g.cache = new
        g.logits = g.logits.at[idx].set(logits)

    # ------------------------------------------------------------------ #
    # legacy blocking API (compatibility wrapper)
    # ------------------------------------------------------------------ #
    def generate(self, prompts: List[np.ndarray],
                 embeds: Optional[np.ndarray] = None
                 ) -> List[np.ndarray]:
        """Blocking wave-batch generation (legacy surface).

        A thin wrapper over ``submit``/``drain``: prompts are submitted
        in batch-sized waves and each wave is drained before the next is
        admitted, which reproduces the historical wave engine exactly —
        each wave decodes against its own requests' frontend embeddings
        (the PR-7 regression), padded to the wave's own max prompt
        length.
        """
        if embeds is not None and len(embeds) != len(prompts):
            raise ValueError(f"{len(prompts)} prompts got {len(embeds)} "
                             "embeddings")
        results: Dict[int, np.ndarray] = {}
        ids: List[int] = []
        for start in range(0, len(prompts), self.scfg.batch):
            wave = prompts[start:start + self.scfg.batch]
            for j, p in enumerate(wave):
                emb = None if embeds is None else embeds[start + j]
                ids.append(self.submit(Request(prompt=np.asarray(p),
                                               embed=emb)))
            for c in self.drain():
                results[c.req_id] = c.tokens
        return [results[i] for i in ids]
