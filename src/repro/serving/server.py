"""Inference server: admission queue + background decode worker.

JetStream-offline-inference shape: callers from any thread ``submit()``
into a bounded admission queue and get a ``concurrent.futures.Future``
back; one worker thread owns the :class:`ServingEngine` outright and
loops

    drain inbox → (every ``poll_every`` ticks) poll the snapshot
    watcher and hot-swap → ``engine.step()`` → resolve futures

so the engine never needs locks.  Back-pressure is the queue bound:
``submit`` blocks (or raises, with ``block=False``) when the server is
``max_queue`` requests behind.  Requests are never dropped — a swap only
redirects *future* admissions (see :meth:`ServingEngine.set_params`),
and shutdown drains in-flight work before the worker exits.

The worker also keeps the latency book: per-token wall-clock stamps from
``StepResult.emitted``, per-request first-token/total latency, and the
``swap_stall`` — wall time the decode loop spent loading a snapshot
inside :meth:`SnapshotWatcher.poll`, which is exactly the serving-side
cost of a hot-swap (``benchmarks/serve_bench.py`` reports its max).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional

from repro.serving.engine import Completion, Request, ServingEngine
from repro.serving.snapshot_bus import SnapshotWatcher

__all__ = ["InferenceServer", "ServerStats"]


@dataclasses.dataclass
class ServerStats:
    """Counters + raw latency samples (seconds) for one server run."""

    submitted: int = 0
    completed: int = 0
    swaps: int = 0
    snapshots_skipped: int = 0
    steps: int = 0
    timeouts: int = 0           # requests failed on their deadline
    worker_restarts: int = 0    # decode-worker crash recoveries
    readmitted: int = 0         # requests re-submitted after a crash
    token_times: List[float] = dataclasses.field(default_factory=list)
    first_token_lat: List[float] = dataclasses.field(default_factory=list)
    request_lat: List[float] = dataclasses.field(default_factory=list)
    swap_stalls: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Tracked:
    future: Future
    t_submit: float
    request: Request            # original request (worker-death re-admission)
    t_first: Optional[float] = None


class InferenceServer:
    """Threaded front-end over a :class:`ServingEngine`.

    ``watcher=None`` serves a fixed snapshot; with a watcher the worker
    polls every ``poll_every`` decode ticks (and when idle).  Use as a
    context manager or call :meth:`shutdown`.
    """

    def __init__(self, engine: ServingEngine, *,
                 watcher: Optional[SnapshotWatcher] = None,
                 max_queue: int = 256, poll_every: int = 8,
                 idle_wait: float = 0.01, max_restarts: int = 2):
        self.engine = engine
        self.watcher = watcher
        self.poll_every = poll_every
        self.max_restarts = max_restarts
        self.stats = ServerStats()
        self._inbox: "queue.Queue" = queue.Queue(maxsize=max_queue)
        self._tracked: Dict[int, _Tracked] = {}
        # every snapshot this server has served, pruned to versions still
        # pinned by a live group — the book worker-death re-admission
        # reads to rebuild a cohort on its original params
        self._params_history: Dict[int, object] = {engine.version:
                                                   engine.params}
        self._idle_wait = idle_wait
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._fault: Optional[BaseException] = None
        self._restarts = 0
        self._thread = threading.Thread(target=self._worker,
                                        name="serve-worker", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------ #
    # caller side (any thread)
    # ------------------------------------------------------------------ #
    def submit(self, req: Request, *, block: bool = True,
               timeout: Optional[float] = None) -> "Future[Completion]":
        """Enqueue a request; the future resolves to its Completion.

        Blocks when the admission queue is full (back-pressure); with
        ``block=False`` raises ``queue.Full`` instead.
        """
        self._raise_worker_error()
        if self._stop.is_set():
            raise RuntimeError("server is shut down")
        fut: "Future[Completion]" = Future()
        self._inbox.put((req, fut, time.monotonic()), block=block,
                        timeout=timeout)
        return fut

    def inject_worker_fault(self, exc: Optional[BaseException] = None) -> None:
        """Chaos hook: make the decode worker raise at its next tick.

        The fault-plan ``kill`` event for the serving tier (one decode
        worker per server — :meth:`repro.core.faults.FaultPlan.
        serving_kill_index`) lands here: the worker thread raises,
        recovery re-admits in-flight requests on their pinned snapshots
        (bit-exact under greedy decode) and the loop continues, up to
        ``max_restarts`` times.
        """
        self._fault = exc or RuntimeError("injected decode-worker fault")

    def shutdown(self, *, drain: bool = True) -> None:
        """Stop the worker; with ``drain`` (default) finish all admitted
        and queued work first so no request is dropped."""
        self._stop.set()
        self._thread.join()
        if drain:
            self._drain_inbox()
            while self.engine.has_pending():
                self._tick(poll=False)
        # anything still unresolved (drain=False) fails loudly
        for tr in self._tracked.values():
            if not tr.future.done():
                tr.future.set_exception(RuntimeError("server shut down"))
        self._tracked.clear()
        self._raise_worker_error()

    def __enter__(self) -> "InferenceServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------------------ #
    # worker side (single thread owns the engine)
    # ------------------------------------------------------------------ #
    def _worker(self):
        while True:
            try:
                self._serve_loop()
                return                          # clean stop
            except BaseException as e:
                if self._stop.is_set() or self._restarts >= self.max_restarts:
                    self._error = e             # surfaced to callers
                    self._stop.set()
                    return
                self._restarts += 1
                self.stats.worker_restarts += 1
                try:
                    self._recover()
                except BaseException as e2:     # recovery itself died
                    self._error = e2
                    self._stop.set()
                    return

    def _serve_loop(self):
        while not self._stop.is_set():
            if self._fault is not None:
                exc, self._fault = self._fault, None
                raise exc
            got = self._drain_inbox()
            self._check_deadlines(time.monotonic())
            if not self.engine.has_pending():
                self._poll_watcher()            # swap while idle is free
                if not got:
                    time.sleep(self._idle_wait)
                continue
            self._tick(poll=self.stats.steps % self.poll_every == 0)

    def _recover(self):
        """Worker-death re-admission: rebuild the engine's request book.

        The crashed step may have left groups inconsistent, so the
        engine is reset and every live request re-submitted from the
        server's own copy — in-flight requests **per version cohort on
        the exact snapshot their group pinned** (``set_params`` to the
        pinned version, submit, ``admit_queued`` to pin the fresh group
        before moving on), still-queued requests last under the current
        snapshot.  Re-decoding restarts each request from token zero,
        which under greedy decode reproduces the identical completion
        (same params, same prompt ⇒ same argmax path) — the re-admitted
        future resolves bit-exact to what the uninterrupted decode would
        have returned.  Per-token latency samples of replayed tokens are
        counted twice in ``stats.token_times``; completions are not.
        """
        latest = (self.engine.params, self.engine.version)
        versions = self.engine.request_versions()
        self.engine.reset()
        cohorts: Dict[Optional[int], List[int]] = {}
        for rid, ver in versions.items():
            if rid in self._tracked:
                cohorts.setdefault(ver, []).append(rid)
        for ver in sorted(v for v in cohorts if v is not None):
            params = self._params_history.get(ver)
            if params is None:                  # history pruned: serve fresh
                params, ver_pin = latest
            else:
                ver_pin = ver
            self.engine.set_params(params, ver_pin)
            self._resubmit(cohorts[ver])
            self.engine.admit_queued()          # pin the cohort's groups
        self.engine.set_params(*latest)
        self._resubmit(cohorts.get(None, []))

    def _resubmit(self, rids: List[int]):
        for rid in rids:
            tr = self._tracked.pop(rid)
            new_rid = self.engine.submit(tr.request)
            self._tracked[new_rid] = tr
            self.stats.readmitted += 1

    def _drain_inbox(self) -> bool:
        got = False
        while True:
            try:
                req, fut, t_sub = self._inbox.get_nowait()
            except queue.Empty:
                return got
            got = True
            if (req.deadline_s is not None
                    and time.monotonic() - t_sub > req.deadline_s):
                self.stats.timeouts += 1        # expired while queued
                fut.set_exception(TimeoutError(
                    f"request missed its {req.deadline_s}s deadline "
                    "in the admission queue"))
                continue
            try:
                rid = self.engine.submit(req)
            except ValueError as e:             # unservable request
                fut.set_exception(e)
                continue
            self._tracked[rid] = _Tracked(fut, t_sub, req)
            self.stats.submitted += 1

    def _check_deadlines(self, now: float):
        """Fail + cancel tracked requests past their deadline."""
        expired = [rid for rid, tr in self._tracked.items()
                   if tr.request.deadline_s is not None
                   and now - tr.t_submit > tr.request.deadline_s]
        for rid in expired:
            tr = self._tracked.pop(rid)
            self.engine.cancel(rid)
            self.stats.timeouts += 1
            tr.future.set_exception(TimeoutError(
                f"request exceeded its {tr.request.deadline_s}s deadline"))

    def _poll_watcher(self):
        if self.watcher is None:
            return
        t0 = time.monotonic()
        loaded = self.watcher.poll()
        self.stats.snapshots_skipped = self.watcher.skipped
        if loaded is None:
            return
        params, version = loaded
        self.engine.set_params(params, version)
        self._params_history[version] = params
        live = set(self.engine.live_versions()) | {version}
        for v in [v for v in self._params_history if v not in live]:
            del self._params_history[v]
        self.stats.swaps += 1
        self.stats.swap_stalls.append(time.monotonic() - t0)

    def _tick(self, *, poll: bool):
        if poll:
            self._poll_watcher()
        self._check_deadlines(time.monotonic())
        res = self.engine.step()
        now = time.monotonic()
        self.stats.steps += 1
        for rid, _tok in res.emitted:
            self.stats.token_times.append(now)
            tr = self._tracked.get(rid)
            if tr is not None and tr.t_first is None:
                tr.t_first = now
                self.stats.first_token_lat.append(now - tr.t_submit)
        for comp in res.completions:
            tr = self._tracked.pop(comp.req_id, None)
            self.stats.completed += 1
            if tr is not None:
                self.stats.request_lat.append(now - tr.t_submit)
                tr.future.set_result(comp)

    def _raise_worker_error(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("serve worker thread failed") from err
