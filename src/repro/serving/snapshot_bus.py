"""Trainer→server snapshot bus over a shared directory.

The bus is the checkpoint subsystem worn sideways: the trainer publishes
versioned model snapshots with the exact atomic npz + JSON-sidecar
protocol of :mod:`repro.checkpoint` (sidecar renamed first, npz last, so
a discoverable snapshot is always complete), and the server polls the
directory for the newest publishable step.  No socket, no RPC, no
coordination — a crash on either side leaves at worst a torn write that
``latest_step`` refuses to select and the next publisher garbage-collects.

* :class:`SnapshotPublisher` — trainer side.  Thin wrapper over
  :class:`repro.checkpoint.manager.CheckpointManager`: async background
  writer off the training critical path, bounded-queue back-pressure,
  retention GC.  Publishes **serving params only** (not optimizer state),
  stamping each snapshot's sidecar with its version.
* :class:`SnapshotWatcher` — server side.  ``poll()`` returns a
  ``(params, version)`` pair when a *new, loadable* snapshot appeared,
  else ``None``.  Corrupt, torn, or config-mismatched snapshots are
  skipped and the server keeps serving its current version — staleness
  beats an outage, the same trade PSP makes at the training barrier.
  Bad steps are remembered in a **bounded blacklist with exponential
  backoff**: a failing step is retried on a jittered doubling schedule
  (a half-written file that completes later still gets picked up)
  instead of once per poll, entries are capped and expire after a
  retention TTL, and anything at or below the currently served step is
  dropped (it can never be selected again), so a long-running server
  under sustained corruption holds O(1) memory.
* :class:`ChaosPublisher` — fault-injecting publisher for chaos tests
  and ``benchmarks/chaos_bench.py``: executes the publish-fault events
  of a :class:`repro.core.faults.FaultPlan` (torn/corrupt snapshot
  writes, delayed/dropped publications, transient disk-full) while
  delegating clean publications to the real manager.
"""
from __future__ import annotations

import dataclasses
import errno
import json
import os
import random
import time
from typing import Any, Dict, Optional, Tuple

from repro.checkpoint import (CheckpointManager, CheckpointPolicy,
                              latest_step, read_metadata, restore_checkpoint)
from repro.core import env
from repro.core.faults import FaultPlan

PyTree = Any

__all__ = ["ChaosPublisher", "SnapshotPublisher", "SnapshotWatcher"]


class SnapshotPublisher:
    """Trainer-side publisher: versioned serving snapshots, written
    asynchronously with retention.

    ``every_steps`` is the publication cadence for :meth:`maybe_publish`;
    :meth:`publish` writes unconditionally.  ``keep`` old snapshots stay
    on disk so a watcher mid-load never sees its file deleted under it
    (retention deletes oldest-first and the watcher only reads the
    newest; ``keep=0`` disables GC — the cluster harness needs every
    version addressable).  Transient write failures (disk full, EIO)
    retry with backoff inside the manager's writer thread before
    surfacing.
    """

    def __init__(self, out_dir: str, *, every_steps: Optional[int] = None,
                 keep: int = 3, async_write: bool = True):
        self.out_dir = out_dir
        self._mgr = CheckpointManager(
            out_dir, CheckpointPolicy(every_steps=every_steps),
            keep=keep, async_write=async_write)
        self.published = 0

    def maybe_publish(self, step: int, params: PyTree,
                      metadata: Optional[dict] = None) -> bool:
        """Publish iff the step cadence fires; returns whether it did."""
        if not self._mgr.should_save(step):
            return False
        self.publish(step, params, metadata)
        return True

    def publish(self, step: int, params: PyTree,
                metadata: Optional[dict] = None, *,
                block: bool = False) -> None:
        """Snapshot ``params`` to host and enqueue the atomic write."""
        meta = {"kind": "serving_snapshot", "version": step,
                **(metadata or {})}
        self._mgr.save(step, params, meta, block=block)
        self.published += 1

    def wait(self) -> None:
        """Block until every enqueued snapshot is on disk."""
        self._mgr.wait()

    def close(self) -> None:
        """Drain pending publications and stop the writer."""
        self._mgr.close()

    def __enter__(self) -> "SnapshotPublisher":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
            return
        try:                    # never mask the in-flight body exception
            self.close()
        except Exception:
            pass


class ChaosPublisher(SnapshotPublisher):
    """A :class:`SnapshotPublisher` that executes a fault plan.

    Each :meth:`publish` call is a *publication index* (0, 1, 2, ...)
    looked up in the plan (:meth:`repro.core.faults.FaultPlan.
    publish_fault`); covered indices execute the fault instead of / on
    top of the clean write:

    * ``torn_snapshot`` — write a truncated npz with **no sidecar**: by
      the bus protocol it is invisible to ``latest_step`` (the watcher
      never even sees the version; a stale server keeps serving).
    * ``corrupt_snapshot`` — write junk npz *plus* a valid sidecar: the
      watcher discovers it, fails to load it, and must skip/backoff.
    * ``delay_publish`` — sleep ``seconds`` before a clean publish
      (staleness at the bus, the PSP trade).
    * ``drop_publish`` — swallow the publication entirely.
    * ``disk_full`` — raise a transient ``ENOSPC`` from the writer via a
      one-shot injected failure, exercising the manager's retry path
      (the write succeeds on retry).

    Counters (``torn``, ``corrupt``, ``delayed``, ``dropped``,
    ``disk_full``) record what actually fired, for bench invariants.
    """

    def __init__(self, out_dir: str, plan: FaultPlan, **kw):
        super().__init__(out_dir, **kw)
        self.plan = plan
        self.index = 0
        self.counters: Dict[str, int] = {
            "torn": 0, "corrupt": 0, "delayed": 0, "dropped": 0,
            "disk_full": 0}

    def publish(self, step: int, params: PyTree,
                metadata: Optional[dict] = None, *,
                block: bool = False) -> None:
        """Publish with the plan's fault (if any) applied to this index."""
        ev = self.plan.publish_fault(self.index)
        self.index += 1
        if ev is None:
            super().publish(step, params, metadata, block=block)
            return
        if ev.kind == "torn_snapshot":
            self._write_junk(step, sidecar=False)
            self.counters["torn"] += 1
        elif ev.kind == "corrupt_snapshot":
            self._write_junk(step, sidecar=True)
            self.counters["corrupt"] += 1
        elif ev.kind == "delay_publish":
            time.sleep(ev.seconds)
            self.counters["delayed"] += 1
            super().publish(step, params, metadata, block=block)
        elif ev.kind == "drop_publish":
            self.counters["dropped"] += 1
        elif ev.kind == "disk_full":
            self.counters["disk_full"] += 1
            self._mgr.inject_write_fault(
                OSError(errno.ENOSPC, "No space left on device (injected)"))
            super().publish(step, params, metadata, block=block)

    def _write_junk(self, step: int, *, sidecar: bool) -> None:
        """Write a deliberately unloadable snapshot for version ``step``."""
        base = os.path.join(self.out_dir, f"step_{step:08d}.npz")
        if sidecar:
            with open(base + ".json", "w") as f:
                json.dump({"kind": "serving_snapshot", "version": step}, f)
        with open(base, "wb") as f:
            f.write(b"PK\x03\x04 this is not a real npz")


@dataclasses.dataclass
class _BadStep:
    """Blacklist entry: failure count + when to retry next."""

    first_seen: float
    fails: int
    next_retry: float


class SnapshotWatcher:
    """Server-side poller: loads the newest complete snapshot from a
    directory into the structure of ``template``.

    ``poll()`` is cheap when nothing changed (one ``listdir``).  Any
    failure to load a candidate step — torn npz, shape/key mismatch from
    a different config, file deleted between list and read — blacklists
    that step and keeps the current version serving; a *newer* step is
    still picked up normally.  Blacklisted steps are retried on a
    jittered exponential-backoff schedule (base
    ``PSP_BUS_BACKOFF_BASE``, doubling per failure up to
    ``PSP_BUS_BACKOFF_MAX``) — a write that completes late still lands —
    and the blacklist is bounded: at most ``PSP_BUS_BLACKLIST_MAX``
    entries (oldest evicted first), each expiring after
    ``PSP_BUS_BLACKLIST_TTL`` seconds, and every entry at or below the
    served step dropped on swap.  ``strict=True`` re-raises instead
    (tests, one-shot restore).
    """

    def __init__(self, watch_dir: str, template: PyTree, *,
                 strict: bool = False,
                 backoff_base: Optional[float] = None,
                 backoff_max: Optional[float] = None,
                 blacklist_max: Optional[int] = None,
                 blacklist_ttl: Optional[float] = None,
                 jitter_seed: Optional[int] = None):
        self.watch_dir = watch_dir
        self.template = template
        self.strict = strict
        self.loaded_step: Optional[int] = None
        self.bad_steps: Dict[int, _BadStep] = {}
        self.skipped = 0          # failed load attempts (incl. retries)
        self.retries = 0          # backoff-scheduled re-attempts
        self.backoff_base = (env.get_float("PSP_BUS_BACKOFF_BASE")
                             if backoff_base is None else backoff_base)
        self.backoff_max = (env.get_float("PSP_BUS_BACKOFF_MAX")
                            if backoff_max is None else backoff_max)
        self.blacklist_max = (env.get_int("PSP_BUS_BLACKLIST_MAX")
                              if blacklist_max is None else blacklist_max)
        self.blacklist_ttl = (env.get_float("PSP_BUS_BLACKLIST_TTL")
                              if blacklist_ttl is None else blacklist_ttl)
        self._rng = random.Random(jitter_seed)

    def poll(self) -> Optional[Tuple[PyTree, int]]:
        """Return ``(params, version)`` if a new snapshot is loadable."""
        now = time.monotonic()
        self._evict(now)
        step = latest_step(self.watch_dir)
        if step is None or step == self.loaded_step:
            return None
        bad = self.bad_steps.get(step)
        if bad is not None and now < bad.next_retry:
            return None                       # backing off, serve stale
        if bad is not None:
            self.retries += 1
        try:
            params, _ = restore_checkpoint(self.watch_dir, self.template,
                                           step)
            meta = read_metadata(self.watch_dir, step)
        except Exception:
            if self.strict:
                raise
            self._record_failure(step, bad, now)
            return None
        self.loaded_step = step
        # nothing at/below the served step can ever be selected again
        self.bad_steps = {s: b for s, b in self.bad_steps.items()
                          if s > step}
        return params, int(meta.get("version", step))

    def _record_failure(self, step: int, bad: Optional[_BadStep],
                        now: float) -> None:
        """Blacklist ``step`` (or push its retry horizon further out)."""
        self.skipped += 1
        if bad is None:
            bad = _BadStep(first_seen=now, fails=0, next_retry=now)
            self.bad_steps[step] = bad
            while len(self.bad_steps) > max(1, self.blacklist_max):
                del self.bad_steps[min(self.bad_steps)]   # oldest step out
        bad.fails += 1
        delay = min(self.backoff_base * (2.0 ** (bad.fails - 1)),
                    self.backoff_max)
        bad.next_retry = now + delay * (1.0 + 0.5 * self._rng.random())

    def _evict(self, now: float) -> None:
        """Expire blacklist entries older than the retention TTL."""
        if not self.bad_steps:
            return
        self.bad_steps = {
            s: b for s, b in self.bad_steps.items()
            if now - b.first_seen <= self.blacklist_ttl}
