"""Trainer→server snapshot bus over a shared directory.

The bus is the checkpoint subsystem worn sideways: the trainer publishes
versioned model snapshots with the exact atomic npz + JSON-sidecar
protocol of :mod:`repro.checkpoint` (sidecar renamed first, npz last, so
a discoverable snapshot is always complete), and the server polls the
directory for the newest publishable step.  No socket, no RPC, no
coordination — a crash on either side leaves at worst a torn write that
``latest_step`` refuses to select and the next publisher garbage-collects.

* :class:`SnapshotPublisher` — trainer side.  Thin wrapper over
  :class:`repro.checkpoint.manager.CheckpointManager`: async background
  writer off the training critical path, bounded-queue back-pressure,
  retention GC.  Publishes **serving params only** (not optimizer state),
  stamping each snapshot's sidecar with its version.
* :class:`SnapshotWatcher` — server side.  ``poll()`` returns a
  ``(params, version)`` pair when a *new, loadable* snapshot appeared,
  else ``None``.  Corrupt, torn, or config-mismatched snapshots are
  skipped (remembered, so a permanently bad step is not re-tried every
  poll) and the server keeps serving its current version — staleness
  beats an outage, the same trade PSP makes at the training barrier.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

from repro.checkpoint import (CheckpointManager, CheckpointPolicy,
                              latest_step, read_metadata, restore_checkpoint)

PyTree = Any

__all__ = ["SnapshotPublisher", "SnapshotWatcher"]


class SnapshotPublisher:
    """Trainer-side publisher: versioned serving snapshots, written
    asynchronously with retention.

    ``every_steps`` is the publication cadence for :meth:`maybe_publish`;
    :meth:`publish` writes unconditionally.  ``keep`` old snapshots stay
    on disk so a watcher mid-load never sees its file deleted under it
    (retention deletes oldest-first and the watcher only reads the
    newest).
    """

    def __init__(self, out_dir: str, *, every_steps: Optional[int] = None,
                 keep: int = 3, async_write: bool = True):
        self.out_dir = out_dir
        self._mgr = CheckpointManager(
            out_dir, CheckpointPolicy(every_steps=every_steps),
            keep=keep, async_write=async_write)
        self.published = 0

    def maybe_publish(self, step: int, params: PyTree,
                      metadata: Optional[dict] = None) -> bool:
        """Publish iff the step cadence fires; returns whether it did."""
        if not self._mgr.should_save(step):
            return False
        self.publish(step, params, metadata)
        return True

    def publish(self, step: int, params: PyTree,
                metadata: Optional[dict] = None, *,
                block: bool = False) -> None:
        """Snapshot ``params`` to host and enqueue the atomic write."""
        meta = {"kind": "serving_snapshot", "version": step,
                **(metadata or {})}
        self._mgr.save(step, params, meta, block=block)
        self.published += 1

    def wait(self) -> None:
        """Block until every enqueued snapshot is on disk."""
        self._mgr.wait()

    def close(self) -> None:
        self._mgr.close()

    def __enter__(self) -> "SnapshotPublisher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class SnapshotWatcher:
    """Server-side poller: loads the newest complete snapshot from a
    directory into the structure of ``template``.

    ``poll()`` is cheap when nothing changed (one ``listdir``).  Any
    failure to load a candidate step — torn npz, shape/key mismatch from
    a different config, file deleted between list and read — marks that
    step bad and keeps the current version serving; a *newer* step is
    still picked up normally.  ``strict=True`` re-raises instead (tests,
    one-shot restore).
    """

    def __init__(self, watch_dir: str, template: PyTree, *,
                 strict: bool = False):
        self.watch_dir = watch_dir
        self.template = template
        self.strict = strict
        self.loaded_step: Optional[int] = None
        self.bad_steps: set = set()
        self.skipped = 0

    def poll(self) -> Optional[Tuple[PyTree, int]]:
        """Return ``(params, version)`` if a new snapshot is loadable."""
        step = latest_step(self.watch_dir)
        if step is None or step == self.loaded_step or step in self.bad_steps:
            return None
        try:
            params, _ = restore_checkpoint(self.watch_dir, self.template,
                                           step)
            meta = read_metadata(self.watch_dir, step)
        except Exception:
            if self.strict:
                raise
            self.bad_steps.add(step)
            self.skipped += 1
            return None
        self.loaded_step = step
        return params, int(meta.get("version", step))
