import os

# smoke tests and benches must see the real single CPU device — the 512-way
# override belongs ONLY to launch/dryrun.py.  Tests that need a small mesh
# spawn subprocesses (see test_dryrun_small.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
