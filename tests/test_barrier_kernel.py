"""Pins the SPMD trainer to the shared BarrierKernel (no silent drift).

The unified barrier/straggler model (:mod:`repro.core.barrier_kernel`) is
the single jnp source for "may a worker advance" and "how long does a step
take".  These tests pin (a) ``spmd_psp``'s decisions to the
``BarrierKernel`` outputs, same seed → same pass/block pattern, (b) the
``BarrierKernel`` itself to a paper-semantics oracle built from the raw
sampling primitive + ``can_pass_jax``, and (c) the sweep engine's
reference decide path to the same functions — so the trainer and the
simulator cannot diverge again without a test going red.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import barrier_kernel as bk
from repro.core import spmd_psp
from repro.core.sampling import sample_steps_jax
from repro.core.spmd_psp import PSPConfig

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")


def _steps(seed, w=8, hi=9):
    return jnp.asarray(
        np.random.default_rng(seed).integers(0, hi, w), jnp.int32)


@pytest.mark.parametrize("barrier", FIVE)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_spmd_decisions_pinned_to_barrier_kernel(barrier, seed):
    """Same seed ⇒ the trainer's pass/block pattern IS the kernel's."""
    cfg = PSPConfig(barrier=barrier, n_workers=8, staleness=2, sample_size=2)
    key = jax.random.PRNGKey(seed)
    steps = _steps(seed)
    got = spmd_psp._barrier_allowed(cfg, key, steps)
    want = cfg.barrier_kernel.allowed(key, steps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # deterministic: same seed twice → same pattern
    again = spmd_psp._barrier_allowed(cfg, key, steps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(again))


@pytest.mark.parametrize("barrier", FIVE)
@pytest.mark.parametrize("seed", [3, 4])
def test_barrier_kernel_matches_paper_oracle(barrier, seed):
    """BarrierKernel ≡ the §6.4 oracle (sampling primitive + can_pass_jax)."""
    cfg = PSPConfig(barrier=barrier, n_workers=8, staleness=2, sample_size=2)
    key = jax.random.PRNGKey(seed)
    steps = _steps(seed + 10)
    got = cfg.barrier_kernel.allowed(key, steps)
    if cfg.is_asp:
        want = jnp.ones_like(steps, dtype=bool)
    elif cfg.is_classic:
        lag = steps[:, None] - steps[None, :]
        want = jnp.all(lag <= cfg.effective_staleness, axis=1)
    else:
        sampled, valid = sample_steps_jax(key, steps, cfg.beta)
        want = cfg.make_barrier().can_pass_jax(steps, sampled, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_spmd_duration_pinned_to_step_duration():
    """The trainer's straggler model is the shared step_duration formula."""
    cfg = PSPConfig(n_workers=8, compute_jitter=0.4, straggler_frac=0.25,
                    straggler_slowdown=4.0)
    key = jax.random.PRNGKey(5)
    slow = jnp.arange(8) < 2
    got = spmd_psp._duration(cfg, key, slow)
    base = cfg.base_compute * jnp.where(slow, cfg.straggler_slowdown, 1.0)
    want = bk.step_duration(jax.random.uniform(key, (8,)), base,
                            cfg.compute_jitter)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # straggler slowdown lands where assigned
    assert float(got[:2].min()) > float(got[2:].max())


def test_sweep_decide_uses_same_functions():
    """The sweep tick's full-view/sampled predicates are these functions,
    evaluated batched with alive masks — check against numpy oracles."""
    rng = np.random.default_rng(6)
    B, P, k = 3, 10, 3
    steps = jnp.asarray(rng.integers(0, 8, (B, P)), jnp.int32)
    alive = jnp.asarray(rng.random((B, P)) < 0.8)
    stal = jnp.asarray(np.full((B, P), 2), jnp.int32)
    fv = bk.full_view_allowed(steps, stal, alive)
    m = np.where(np.asarray(alive), np.asarray(steps), np.iinfo(np.int32).max)
    want_fv = np.asarray(steps) - m.min(axis=1, keepdims=True) <= 2
    np.testing.assert_array_equal(np.asarray(fv), want_fv)

    scores = jax.random.uniform(jax.random.PRNGKey(7), (B, P, P))
    ok, n_samp = bk.sampled_allowed(steps, stal, k, scores=scores,
                                    alive=alive)
    # oracle: top-k smallest scores over alive non-self peers
    sc = np.asarray(scores).copy()
    al = np.asarray(alive)
    st = np.asarray(steps)
    for b in range(B):
        sc[b][:, ~al[b]] = 2.0
        np.fill_diagonal(sc[b], 2.0)
    order = np.argsort(sc, axis=-1, kind="stable")[..., :k]
    valid = np.take_along_axis(sc, order, axis=-1) < 1.5
    peer = np.take_along_axis(np.broadcast_to(st[:, None, :], (B, P, P)),
                              order, axis=-1)
    want_ok = np.all((st[..., None] - peer <= 2) | ~valid, axis=-1)
    np.testing.assert_array_equal(np.asarray(ok), want_ok)
    np.testing.assert_array_equal(np.asarray(n_samp), valid.sum(-1))


def test_barrier_kernel_beta_zero_degenerates_to_asp():
    """S = ∅ (β = 0 or single worker) must always pass — Eq. 5's limit."""
    kern = bk.BarrierKernel(barrier="pssp", staleness=0, beta=0)
    steps = jnp.asarray([5, 0, 9], jnp.int32)
    assert bool(jnp.all(kern.allowed(jax.random.PRNGKey(0), steps)))
    one = bk.BarrierKernel(barrier="pbsp", staleness=0, beta=4)
    assert bool(jnp.all(one.allowed(jax.random.PRNGKey(0),
                                    jnp.asarray([3], jnp.int32))))


# --------------------------------------------------------------------------- #
# BarrierPolicy: the stateful decision layer over the kernel
# --------------------------------------------------------------------------- #
ADAPTIVE = ("dssp", "ebsp", "apbsp", "apssp")


@pytest.mark.parametrize("barrier", FIVE)
def test_static_policy_decide_is_kernel_allowed(barrier):
    """Static names wrap the kernel: decide ≡ allowed, state untouched."""
    pol = bk.make_policy(barrier, staleness=2, beta=2)
    assert not pol.stateful
    assert pol.init(8) == {}
    key, steps = jax.random.PRNGKey(3), _steps(3)
    carried = {"denom": jnp.float32(8.0)}        # foreign keys ride along
    allowed, new_state = pol.decide(carried, key, steps,
                                    jnp.ones(8, jnp.float32))
    want = pol.kernel.allowed(key, steps)
    np.testing.assert_array_equal(np.asarray(allowed), np.asarray(want))
    assert new_state is carried


@pytest.mark.parametrize("name", ADAPTIVE)
def test_adaptive_policy_state_roundtrip(name):
    """init → decide chains keep the state pytree's structure/dtypes and
    pass foreign keys (the trainer's ``denom``) through untouched."""
    pol = bk.make_policy(name, staleness=3, beta=3, staleness_lo=1,
                         beta_lo=1)
    assert pol.stateful
    state = dict(pol.init(8), denom=jnp.float32(5.0))
    ref_struct = jax.tree.map(lambda x: (jnp.shape(x), jnp.asarray(x).dtype),
                              state)
    key = jax.random.PRNGKey(0)
    for i in range(4):
        allowed, state = pol.decide(state, jax.random.fold_in(key, i),
                                    _steps(i, hi=5),
                                    jnp.ones(8, jnp.float32) * (i + 1))
        assert allowed.shape == (8,) and allowed.dtype == bool
        got = jax.tree.map(lambda x: (jnp.shape(x), jnp.asarray(x).dtype),
                           state)
        assert got == ref_struct
        assert float(state["denom"]) == 5.0


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dssp_pinned_range_reduces_to_ssp(seed):
    """lo == hi pins the threshold: DSSP ≡ SSP bit-for-bit."""
    dssp = bk.make_policy("dssp", staleness=2, staleness_lo=2)
    ssp = bk.make_policy("ssp", staleness=2)
    state = dssp.init(8)
    key = jax.random.PRNGKey(seed)
    for i in range(5):
        steps = _steps(seed * 10 + i, hi=5)
        a, state = dssp.decide(state, key, steps)
        b, _ = ssp.decide({}, key, steps)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("seed", [0, 1])
def test_ebsp_zero_advance_reduces_to_bsp(seed):
    """max_advance == 0 schedules a barrier every step: ≡ BSP."""
    ebsp = bk.make_policy("ebsp", max_advance=0)
    bsp = bk.make_policy("bsp")
    state = ebsp.init(8)
    key = jax.random.PRNGKey(seed)
    for i in range(5):
        steps = _steps(seed * 10 + i, hi=3)
        dur = jnp.abs(jnp.sin(jnp.arange(8.0) + i))
        a, state = ebsp.decide(state, key, steps, dur)
        b, _ = bsp.decide({}, key, steps)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["apbsp", "apssp"])
@pytest.mark.parametrize("seed", [0, 1])
def test_anneal_pinned_beta_reduces_to_static_parent(name, seed):
    """β_min == β_max freezes the sample size: ≡ pBSP/pSSP (same key
    stream — the annealed sample routes through the same primitive)."""
    s = 2 if name == "apssp" else 0
    anneal = bk.make_policy(name, staleness=s, beta=3, beta_lo=3)
    parent = bk.make_policy(name[1:], staleness=s, beta=3)
    state = anneal.init(8)
    for i in range(5):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        steps = _steps(seed * 10 + i, hi=6)
        a, state = anneal.decide(state, key, steps)
        b, _ = parent.decide({}, key, steps)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dssp_threshold_tracks_observed_gap():
    """The carried threshold is last tick's alive spread, clipped."""
    pol = bk.make_policy("dssp", staleness=4, staleness_lo=1)
    state = pol.init(4)
    assert int(state["thr"]) == 4
    steps = jnp.asarray([0, 2, 2, 9], jnp.int32)
    alive = jnp.asarray([True, True, True, False])
    _, state = pol.decide(state, jax.random.PRNGKey(0), steps, alive=alive)
    assert int(state["thr"]) == 2          # departed outlier masked out
    _, state = pol.decide(state, jax.random.PRNGKey(0),
                          jnp.zeros(4, jnp.int32))
    assert int(state["thr"]) == 1          # clipped up to lo


def test_ebsp_slack_rewards_fast_workers():
    """Faster-than-slowest workers earn slack; the slowest earns none."""
    ema = jnp.asarray([1.0, 0.5, 0.25, 1.0], jnp.float32)
    slack = bk.elastic_slack(ema, 4.0, None)
    assert slack.tolist() == [0, 2, 3, 0]
    # a departed slowest worker stops defining the denominator
    alive = jnp.asarray([False, True, True, True])
    slack = bk.elastic_slack(ema, 4.0, alive)
    assert slack.tolist()[1:] == [2, 3, 0]


def test_anneal_beta_rises_with_spread_and_clips():
    """β grows one per step of spread beyond s, clipped into [lo, hi]."""
    pol = bk.make_policy("apssp", staleness=2, beta=4, beta_lo=1)
    state = pol.init(8)
    assert int(state["beta"]) == 1
    _, state = pol.decide(state, jax.random.PRNGKey(0),
                          jnp.asarray([0, 0, 0, 0, 0, 0, 0, 8], jnp.int32))
    assert int(state["beta"]) == 4         # 1 + 8 − 2 = 7 → clip hi (β=4)
    _, state = pol.decide(state, jax.random.PRNGKey(0),
                          jnp.zeros(8, jnp.int32))
    assert int(state["beta"]) == 1         # gap 0 → clip lo


def test_make_policy_rejects_unknown_names():
    with pytest.raises(ValueError, match="unknown barrier policy"):
        bk.make_policy("gossip")
