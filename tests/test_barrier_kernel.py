"""Pins the SPMD trainer to the shared BarrierKernel (no silent drift).

The unified barrier/straggler model (:mod:`repro.core.barrier_kernel`) is
the single jnp source for "may a worker advance" and "how long does a step
take".  These tests pin (a) ``spmd_psp``'s decisions to the
``BarrierKernel`` outputs, same seed → same pass/block pattern, (b) the
``BarrierKernel`` itself to a paper-semantics oracle built from the raw
sampling primitive + ``can_pass_jax``, and (c) the sweep engine's
reference decide path to the same functions — so the trainer and the
simulator cannot diverge again without a test going red.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import barrier_kernel as bk
from repro.core import spmd_psp
from repro.core.sampling import sample_steps_jax
from repro.core.spmd_psp import PSPConfig

FIVE = ("bsp", "ssp", "asp", "pbsp", "pssp")


def _steps(seed, w=8, hi=9):
    return jnp.asarray(
        np.random.default_rng(seed).integers(0, hi, w), jnp.int32)


@pytest.mark.parametrize("barrier", FIVE)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_spmd_decisions_pinned_to_barrier_kernel(barrier, seed):
    """Same seed ⇒ the trainer's pass/block pattern IS the kernel's."""
    cfg = PSPConfig(barrier=barrier, n_workers=8, staleness=2, sample_size=2)
    key = jax.random.PRNGKey(seed)
    steps = _steps(seed)
    got = spmd_psp._barrier_allowed(cfg, key, steps)
    want = cfg.barrier_kernel.allowed(key, steps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # deterministic: same seed twice → same pattern
    again = spmd_psp._barrier_allowed(cfg, key, steps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(again))


@pytest.mark.parametrize("barrier", FIVE)
@pytest.mark.parametrize("seed", [3, 4])
def test_barrier_kernel_matches_paper_oracle(barrier, seed):
    """BarrierKernel ≡ the §6.4 oracle (sampling primitive + can_pass_jax)."""
    cfg = PSPConfig(barrier=barrier, n_workers=8, staleness=2, sample_size=2)
    key = jax.random.PRNGKey(seed)
    steps = _steps(seed + 10)
    got = cfg.barrier_kernel.allowed(key, steps)
    if cfg.is_asp:
        want = jnp.ones_like(steps, dtype=bool)
    elif cfg.is_classic:
        lag = steps[:, None] - steps[None, :]
        want = jnp.all(lag <= cfg.effective_staleness, axis=1)
    else:
        sampled, valid = sample_steps_jax(key, steps, cfg.beta)
        want = cfg.make_barrier().can_pass_jax(steps, sampled, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_spmd_duration_pinned_to_step_duration():
    """The trainer's straggler model is the shared step_duration formula."""
    cfg = PSPConfig(n_workers=8, compute_jitter=0.4, straggler_frac=0.25,
                    straggler_slowdown=4.0)
    key = jax.random.PRNGKey(5)
    slow = jnp.arange(8) < 2
    got = spmd_psp._duration(cfg, key, slow)
    base = cfg.base_compute * jnp.where(slow, cfg.straggler_slowdown, 1.0)
    want = bk.step_duration(jax.random.uniform(key, (8,)), base,
                            cfg.compute_jitter)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # straggler slowdown lands where assigned
    assert float(got[:2].min()) > float(got[2:].max())


def test_sweep_decide_uses_same_functions():
    """The sweep tick's full-view/sampled predicates are these functions,
    evaluated batched with alive masks — check against numpy oracles."""
    rng = np.random.default_rng(6)
    B, P, k = 3, 10, 3
    steps = jnp.asarray(rng.integers(0, 8, (B, P)), jnp.int32)
    alive = jnp.asarray(rng.random((B, P)) < 0.8)
    stal = jnp.asarray(np.full((B, P), 2), jnp.int32)
    fv = bk.full_view_allowed(steps, stal, alive)
    m = np.where(np.asarray(alive), np.asarray(steps), np.iinfo(np.int32).max)
    want_fv = np.asarray(steps) - m.min(axis=1, keepdims=True) <= 2
    np.testing.assert_array_equal(np.asarray(fv), want_fv)

    scores = jax.random.uniform(jax.random.PRNGKey(7), (B, P, P))
    ok, n_samp = bk.sampled_allowed(steps, stal, k, scores=scores,
                                    alive=alive)
    # oracle: top-k smallest scores over alive non-self peers
    sc = np.asarray(scores).copy()
    al = np.asarray(alive)
    st = np.asarray(steps)
    for b in range(B):
        sc[b][:, ~al[b]] = 2.0
        np.fill_diagonal(sc[b], 2.0)
    order = np.argsort(sc, axis=-1, kind="stable")[..., :k]
    valid = np.take_along_axis(sc, order, axis=-1) < 1.5
    peer = np.take_along_axis(np.broadcast_to(st[:, None, :], (B, P, P)),
                              order, axis=-1)
    want_ok = np.all((st[..., None] - peer <= 2) | ~valid, axis=-1)
    np.testing.assert_array_equal(np.asarray(ok), want_ok)
    np.testing.assert_array_equal(np.asarray(n_samp), valid.sum(-1))


def test_barrier_kernel_beta_zero_degenerates_to_asp():
    """S = ∅ (β = 0 or single worker) must always pass — Eq. 5's limit."""
    kern = bk.BarrierKernel(barrier="pssp", staleness=0, beta=0)
    steps = jnp.asarray([5, 0, 9], jnp.int32)
    assert bool(jnp.all(kern.allowed(jax.random.PRNGKey(0), steps)))
    one = bk.BarrierKernel(barrier="pbsp", staleness=0, beta=4)
    assert bool(jnp.all(one.allowed(jax.random.PRNGKey(0),
                                    jnp.asarray([3], jnp.int32))))
