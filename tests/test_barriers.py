"""Barrier predicate semantics (paper §6.1, Algorithms 1–2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.barriers import (ASP, BSP, PBSP, PSSP, SSP, make_barrier)


def rng():
    return np.random.default_rng(0)


class TestClassic:
    def test_bsp_blocks_leader(self):
        # a worker ahead of anyone may not advance
        assert not BSP().can_pass(3, [3, 3, 2], rng())
        assert BSP().can_pass(3, [3, 3, 3], rng())

    def test_bsp_is_ssp_zero(self):
        steps = [5, 5, 4]
        assert BSP().can_pass(5, steps, rng()) == \
            SSP(staleness=0).can_pass(5, steps, rng())

    def test_ssp_staleness_window(self):
        s = SSP(staleness=4)
        assert s.can_pass(6, [2, 6, 6], rng())       # lag 4 ≤ 4
        assert not s.can_pass(7, [2, 6, 6], rng())   # lag 5 > 4

    def test_asp_always_passes(self):
        assert ASP().can_pass(100, [0, 0, 0], rng())


class TestProbabilistic:
    def test_pbsp_full_sample_equals_bsp(self):
        steps = list(range(10))
        b = PBSP(sample_size=10)
        for my in (0, 5, 9):
            assert b.can_pass(my, steps, rng()) == \
                BSP().can_pass(my, steps, rng())

    def test_sample_size_zero_is_asp(self):
        b = PBSP(sample_size=0)
        assert b.can_pass(99, [0] * 8, rng())

    def test_pssp_generalises(self):
        # pSSP with S=V, s=0 reduces to BSP (paper §6.1)
        steps = [4, 4, 5]
        b = PSSP(staleness=0, sample_size=3)
        assert b.can_pass(4, steps, rng()) == BSP().can_pass(4, steps, rng())

    def test_sampling_probabilistic_pass(self):
        # one straggler among 100: a β=1 sample should often miss it
        steps = [0] + [10] * 99
        b = PBSP(sample_size=1)
        r = np.random.default_rng(1)
        passes = sum(b.can_pass(10, steps, r) for _ in range(200))
        assert 150 < passes < 200   # ~99% pass rate


class TestJaxPath:
    def test_can_pass_jax_matches_python(self):
        b = PSSP(staleness=2, sample_size=3)
        my = jnp.asarray([5, 3])
        sampled = jnp.asarray([[3, 4, 5], [5, 5, 5]])
        out = b.can_pass_jax(my, sampled)
        assert out.tolist() == [True, True]
        out2 = b.can_pass_jax(jnp.asarray([7]), jnp.asarray([[3, 4, 5]]))
        assert out2.tolist() == [False]

    def test_valid_mask(self):
        b = PBSP(sample_size=4)
        my = jnp.asarray([5])
        sampled = jnp.asarray([[0, 5, 5, 5]])
        valid = jnp.asarray([[False, True, True, True]])
        assert b.can_pass_jax(my, sampled, valid).tolist() == [True]


def test_factory_staleness_only_for_ssp_family():
    assert make_barrier("bsp", staleness=7).staleness == 0
    assert make_barrier("pbsp", staleness=7, sample_size=3).staleness == 0
    assert make_barrier("ssp", staleness=7).staleness == 7
    assert make_barrier("pssp", staleness=7, sample_size=3).sample_size == 3
    with pytest.raises(ValueError):
        make_barrier("nope")
