"""Theorem 2 / Eq. 54 / Eq. 55 — theory code vs paper structure & simulation."""
import numpy as np
import pytest

from repro.core.bounds import (asp_regret_constants, empirical_lag_distribution,
                               mean_lag_bound, psp_alpha, psp_lag_pmf,
                               psp_regret_constants, regret_tail_bound,
                               variance_lag_bound)


def uniform_f(T, width=10):
    f = np.zeros(T + 1)
    f[: width] = 1.0 / width
    return f


class TestTheorem2:
    def test_pmf_normalised(self):
        p = psp_lag_pmf(uniform_f(100), beta=4, r=4, T=100)
        assert abs(p.sum() - 1.0) < 1e-9

    def test_geometric_tail(self):
        f = uniform_f(100)
        p = psp_lag_pmf(f, beta=4, r=4, T=100)
        F_r = f[:5].sum()
        a = F_r ** 4
        # tail decays geometrically with ratio a (paper: p(s) ∝ a^{s−r})
        ratio = p[20] / p[19]
        assert abs(ratio - a) < 1e-6

    def test_bigger_beta_tighter_tail(self):
        f = uniform_f(100)
        p1 = psp_lag_pmf(f, beta=1, r=4, T=100)
        p8 = psp_lag_pmf(f, beta=8, r=4, T=100)
        assert p8[30] < p1[30]

    def test_alpha_exact_normalisation(self):
        # α · ( F(r) + Σ_{s=1}^{T−r} a^s ) = 1 (exact Eq. 41–42 form).
        # Note: the paper's Eq. 20 lower bound 1/(F(r)+F(r)^β) drops the
        # geometric 1/(1−a) factor and is slightly loose; we implement the
        # exact normaliser.
        F_r, beta, T, r = 0.5, 4, 1000, 4
        a_geom = F_r ** beta
        alpha = psp_alpha(F_r, beta, T, r)
        tail = a_geom * (1 - a_geom ** (T - r)) / (1 - a_geom)
        assert abs(alpha * (F_r + tail) - 1.0) < 1e-9
        # and it is within the (loose) paper bound's neighbourhood
        assert alpha >= 0.95 / (F_r + F_r ** beta)


class TestBounds:
    def test_mean_bound_decreases_with_beta_at_fixed_a(self):
        # Fig 4 axes: fixed a = F(r)^β, per-curve F(r) = a^{1/β}; larger β
        # (sampling count) gives a tighter bound
        a = 0.5
        vals = [mean_lag_bound(a ** (1 / b), b, r=4, T=10_000)
                for b in (1, 5, 100)]
        assert vals[0] > vals[1] > vals[2]

    def test_variance_bound_decreases_with_beta_at_fixed_a(self):
        a = 0.5
        vals = [variance_lag_bound(a ** (1 / b), b, r=4, T=10_000)
                for b in (1, 5, 100)]
        assert vals[0] > vals[1] > vals[2]

    def test_small_beta_near_optimal(self):
        # paper: "a small sample size can effectively push the probabilistic
        # convergence guarantee to its optimum"
        a = 0.5
        b5 = mean_lag_bound(a ** (1 / 5), 5, r=4, T=10_000)
        b100 = mean_lag_bound(a ** (1 / 100), 100, r=4, T=10_000)
        assert b5 < 1.5 * b100 + 1.0

    def test_a_equals_one_diverges(self):
        # β=0 → a=1 → O(T) mean bound: no convergence (paper §6.4 end)
        m = mean_lag_bound(1.0, 0, r=4, T=10_000)
        assert m > 1000     # O(T)
        v = variance_lag_bound(1.0, 0, r=4, T=10_000)
        assert v > 1e6      # O(T²)

    def test_psp_beats_asp_for_heavy_tail(self):
        # §7.2: PSP's q is independent of the lag-distribution mean; ASP's
        # q = 4PσLμ deteriorates with heavy tails
        P, sigma, L, T = 100, 1.0, 1.0, 10_000
        heavy_mu, heavy_phi = 500.0, 50_000.0     # heavy-tailed lags
        asp = asp_regret_constants(P, sigma, L, heavy_mu, heavy_phi, T)
        psp = psp_regret_constants(P, sigma, L, F_r=0.5, beta=16, r=4, T=T)
        assert psp.q < asp.q
        assert regret_tail_bound(psp, T, delta=1.0) <= \
            regret_tail_bound(asp, T, delta=1.0) + 1e-12


class TestEmpirical:
    def test_simulator_lags_match_theory_shape(self):
        """pBSP-simulated lag histogram has a geometric-ish tail."""
        from repro.core.barriers import PBSP
        from repro.core.simulator import SimConfig, run_simulation
        res = run_simulation(SimConfig(n_nodes=200, duration=20.0, dim=16,
                                       barrier=PBSP(sample_size=2), seed=7))
        pmf = empirical_lag_distribution(res.steps)
        # mass concentrated near zero lag (tight synchronisation)
        assert pmf[:3].sum() > 0.5
