"""Unit tests for the benchmark-regression gate (tools/check_bench.py).

The gate compares ``speedup_vs_event`` per engine row between a fresh
sweep run and the committed baseline.  The asymmetry under test: a row
missing from the *fresh* run is a failure (a silently dropped benchmark
must not pass), while a row missing from the *baseline* only is skipped
— it was added by a PR newer than the committed ``BENCH_sweep.json`` and
starts being gated once the baseline is regenerated.
"""
from __future__ import annotations

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_bench  # noqa: E402


def _row(speedup, **extra):
    return {"speedup_vs_event": speedup, "seconds": 1.0, **extra}


BASELINE = {
    "event": {"seconds": 10.0},
    "numpy": _row(8.0),
    "jax": _row(30.0, n_devices=1),
}
GATE = [("numpy", 0.25), ("jax", 0.25)]


class TestCheck:
    def test_within_tolerance_passes(self):
        fresh = {"numpy": _row(7.0), "jax": _row(28.0, n_devices=1)}
        assert check_bench.check(BASELINE, fresh, GATE) == []

    def test_regression_fails(self):
        fresh = {"numpy": _row(2.0), "jax": _row(28.0, n_devices=1)}
        failures = check_bench.check(BASELINE, fresh, GATE)
        assert len(failures) == 1
        assert "numpy" in failures[0] and "FAIL" in failures[0]

    def test_row_missing_from_fresh_fails(self):
        # a gated engine silently dropped from the fresh run = failure
        fresh = {"numpy": _row(8.0)}
        failures = check_bench.check(BASELINE, fresh, GATE)
        assert len(failures) == 1
        assert "jax" in failures[0] and "fresh" in failures[0]

    def test_row_missing_from_baseline_skips(self, capsys):
        # the fresh run carries a row the committed baseline predates
        # (e.g. this PR's adaptive-policy benchmark additions): the gate
        # must note-and-skip it, not fail
        fresh = {"numpy": _row(8.0), "jax": _row(30.0, n_devices=1),
                 "pallas": _row(12.0)}
        gate = GATE + [("pallas", 0.45)]
        assert check_bench.check(BASELINE, fresh, gate) == []
        out = capsys.readouterr().out
        assert "skip pallas" in out
        assert "baseline" in out

    def test_missing_metric_fails(self):
        fresh = {"numpy": {"seconds": 1.0}, "jax": _row(30.0, n_devices=1)}
        failures = check_bench.check(BASELINE, fresh, GATE)
        assert len(failures) == 1
        assert "numpy" in failures[0]

    def test_mesh_mismatch_warns_but_does_not_fail(self, capsys):
        fresh = {"numpy": _row(8.0), "jax": _row(30.0, n_devices=8)}
        assert check_bench.check(BASELINE, fresh, GATE) == []
        assert "mesh size differs" in capsys.readouterr().out


class TestParseEngines:
    def test_bare_names_take_defaults(self):
        got = check_bench.parse_engines("numpy,jax,pallas", 0.25)
        assert got == [("numpy", 0.25), ("jax", 0.25), ("pallas", 0.45)]

    def test_explicit_tolerance_wins(self):
        got = check_bench.parse_engines("numpy:0.1,pallas:0.9", 0.25)
        assert got == [("numpy", 0.1), ("pallas", 0.9)]


class TestMain:
    def _dump(self, tmp_path, name, engines):
        path = tmp_path / name
        path.write_text(json.dumps({"engines": engines}))
        return str(path)

    def test_cli_new_row_in_fresh_passes(self, tmp_path):
        base = self._dump(tmp_path, "base.json",
                          {"numpy": _row(8.0), "jax": _row(30.0)})
        fresh = self._dump(tmp_path, "fresh.json",
                           {"numpy": _row(8.0), "jax": _row(30.0),
                            "pallas": _row(12.0)})
        assert check_bench.main(["--baseline", base, "--fresh", fresh]) == 0

    def test_cli_regression_exits_nonzero(self, tmp_path):
        base = self._dump(tmp_path, "base.json", {"numpy": _row(8.0)})
        fresh = self._dump(tmp_path, "fresh.json", {"numpy": _row(1.0)})
        assert check_bench.main(["--baseline", base, "--fresh", fresh,
                                 "--engines", "numpy"]) == 1

    def test_cli_rejects_non_sweep_json(self, tmp_path):
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"not_engines": {}}))
        with pytest.raises(ValueError):
            check_bench.main(["--baseline", str(bad),
                              "--fresh", str(bad)])
