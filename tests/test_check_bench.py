"""Unit tests for the benchmark-regression gate (tools/check_bench.py).

The gate compares ``speedup_vs_event`` per engine row between a fresh
sweep run and the committed baseline.  The asymmetry under test: a row
missing from the *fresh* run is a failure (a silently dropped benchmark
must not pass), while a row missing from the *baseline* only is skipped
— it was added by a PR newer than the committed ``BENCH_sweep.json`` and
starts being gated once the baseline is regenerated.

Jax-family rows additionally carry 2-D mesh metadata (``mesh`` /
``mesh_axes`` / ``n_devices``): missing or incoherent metadata fails,
and when baseline and fresh ran different device counts the gated
metric is compared per device.
"""
from __future__ import annotations

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_bench  # noqa: E402


def _row(speedup, **extra):
    return {"speedup_vs_event": speedup, "seconds": 1.0, **extra}


def _jrow(speedup, rows=1, nodes=1, **extra):
    """A jax-family row with coherent 2-D mesh metadata."""
    return _row(speedup, n_devices=rows * nodes, mesh=[rows, nodes],
                mesh_axes={"rows": rows, "nodes": nodes}, **extra)


BASELINE = {
    "event": {"seconds": 10.0},
    "numpy": _row(8.0),
    "jax": _jrow(30.0),
}
GATE = [("numpy", 0.25), ("jax", 0.25)]


class TestCheck:
    def test_within_tolerance_passes(self):
        fresh = {"numpy": _row(7.0), "jax": _jrow(28.0)}
        assert check_bench.check(BASELINE, fresh, GATE) == []

    def test_regression_fails(self):
        fresh = {"numpy": _row(2.0), "jax": _jrow(28.0)}
        failures = check_bench.check(BASELINE, fresh, GATE)
        assert len(failures) == 1
        assert "numpy" in failures[0] and "FAIL" in failures[0]

    def test_row_missing_from_fresh_fails(self):
        # a gated engine silently dropped from the fresh run = failure
        fresh = {"numpy": _row(8.0)}
        failures = check_bench.check(BASELINE, fresh, GATE)
        assert len(failures) == 1
        assert "jax" in failures[0] and "fresh" in failures[0]

    def test_row_missing_from_baseline_skips(self, capsys):
        # the fresh run carries a row the committed baseline predates
        # (e.g. this PR's adaptive-policy benchmark additions): the gate
        # must note-and-skip it, not fail
        fresh = {"numpy": _row(8.0), "jax": _jrow(30.0),
                 "pallas": _jrow(12.0)}
        gate = GATE + [("pallas", 0.45)]
        assert check_bench.check(BASELINE, fresh, gate) == []
        out = capsys.readouterr().out
        assert "skip pallas" in out
        assert "baseline" in out

    def test_missing_metric_fails(self):
        fresh = {"numpy": {"seconds": 1.0}, "jax": _jrow(30.0)}
        failures = check_bench.check(BASELINE, fresh, GATE)
        assert len(failures) == 1
        assert "numpy" in failures[0]


class TestMesh2D:
    """The 2-D mesh-metadata contract on jax-family rows."""

    def test_missing_mesh_metadata_fails(self):
        fresh = {"numpy": _row(8.0), "jax": _row(30.0, n_devices=1)}
        failures = check_bench.check(BASELINE, fresh, GATE)
        assert len(failures) == 1
        assert "jax" in failures[0] and "mesh" in failures[0]

    def test_incoherent_mesh_axes_fails(self):
        row = _jrow(30.0, rows=4, nodes=2)
        row["mesh_axes"] = {"rows": 2, "nodes": 4}     # transposed
        fresh = {"numpy": _row(8.0), "jax": row}
        failures = check_bench.check(BASELINE, fresh, GATE)
        assert len(failures) == 1
        assert "mesh_axes" in failures[0]

    def test_device_count_mesh_product_mismatch_fails(self):
        row = _jrow(30.0, rows=4, nodes=2)
        row["n_devices"] = 4                           # lies about the mesh
        fresh = {"numpy": _row(8.0), "jax": row}
        failures = check_bench.check(BASELINE, fresh, GATE)
        assert len(failures) == 1
        assert "n_devices" in failures[0]

    def test_numpy_rows_need_no_mesh(self):
        # only jax-family rows carry a mesh; numpy stays schema-stable
        fresh = {"numpy": _row(8.0), "jax": _jrow(28.0)}
        assert check_bench.check(BASELINE, fresh, GATE) == []

    def test_differing_device_counts_compare_per_device(self, capsys):
        # fresh ran an 8-device 2-D mesh vs the 1-device baseline: raw
        # speedup 8× higher but identical per device → ok, with a note
        fresh = {"numpy": _row(8.0), "jax": _jrow(240.0, rows=4, nodes=2)}
        assert check_bench.check(BASELINE, fresh, GATE) == []
        assert "per-device" in capsys.readouterr().out

    def test_bigger_fresh_mesh_cannot_mask_a_regression(self):
        # raw 80 > baseline 30, but per device it's 10 vs 30 → FAIL
        fresh = {"numpy": _row(8.0), "jax": _jrow(80.0, rows=8, nodes=1)}
        failures = check_bench.check(BASELINE, fresh, GATE)
        assert len(failures) == 1
        assert "jax" in failures[0] and "per-device" in failures[0]

    def test_100k_row_gated_on_per_device_node_steps(self):
        base = dict(BASELINE)
        base["jax_100k"] = _jrow(None, rows=1, nodes=1,
                                 node_steps_per_device_sec=1000.0)
        gate = GATE + [("jax_100k", 0.6)]
        # per-device metric: no renorm across device counts — 500/dev on
        # an 8-device mesh is a genuine 2× per-device drop (within 60%)
        ok = {"numpy": _row(8.0), "jax": _jrow(30.0),
              "jax_100k": _jrow(None, rows=1, nodes=8,
                                node_steps_per_device_sec=500.0)}
        assert check_bench.check(base, ok, gate) == []
        bad = dict(ok)
        bad["jax_100k"] = _jrow(None, rows=1, nodes=8,
                                node_steps_per_device_sec=100.0)
        failures = check_bench.check(base, bad, gate)
        assert len(failures) == 1
        assert "jax_100k" in failures[0]
        assert "node_steps_per_device_sec" in failures[0]

    def test_mesh_only_skips_throughput_floor(self):
        # the CI factorization matrix forces N host devices onto one
        # CPU: per-device throughput drops ~Nx by construction, so the
        # lane gates metadata coherence only — a heavy raw regression
        # passes, but missing mesh metadata still fails
        slow = {"numpy": _row(8.0), "jax": _jrow(1.0, rows=4, nodes=2)}
        assert check_bench.check(BASELINE, slow, GATE, mesh_only=True) == []
        bare = {"numpy": _row(8.0), "jax": _row(1.0, n_devices=8)}
        failures = check_bench.check(BASELINE, bare, GATE, mesh_only=True)
        assert len(failures) == 1
        assert "mesh" in failures[0]

    def test_mesh_only_still_fails_on_missing_row(self):
        fresh = {"numpy": _row(8.0)}
        failures = check_bench.check(BASELINE, fresh, GATE, mesh_only=True)
        assert len(failures) == 1
        assert "jax" in failures[0] and "fresh" in failures[0]

    def test_100k_row_missing_mesh_fails(self):
        base = dict(BASELINE)
        base["jax_100k"] = _jrow(None, node_steps_per_device_sec=1000.0)
        fresh = {"numpy": _row(8.0), "jax": _jrow(30.0),
                 "jax_100k": _row(None, n_devices=8,
                                  node_steps_per_device_sec=900.0)}
        failures = check_bench.check(base, fresh,
                                     GATE + [("jax_100k", 0.6)])
        assert len(failures) == 1
        assert "jax_100k" in failures[0] and "mesh" in failures[0]


class TestParseEngines:
    def test_bare_names_take_defaults(self):
        got = check_bench.parse_engines("numpy,jax,pallas,jax_100k", 0.25)
        assert got == [("numpy", 0.25), ("jax", 0.25), ("pallas", 0.45),
                       ("jax_100k", 0.6)]

    def test_explicit_tolerance_wins(self):
        got = check_bench.parse_engines("numpy:0.1,pallas:0.9", 0.25)
        assert got == [("numpy", 0.1), ("pallas", 0.9)]


class TestMain:
    def _dump(self, tmp_path, name, engines):
        path = tmp_path / name
        path.write_text(json.dumps({"engines": engines}))
        return str(path)

    def test_cli_new_row_in_fresh_passes(self, tmp_path):
        base = self._dump(tmp_path, "base.json",
                          {"numpy": _row(8.0), "jax": _jrow(30.0)})
        fresh = self._dump(tmp_path, "fresh.json",
                           {"numpy": _row(8.0), "jax": _jrow(30.0),
                            "pallas": _jrow(12.0),
                            "jax_100k": _jrow(
                                None, node_steps_per_device_sec=1000.0)})
        assert check_bench.main(["--baseline", base, "--fresh", fresh]) == 0

    def test_cli_mesh_only_flag(self, tmp_path):
        base = self._dump(tmp_path, "base.json",
                          {"numpy": _row(8.0), "jax": _jrow(30.0)})
        fresh = self._dump(tmp_path, "fresh.json",
                           {"numpy": _row(8.0),
                            "jax": _jrow(2.0, rows=4, nodes=2)})
        assert check_bench.main(["--baseline", base, "--fresh", fresh,
                                 "--engines", "numpy,jax",
                                 "--mesh-only"]) == 0
        assert check_bench.main(["--baseline", base, "--fresh", fresh,
                                 "--engines", "numpy,jax"]) == 1

    def test_cli_regression_exits_nonzero(self, tmp_path):
        base = self._dump(tmp_path, "base.json", {"numpy": _row(8.0)})
        fresh = self._dump(tmp_path, "fresh.json", {"numpy": _row(1.0)})
        assert check_bench.main(["--baseline", base, "--fresh", fresh,
                                 "--engines", "numpy"]) == 1

    def test_cli_rejects_non_sweep_json(self, tmp_path):
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"not_engines": {}}))
        with pytest.raises(ValueError):
            check_bench.main(["--baseline", str(bad),
                              "--fresh", str(bad)])


def _serve(tokens_per_s=40.0, swaps=2, dropped=0, versions=(0, 1),
           **extra):
    """A serve_bench-schema result at the canonical load shape."""
    return {"requests": 32, "rate_rps": 4.0, "batch": 4,
            "max_new_tokens": 16, "tokens_per_s": tokens_per_s,
            "swaps": swaps, "dropped": dropped,
            "versions_served": list(versions),
            "swap_stall_s": {"max": 0.03}, **extra}


class TestServeGate:
    """The serving-tier gate: swap/drop invariants always, the
    throughput floor only at the baseline's load shape."""

    def test_healthy_run_passes(self):
        assert check_bench.check_serve(_serve(), _serve()) == []

    def test_single_swap_fails(self):
        fails = check_bench.check_serve(_serve(), _serve(swaps=1))
        assert any("swap" in f for f in fails)

    def test_dropped_request_fails(self):
        fails = check_bench.check_serve(_serve(), _serve(dropped=3))
        assert any("dropped" in f for f in fails)

    def test_single_version_fails(self):
        # two swaps but all completed traffic on one version: the run
        # never actually served across a swap boundary
        fails = check_bench.check_serve(_serve(), _serve(versions=(0,)))
        assert any("versions" in f for f in fails)

    def test_throughput_floor_at_matched_scale(self):
        fails = check_bench.check_serve(_serve(tokens_per_s=100.0),
                                        _serve(tokens_per_s=10.0))
        assert any("tokens_per_s" in f for f in fails)
        assert check_bench.check_serve(
            _serve(tokens_per_s=100.0), _serve(tokens_per_s=50.0)) == []

    def test_smoke_scale_skips_floor_not_invariants(self):
        smoke = _serve(tokens_per_s=1.0, requests=9, rate_rps=16.0)
        assert check_bench.check_serve(_serve(tokens_per_s=100.0),
                                       smoke) == []
        smoke_bad = _serve(tokens_per_s=1.0, requests=9, swaps=0)
        assert check_bench.check_serve(_serve(), smoke_bad) != []

    def test_cli_serve_mode(self, tmp_path):
        base = tmp_path / "serve_base.json"
        base.write_text(json.dumps(_serve()))
        good = tmp_path / "serve_good.json"
        good.write_text(json.dumps(_serve(tokens_per_s=35.0)))
        bad = tmp_path / "serve_bad.json"
        bad.write_text(json.dumps(_serve(dropped=1)))
        assert check_bench.main(["--serve", "--baseline", str(base),
                                 "--fresh", str(good)]) == 0
        assert check_bench.main(["--serve", "--baseline", str(base),
                                 "--fresh", str(bad)]) == 1


def _chaos(*, ratio=0.9, latency=3.5, live_restarts=0, dropped=0,
           swaps=2, restarts=1, torn=3, completed=True, **cluster_extra):
    cluster = {"workers": 3, "ticks": 30, "dim": 16, "batch": 4,
               "goodput_ratio": ratio, "recovery_latency_s": latency,
               "victims": [0], "live_restarts": live_restarts,
               "completed": completed}
    cluster.update(cluster_extra)
    return {"smoke": False, "cluster": cluster,
            "serving": {"requests": 16, "completed": 16 - dropped,
                        "dropped": dropped, "swaps": swaps,
                        "worker_restarts": restarts,
                        "publish_faults": {"torn": torn}}}


class TestChaosGate:
    """The chaos gate: recovery/zero-drop invariants always, the
    goodput/latency floors only at the baseline's cluster shape."""

    def test_healthy_run_passes(self):
        assert check_bench.check_chaos(_chaos(), _chaos()) == []

    def test_victim_never_contributed_fails(self):
        fails = check_bench.check_chaos(_chaos(), _chaos(latency=None))
        assert any("rejoined" in f for f in fails)

    def test_live_restart_fails(self):
        fails = check_bench.check_chaos(_chaos(), _chaos(live_restarts=1))
        assert any("live worker" in f for f in fails)

    def test_dropped_request_fails(self):
        fails = check_bench.check_chaos(_chaos(), _chaos(dropped=2))
        assert any("dropped" in f for f in fails)

    def test_missing_worker_recovery_fails(self):
        fails = check_bench.check_chaos(_chaos(), _chaos(restarts=0))
        assert any("decode-worker" in f for f in fails)

    def test_storm_never_fired_fails(self):
        fails = check_bench.check_chaos(_chaos(), _chaos(torn=0))
        assert any("torn" in f for f in fails)

    def test_floors_at_matched_shape(self):
        fails = check_bench.check_chaos(_chaos(ratio=0.9),
                                        _chaos(ratio=0.1))
        assert any("goodput_ratio" in f for f in fails)
        fails = check_bench.check_chaos(_chaos(latency=2.0),
                                        _chaos(latency=20.0))
        assert any("recovery_latency_s" in f for f in fails)
        assert check_bench.check_chaos(_chaos(ratio=0.9, latency=2.0),
                                       _chaos(ratio=0.6,
                                              latency=5.0)) == []

    def test_smoke_shape_skips_floors_not_invariants(self):
        smoke = _chaos(ratio=0.01, latency=99.0, ticks=24)
        assert check_bench.check_chaos(_chaos(), smoke) == []
        smoke_bad = _chaos(dropped=1, ticks=24)
        assert check_bench.check_chaos(_chaos(), smoke_bad) != []

    def test_cli_chaos_mode(self, tmp_path):
        base = tmp_path / "chaos_base.json"
        base.write_text(json.dumps(_chaos()))
        good = tmp_path / "chaos_good.json"
        good.write_text(json.dumps(_chaos(ratio=0.8)))
        bad = tmp_path / "chaos_bad.json"
        bad.write_text(json.dumps(_chaos(live_restarts=2)))
        assert check_bench.main(["--chaos", "--baseline", str(base),
                                 "--fresh", str(good)]) == 0
        assert check_bench.main(["--chaos", "--baseline", str(base),
                                 "--fresh", str(bad)]) == 1
