"""Checkpoint store/manager + kill-and-resume fault tolerance.

The kill tests SIGKILL a real training subprocess mid-run (paced by
``--throttle`` so the kill window is deterministic), resume it from the
latest async checkpoint, and require the final full training state to be
bit-for-bit identical to the uninterrupted run — the acceptance bar of
the fault-tolerance tentpole, for both the pjit path and the PSP trainer.
"""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, CheckpointPolicy,
                              latest_step, read_metadata,
                              restore_checkpoint, save_checkpoint)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# --------------------------------------------------------------------------- #
# storage format (satellites: atomic sidecar, robust discovery, real errors)
# --------------------------------------------------------------------------- #
class TestStore:
    def test_bf16_roundtrip_through_f32(self, tmp_path):
        # bf16 leaves are stored as f32 (lossless superset) and cast back
        # through jnp on restore — values and dtype must both survive
        tree = {"w": (jnp.arange(7, dtype=jnp.float32) / 3).astype(jnp.bfloat16),
                "n": {"i": jnp.arange(4, dtype=jnp.int32),
                      "b": jnp.asarray([True, False])}}
        save_checkpoint(str(tmp_path), 5, tree)
        restored, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 5
        assert restored["w"].dtype == tree["w"].dtype
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(x, np.float32),
                                  np.asarray(y, np.float32))

    def test_latest_skips_partial_and_corrupt(self, tmp_path):
        tree = {"w": jnp.ones(3)}
        save_checkpoint(str(tmp_path), 3, tree)
        # partial: npz published without its sidecar (pre-fix crash shape)
        np.savez(tmp_path / "step_00000009.npz", w=np.ones(3))
        # corrupt: sidecar exists but does not parse
        np.savez(tmp_path / "step_00000007.npz", w=np.ones(3))
        (tmp_path / "step_00000007.npz.json").write_text("{not json")
        assert latest_step(str(tmp_path)) == 3
        restored, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 3 and np.array_equal(restored["w"], tree["w"])

    def test_sidecar_lands_before_npz(self, tmp_path):
        # the npz rename is the publication point: the moment it exists,
        # its sidecar must already be valid JSON with the step recorded
        save_checkpoint(str(tmp_path), 12, {"w": jnp.zeros(2)},
                        {"note": "x"})
        meta = read_metadata(str(tmp_path), 12)
        assert meta["step"] == 12 and meta["note"] == "x"

    def test_restore_shape_mismatch_raises_valueerror(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 3))})
        with pytest.raises(ValueError, match=r"w.*\(2, 3\).*\(3, 2\)"):
            restore_checkpoint(str(tmp_path), {"w": jnp.zeros((3, 2))})

    def test_restore_missing_leaf_raises_valueerror(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(2)})
        with pytest.raises(ValueError, match="no entry.*extra"):
            restore_checkpoint(str(tmp_path), {"w": jnp.zeros(2),
                                               "extra": jnp.zeros(1)})


# --------------------------------------------------------------------------- #
# manager: policies, async writer, retention, crash hygiene
# --------------------------------------------------------------------------- #
class TestManager:
    def test_step_policy_and_retention(self, tmp_path):
        tree = {"w": jnp.arange(4.0)}
        with CheckpointManager(str(tmp_path),
                               CheckpointPolicy(every_steps=2),
                               keep=2) as mgr:
            for t in range(1, 11):
                saved = mgr.maybe_save(t, tree, {"data_step": t})
                assert saved == (t % 2 == 0)
            mgr.wait()
            files = sorted(f for f in os.listdir(tmp_path)
                           if f.endswith(".npz"))
            # GC keeps only the newest 2 of the 5 periodic saves
            assert files == ["step_00000008.npz", "step_00000010.npz"]
            assert mgr.latest_step() == 10
            assert read_metadata(str(tmp_path), 10)["data_step"] == 10

    def test_wall_clock_policy(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path),
                                CheckpointPolicy(every_seconds=0.2))
        try:
            assert not mgr.should_save(1)      # interval not yet elapsed
            time.sleep(0.25)
            assert mgr.should_save(2)
            mgr.save(2, {"w": jnp.zeros(1)}, block=True)
            assert not mgr.should_save(3)      # timer reset by the save
        finally:
            mgr.close()
        assert latest_step(str(tmp_path)) == 2

    def test_explicit_save_only_when_no_policy(self, tmp_path):
        with CheckpointManager(str(tmp_path)) as mgr:
            for t in range(1, 5):
                assert not mgr.maybe_save(t, {"w": jnp.zeros(1)})
            mgr.save(4, {"w": jnp.zeros(1)}, block=True)
        assert latest_step(str(tmp_path)) == 4

    def test_stale_tmp_and_orphan_sidecar_cleanup(self, tmp_path):
        (tmp_path / "dead123.tmp").write_bytes(b"half a checkpoint")
        (tmp_path / "step_00000005.npz.json").write_text('{"step": 5}')
        save_checkpoint(str(tmp_path), 2, {"w": jnp.zeros(1)})
        CheckpointManager(str(tmp_path)).close()
        left = sorted(os.listdir(tmp_path))
        assert left == ["step_00000002.npz", "step_00000002.npz.json"]

    def test_writer_error_surfaces_on_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"bad": np.asarray(["not", "numeric"])})
        with pytest.raises(RuntimeError, match="writer thread failed"):
            mgr.wait()
        mgr.close()

    def test_invalid_policy_rejected(self):
        with pytest.raises(ValueError):
            CheckpointPolicy(every_steps=0)
        with pytest.raises(ValueError):
            CheckpointPolicy(every_seconds=-1.0)

    def test_transient_write_fault_retries(self, tmp_path):
        import errno
        mgr = CheckpointManager(str(tmp_path), async_write=False,
                                write_retries=3, retry_backoff=0.01)
        mgr.inject_write_fault(OSError(errno.ENOSPC, "disk full"))
        mgr.inject_write_fault(OSError(errno.EIO, "flaky mount"))
        mgr.save(1, {"w": jnp.zeros(2)})     # two faults, then success
        assert mgr.retried_writes == 2
        assert latest_step(str(tmp_path)) == 1
        mgr.close()

    def test_write_fault_exhausts_retries(self, tmp_path):
        import errno
        mgr = CheckpointManager(str(tmp_path), async_write=False,
                                write_retries=1, retry_backoff=0.01)
        for _ in range(2):                   # one more fault than retries
            mgr.inject_write_fault(OSError(errno.ENOSPC, "disk full"))
        with pytest.raises(OSError):
            mgr.save(1, {"w": jnp.zeros(2)})
        assert latest_step(str(tmp_path)) is None

    def test_async_retry_is_transparent(self, tmp_path):
        import errno
        with CheckpointManager(str(tmp_path), write_retries=2,
                               retry_backoff=0.01) as mgr:
            mgr.inject_write_fault(OSError(errno.ENOSPC, "disk full"))
            mgr.save(1, {"w": jnp.zeros(2)}, block=True)  # no raise
            assert mgr.retried_writes == 1
        assert latest_step(str(tmp_path)) == 1

    def test_writer_error_surfaces_on_clean_exit(self, tmp_path):
        # regression: a failure on the LAST save before shutdown must not
        # be swallowed by the context-manager exit
        with pytest.raises(RuntimeError, match="writer thread failed"):
            with CheckpointManager(str(tmp_path), write_retries=0) as mgr:
                mgr.save(1, {"bad": np.asarray(["not", "numeric"])})

    def test_writer_error_does_not_mask_body_exception(self, tmp_path):
        # regression: when the with-body is already raising, a pending
        # writer error must NOT replace it as the surfaced exception
        with pytest.raises(ValueError, match="body failed first"):
            with CheckpointManager(str(tmp_path), write_retries=0) as mgr:
                mgr.save(1, {"bad": np.asarray(["not", "numeric"])})
                raise ValueError("body failed first")


# --------------------------------------------------------------------------- #
# kill-and-resume: the golden equivalence, with a real SIGKILL
# --------------------------------------------------------------------------- #
TRAIN_ARGS = ["--arch", "qwen2-0.5b", "--reduced", "--batch", "2",
              "--seq", "64", "--d-model", "128", "--vocab", "128",
              "--log-every", "50"]
STEPS = 12


def _train(args, wait=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", *TRAIN_ARGS, *args],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, env=env)
    if not wait:
        return proc
    out, err = proc.communicate(timeout=600)
    assert proc.returncode == 0, err.decode()[-2000:]
    return proc


def _final_state(ckpt_dir):
    data = np.load(os.path.join(ckpt_dir, f"step_{STEPS:08d}.npz"))
    return {k: data[k] for k in data.files}


@pytest.mark.parametrize("barrier", ["none", "pbsp"])
def test_kill_and_resume_bit_exact(tmp_path, barrier):
    """SIGKILL mid-run + --resume ≡ the uninterrupted run, leaf for leaf."""
    mode = ([] if barrier == "none"
            else ["--barrier", barrier, "--workers", "2"])
    ref, killed = str(tmp_path / "ref"), str(tmp_path / "killed")
    common = [*mode, "--steps", str(STEPS)]

    # uninterrupted reference: STEPS steps, one final full-state checkpoint
    _train([*common, "--ckpt-dir", ref])

    # victim: same config, throttled so the kill window is deterministic,
    # async-checkpointing every 2 steps.  SIGKILL as soon as a checkpoint
    # is discoverable — long before the run could finish.
    proc = _train([*common, "--ckpt-dir", killed, "--save-every", "2",
                   "--throttle", "0.3"], wait=False)
    deadline = time.monotonic() + 540
    try:
        while latest_step(killed) is None:
            assert proc.poll() is None, proc.stderr.read().decode()[-2000:]
            assert time.monotonic() < deadline, "no checkpoint appeared"
            time.sleep(0.02)
    finally:
        proc.kill()
        proc.wait()
    s = latest_step(killed)
    assert s is not None and s < STEPS, f"killed run already at {s}"

    # resume from the latest async checkpoint and finish the run
    _train([*common, "--ckpt-dir", killed, "--resume"])

    a, b = _final_state(ref), _final_state(killed)
    assert set(a) == set(b)
    for k in a:
        assert np.array_equal(a[k], b[k]), f"leaf {k} diverged after resume"


def test_resume_metadata_records_data_stream(tmp_path):
    """The sidecar records how much of the data stream was consumed."""
    _train(["--steps", "4", "--ckpt-dir", str(tmp_path)])
    assert read_metadata(str(tmp_path), 4)["data_step"] == 4


# --------------------------------------------------------------------------- #
# elastic trainer: resume under churn, through the real store
# --------------------------------------------------------------------------- #
def test_elastic_resume_equivalence(tmp_path):
    """N ticks + checkpoint + resume N ≡ 2N uninterrupted ticks (churn on).

    The full :class:`PSPState` — alive mask, churn cursors, policy
    pytree, RNG key — round-trips through the on-disk store and the
    resumed drive consumes the identical minibatch key stream, so the
    final server params (and every other leaf) match bit-for-bit.
    """
    from repro.core.spmd_psp import (ChurnConfig, PSPConfig, elastic_drive,
                                     linear_psp_state, state_from_tree,
                                     state_to_tree)
    cfg = PSPConfig(barrier="pssp", n_workers=4, sample_size=2, staleness=3,
                    straggler_frac=0.25, contribution="mean-alive",
                    churn=ChurnConfig(leave_rate=2.0, join_rate=2.0,
                                      horizon=30.0, seed=7))
    dim, n = 8, 12
    _, it = elastic_drive(cfg, dim, 2 * n)
    states = [st for st, _ in it]
    mid, full = states[n - 1], states[-1]

    save_checkpoint(str(tmp_path), n, state_to_tree(mid))
    tree, step = restore_checkpoint(str(tmp_path),
                                    state_to_tree(linear_psp_state(cfg, dim)))
    assert step == n
    _, it2 = elastic_drive(cfg, dim, 2 * n, state=state_from_tree(tree),
                           start_tick=n)
    resumed = [st for st, _ in it2][-1]

    flat_a = jax.tree_util.tree_flatten_with_path(state_to_tree(full))[0]
    flat_b = jax.tree_util.tree_flatten_with_path(state_to_tree(resumed))[0]
    for (pa, xa), (_, xb) in zip(flat_a, flat_b):
        assert np.array_equal(np.asarray(xa), np.asarray(xb)), (
            f"PSPState leaf {jax.tree_util.keystr(pa)} diverged")
