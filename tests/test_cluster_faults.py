"""Chaos tier: fault plans, external churn, and the multi-process cluster.

The integration tests spawn REAL worker subprocesses and SIGKILL them.
The load-bearing claim is bit-exactness: a multi-process cluster run —
fault plan, kills, rejoins and all — must reproduce the single-process
elastic trainer's server params exactly, once its recorded membership
events are replayed through :func:`repro.core.spmd_psp.external_drive`.
That holds because of two facts pinned here as unit tests first:

* a solo ``jax.jit(grad_fn)`` on one worker's view equals that worker's
  row of the in-graph ``vmap`` (what the worker subprocess computes);
* :func:`psp_apply_tick` fed externally-computed constant gradients
  (pushers' solo grads, zeros elsewhere) is bit-identical to
  :func:`make_psp_step_fn`'s fused step (what the coordinator applies).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.faults import (BUILDERS, FaultEvent, FaultPlan, make_plan,
                               plan_from_env)
from repro.core.spmd_psp import (PSPConfig, apply_external_churn,
                                 external_drive, linear_psp_state,
                                 linear_psp_task, make_psp_step_fn,
                                 psp_apply_tick)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


# --------------------------------------------------------------------------- #
# fault plans
# --------------------------------------------------------------------------- #
class TestFaultPlan:
    def test_builders_produce_valid_plans(self):
        for name in BUILDERS:
            plan = make_plan(name, n_workers=4, ticks=30)
            assert plan.name == name
            for ev in plan.events:
                assert 0 <= ev.tick < 30
                if ev.worker is not None:
                    assert 0 <= ev.worker < 4

    def test_seed_determinism(self):
        a = make_plan("kill-one:seed=7", n_workers=6, ticks=40)
        b = make_plan("kill-one:seed=7", n_workers=6, ticks=40)
        c = make_plan("kill-one:seed=8", n_workers=6, ticks=40)
        assert a.events == b.events
        assert (a.events != c.events
                or a.seed != c.seed)        # same victim possible; seed kept

    def test_json_roundtrip(self, tmp_path):
        plan = make_plan("standard:seed=3", n_workers=5, ticks=24)
        path = str(tmp_path / "plan.json")
        plan.save(path)
        back = FaultPlan.from_json(open(path).read())
        assert back == plan
        # a JSON path is a valid spec
        again = make_plan(path, n_workers=5, ticks=24)
        assert again.events == plan.events

    def test_publish_fault_covers_count_window(self):
        plan = make_plan("torn-storm:k=3,at=2", n_workers=1, ticks=10)
        kinds = [getattr(plan.publish_fault(i), "kind", None)
                 for i in range(7)]
        assert kinds[2:5] == ["torn_snapshot"] * 3
        assert kinds[0] is None and kinds[5] is None

    def test_rack_never_kills_everyone(self):
        for seed in range(5):
            plan = make_plan(f"rack:g=2,seed={seed}", n_workers=4, ticks=20)
            killed = {e.worker for e in plan.events if e.kind == "kill"}
            assert 0 < len(killed) < 4

    def test_bad_specs_raise(self):
        with pytest.raises(ValueError, match="unknown fault plan"):
            make_plan("no-such-plan", n_workers=2, ticks=10)
        with pytest.raises(ValueError):
            make_plan("kill-one:worker", n_workers=2, ticks=10)
        with pytest.raises(ValueError):
            FaultEvent("not-a-kind", 0)

    def test_plan_from_env(self, monkeypatch):
        monkeypatch.delenv("PSP_FAULT_PLAN", raising=False)
        assert plan_from_env(n_workers=2, ticks=10).name == "none"
        monkeypatch.setenv("PSP_FAULT_PLAN", "kill-one:worker=1,at=4")
        plan = plan_from_env(n_workers=2, ticks=10)
        assert plan.kills_at(4) == [1]


# --------------------------------------------------------------------------- #
# the two numerical facts the cluster protocol rests on
# --------------------------------------------------------------------------- #
def _cfg(**kw):
    base = dict(barrier="pbsp", n_workers=4, staleness=3, sample_size=2,
                straggler_frac=0.25)
    base.update(kw)
    return PSPConfig(**base)


class TestClusterNumerics:
    def test_solo_grad_equals_vmap_row(self):
        dim = 16
        w_true, grad_fn, _ = linear_psp_task(dim, lr=0.1, seed=0)
        state = linear_psp_state(_cfg(), dim, 1)
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 8, dim))
        y = x @ w_true
        v_loss, v_grads = jax.vmap(grad_fn)(state.views, (x, y))
        for w in range(4):
            view = jax.tree_util.tree_map(lambda a, w=w: a[w], state.views)
            s_loss, s_grads = jax.jit(grad_fn)(view, (x[w], y[w]))
            assert np.array_equal(np.asarray(s_loss), np.asarray(v_loss)[w])
            for sv, vv in zip(jax.tree_util.tree_leaves(s_grads),
                              jax.tree_util.tree_leaves(v_grads)):
                assert np.array_equal(np.asarray(sv), np.asarray(vv)[w])

    def test_apply_tick_with_constant_grads_matches_fused_step(self):
        # the coordinator path: grads computed OUTSIDE the jitted step
        # (pushers' solo grads, zeros elsewhere) must be bit-identical to
        # the in-graph vmap step, for every state leaf, over many ticks
        dim, W, B = 16, 4, 8
        cfg = _cfg()
        w_true, grad_fn, opt_update = linear_psp_task(dim, lr=0.1, seed=0)
        fused = jax.jit(make_psp_step_fn(cfg, grad_fn, opt_update))
        constant = jax.jit(lambda st, losses, grads: psp_apply_tick(
            cfg, opt_update, st, lambda _: (losses, grads)))
        solo = jax.jit(grad_fn)

        sa = linear_psp_state(cfg, dim, 1)
        sb = linear_psp_state(cfg, dim, 1)
        kb = jax.random.PRNGKey(2)
        for _t in range(40):
            kb, k1 = jax.random.split(kb)
            x = jax.random.normal(k1, (W, B, dim))
            batch = (x, x @ w_true)
            push = np.asarray((sb.busy_until <= sb.now) & ~sb.pushed
                              & sb.alive)
            losses = np.zeros((W,), np.float32)
            grads_np = jax.tree_util.tree_map(
                lambda p: np.zeros((W,) + np.shape(p), np.float32),
                sb.server_params)
            for w in np.flatnonzero(push):
                view = jax.tree_util.tree_map(lambda a, w=w: a[w], sb.views)
                l, g = solo(view, (x[w], batch[1][w]))
                losses[w] = np.asarray(l)
                for dst, src in zip(jax.tree_util.tree_leaves(grads_np),
                                    jax.tree_util.tree_leaves(g)):
                    dst[w] = np.asarray(src)
            sa, _ = fused(sa, batch)
            sb, _ = constant(sb, jnp.asarray(losses),
                             jax.tree_util.tree_map(jnp.asarray, grads_np))
            for la, lb in zip(jax.tree_util.tree_leaves(sa),
                              jax.tree_util.tree_leaves(sb)):
                assert np.array_equal(np.asarray(la), np.asarray(lb))


# --------------------------------------------------------------------------- #
# external churn (the coordinator's membership primitive)
# --------------------------------------------------------------------------- #
class TestExternalChurn:
    def test_leave_then_join_reanchors(self):
        cfg = _cfg(straggler_frac=0.0)
        dim = 8
        w_true, grad_fn, opt_update = linear_psp_task(dim, lr=0.1, seed=0)
        step = jax.jit(make_psp_step_fn(cfg, grad_fn, opt_update))
        state = linear_psp_state(cfg, dim, 1)
        kb = jax.random.PRNGKey(2)
        for _ in range(5):
            kb, k1 = jax.random.split(kb)
            x = jax.random.normal(k1, (4, 8, dim))
            state, _ = step(state, (x, x @ w_true))
        state = apply_external_churn(cfg, state, leave=(1,))
        assert not bool(np.asarray(state.alive)[1])
        # leaving again is a no-op; joining an alive worker is a no-op
        state2 = apply_external_churn(cfg, state, leave=(1,), join=(0,))
        for la, lb in zip(jax.tree_util.tree_leaves(state),
                          jax.tree_util.tree_leaves(state2)):
            assert np.array_equal(np.asarray(la), np.asarray(lb))
        state = apply_external_churn(cfg, state, join=(1,))
        alive_steps = np.asarray(state.step)[np.asarray(state.alive)]
        # joiner restarts at the max alive step with a fresh server pull,
        # masked out of this tick's push
        assert np.asarray(state.step)[1] == alive_steps.max()
        assert bool(np.asarray(state.pushed)[1])
        v1 = jax.tree_util.tree_map(lambda a: np.asarray(a)[1], state.views)
        for lv, ls in zip(jax.tree_util.tree_leaves(v1),
                          jax.tree_util.tree_leaves(state.server_params)):
            assert np.array_equal(lv, np.asarray(ls))

    def test_rack_leave_multiple_workers(self):
        cfg = _cfg(n_workers=6)
        state = linear_psp_state(cfg, 8, 1)
        state = apply_external_churn(cfg, state, leave=(0, 1, 2))
        assert np.asarray(state.alive).tolist() == [False] * 3 + [True] * 3

    def test_external_drive_replays_events(self):
        cfg = _cfg(straggler_frac=0.0)
        events = {3: ((1,), ()), 7: ((), (1,))}
        _, it = external_drive(cfg, 8, 12, events, batch=4)
        states = [s for s, _m in it]
        assert not bool(np.asarray(states[3].alive)[1])
        assert bool(np.asarray(states[7].alive)[1])


# --------------------------------------------------------------------------- #
# the real thing: subprocess cluster runs
# --------------------------------------------------------------------------- #
def _replay(cfg, dim, ticks, result, batch):
    """Feed a cluster run's recorded events back through external_drive."""
    events = {}
    for t, kind, w in result["events"]:
        lv, jn = events.setdefault(t, ([], []))
        (lv if kind == "leave" else jn).append(w)
    events = {t: (tuple(l), tuple(j)) for t, (l, j) in events.items()}
    _, it = external_drive(cfg, dim, ticks, events, batch=batch)
    state = None
    for state, _m in it:
        pass
    return state


@pytest.mark.slow
class TestClusterIntegration:
    DIM, BATCH = 8, 4

    def test_nofault_run_matches_single_process(self, tmp_path):
        from repro.launch.cluster import run_cluster
        cfg = _cfg(n_workers=3)
        res = run_cluster(cfg, self.DIM, 16, str(tmp_path),
                          batch=self.BATCH, tick_timeout=120.0)
        assert res["events"] == []
        ref = _replay(cfg, self.DIM, 16, res, self.BATCH)
        assert np.array_equal(np.asarray(ref.server_params["w"]),
                              res["final_params"]["w"])
        assert int(ref.total_pushes) == res["total_pushes"]
        # result.json is the same record minus the in-process arrays
        on_disk = json.load(open(os.path.join(str(tmp_path),
                                              "result.json")))
        assert on_disk["total_pushes"] == res["total_pushes"]

    def test_kill_one_rejoins_and_replays_bit_exact(self, tmp_path):
        from repro.launch.cluster import run_cluster
        cfg = _cfg(n_workers=3, straggler_frac=0.0)
        plan = make_plan("kill-one:worker=1,at=4", n_workers=3, ticks=26)
        res = run_cluster(cfg, self.DIM, 26, str(tmp_path), batch=self.BATCH,
                          plan=plan, tick_timeout=120.0, tick_min_wall=0.5)
        kinds = [(kind, w) for _t, kind, w in res["events"]]
        assert ("leave", 1) in kinds        # the SIGKILL was observed
        assert ("join", 1) in kinds         # ... and the respawn rejoined
        # only the victim was restarted; live workers kept their process
        assert res["epochs"] == {"0": 0, "1": 1, "2": 0}
        rec = res["recovery"]["1"]
        assert rec["latency_s"] > 0         # kill -> rejoin -> first push
        assert rec["t_kill"] < rec["t_rejoin"] < rec["t_push"]
        # the acceptance criterion: same alive trajectory => bit-exact
        ref = _replay(cfg, self.DIM, 26, res, self.BATCH)
        assert np.array_equal(np.asarray(ref.server_params["w"]),
                              res["final_params"]["w"])
        assert int(ref.total_pushes) == res["total_pushes"]
        assert np.asarray(ref.alive).tolist() == res["alive"]

    def test_cluster_rejects_internal_churn_config(self, tmp_path):
        from repro.core.spmd_psp import ChurnConfig
        from repro.launch.cluster import run_cluster
        cfg = _cfg(churn=ChurnConfig(leave_rate=0.1, join_rate=0.1))
        with pytest.raises(ValueError, match="churn"):
            run_cluster(cfg, 8, 4, str(tmp_path))
