"""Teacher-forced forward ≡ prefill + N decode steps, for every arch.

This is the strongest correctness test of the serving path: it exercises
KV caches (full + sliding-window ring), SSM/RG-LRU state carry-over, conv
state, RoPE offsets and the head-padding logic in one shot.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import init_model, prefill, decode_step
from repro.models.transformer import forward, _head

ARCH_NAMES = sorted(ARCHS)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    if cfg.is_moe:    # capacity drops depend on T; disable for exactness
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    params = init_model(cfg, key)
    B, S, N = 2, 96, 4      # S > reduced window (64) → exercises the ring
    F = cfg.frontend_tokens
    toks = jax.random.randint(key, (B, S + N), 0, cfg.vocab_size)
    emb = (jax.random.normal(key, (B, F, cfg.d_model), jnp.bfloat16)
           if F else None)

    h, _, _ = forward(params, toks, cfg, embeds=emb, mode="train")
    ref = _head(h[:, -1], params, cfg)

    logits, cache = prefill(params, toks[:, :S], cfg, embeds=emb,
                            max_len=S + N + F)
    for i in range(N):
        logits, cache = decode_step(params, cache,
                                    toks[:, S + i:S + i + 1], cfg)
    rel = float(jnp.max(jnp.abs(logits - ref))) / \
        (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.02, (arch, rel)
