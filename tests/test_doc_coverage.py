"""Doc-coverage gate: public engine/kernel/tool APIs must keep docstrings.

Runs ``tools/check_docstrings.py`` (stdlib-``ast`` based, no third-party
dependency) over ``src/repro/core``, ``src/repro/kernels`` and ``tools``
— the same command the CI doc-coverage step executes — and fails listing
the exact violations, so a missing docstring on a public
module/class/function in the engine, kernel, or CI-gate-script layers is
a red test, not a review nit.
"""
import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_engine_and_kernel_apis_are_documented():
    """`python tools/check_docstrings.py` exits 0 (zero violations)."""
    proc = subprocess.run(
        [sys.executable, os.path.join("tools", "check_docstrings.py")],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, \
        f"doc-coverage violations:\n{proc.stdout}{proc.stderr}"


def test_gate_detects_missing_docstrings(tmp_path):
    """The checker itself works: an undocumented def must be flagged."""
    bad = tmp_path / "bad.py"
    bad.write_text('"""Module doc."""\ndef public(x):\n    return x\n')
    proc = subprocess.run(
        [sys.executable, os.path.join("tools", "check_docstrings.py"),
         str(bad)],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert proc.returncode == 1
    assert "public" in proc.stdout
