"""Guard the multi-pod dry-run deliverable: every (arch × shape × mesh)
artifact in results/dryrun must be ok (or a documented long_500k skip),
with coherent roofline fields.

These tests read the committed artifacts — regenerate with
``python -m repro.launch.dryrun --arch all --shape all --mesh single,multi``.
"""
import glob
import json
import os

import pytest

from repro.configs import ARCHS, INPUT_SHAPES, LONG_CONTEXT_ARCHS

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

have_artifacts = pytest.mark.skipif(
    not glob.glob(os.path.join(RESULTS, "*.json")),
    reason="dry-run artifacts not generated")


def load_all():
    recs = {}
    for path in glob.glob(os.path.join(RESULTS, "*.json")):
        name = os.path.basename(path)[:-5]
        with open(path) as f:
            recs[name] = json.load(f)
    return recs


@have_artifacts
def test_all_80_combinations_present_and_green():
    recs = load_all()
    missing, failed = [], []
    for arch in ARCHS:
        for shape in INPUT_SHAPES:
            for mesh in ("single", "multi"):
                tag = f"{arch}__{shape}__{mesh}"
                r = recs.get(tag)
                if r is None:
                    missing.append(tag)
                    continue
                if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                    assert r["status"] == "skipped", tag
                elif r["status"] != "ok":
                    failed.append((tag, r.get("error", "")[:120]))
    assert not missing, missing
    assert not failed, failed


@have_artifacts
def test_roofline_fields_coherent():
    for tag, r in load_all().items():
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        rf = r["roofline"]
        assert rf["bottleneck"] in ("compute", "memory", "collective"), tag
        assert r["cost"]["flops"] > 0, tag
        assert 0 < rf["useful_ratio"] <= 1.5, (tag, rf["useful_ratio"])
        assert r["cost"]["bytes_accessed"] <= \
            r["cost"]["bytes_accessed_naive"] * 1.001, tag


@have_artifacts
def test_multi_pod_shards_the_pod_axis():
    """512-chip lowering must roughly halve per-device flops vs 256."""
    recs = load_all()
    for arch in ("gemma2-27b", "qwen3-moe-30b-a3b", "mamba2-780m"):
        s = recs.get(f"{arch}__train_4k__single")
        m = recs.get(f"{arch}__train_4k__multi")
        if not (s and m and s.get("status") == m.get("status") == "ok"):
            continue
        ratio = m["cost"]["flops"] / s["cost"]["flops"]
        assert 0.35 < ratio < 0.75, (arch, ratio)


@have_artifacts
def test_decode_caches_fit_v5e():
    """Every decode-shape combo must fit in 16 GB.

    CPU-analyzed temp is inflated by two backend artifacts (no buffer
    donation → cache double-buffer; no native bf16 → f32 copies of dot
    operands), so the robust TPU fit criterion is on the *resident state*:
    cache + params (argument bytes) must leave headroom for streaming
    weights and transients.
    """
    for tag, r in load_all().items():
        if r.get("status") != "ok" or "memory" not in r:
            continue
        if any(k in tag for k in ("decode_32k", "long_500k")):
            args = r["memory"]["argument_bytes"]
            assert args < 12e9, (tag, args / 1e9)
