"""Dry-run machinery on a small in-process host mesh (8 fake devices).

The full 512-device production dry-run runs via
``python -m repro.launch.dryrun`` (results in results/dryrun); here we
verify the same pipeline — rules → abstract inputs → lower → compile →
roofline — works end-to-end for representative archs at reduced scale, in
a subprocess so the forced device count cannot leak into other tests.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json, sys
    import jax, jax.numpy as jnp
    from repro.configs import get_config, reduced, INPUT_SHAPES
    from repro.parallel.sharding import make_rules, use_rules
    from repro.launch.steps import dryrun_inputs
    from repro.roofline.analysis import roofline_report
    from repro.roofline.hlo_cost import analyze_hlo

    arch, shape_name, multipod = sys.argv[1], sys.argv[2], sys.argv[3] == "1"
    cfg = reduced(get_config(arch), n_layers=4, d_model=256)
    shape = dataclasses.replace(INPUT_SHAPES[shape_name],
                                seq_len=512, global_batch=8)
    if multipod:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    else:
        mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_rules(cfg, shape, mesh)
    with use_rules(rules):
        args, step, donate = dryrun_inputs(cfg, shape, rules)
        with mesh:
            lowered = jax.jit(step, donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)
    rep = roofline_report({"flops": cost.flops, "bytes accessed": cost.bytes},
                          hlo, chips=mesh.devices.size,
                          model_flops_total=1.0)
    ma = compiled.memory_analysis()
    print(json.dumps({
        "flops": cost.flops, "bytes": cost.bytes,
        "coll": cost.coll_total, "bottleneck": rep.bottleneck,
        "temp": ma.temp_size_in_bytes,
    }))
""")

CASES = [
    ("qwen2-0.5b", "train_4k", False),
    ("gemma2-27b", "train_4k", False),
    ("qwen3-moe-30b-a3b", "train_4k", False),
    ("mamba2-780m", "decode_32k", False),
    ("recurrentgemma-2b", "prefill_32k", False),
    ("h2o-danube-1.8b", "train_4k", True),     # multi-pod axis
]


@pytest.mark.parametrize("arch,shape,multipod", CASES)
def test_small_mesh_lower_compile(arch, shape, multipod, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, arch, shape, "1" if multipod else "0"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["bytes"] > 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
