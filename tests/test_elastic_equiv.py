"""Cross-layer elastic (churn) equivalence: trainer ↔ sweep engines.

The elastic SPMD trainer (:mod:`repro.core.spmd_psp` with
``PSPConfig(churn=...)``) must execute the *same* churn protocol as the
simulators: the numpy grid engine's ``_churn_leave``/``_churn_join``, the
fused tick reference, and the event engine all agree on who leaves, who
rejoins, and how a joiner is re-anchored.  These tests pin that
cross-layer contract:

* the shared selection rules (:func:`repro.core.barrier_kernel.churn_victim`
  / ``churn_joiner``) reproduce the numpy engine's victim/joiner choices
  draw-for-draw (same uniforms in, same index out);
* a full trainer run's alive-mask trajectory is replayed tick-for-tick by
  an independent mirror of the sweep-engine churn rules (due-event
  cursors, population floor, one event per tick);
* joiners are re-anchored exactly (fresh-start step = max alive step, a
  fresh pull of the server model);
* departed workers contribute zero gradient and zero bytes to the server
  psum, and their views are never touched.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import barrier_kernel as bk
from repro.core.barriers import make_barrier
from repro.core.simulator import SimConfig
from repro.core.spmd_psp import (ChurnConfig, PSPConfig, linear_psp_task,
                                 psp_init, psp_train_step)
from repro.core.vector_sim import VectorSimulator, sample_churn_schedules

D = 8
W = 8


# --------------------------------------------------------------------------- #
# selection rules: trainer helpers == numpy sweep engine, draw-for-draw
# --------------------------------------------------------------------------- #
class TestChurnSelectionPinnedToSweepEngine:
    """churn_victim/churn_joiner reproduce VectorSimulator's choices when
    fed the exact uniforms the engine consumes (rng rewind trick)."""

    @staticmethod
    def _sim():
        cfg = SimConfig(n_nodes=10, duration=2.0, dim=4, seed=5,
                        churn_leave_rate=0.5, churn_join_rate=0.5,
                        barrier=make_barrier("pbsp", staleness=2,
                                             sample_size=2))
        return VectorSimulator([cfg], backend="numpy")

    @pytest.mark.parametrize("dead", [(), (0, 4), (1, 2, 3, 7)])
    def test_leave_victim_matches(self, dead):
        sim = self._sim()
        sim.alive[0, list(dead)] = False
        alive_before = sim.alive.copy()
        snap = sim.rng.bit_generator.state
        u = sim.rng.random((1, sim.P))       # the scores _churn_leave draws
        sim.rng.bit_generator.state = snap   # rewind so the engine redraws
        sim._churn_leave(np.array([True]))
        died = np.flatnonzero(alive_before[0] & ~sim.alive[0])
        assert died.size == 1
        got = int(bk.churn_victim(jnp.asarray(u[0]),
                                  jnp.asarray(alive_before[0])))
        assert got == int(died[0])

    @pytest.mark.parametrize("dead", [(0,), (0, 4), (1, 2, 3, 7)])
    def test_join_slot_and_anchor_match(self, dead):
        sim = self._sim()
        sim.alive[0, list(dead)] = False
        sim.steps[0] = np.arange(sim.P)      # distinguishable counters
        alive_before = sim.alive.copy()
        snap = sim.rng.bit_generator.state
        u = sim.rng.random((1, sim.P))
        sim.rng.bit_generator.state = snap
        t = 1.25
        sim._churn_join(np.array([True]), t)
        joined = np.flatnonzero(~alive_before[0] & sim.alive[0])
        assert joined.size == 1
        got = int(bk.churn_joiner(jnp.asarray(u[0]),
                                  jnp.asarray(alive_before[0])))
        assert got == int(joined[0])
        # fresh-start anchor: max alive step, decides at t
        j = int(joined[0])
        assert sim.steps[0, j] == sim.steps[0, sim.alive[0]].max()
        assert sim.event_time[0, j] == t and not sim.computing[0, j]

    def test_shared_schedule_machinery(self):
        """psp_init consumes the engines' exact Poisson schedule draw."""
        churn = ChurnConfig(leave_rate=1.0, join_rate=0.5, horizon=20.0,
                            seed=9)
        cfg = PSPConfig(n_workers=4, churn=churn)
        st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                      jax.random.PRNGKey(0))
        lt, jt = sample_churn_schedules(np.random.default_rng(churn.seed),
                                        churn.leave_rate, churn.join_rate,
                                        churn.horizon)
        np.testing.assert_allclose(np.asarray(st.leave_times),
                                   lt.astype(np.float32))
        np.testing.assert_allclose(np.asarray(st.join_times),
                                   jt.astype(np.float32))


# --------------------------------------------------------------------------- #
# full trainer run: alive-mask trajectory replayed by a sweep-rule mirror
# --------------------------------------------------------------------------- #
class TestTrainerChurnTrajectory:
    """Drive the elastic trainer and independently replay its churn
    decisions with a numpy mirror of the sweep-engine rules."""

    CFG = PSPConfig(barrier="pssp", n_workers=W, sample_size=2, staleness=3,
                    straggler_frac=0.25,
                    churn=ChurnConfig(leave_rate=2.0, join_rate=2.0,
                                      horizon=50.0, seed=3))

    @pytest.fixture(scope="class")
    def trace(self):
        w_true, grad_fn, opt_update = linear_psp_task(D)
        cfg = self.CFG
        st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                      jax.random.PRNGKey(1))
        step = jax.jit(lambda s, b: psp_train_step(cfg, grad_fn, opt_update,
                                                   s, b))
        kb = jax.random.PRNGKey(2)
        rows = []
        for _ in range(140):
            kb, k1 = jax.random.split(kb)
            x = jax.random.normal(k1, (W, 8, D))
            pre = dict(key=st.key, now=float(st.now),
                       alive=np.asarray(st.alive).copy(),
                       step=np.asarray(st.step).copy(),
                       w=np.asarray(st.server_params["w"]).copy())
            st, m = step(st, (x, x @ w_true))
            rows.append((pre, dict(alive=np.asarray(st.alive).copy(),
                                   step=np.asarray(st.step).copy(),
                                   views=np.asarray(st.views["w"]).copy(),
                                   w=np.asarray(st.server_params["w"]).copy())))
        return st, rows

    def test_alive_trajectory_matches_mirror(self, trace):
        st, rows = trace
        cfg = self.CFG
        lt = np.asarray(st.leave_times)
        jt = np.asarray(st.join_times)
        alive_m = np.ones(W, bool)
        lc = jc = 0
        n_leaves = n_joins = 0
        for pre, post in rows:
            # replicate the step's key chain to recover the churn uniforms
            _, _, _, k_churn = jax.random.split(pre["key"], 4)
            k_leave, k_join = jax.random.split(k_churn)
            u_l = np.asarray(jax.random.uniform(k_leave, (W,)))
            u_j = np.asarray(jax.random.uniform(k_join, (W,)))
            now = pre["now"]
            # sweep-engine rules: ≤1 leave, then ≤1 join; cursors consume
            # due events even when the population guard skips the effect
            if lc < lt.size and lt[lc] <= now:
                lc += 1
                if alive_m.sum() > 2:
                    alive_m[np.argmax(np.where(alive_m, u_l, -1.0))] = False
                    n_leaves += 1
            if jc < jt.size and jt[jc] <= now:
                jc += 1
                if not alive_m.all():
                    alive_m[np.argmax(np.where(~alive_m, u_j, -1.0))] = True
                    n_joins += 1
            np.testing.assert_array_equal(post["alive"], alive_m)
        assert int(st.leave_cursor) == lc and int(st.join_cursor) == jc
        # the scenario must actually exercise churn, both directions
        assert n_leaves >= 2 and n_joins >= 2
        assert 2 <= alive_m.sum() <= W

    def test_joiner_fresh_start_and_reanchor(self, trace):
        st, rows = trace
        checked = 0
        for pre, post in rows:
            joined = np.flatnonzero(~pre["alive"] & post["alive"])
            for j in joined:
                # fresh-start: max step over the post-churn alive set,
                # +1 iff the joiner immediately passed the barrier
                fresh = pre["step"][post["alive"]].max()
                assert post["step"][j] - fresh in (0, 1)
                # re-anchored view: the server model as of this tick
                # (pre-push if the joiner blocked, post-push if it pulled)
                d_pre = np.abs(post["views"][j] - pre["w"]).max()
                d_post = np.abs(post["views"][j] - post["w"]).max()
                assert min(d_pre, d_post) < 1e-6
                checked += 1
        assert checked >= 2

    def test_departed_views_frozen(self, trace):
        _, rows = trace
        checked = 0
        for pre, post in rows:
            stayed_dead = ~pre["alive"] & ~post["alive"]
            for j in np.flatnonzero(stayed_dead):
                checked += 1
                assert post["step"][j] == pre["step"][j]
        assert checked > 0


# --------------------------------------------------------------------------- #
# masked psum: departed workers contribute zero bytes and zero gradient
# --------------------------------------------------------------------------- #
class TestMaskedPsum:
    """One tick with hand-set alive/busy state: the server update is the
    masked mean over *alive* completed workers only."""

    @staticmethod
    def _step(alive_mask):
        cfg = PSPConfig(barrier="asp", n_workers=W,
                        churn=ChurnConfig(leave_rate=0.0, join_rate=0.0))

        def grad_fn(params, x):
            # per-worker distinguishable constant gradient
            return 0.0 * x, {"w": jnp.full((D,), x)}

        def opt_update(g, s, p):
            return jax.tree.map(lambda gi: -1.0 * gi, g), s

        st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                      jax.random.PRNGKey(0))
        st = st._replace(alive=jnp.asarray(alive_mask),
                         busy_until=jnp.zeros((W,)))  # everyone completed
        x = jnp.arange(1.0, W + 1.0)                  # worker i pushes i+1
        new, m = psp_train_step(cfg, grad_fn, opt_update, st, x)
        return st, new, m

    def test_dead_workers_push_nothing(self):
        alive = np.ones(W, bool)
        alive[[1, 4, 6]] = False
        st, new, m = self._step(alive)
        want = -np.mean(np.arange(1.0, W + 1.0)[alive])  # masked mean only
        np.testing.assert_allclose(np.asarray(new.server_params["w"]),
                                   np.full(D, want), rtol=1e-6)
        assert int(m["pushes"]) == int(alive.sum())
        assert int(new.total_pushes) == int(alive.sum())
        # dead workers: no pull, no step bump, views untouched
        views = np.asarray(new.views["w"])
        for j in np.flatnonzero(~alive):
            assert int(new.step[j]) == 0
            np.testing.assert_array_equal(views[j], np.zeros(D))

    def test_all_alive_is_plain_mean(self):
        st, new, m = self._step(np.ones(W, bool))
        want = -np.mean(np.arange(1.0, W + 1.0))
        np.testing.assert_allclose(np.asarray(new.server_params["w"]),
                                   np.full(D, want), rtol=1e-6)


# --------------------------------------------------------------------------- #
# barrier decisions under churn: trainer pinned to the masked BarrierKernel
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("barrier", ("bsp", "ssp", "asp", "pbsp", "pssp"))
def test_elastic_decisions_pinned_to_masked_kernel(barrier):
    """Same key ⇒ the elastic trainer's pass pattern IS the alive-masked
    BarrierKernel's (the sweep engines route through the same functions)."""
    from repro.core import spmd_psp
    cfg = PSPConfig(barrier=barrier, n_workers=W, staleness=2, sample_size=2,
                    churn=ChurnConfig())
    key = jax.random.PRNGKey(11)
    steps = jnp.asarray(np.random.default_rng(1).integers(0, 9, W), jnp.int32)
    alive = jnp.asarray(np.random.default_rng(2).random(W) < 0.7)
    got = spmd_psp._barrier_allowed(cfg, key, steps, alive)
    want = cfg.barrier_kernel.allowed(key, steps, alive)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if barrier in ("bsp", "ssp"):
        # a departed straggler's frozen minimum never gates alive waiters
        m = np.where(np.asarray(alive), np.asarray(steps),
                     np.iinfo(np.int32).max)
        lag = np.asarray(steps) - m.min()
        np.testing.assert_array_equal(np.asarray(want),
                                      lag <= cfg.effective_staleness)
