"""Engine/barrier combination matrix (paper §4.1, Table 1)."""
import pytest

from repro.core.engines import (MapReduceEngine, P2PEngine,
                                ParameterServerEngine, valid_combinations)


def test_ps_engine_hosts_everything():
    for b in ("bsp", "ssp", "asp", "pbsp", "pssp"):
        r = ParameterServerEngine(b).run(n_nodes=32, duration=4.0, dim=8)
        assert r.mean_progress > 0


def test_p2p_rejects_global_state_barriers():
    # BSP/SSP need centralised state — invalid on the p2p engine (§4.1)
    with pytest.raises(ValueError):
        P2PEngine("bsp")
    with pytest.raises(ValueError):
        P2PEngine("ssp")


def test_p2p_runs_probabilistic():
    r = P2PEngine("pbsp").run(n_nodes=32, duration=4.0, dim=8)
    assert r.mean_progress > 0
    assert r.control_messages > 0    # overlay sampling cost


def test_mapreduce_is_bsp():
    eng = MapReduceEngine()
    assert eng.barrier.name == "bsp"
    r = eng.run(n_nodes=16, duration=4.0, dim=8)
    assert int(r.steps.max() - r.steps.min()) <= 1


def test_combination_table():
    assert "p2p" in valid_combinations("pssp")
    assert "p2p" not in valid_combinations("bsp")
