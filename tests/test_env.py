"""The typed PSP_* env-override registry and its generated docs table."""
import os

import pytest

from repro.core import env


class TestAccessors:
    def test_default_when_unset(self, monkeypatch):
        monkeypatch.delenv("PSP_HYP_EXAMPLES", raising=False)
        assert env.get_int("PSP_HYP_EXAMPLES") == 10
        monkeypatch.delenv("PSP_TICK_IMPL", raising=False)
        assert env.get_str("PSP_TICK_IMPL") == "auto"

    def test_empty_string_is_unset(self, monkeypatch):
        # `PSP_SWEEP_MESH= python ...` must CLEAR an ambient override
        monkeypatch.setenv("PSP_SWEEP_MESH", "")
        assert env.get_str("PSP_SWEEP_MESH") is None
        monkeypatch.setenv("PSP_REGEN_GOLDEN", "")
        assert env.flag("PSP_REGEN_GOLDEN") is False

    def test_typed_reads(self, monkeypatch):
        monkeypatch.setenv("PSP_SWEEP_DEVICES", "4")
        assert env.get_int("PSP_SWEEP_DEVICES") == 4
        monkeypatch.setenv("PSP_REGEN_GOLDEN", "1")
        assert env.flag("PSP_REGEN_GOLDEN") is True
        monkeypatch.setenv("PSP_SWEEP_MESH", "4x2")
        assert env.get_str("PSP_SWEEP_MESH") == "4x2"

    def test_garbage_int_names_the_variable(self, monkeypatch):
        monkeypatch.setenv("PSP_SWEEP_CHUNK", "not-a-number")
        with pytest.raises(ValueError, match="PSP_SWEEP_CHUNK"):
            env.get_int("PSP_SWEEP_CHUNK")

    def test_unregistered_name_raises(self):
        with pytest.raises(KeyError, match="not a registered"):
            env.get_str("PSP_TYPO_VAR")
        with pytest.raises(KeyError):
            env.flag("PSP_TYPO_VAR")


class TestRegistry:
    def test_kinds_are_valid(self):
        for v in env.REGISTRY.values():
            assert v.kind in ("str", "int", "float", "flag"), v.name
            assert v.name.startswith("PSP_"), v.name
            assert v.help, v.name

    def test_docs_table_covers_registry(self):
        # the ARCHITECTURE table is generated — regen with
        # `python -m repro.core.env` if this fails after adding a var
        doc = os.path.join(os.path.dirname(__file__), "..", "docs",
                           "ARCHITECTURE.md")
        with open(doc) as f:
            text = f.read()
        for name in env.REGISTRY:
            assert f"`{name}`" in text, (
                f"{name} is registered but missing from "
                "docs/ARCHITECTURE.md (regen: python -m repro.core.env)")

    def test_markdown_table_escapes_pipes(self):
        table = env.markdown_table()
        for line in table.splitlines()[2:]:
            # 4 columns = exactly 5 unescaped pipes per row
            assert line.replace("\\|", "").count("|") == 5, line

    def test_every_registered_var_has_a_read_site(self):
        # the registry types READS: a var nobody reads is dead weight.
        # _host_mesh.py reads PSP_BENCH_HOST_DEVICES raw (pre-jax-import
        # constraint, documented there), so grep source text instead of
        # importing.
        import glob
        root = os.path.join(os.path.dirname(__file__), "..")
        text = ""
        for pat in ("src/repro/**/*.py", "benchmarks/*.py", "tests/*.py",
                    "tools/*.py", "examples/*.py"):
            for fn in glob.glob(os.path.join(root, pat), recursive=True):
                if os.path.basename(fn) == "env.py":
                    continue
                with open(fn) as f:
                    text += f.read()
        for name in env.REGISTRY:
            assert name in text, f"{name} registered but never read"
