"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.rmsnorm import rmsnorm_tpu
from repro.kernels.ssd_scan import ssd_scan_tpu

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(atol=5e-3, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=1e-4)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,H,hd,window,cap", [
    (128, 2, 64, None, None),
    (256, 4, 64, None, None),
    (256, 2, 128, 64, None),
    (128, 2, 64, None, 30.0),
    (256, 1, 64, 128, 50.0),
    (384, 2, 32, None, None),       # non-pow2 sequence (3 blocks of 128)
])
def test_flash_vs_oracle(S, H, hd, window, cap, dtype):
    q = jax.random.normal(KEY, (2, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, S, H, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, S, H, hd), dtype)
    got = ops.attention(q, k, v, window=window, softcap=cap,
                        impl="interpret")
    want = ref.attention_ref(q, k, v, window=window, softcap=cap)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


def test_flash_block_shapes_swept():
    q = jax.random.normal(KEY, (1, 4, 256, 64))
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        got = flash_attention_tpu(q, q, q, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
        want = ref.attention_ref(q.transpose(0, 2, 1, 3),
                                 q.transpose(0, 2, 1, 3),
                                 q.transpose(0, 2, 1, 3))
        np.testing.assert_allclose(got.transpose(0, 2, 1, 3), want,
                                   atol=2e-5, rtol=1e-4)


def test_flash_tile_skipping_correct():
    """pl.when-skipped tiles must not corrupt the accumulation (SWA)."""
    q = jax.random.normal(KEY, (1, 1, 512, 64))
    got = flash_attention_tpu(q, q, q, causal=True, window=128,
                              block_q=128, block_k=128, interpret=True)
    want = ref.attention_ref(q.transpose(0, 2, 1, 3),
                             q.transpose(0, 2, 1, 3),
                             q.transpose(0, 2, 1, 3), window=128)
    np.testing.assert_allclose(got.transpose(0, 2, 1, 3), want,
                               atol=2e-5, rtol=1e-4)


# --------------------------------------------------------------------------- #
# SSD scan
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,hd,N,chunk", [
    (128, 64, 32, 64),
    (256, 64, 128, 128),
    (256, 32, 64, 64),
])
def test_ssd_vs_sequential_oracle(S, hd, N, chunk, dtype):
    BH = 4
    xdt = (jax.random.normal(KEY, (BH, S, hd)) * 0.5).astype(dtype)
    dA = (-jax.random.uniform(jax.random.fold_in(KEY, 3), (BH, S)) * 0.1
          ).astype(dtype)
    Bm = (jax.random.normal(jax.random.fold_in(KEY, 4), (BH, S, N)) * 0.3
          ).astype(dtype)
    Cm = (jax.random.normal(jax.random.fold_in(KEY, 5), (BH, S, N)) * 0.3
          ).astype(dtype)
    got = ssd_scan_tpu(xdt, dA, Bm, Cm, chunk=chunk, interpret=True)
    want = ops.ssd(xdt, dA, Bm, Cm, impl="cpu")
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-3)


def test_ssd_chunk_invariance():
    """The chunked dual form must be invariant to the chunk size."""
    BH, S, hd, N = 2, 256, 32, 16
    xdt = jax.random.normal(KEY, (BH, S, hd)) * 0.5
    dA = -jax.random.uniform(jax.random.fold_in(KEY, 1), (BH, S)) * 0.2
    Bm = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(KEY, 3), (BH, S, N)) * 0.3
    outs = [ssd_scan_tpu(xdt, dA, Bm, Cm, chunk=c, interpret=True)
            for c in (32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4, rtol=1e-3)


# --------------------------------------------------------------------------- #
# rmsnorm
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (3, 100, 512), (2, 7, 896)])
def test_rmsnorm_vs_oracle(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 6), (shape[-1],))
    got = rmsnorm_tpu(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


# --------------------------------------------------------------------------- #
# XLA flash path (models/flash.py custom VJP) vs oracle incl. gradients
# --------------------------------------------------------------------------- #
def test_xla_flash_custom_vjp_grads():
    from repro.models.flash import flash_attention
    q = jax.random.normal(KEY, (2, 256, 4, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 256, 4, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 256, 4, 32))

    for window, cap in [(None, None), (64, None), (None, 30.0)]:
        f = lambda *a: jnp.sum(jnp.sin(
            flash_attention(*a, True, window, cap, 128, 128)))
        g = lambda *a: jnp.sum(jnp.sin(ref.attention_ref(
            *a, window=window, softcap=cap)))
        d1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        d2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(d1, d2):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)
