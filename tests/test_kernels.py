"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.rmsnorm import rmsnorm_tpu
from repro.kernels.ssd_scan import ssd_scan_tpu

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(atol=5e-3, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=1e-4)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,H,hd,window,cap", [
    (128, 2, 64, None, None),
    (256, 4, 64, None, None),
    (256, 2, 128, 64, None),
    (128, 2, 64, None, 30.0),
    (256, 1, 64, 128, 50.0),
    (384, 2, 32, None, None),       # non-pow2 sequence (3 blocks of 128)
])
def test_flash_vs_oracle(S, H, hd, window, cap, dtype):
    q = jax.random.normal(KEY, (2, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, S, H, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, S, H, hd), dtype)
    got = ops.attention(q, k, v, window=window, softcap=cap,
                        impl="interpret")
    want = ref.attention_ref(q, k, v, window=window, softcap=cap)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


def test_flash_block_shapes_swept():
    q = jax.random.normal(KEY, (1, 4, 256, 64))
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        got = flash_attention_tpu(q, q, q, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
        want = ref.attention_ref(q.transpose(0, 2, 1, 3),
                                 q.transpose(0, 2, 1, 3),
                                 q.transpose(0, 2, 1, 3))
        np.testing.assert_allclose(got.transpose(0, 2, 1, 3), want,
                                   atol=2e-5, rtol=1e-4)


def test_flash_tile_skipping_correct():
    """pl.when-skipped tiles must not corrupt the accumulation (SWA)."""
    q = jax.random.normal(KEY, (1, 1, 512, 64))
    got = flash_attention_tpu(q, q, q, causal=True, window=128,
                              block_q=128, block_k=128, interpret=True)
    want = ref.attention_ref(q.transpose(0, 2, 1, 3),
                             q.transpose(0, 2, 1, 3),
                             q.transpose(0, 2, 1, 3), window=128)
    np.testing.assert_allclose(got.transpose(0, 2, 1, 3), want,
                               atol=2e-5, rtol=1e-4)


# --------------------------------------------------------------------------- #
# SSD scan
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,hd,N,chunk", [
    (128, 64, 32, 64),
    (256, 64, 128, 128),
    (256, 32, 64, 64),
])
def test_ssd_vs_sequential_oracle(S, hd, N, chunk, dtype):
    BH = 4
    xdt = (jax.random.normal(KEY, (BH, S, hd)) * 0.5).astype(dtype)
    dA = (-jax.random.uniform(jax.random.fold_in(KEY, 3), (BH, S)) * 0.1
          ).astype(dtype)
    Bm = (jax.random.normal(jax.random.fold_in(KEY, 4), (BH, S, N)) * 0.3
          ).astype(dtype)
    Cm = (jax.random.normal(jax.random.fold_in(KEY, 5), (BH, S, N)) * 0.3
          ).astype(dtype)
    got = ssd_scan_tpu(xdt, dA, Bm, Cm, chunk=chunk, interpret=True)
    want = ops.ssd(xdt, dA, Bm, Cm, impl="cpu")
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-3)


def test_ssd_chunk_invariance():
    """The chunked dual form must be invariant to the chunk size."""
    BH, S, hd, N = 2, 256, 32, 16
    xdt = jax.random.normal(KEY, (BH, S, hd)) * 0.5
    dA = -jax.random.uniform(jax.random.fold_in(KEY, 1), (BH, S)) * 0.2
    Bm = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(KEY, 3), (BH, S, N)) * 0.3
    outs = [ssd_scan_tpu(xdt, dA, Bm, Cm, chunk=c, interpret=True)
            for c in (32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4, rtol=1e-3)


# --------------------------------------------------------------------------- #
# rmsnorm
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (3, 100, 512), (2, 7, 896)])
def test_rmsnorm_vs_oracle(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 6), (shape[-1],))
    got = rmsnorm_tpu(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


# --------------------------------------------------------------------------- #
# XLA flash path (models/flash.py custom VJP) vs oracle incl. gradients
# --------------------------------------------------------------------------- #
def test_xla_flash_custom_vjp_grads():
    from repro.models.flash import flash_attention
    q = jax.random.normal(KEY, (2, 256, 4, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 256, 4, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 256, 4, 32))

    for window, cap in [(None, None), (64, None), (None, 30.0)]:
        f = lambda *a: jnp.sum(jnp.sin(
            flash_attention(*a, True, window, cap, 128, 128)))
        g = lambda *a: jnp.sum(jnp.sin(ref.attention_ref(
            *a, window=window, softcap=cap)))
        d1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        d2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(d1, d2):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)


# --------------------------------------------------------------------------- #
# psp_tick: fused sweep tick (control + data plane) vs its jnp reference
# --------------------------------------------------------------------------- #
def _tick_problem(seed, B, P, churn, ragged, k_max, d=5, m=4,
                  adaptive=False):
    """Random mid-flight tick state + params + one tick's noise.

    Row 0 gets a short horizon so the chained-tick tests cross the
    row-freeze gate (the merged-duration / dead-padding path) mid-run.
    With ``adaptive`` the batch mixes static rows with DSSP / Elastic-BSP
    / β-annealing rows carrying mid-flight policy state.
    """
    rng = np.random.default_rng(seed)
    n_true = np.full(B, P)
    if ragged:
        n_true = rng.integers(max(3, P // 2), P + 1, size=B)
        n_true[rng.integers(B)] = P          # batch width = max population
    valid_slot = np.arange(P) < n_true[:, None]
    alive = valid_slot & (rng.random((B, P)) < 0.85)
    alive[:, 0] = valid_slot[:, 0]           # keep every row populated
    kind = rng.integers(0, 3, size=B)        # 0=asp 1=full-view 2=sampled
    state = {
        "steps": rng.integers(0, 6, (B, P)).astype(np.int32),
        "alive": alive,
        "computing": rng.random((B, P)) < 0.5,
        "event_time": (rng.random((B, P)) * 2).astype(np.float32),
        "ready": (rng.random((B, P)) * 2).astype(np.float32),
        "blocked": rng.random((B, P)) < 0.3,
        "pend_leave": rng.integers(0, 2, B).astype(np.int32),
        "pend_join": rng.integers(0, 2, B).astype(np.int32),
        "w": rng.normal(size=(B, d)).astype(np.float32),
        "pulled": rng.normal(size=(B, P, d)).astype(np.float32),
    }
    horizon = np.full(B, 10.0, np.float32)
    horizon[0] = 0.5                         # row 0 freezes mid-run
    params = {
        "staleness": rng.integers(0, 4, B).astype(np.int32),
        "beta_clip": np.clip(k_max, 0, n_true - 1).astype(np.int32),
        "is_asp": kind == 0,
        "full_view": kind == 1,
        "sampled": kind == 2,
        "dist_hops": rng.integers(0, 5, B).astype(np.int32),
        "compute_time": (0.05 + rng.random((B, P)) * 0.1).astype(np.float32),
        "valid_slot": valid_slot,
        "w_true": rng.normal(size=(B, d)).astype(np.float32),
        "lr": (0.01 + rng.random(B) * 0.1).astype(np.float32),
        "noise_std": (rng.random(B) * 0.2).astype(np.float32),
        "horizon": horizon,
        "eps": np.float32(1e-4),
        "poll": np.float32(0.02),
    }
    masked = churn or ragged
    rand = {"dur": rng.random((B, P)).astype(np.float32),
            "X": rng.normal(size=(P, m, d)).astype(np.float32),
            "mb": rng.normal(size=(P, m)).astype(np.float32)}
    if k_max == 1 and not masked:
        rand["u1"] = rng.random(P).astype(np.float32)
    elif k_max > 0:
        shape = (B, P, P) if masked else (P, P)
        rand["scores"] = rng.random(shape).astype(np.float32)
    if churn:
        rand["leave"] = rng.random((B, P)).astype(np.float32)
        rand["join"] = rng.random((B, P)).astype(np.float32)
    leave_n = rng.integers(0, 2, B).astype(np.int32) * churn
    join_n = rng.integers(0, 2, B).astype(np.int32) * churn
    if adaptive:
        # draws appended last so static problems stay bit-identical
        akind = rng.integers(0, 4, size=B)   # 0=keep 1=dssp 2=ebsp 3=anneal
        is_dssp, is_ebsp = akind == 1, akind == 2
        is_ann = (akind == 3) & (k_max > 0)
        adapt = is_dssp | is_ebsp | is_ann
        params["is_dssp"], params["is_ebsp"] = is_dssp, is_ebsp
        params["is_anneal"] = is_ann
        params["full_view"] = np.where(adapt, is_dssp | is_ebsp,
                                       params["full_view"])
        params["sampled"] = np.where(adapt, is_ann, params["sampled"])
        params["is_asp"] = np.where(adapt, False, params["is_asp"])
        params["pol_lo"] = rng.integers(
            0, params["staleness"] + 1).astype(np.int32)
        params["beta_lo"] = rng.integers(
            0, params["beta_clip"] + 1).astype(np.int32)
        params["ebsp_range"] = (rng.random(B) * 4).astype(np.float32)
        params["ebsp_alpha"] = np.full(B, 0.5, np.float32)
        state["pol_thr"] = rng.integers(
            0, params["staleness"] + 1).astype(np.int32)
        state["pol_ema"] = (rng.random((B, P)) * 0.3).astype(np.float32)
        state["pol_beta"] = np.where(
            is_ann, params["beta_lo"], max(k_max, 0)).astype(np.int32)
    return state, rand, params, leave_n, join_n, masked


@pytest.mark.parametrize("churn,ragged,k_max,adaptive", [
    (False, False, 0, False),
    (False, False, 1, False),        # β = 1 fast path
    (False, False, 3, False),        # shared-score rank path
    (True, False, 2, False),         # churn: per-row masked scores
    (False, True, 2, False),         # ragged padding: dead-slot masking
    (True, True, 2, False),          # churn × ragged
    (False, False, 0, True),         # adaptive full-view (dssp/ebsp) rows
    (False, False, 3, True),         # adaptive incl. β-annealing rows
    (True, True, 2, True),           # adaptive × churn × ragged
])
def test_psp_tick_kernel_matches_ref(churn, ragged, k_max, adaptive):
    """Interpret-mode Pallas tick ≡ jnp reference, bit for bit, tick for
    tick — including the data-plane state (``w``/``pulled``) carried
    across several chained ticks, and the row-freeze (horizon) gate.

    Both paths run under jit, as in production (inside the sweep scan):
    eager-vs-compiled would differ by FMA-contraction ulps, jitted they
    must agree exactly.
    """
    import functools
    import jax
    from repro.kernels import ops as kops
    B, P = 3, 8
    state, rand, params, leave_n, join_n, masked = _tick_problem(
        0, B, P, churn, ragged, k_max, adaptive=adaptive)
    tick = {impl: jax.jit(functools.partial(
        kops.psp_tick, k_max=k_max, has_churn=churn, masked=masked,
        adaptive=adaptive, impl=impl)) for impl in ("ref", "interpret")}
    s_ref, s_ker = dict(state), dict(state)
    for i in range(5):
        t = np.float32(0.4 * (i + 1))
        rng_i = np.random.default_rng(100 + i)
        rand_i = {k: (rng_i.normal(size=v.shape) if k in ("X", "mb")
                      else rng_i.random(v.shape)).astype(np.float32)
                  for k, v in rand.items()}
        s_ref, o_ref = tick["ref"](s_ref, rand_i, params, t, leave_n,
                                   join_n)
        s_ker, o_ker = tick["interpret"](s_ker, rand_i, params, t, leave_n,
                                         join_n)
        for k in s_ref:
            np.testing.assert_array_equal(np.asarray(s_ref[k]),
                                          np.asarray(s_ker[k]),
                                          err_msg=f"tick {i} state {k}")
        for k in o_ref:
            np.testing.assert_array_equal(np.asarray(o_ref[k]),
                                          np.asarray(o_ker[k]),
                                          err_msg=f"tick {i} out {k}")


@pytest.mark.parametrize("adaptive", (False, True))
def test_psp_tick_frozen_row_is_inert(adaptive):
    """A row past its horizon must not move at all — state bit-frozen
    (including adaptive policy state), zero finishes, zero control
    traffic (the dead-padding-tick guarantee the chunk scheduler
    relies on)."""
    import functools
    import jax
    from repro.kernels import ops as kops
    B, P = 3, 8
    state, rand, params, leave_n, join_n, masked = _tick_problem(
        1, B, P, True, False, 2, adaptive=adaptive)
    params = dict(params)
    params["horizon"] = np.zeros(B, np.float32)      # all rows frozen
    leave_n = leave_n + 1                            # pending churn too
    tick = jax.jit(functools.partial(kops.psp_tick, k_max=2,
                                     has_churn=True, masked=masked,
                                     adaptive=adaptive, impl="ref"))
    new_state, out = tick(state, rand, params, np.float32(1.0),
                          leave_n, join_n)
    for k in state:
        np.testing.assert_array_equal(np.asarray(new_state[k]),
                                      np.asarray(state[k]),
                                      err_msg=f"state {k} moved")
    assert not np.asarray(out["fin"]).any()
    assert not np.asarray(out["start"]).any()
    assert np.asarray(out["n_fin"]).sum() == 0
    assert np.asarray(out["ctrl"]).sum() == 0


def test_psp_tick_interpret_reproduces_golden_sweep(monkeypatch):
    """A whole sweep through the interpret-mode kernel reproduces the jax
    backend's committed golden trace (β = 1 fast path scenario)."""
    import json
    import os
    from repro.core.simulator import SimConfig
    from repro.core.vector_sim import run_sweep
    from repro.core.barriers import make_barrier

    monkeypatch.setenv("PSP_TICK_IMPL", "interpret")
    cfg = SimConfig(n_nodes=3, duration=4.0, dim=4, batch=4, seed=11,
                    barrier=make_barrier("pbsp", staleness=2, sample_size=1))
    r = run_sweep([cfg], backend="jax")[0]
    golden_path = os.path.join(os.path.dirname(__file__), "golden",
                               "vector_sim_trace.json")
    with open(golden_path) as f:
        g = json.load(f)["jax"]
    assert r.steps.tolist() == g["steps"]
    assert r.total_updates == g["total_updates"]
    assert r.server_updates.tolist() == g["server_updates"]
    np.testing.assert_allclose(r.errors, g["errors"], rtol=1e-4, atol=1e-5)


def test_psp_tick_churn_sweep_impl_invariant(monkeypatch):
    """Churn sweeps agree exactly across tick impls (ref vs interpret)."""
    from repro.core.simulator import SimConfig
    from repro.core.vector_sim import run_sweep
    from repro.core.barriers import make_barrier

    cfgs = [SimConfig(n_nodes=10, duration=3.0, dim=4, batch=4, seed=s,
                      churn_leave_rate=1.0, churn_join_rate=1.0,
                      barrier=make_barrier("pssp", staleness=2,
                                           sample_size=2))
            for s in (0, 1)]
    monkeypatch.setenv("PSP_TICK_IMPL", "ref")
    ref = run_sweep(cfgs, backend="jax")
    monkeypatch.setenv("PSP_TICK_IMPL", "interpret")
    ker = run_sweep(cfgs, backend="jax")
    for a, b in zip(ref, ker):
        np.testing.assert_array_equal(a.steps, b.steps)
        # error traces may differ by GEMM-microkernel ulps: XLA picks a
        # different dot microkernel per scenario-batch width (the ref
        # batches rows, the kernel grid iterates them), which reorders
        # the f32 reduction.  Control-plane integers stay exact.
        np.testing.assert_allclose(a.errors, b.errors, rtol=0, atol=1e-6)
        assert a.total_updates == b.total_updates
        assert a.control_messages == b.control_messages
