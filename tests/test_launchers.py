"""CLI launcher smoke tests (train/serve/dryrun entry points)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_cli(mod, *args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-m", mod, *args],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_train_plain():
    out = run_cli("repro.launch.train", "--arch", "qwen2-0.5b", "--reduced",
                  "--steps", "6", "--batch", "2", "--seq", "64",
                  "--d-model", "128", "--vocab", "128", "--log-every", "2")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss" in out.stdout


def test_train_psp_barrier_and_checkpoint(tmp_path):
    out = run_cli("repro.launch.train", "--arch", "qwen2-0.5b", "--reduced",
                  "--steps", "6", "--batch", "2", "--seq", "64",
                  "--d-model", "128", "--vocab", "128",
                  "--barrier", "pbsp", "--workers", "2",
                  "--ckpt-dir", str(tmp_path))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "mean_step" in out.stdout
    assert any(f.endswith(".npz") for f in os.listdir(tmp_path))


def test_serve_cli():
    out = run_cli("repro.launch.serve", "--arch", "mamba2-780m", "--reduced",
                  "--requests", "2", "--batch", "2", "--prompt-len", "8",
                  "--max-new", "4")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout
