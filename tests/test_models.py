"""Per-arch smoke tests: reduced configs (≤2 layers, d_model ≤ 512,
≤4 experts) run one forward/train step on CPU asserting shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import init_model, loss_fn, prefill, decode_step
from repro.optim import adamw, apply_updates

ARCH_NAMES = sorted(ARCHS)


def make_batch(cfg, key, B=2, S=64):
    F = cfg.frontend_tokens
    batch = {"tokens": jax.random.randint(key, (B, S - F), 0,
                                          cfg.vocab_size)}
    if F:
        batch["embeds"] = jax.random.normal(key, (B, F, cfg.d_model),
                                            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_config_invariants(arch):
    cfg = reduced(get_config(arch))
    assert cfg.n_layers <= 5 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    batch = make_batch(cfg, key)

    opt = adamw(1e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        upd, state = opt.update(grads, state, params)
        return apply_updates(params, upd), state, loss

    params, state, loss = step(params, state, batch)
    assert jnp.isfinite(loss), arch
    leaves = jax.tree.leaves(params)
    assert all(jnp.all(jnp.isfinite(x)) for x in leaves), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_serve_step_shapes(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_model(cfg, key)
    B, S = 2, 32
    F = cfg.frontend_tokens
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    emb = (jax.random.normal(key, (B, F, cfg.d_model), jnp.bfloat16)
           if F else None)
    logits, cache = prefill(params, toks, cfg, embeds=emb, max_len=S + F + 8)
    assert logits.shape == (B, cfg.vocab_size)
    nxt = jnp.argmax(logits, -1)[:, None]
    logits2, cache = decode_step(params, cache, nxt, cfg)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(cache["length"]) == S + F + 1


def test_loss_decreases_when_training():
    cfg = reduced(get_config("qwen2-0.5b"))
    cfg = dataclasses.replace(cfg, vocab_size=64, remat=False)
    key = jax.random.PRNGKey(2)
    params = init_model(cfg, key)
    opt = adamw(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg)
        upd, state = opt.update(grads, state, params)
        return apply_updates(params, upd), state, loss

    # memorise one small batch
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}
    losses = []
    for _ in range(30):
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_param_count_sane():
    # full configs should land near the advertised sizes
    approx = {
        "qwen2-0.5b": (0.3e9, 0.8e9),
        "h2o-danube-1.8b": (1.4e9, 2.3e9),
        "gemma2-27b": (20e9, 32e9),
        "dbrx-132b": (100e9, 150e9),
        "mamba2-780m": (0.5e9, 1.1e9),
        "qwen3-moe-30b-a3b": (22e9, 36e9),
    }
    for name, (lo, hi) in approx.items():
        n = get_config(name).param_count()
        assert lo < n < hi, (name, n)
    # MoE active < total
    q3 = get_config("qwen3-moe-30b-a3b")
    assert q3.param_count(active_only=True) < 0.2 * q3.param_count()
