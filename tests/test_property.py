"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an optional test-only dependency (see ``pyproject.toml``'s
``[test]`` extra); the whole module is skipped when it is absent so that the
tier-1 suite collects cleanly on minimal environments.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.barriers import ASP, BSP, PBSP, PSSP, SSP
from repro.core.bounds import mean_lag_bound, psp_lag_pmf, variance_lag_bound
from repro.core.sampling import sample_steps_jax
from repro.models.layers import chunked_cross_entropy, rmsnorm
from repro.kernels import ref

steps_strategy = st.lists(st.integers(0, 50), min_size=2, max_size=32)


class TestBarrierProperties:
    @given(steps_strategy, st.integers(0, 8))
    @settings(max_examples=50, deadline=None)
    def test_pssp_no_stricter_than_pbsp(self, steps, s):
        """Monotonicity: larger staleness can only make passing easier."""
        rng = np.random.default_rng(0)
        my = max(steps)
        loose = PSSP(staleness=s, sample_size=len(steps))
        strict = PBSP(sample_size=len(steps))
        if strict.can_pass(my, steps, np.random.default_rng(0)):
            assert loose.can_pass(my, steps, np.random.default_rng(0))

    @given(steps_strategy)
    @settings(max_examples=50, deadline=None)
    def test_minimum_always_passes(self, steps):
        """The slowest worker can never be barrier-blocked."""
        rng = np.random.default_rng(1)
        my = min(steps)
        for barrier in (BSP(), SSP(staleness=3), ASP(),
                        PBSP(sample_size=4), PSSP(staleness=2,
                                                  sample_size=4)):
            assert barrier.can_pass(my, steps, rng)

    @given(steps_strategy, st.integers(1, 8))
    @settings(max_examples=50, deadline=None)
    def test_sample_subsets_full_view(self, steps, beta):
        """If the classic barrier passes, any sampled version passes too
        (a subset of constraints cannot be stricter)."""
        my = max(steps)
        if SSP(staleness=4).can_pass(my, steps, np.random.default_rng(0)):
            assert PSSP(staleness=4, sample_size=beta).can_pass(
                my, steps, np.random.default_rng(2))


class TestTheoryProperties:
    @given(st.floats(0.05, 0.95), st.integers(1, 64), st.integers(0, 8))
    @settings(max_examples=60, deadline=None)
    def test_pmf_valid(self, F_r, beta, r):
        f = np.zeros(201)
        f[: r + 1] = F_r / (r + 1)
        f[r + 1:] = (1 - F_r) / (200 - r)
        p = psp_lag_pmf(f, beta=beta, r=r, T=200)
        assert abs(p.sum() - 1) < 1e-8
        assert (p >= -1e-12).all()

    @given(st.floats(0.1, 0.9), st.integers(0, 6))
    @settings(max_examples=40, deadline=None)
    def test_bounds_monotone_in_beta_at_fixed_a(self, a, r):
        # the paper's Fig-4/5 monotonicity statement holds at fixed
        # a = F(r)^β with per-curve F(r) = a^{1/β}
        T = 5000
        ms = [mean_lag_bound(a ** (1 / b), b, r, T) for b in (1, 4, 16, 64)]
        vs = [variance_lag_bound(a ** (1 / b), b, r, T)
              for b in (1, 4, 16, 64)]
        assert all(x >= y - 1e-9 for x, y in zip(ms, ms[1:]))
        assert all(x >= y - 1e-9 for x, y in zip(vs, vs[1:]))


class TestSamplingProperties:
    @given(st.integers(2, 24), st.integers(1, 8), st.integers(0, 1000))
    @settings(max_examples=40, deadline=None)
    def test_sample_steps_jax_bounds(self, w, beta, seed):
        beta = min(beta, w - 1)
        steps = jnp.arange(w, dtype=jnp.int32) * 3
        sampled, valid = sample_steps_jax(jax.random.PRNGKey(seed), steps,
                                          beta)
        assert sampled.shape == (w, beta)
        vals = set(np.asarray(steps).tolist())
        assert set(np.asarray(sampled).ravel().tolist()) <= vals


class TestNumericsProperties:
    @given(st.integers(1, 4), st.integers(2, 40), st.integers(8, 64))
    @settings(max_examples=20, deadline=None)
    def test_rmsnorm_scale_invariant_structure(self, b, s, d):
        x = jax.random.normal(jax.random.PRNGKey(b), (b, s, d))
        w = jnp.ones((d,))
        y = rmsnorm(x, w)
        # RMS of output rows ≈ 1
        rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, -1))
        assert bool(jnp.all(jnp.abs(rms - 1.0) < 1e-2))
        # positive-homogeneous: rmsnorm(c·x) == rmsnorm(x)
        y2 = rmsnorm(3.7 * x, w)
        assert bool(jnp.allclose(y, y2, atol=1e-4))

    @given(st.integers(2, 6), st.integers(4, 32))
    @settings(max_examples=20, deadline=None)
    def test_chunked_ce_matches_direct(self, b, s):
        import dataclasses
        from repro.configs import get_config, reduced
        cfg = reduced(get_config("qwen2-0.5b"))
        cfg = dataclasses.replace(cfg, logit_softcap=None)
        d, v = 16, 32
        h = jax.random.normal(jax.random.PRNGKey(0), (b, s, d))
        u = jax.random.normal(jax.random.PRNGKey(1), (d, v))
        labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
        got = chunked_cross_entropy(h, labels, u, cfg, chunk=8)
        logits = (h @ u).astype(jnp.float32)
        want = jnp.mean(jax.nn.logsumexp(logits, -1) -
                        jnp.take_along_axis(logits, labels[..., None],
                                            -1)[..., 0])
        assert abs(float(got - want)) < 1e-4

    @given(st.integers(16, 128))
    @settings(max_examples=15, deadline=None)
    def test_attention_rows_sum_to_one(self, s):
        """Attention output of constant V must be that constant."""
        s = (s // 16) * 16
        q = jax.random.normal(jax.random.PRNGKey(0), (1, s, 2, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, s, 2, 16))
        v = jnp.ones((1, s, 2, 16))
        o = ref.attention_ref(q, k, v)
        assert bool(jnp.allclose(o, 1.0, atol=1e-5))
