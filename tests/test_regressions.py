"""Regression tests for simulator barrier-semantics bugfixes.

1. **Self-sampling** — a worker must never draw *itself* into its β-sample
   (paper §6.4 samples β *other* workers); with self-sampling a worker
   trivially satisfies the barrier and drifts ahead.
2. **Churn wake** — when a departed node was the global step minimum, its
   frozen step must not keep blocking waiters (full-view SSP waiters were
   only woken by the min *moving*, which a dead node's step never does).
"""
import numpy as np
import pytest

from repro.core.barriers import PBSP, PSSP, SSP, make_barrier
from repro.core.sampling import CentralSampler
from repro.core.simulator import SimConfig, Simulator, run_simulation


class TestSelfSamplingExcluded:
    def test_pbsp_beta1_two_nodes_is_bsp(self):
        """β=1, P=2 makes self-sampling deterministic: the only valid
        sample is the *other* node, so pBSP(β=1) must behave exactly like
        BSP — lockstep, spread ≤ 1.  With the self-sampling bug the leader
        passes ~every other poll and drifts unboundedly ahead."""
        r = run_simulation(SimConfig(
            n_nodes=2, duration=10.0, dim=8, seed=0,
            barrier=make_barrier("pbsp", sample_size=1)))
        assert int(r.steps.max() - r.steps.min()) <= 1

    def test_view_never_contains_self(self):
        steps = np.arange(10) * 100          # distinct markers
        bar = PBSP(sample_size=4)
        rng = np.random.default_rng(0)
        for self_index in (0, 3, 9):
            for _ in range(50):
                view = bar.view(steps, rng, self_index=self_index)
                assert steps[self_index] not in view

    def test_can_pass_excludes_self(self):
        # my own step is the only one within staleness: with self excluded
        # the sampled peer is always the straggler, so the check must fail
        bar = PBSP(sample_size=1)
        rng = np.random.default_rng(0)
        for _ in range(25):
            assert not bar.can_pass(10, [10, 0], rng, self_index=0)

    def test_full_view_keeps_whole_vector(self):
        # classic barriers still evaluate the full state (self is harmless)
        bar = SSP(staleness=4)
        view = bar.view([1, 2, 3], np.random.default_rng(0), self_index=1)
        assert view.tolist() == [1, 2, 3]

    def test_central_sampler_exclude(self):
        s = CentralSampler(seed=0)
        steps = np.arange(8) * 10
        for _ in range(30):
            out = s.sample(steps, beta=3, exclude=5)
            assert 50 not in out.steps
            assert 5 not in out.worker_ids

    def test_simulator_centralised_path_excludes_self(self, monkeypatch):
        """The simulator must pass the deciding node's index through to the
        sampler on the centralised path."""
        sim = Simulator(SimConfig(n_nodes=4, dim=4, seed=0,
                                  barrier=make_barrier("pbsp",
                                                       sample_size=2)))
        seen = []
        orig = sim.sampler.sample

        def spy(steps, beta, exclude=None):
            seen.append(exclude)
            return orig(steps, beta, exclude=exclude)

        monkeypatch.setattr(sim.sampler, "sample", spy)
        sim._can_pass(2)
        assert seen == [2]


class _LeaveRig:
    """Deterministic stand-in for the simulator RNG inside ``_on_leave``."""

    def __init__(self, leave_node):
        self._leave_node = leave_node

    def choice(self, ids):
        return self._leave_node

    def exponential(self, scale):
        return 1.0

    def random(self, *a, **kw):
        return 0.5


class TestChurnWake:
    def _blocked_sim(self, barrier):
        cfg = SimConfig(n_nodes=4, dim=4, seed=0, barrier=barrier,
                        churn_leave_rate=0.1)
        sim = Simulator(cfg)
        sim.steps = np.array([0, 10, 10, 10], dtype=np.int64)
        sim._waiting = {1: 10, 2: 10, 3: 10}
        sim.rng = _LeaveRig(leave_node=0)
        return sim

    def test_leave_of_straggler_wakes_full_view_waiters(self):
        sim = self._blocked_sim(SSP(staleness=4))
        assert sim._full_view
        sim._on_leave()
        assert not sim.alive[0]
        assert sim._waiting == {}        # all three waiters released

    def test_leave_of_straggler_wakes_sampled_waiters(self):
        """Pre-fix only full-view barriers re-checked on leave; a departed
        global-minimum straggler must also wake sampled-barrier waiters."""
        sim = self._blocked_sim(PSSP(staleness=4, sample_size=2))
        assert not sim._full_view
        sim._on_leave()
        assert not sim.alive[0]
        assert sim._waiting == {}

    def test_leave_of_non_minimum_keeps_sampled_waiters_polling(self):
        sim = self._blocked_sim(PSSP(staleness=4, sample_size=2))
        sim.steps = np.array([0, 10, 10, 10], dtype=np.int64)
        sim.rng = _LeaveRig(leave_node=2)   # not the straggler
        sim._waiting = {1: 10, 3: 10}
        sim._on_leave()
        # blocked by the still-alive straggler: nothing released eagerly
        assert 1 in sim._waiting and 3 in sim._waiting

    def test_churn_run_stays_live(self):
        r = run_simulation(SimConfig(
            n_nodes=16, duration=8.0, dim=8, seed=1,
            barrier=SSP(staleness=2),
            churn_leave_rate=0.5, churn_join_rate=0.5))
        assert r.mean_progress > 0
        assert np.isfinite(r.final_error)
