"""Regression tests for simulator barrier-semantics bugfixes.

1. **Self-sampling** — a worker must never draw *itself* into its β-sample
   (paper §6.4 samples β *other* workers); with self-sampling a worker
   trivially satisfies the barrier and drifts ahead.
2. **Churn wake** — when a departed node was the global step minimum, its
   frozen step must not keep blocking waiters (full-view SSP waiters were
   only woken by the min *moving*, which a dead node's step never does).
3. **Batched churn** — the vectorized engine's alive-masked churn rows
   must reproduce the event engine's ``_on_leave`` wake semantics (masked
   min-step wakeup, frozen departed nodes, one poll per failed attempt).
"""
import numpy as np
import pytest

from repro.core.barriers import PBSP, PSSP, SSP, make_barrier
from repro.core.sampling import CentralSampler
from repro.core.simulator import SimConfig, Simulator, run_simulation
from repro.core.vector_sim import VectorSimulator, run_sweep


class TestSelfSamplingExcluded:
    def test_pbsp_beta1_two_nodes_is_bsp(self):
        """β=1, P=2 makes self-sampling deterministic: the only valid
        sample is the *other* node, so pBSP(β=1) must behave exactly like
        BSP — lockstep, spread ≤ 1.  With the self-sampling bug the leader
        passes ~every other poll and drifts unboundedly ahead."""
        r = run_simulation(SimConfig(
            n_nodes=2, duration=10.0, dim=8, seed=0,
            barrier=make_barrier("pbsp", sample_size=1)))
        assert int(r.steps.max() - r.steps.min()) <= 1

    def test_view_never_contains_self(self):
        steps = np.arange(10) * 100          # distinct markers
        bar = PBSP(sample_size=4)
        rng = np.random.default_rng(0)
        for self_index in (0, 3, 9):
            for _ in range(50):
                view = bar.view(steps, rng, self_index=self_index)
                assert steps[self_index] not in view

    def test_can_pass_excludes_self(self):
        # my own step is the only one within staleness: with self excluded
        # the sampled peer is always the straggler, so the check must fail
        bar = PBSP(sample_size=1)
        rng = np.random.default_rng(0)
        for _ in range(25):
            assert not bar.can_pass(10, [10, 0], rng, self_index=0)

    def test_full_view_keeps_whole_vector(self):
        # classic barriers still evaluate the full state (self is harmless)
        bar = SSP(staleness=4)
        view = bar.view([1, 2, 3], np.random.default_rng(0), self_index=1)
        assert view.tolist() == [1, 2, 3]

    def test_central_sampler_exclude(self):
        s = CentralSampler(seed=0)
        steps = np.arange(8) * 10
        for _ in range(30):
            out = s.sample(steps, beta=3, exclude=5)
            assert 50 not in out.steps
            assert 5 not in out.worker_ids

    def test_simulator_centralised_path_excludes_self(self, monkeypatch):
        """The simulator must pass the deciding node's index through to the
        sampler on the centralised path."""
        sim = Simulator(SimConfig(n_nodes=4, dim=4, seed=0,
                                  barrier=make_barrier("pbsp",
                                                       sample_size=2)))
        seen = []
        orig = sim.sampler.sample

        def spy(steps, beta, exclude=None):
            seen.append(exclude)
            return orig(steps, beta, exclude=exclude)

        monkeypatch.setattr(sim.sampler, "sample", spy)
        sim._can_pass(2)
        assert seen == [2]


class _LeaveRig:
    """Deterministic stand-in for the simulator RNG inside ``_on_leave``."""

    def __init__(self, leave_node):
        self._leave_node = leave_node

    def choice(self, ids):
        return self._leave_node

    def exponential(self, scale):
        return 1.0

    def random(self, *a, **kw):
        return 0.5


class TestChurnWake:
    def _blocked_sim(self, barrier):
        cfg = SimConfig(n_nodes=4, dim=4, seed=0, barrier=barrier,
                        churn_leave_rate=0.1)
        sim = Simulator(cfg)
        sim.steps = np.array([0, 10, 10, 10], dtype=np.int64)
        sim._waiting = {1: 10, 2: 10, 3: 10}
        sim.rng = _LeaveRig(leave_node=0)
        return sim

    def test_leave_of_straggler_wakes_full_view_waiters(self):
        sim = self._blocked_sim(SSP(staleness=4))
        assert sim._full_view
        sim._on_leave()
        assert not sim.alive[0]
        assert sim._waiting == {}        # all three waiters released

    def test_leave_of_straggler_wakes_sampled_waiters(self):
        """Pre-fix only full-view barriers re-checked on leave; a departed
        global-minimum straggler must also wake sampled-barrier waiters."""
        sim = self._blocked_sim(PSSP(staleness=4, sample_size=2))
        assert not sim._full_view
        sim._on_leave()
        assert not sim.alive[0]
        assert sim._waiting == {}

    def test_leave_of_non_minimum_keeps_sampled_waiters_polling(self):
        sim = self._blocked_sim(PSSP(staleness=4, sample_size=2))
        sim.steps = np.array([0, 10, 10, 10], dtype=np.int64)
        sim.rng = _LeaveRig(leave_node=2)   # not the straggler
        sim._waiting = {1: 10, 3: 10}
        sim._on_leave()
        # blocked by the still-alive straggler: nothing released eagerly
        assert 1 in sim._waiting and 3 in sim._waiting

    def test_churn_run_stays_live(self):
        r = run_simulation(SimConfig(
            n_nodes=16, duration=8.0, dim=8, seed=1,
            barrier=SSP(staleness=2),
            churn_leave_rate=0.5, churn_join_rate=0.5))
        assert r.mean_progress > 0
        assert np.isfinite(r.final_error)


class TestBatchedChurnWake:
    """The vectorized engine's churn rows replay the event engine's
    ``_on_leave`` wake semantics: the barrier minimum is re-derived from
    the alive-masked step matrix, so a departed global-min straggler
    releases waiters instead of gating them forever."""

    def _rig(self, barrier):
        cfg = SimConfig(n_nodes=4, dim=4, seed=0, barrier=barrier,
                        churn_leave_rate=0.1)
        sim = VectorSimulator([cfg])
        # node 0: frozen global min, busy far in the future;
        # nodes 1–3: waiters blocked on it, due every tick
        sim.steps[:] = np.array([0, 10, 10, 10])
        sim.computing[:] = np.array([True, False, False, False])
        sim.event_time[:] = np.array([1e9, 0.0, 0.0, 0.0])
        sim.ready[:] = 0.0
        sim.blocked[:] = np.array([False, True, True, True])
        # drive churn by hand: neutralise the pre-sampled schedules
        sim.leave_counts[:] = 0
        sim.join_counts[:] = 0
        return sim

    def test_departed_min_unblocks_full_view_waiters(self):
        sim = self._rig(SSP(staleness=4))
        sim._tick(0.02, 0)
        assert not sim.computing[0, 1:].any()     # gated by the straggler
        sim.alive[0, 0] = False
        sim._tick(0.04, 1)
        assert sim.computing[0, 1:].all()         # all three released

    def test_departed_min_unblocks_sampled_waiters(self):
        # β = 3 over P = 4 samples *every* alive peer: deterministically
        # fails while the straggler lives, passes once it departs
        sim = self._rig(PSSP(staleness=4, sample_size=3))
        sim._tick(0.02, 0)
        assert not sim.computing[0, 1:].any()
        sim.alive[0, 0] = False
        sim._tick(0.04, 1)
        assert sim.computing[0, 1:].all()

    def test_one_poll_per_failed_attempt(self):
        """The event engine's no-duplicate-poll fix, grid analogue: a
        blocked sampled row advances its poll anchor by exactly one
        ``poll_interval`` per failed attempt — never two chains."""
        sim = self._rig(PSSP(staleness=4, sample_size=3))
        for i, t in enumerate((0.02, 0.04, 0.06)):
            sim._tick(t, i)
        assert not sim.computing[0, 1:].any()
        assert np.allclose(sim.ready[0, 1:], 0.06)
        assert np.allclose(sim.event_time[0, 1:], 0.06)

    def test_departed_node_is_frozen(self):
        """A dead node neither finishes nor updates the server — the event
        engine's early-return in ``_on_finish``."""
        cfg = SimConfig(n_nodes=4, dim=4, seed=0,
                        barrier=make_barrier("asp"), churn_leave_rate=0.1)
        sim = VectorSimulator([cfg])
        sim.leave_counts[:] = 0
        sim.join_counts[:] = 0
        sim.event_time[:] = 0.01                  # everyone due
        sim.alive[0, 0] = False
        sim._tick(0.02, 0)
        assert sim.steps[0].tolist() == [0, 1, 1, 1]
        assert sim.total_updates[0] == 3

    def test_join_restarts_at_max_alive_step(self):
        cfg = SimConfig(n_nodes=4, dim=4, seed=0, barrier=SSP(staleness=4),
                        churn_join_rate=0.1)
        sim = VectorSimulator([cfg])
        sim.join_counts[:] = 0
        sim.alive[0, 0] = False
        sim.steps[:] = np.array([5, 9, 7, 8])
        sim._churn_join(np.array([True]), t=1.0)
        assert sim.alive[0, 0]
        assert sim.steps[0, 0] == 9               # fresh start at max alive
        assert not sim.computing[0, 0]            # decides this tick
        assert sim.event_time[0, 0] == 1.0

    @pytest.mark.parametrize("backend", ("numpy", "jax"))
    def test_leave_only_agrees_with_event_engine(self, backend):
        """End-to-end: under leave-only churn (the regime of the original
        ``_on_leave`` hang) both backends track the event engine's
        progress — a missing masked-min wakeup would stall full-view rows
        and collapse this statistic."""
        cfgs = [SimConfig(n_nodes=8, duration=6.0, dim=8, seed=s,
                          barrier=SSP(staleness=2), churn_leave_rate=0.6)
                for s in range(4)]
        ev = np.mean([run_simulation(c).mean_progress for c in cfgs])
        vec = np.mean([r.mean_progress
                       for r in run_sweep(cfgs, backend=backend)])
        assert abs(vec - ev) <= 0.25 * ev + 1.0
