"""HLO cost analyzer: trip-count multiplication validated on known cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import HW, collective_bytes, roofline_report
from repro.roofline.hlo_cost import analyze_hlo


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(lambda x, y: x @ y).lower(a, a).compile()
    r = analyze_hlo(c.as_text())
    assert r.flops == 2 * 256 ** 3


def test_scan_trip_count_multiplied():
    w = jax.ShapeDtypeStruct((8, 512, 512), jnp.float32)
    x = jax.ShapeDtypeStruct((512,), jnp.float32)

    def f(ws, x):
        return jax.lax.scan(lambda c, wi: (wi @ c, None), x, ws)[0]

    c = jax.jit(f).lower(w, x).compile()
    r = analyze_hlo(c.as_text())
    assert r.flops == 8 * 2 * 512 ** 2
    assert 8 in r.while_trips.values()
    # builtin cost_analysis counts the body once — document the gap
    # (cost_analysis returns a per-device list on newer jax)
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < r.flops


def test_nested_scan():
    w = jax.ShapeDtypeStruct((4, 3, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64,), jnp.float32)

    def f(ws, x):
        def outer(c, wrow):
            def inner(ci, wi):
                return wi @ ci, None
            return jax.lax.scan(inner, c, wrow)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    c = jax.jit(f).lower(w, x).compile()
    r = analyze_hlo(c.as_text())
    assert r.flops == 4 * 3 * 2 * 64 ** 2


def test_bytes_scale_with_trips():
    w = jax.ShapeDtypeStruct((16, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128,), jnp.float32)

    def f(ws, x):
        return jax.lax.scan(lambda c, wi: (jnp.tanh(wi @ c), None), x, ws)[0]

    c = jax.jit(f).lower(w, x).compile()
    r = analyze_hlo(c.as_text())
    # at least the 16 weight slices must be read
    assert r.bytes >= 16 * 128 * 128 * 4


def test_collective_regex_parse():
    hlo = """
ENTRY %main (a: f32[16,1024]) -> f32[16,1024] {
  %a = f32[16,1024]{1,0} parameter(0)
  %ag = f32[256,1024]{1,0} all-gather(%a), dimensions={0}
  %ar = f32[16,1024]{1,0} all-reduce(%a), to_apply=%sum
  ROOT %cp = f32[16,1024]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    # the quick regex variant falls back to OUTPUT size when operand
    # shapes aren't inline (all-gather output = 256 rows);
    # analyze_hlo resolves operands through the instruction table.
    c = collective_bytes(hlo)
    assert c["all-gather"] == 256 * 1024 * 4
    assert c["all-reduce"] == 16 * 1024 * 4
    assert c["collective-permute"] == 16 * 1024 * 4
    r = analyze_hlo(hlo)
    assert r.coll["all-gather"] == 16 * 1024 * 4


def test_roofline_report_terms():
    rep = roofline_report({"flops": 197e12, "bytes accessed": 819e9},
                          "", chips=256, model_flops_total=197e12 * 256)
    assert abs(rep.compute_s - 1.0) < 1e-9
    assert abs(rep.memory_s - 1.0) < 1e-9
    assert rep.bottleneck in ("compute", "memory")
    assert abs(rep.useful_ratio - 1.0) < 1e-9
