"""The sampling primitive + structured overlay (paper §3.2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overlay import ChordOverlay, FullMembershipOverlay
from repro.core.sampling import CentralSampler, OverlaySampler, \
    sample_steps_jax


class TestOverlay:
    def test_population_estimate(self):
        ov = ChordOverlay(seed=0)
        for i in range(500):
            ov.join(i)
        est = ov.estimate_population(probes=64)
        assert 250 < est < 1000    # density estimator is unbiased-ish

    def test_uniform_sampling(self):
        ov = ChordOverlay(seed=1)
        for i in range(64):
            ov.join(i)
        counts = np.zeros(64)
        for _ in range(400):
            for p in ov.sample(4):
                counts[p] += 1
        # successor sampling is gap-proportional (approximately uniform
        # for uniform ids): nearly all nodes reachable, none dominant
        assert (counts > 0).sum() >= 0.9 * len(counts)
        assert counts.max() < 30 * counts.mean()

    def test_churn(self):
        ov = ChordOverlay(seed=2)
        ids = [ov.join(i) for i in range(16)]
        ov.leave(ids[3])
        assert len(ov) == 15
        assert 3 not in ov.sample(15)

    def test_lookup_cost_logarithmic(self):
        ov = ChordOverlay(seed=3)
        for i in range(1024):
            ov.join(i)
        assert ov.lookup_hops(0) == 10

    def test_sample_excludes_self(self):
        ov = ChordOverlay(seed=4)
        for i in range(8):
            ov.join(i)
        for _ in range(20):
            assert 0 not in ov.sample(7, exclude=0)


class TestSamplers:
    def test_central_full_view(self):
        s = CentralSampler(seed=0)
        out = s.sample([1, 2, 3], beta=None)
        assert list(out.steps) == [1, 2, 3]
        assert out.cost_hops == 0

    def test_central_counting_process_is_free(self):
        # paper §5: centralised sampling "is as trivial as a counting process"
        s = CentralSampler(seed=0)
        assert s.sample(list(range(100)), beta=10).cost_hops == 0

    def test_overlay_sampling_charges_hops(self):
        ov = FullMembershipOverlay(100, seed=0)
        s = OverlaySampler(ov)
        out = s.sample(np.arange(100), beta=10)
        assert out.cost_hops > 0
        assert len(out.steps) == 10


class TestJaxSampling:
    def test_shapes_and_no_self(self):
        steps = jnp.arange(16, dtype=jnp.int32)
        sampled, valid = sample_steps_jax(jax.random.PRNGKey(0), steps, 4)
        assert sampled.shape == (16, 4) and bool(valid.all())
        for w in range(16):
            assert w not in sampled[w].tolist()   # exclude_self

    def test_without_replacement(self):
        steps = jnp.arange(8, dtype=jnp.int32)
        sampled, _ = sample_steps_jax(jax.random.PRNGKey(1), steps, 7)
        for w in range(8):
            row = sampled[w].tolist()
            assert len(set(row)) == 7

    def test_beta_zero(self):
        sampled, valid = sample_steps_jax(jax.random.PRNGKey(2),
                                          jnp.arange(4, dtype=jnp.int32), 0)
        assert sampled.shape == (4, 0)
