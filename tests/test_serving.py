"""Serving tier: request lifecycle, snapshot bus, hot-swap under load.

The swap tests pin the tentpole invariant: a snapshot hot-swap NEVER
perturbs in-flight requests — they finish bit-for-bit on the snapshot
they were admitted under (greedy decode), and only requests admitted
after the swap see the new params.
"""
import json
import os
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.faults import make_plan
from repro.models import init_model
from repro.serving import (ChaosPublisher, InferenceServer, Request,
                           ServeConfig, ServingEngine, SnapshotPublisher,
                           SnapshotWatcher)


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("qwen2-0.5b"))
    params = init_model(cfg, jax.random.PRNGKey(0))
    params_b = init_model(cfg, jax.random.PRNGKey(1))
    return cfg, params, params_b


def _scfg(**kw):
    base = dict(batch=2, max_len=64, max_new_tokens=6, max_groups=4)
    base.update(kw)
    return ServeConfig(**base)


class TestLifecycle:
    def test_submit_step_drain(self, model):
        cfg, params, _ = model
        eng = ServingEngine(params, cfg, _scfg())
        ids = [eng.submit(Request(prompt=np.arange(1, 4 + i, dtype=np.int32)))
               for i in range(3)]
        comps = {c.req_id: c for c in eng.drain()}
        assert sorted(comps) == ids
        assert all(len(c.tokens) == 6 for c in comps.values())
        assert all(c.finish_reason == "length" for c in comps.values())
        assert not eng.has_pending()

    def test_continuous_admission_matches_solo(self, model):
        # a request admitted into a RUNNING group is left-padded to the
        # group clock; by batch-row independence it must decode exactly
        # like a solo request with that padding made explicit
        cfg, params, _ = model
        eng = ServingEngine(params, cfg, _scfg(batch=3, max_groups=1))
        eng.submit(Request(prompt=np.arange(1, 8, dtype=np.int32)))
        eng.submit(Request(prompt=np.arange(2, 9, dtype=np.int32)))
        eng.step()
        eng.step()
        clock = eng._groups[0].length            # pad target at admission
        late = np.arange(3, 6, dtype=np.int32)
        rid = eng.submit(Request(prompt=late))   # joins the running group
        comps = {c.req_id: c for c in eng.drain()}
        solo = ServingEngine(params, cfg, _scfg())
        padded = np.concatenate([np.zeros(clock - late.size, np.int32), late])
        sid = solo.submit(Request(prompt=padded))
        ref = {c.req_id: c for c in solo.drain()}
        assert np.array_equal(comps[rid].tokens, ref[sid].tokens)

    def test_max_new_tokens_per_request(self, model):
        cfg, params, _ = model
        eng = ServingEngine(params, cfg, _scfg())
        a = eng.submit(Request(prompt=np.asarray([1, 2, 3], np.int32),
                               max_new_tokens=2))
        b = eng.submit(Request(prompt=np.asarray([1, 2, 3], np.int32)))
        comps = {c.req_id: c for c in eng.drain()}
        assert len(comps[a].tokens) == 2
        assert len(comps[b].tokens) == 6

    def test_oversized_request_rejected(self, model):
        cfg, params, _ = model
        eng = ServingEngine(params, cfg, _scfg(max_len=16))
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(Request(prompt=np.arange(20, dtype=np.int32)))
        with pytest.raises(ValueError, match="non-empty"):
            eng.submit(Request(prompt=np.asarray([], np.int32)))

    def test_queue_backpressure_max_groups(self, model):
        # more distinct-shaped requests than groups: everything still
        # completes, FIFO, nothing dropped
        cfg, params, _ = model
        eng = ServingEngine(params, cfg, _scfg(batch=2, max_groups=2))
        ids = [eng.submit(Request(prompt=np.arange(1, 4, dtype=np.int32)))
               for _ in range(7)]
        comps = {c.req_id for c in eng.drain()}
        assert comps == set(ids)

    def test_eos_stops_early(self, model):
        cfg, params, _ = model
        eng = ServingEngine(params, cfg, _scfg())
        rid = eng.submit(Request(prompt=np.asarray([1, 2, 3], np.int32)))
        first = None
        while first is None:
            for c in eng.step().completions:
                first = c
        greedy_first = int(first.tokens[0])
        eng2 = ServingEngine(params, cfg, _scfg(eos_id=greedy_first))
        eng2.submit(Request(prompt=np.asarray([1, 2, 3], np.int32)))
        (c,) = eng2.drain()
        assert c.finish_reason == "eos"
        assert len(c.tokens) == 1


class TestSwapUnderLoad:
    def _run(self, model, swap_tick):
        cfg, p0, p1 = model
        eng = ServingEngine(p0, cfg, _scfg(max_new_tokens=8), version=0)
        comps = {}
        for tick in range(40):
            if tick == 0:
                eng.submit(Request(prompt=np.arange(1, 5, dtype=np.int32)))
            if tick == 2:
                eng.submit(Request(prompt=np.arange(2, 8, dtype=np.int32)))
            if swap_tick is not None and tick == swap_tick:
                eng.set_params(p1, 1)
            if tick == 5:
                eng.submit(Request(prompt=np.arange(3, 6, dtype=np.int32)))
            for c in eng.step().completions:
                comps[c.req_id] = c
            if tick > 5 and not eng.has_pending():
                break
        assert not eng.has_pending()
        return comps

    def test_inflight_bit_exact_across_swap(self, model):
        swapped = self._run(model, swap_tick=3)
        baseline = self._run(model, swap_tick=None)
        # requests 0,1 were in flight at the swap: pinned to version 0,
        # token-for-token identical to the run with no swap at all
        for rid in (0, 1):
            assert swapped[rid].snapshot_version == 0
            assert np.array_equal(swapped[rid].tokens, baseline[rid].tokens)
        # request 2 was admitted after the swap: new snapshot, and the
        # params genuinely change its greedy decode
        assert swapped[2].snapshot_version == 1
        assert not np.array_equal(swapped[2].tokens, baseline[2].tokens)

    def test_swap_while_idle(self, model):
        cfg, p0, p1 = model
        eng = ServingEngine(p0, cfg, _scfg(), version=0)
        assert eng.set_params(p1) == 1          # auto-increment
        rid = eng.submit(Request(prompt=np.asarray([1, 2], np.int32)))
        comps = {c.req_id: c for c in eng.drain()}
        assert comps[rid].snapshot_version == 1


class TestSnapshotBus:
    def test_roundtrip_and_versioning(self, model, tmp_path):
        cfg, p0, p1 = model
        d = str(tmp_path)
        with SnapshotPublisher(d, every_steps=2, async_write=False) as pub:
            assert not pub.maybe_publish(1, p0)
            assert pub.maybe_publish(2, p0)
            w = SnapshotWatcher(d, p0)
            params, version = w.poll()
            assert version == 2
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p0)):
                assert np.array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
            assert w.poll() is None             # nothing new
            pub.publish(4, p1)
            _, version = w.poll()
            assert version == 4

    def test_torn_write_never_selected(self, model, tmp_path):
        cfg, p0, _ = model
        d = str(tmp_path)
        with SnapshotPublisher(d, async_write=False) as pub:
            pub.publish(3, p0)
        # npz without sidecar = torn publication: latest_step skips it
        open(os.path.join(d, "step_00000009.npz"), "wb").write(b"junk")
        w = SnapshotWatcher(d, p0)
        _, version = w.poll()
        assert version == 3

    def test_corrupt_snapshot_skipped_not_fatal(self, model, tmp_path):
        cfg, p0, _ = model
        d = str(tmp_path)
        with SnapshotPublisher(d, async_write=False) as pub:
            pub.publish(3, p0)
        w = SnapshotWatcher(d, p0)
        assert w.poll()[1] == 3
        # corrupt npz WITH a sidecar: discoverable but unloadable
        open(os.path.join(d, "step_00000011.npz"), "wb").write(b"junk")
        with open(os.path.join(d, "step_00000011.npz.json"), "w") as f:
            json.dump({"step": 11}, f)
        assert w.poll() is None                 # skipped, not raised
        assert w.skipped == 1
        assert w.loaded_step == 3               # still serving v3
        assert w.poll() is None                 # bad step not re-tried
        assert w.skipped == 1
        # a GOOD newer snapshot is still picked up
        with SnapshotPublisher(d, async_write=False) as pub:
            pub.publish(12, p0)
        assert w.poll()[1] == 12

    def test_blacklist_backoff_schedule(self, model, tmp_path):
        cfg, p0, _ = model
        d = str(tmp_path)
        open(os.path.join(d, "step_00000011.npz"), "wb").write(b"junk")
        with open(os.path.join(d, "step_00000011.npz.json"), "w") as f:
            json.dump({"step": 11}, f)
        w = SnapshotWatcher(d, p0, backoff_base=0.05, backoff_max=0.1,
                            jitter_seed=0)
        assert w.poll() is None and w.skipped == 1
        # inside the backoff window: no load attempt at all
        assert w.poll() is None and w.skipped == 1 and w.retries == 0
        time.sleep(0.2)                     # past base*jitter
        assert w.poll() is None
        assert w.retries == 1 and w.skipped == 2
        assert w.bad_steps[11].fails == 2   # horizon doubled

    def test_blacklist_capped(self, model, tmp_path):
        cfg, p0, _ = model
        d = str(tmp_path)
        w = SnapshotWatcher(d, p0, blacklist_max=3, backoff_base=1e-4,
                            backoff_max=1e-4, jitter_seed=0)
        for step in range(10, 16):          # newer corrupt step each poll
            base = os.path.join(d, f"step_{step:08d}.npz")
            open(base, "wb").write(b"junk")
            json.dump({"step": step}, open(base + ".json", "w"))
            assert w.poll() is None
        assert len(w.bad_steps) == 3        # bounded, oldest evicted
        assert min(w.bad_steps) == 13

    def test_blacklist_ttl_eviction(self, model, tmp_path):
        cfg, p0, _ = model
        d = str(tmp_path)
        base = os.path.join(d, "step_00000011.npz")
        open(base, "wb").write(b"junk")
        json.dump({"step": 11}, open(base + ".json", "w"))
        w = SnapshotWatcher(d, p0, blacklist_ttl=0.05, backoff_base=1e-4,
                            backoff_max=1e-4, jitter_seed=0)
        assert w.poll() is None
        assert w.bad_steps[11].fails == 1
        time.sleep(0.1)                     # past the retention TTL
        assert w.poll() is None
        # the entry was evicted and re-recorded fresh, not accumulated
        assert w.bad_steps[11].fails == 1

    def test_half_written_snapshot_recovers_on_retry(self, model, tmp_path):
        # the case backoff retries exist for: a corrupt write that is
        # REPLACED by a complete one at the same step must eventually load
        cfg, p0, _ = model
        d = str(tmp_path)
        base = os.path.join(d, "step_00000011.npz")
        open(base, "wb").write(b"junk")
        json.dump({"step": 11, "version": 11}, open(base + ".json", "w"))
        w = SnapshotWatcher(d, p0, backoff_base=1e-4, backoff_max=1e-4,
                            jitter_seed=0)
        assert w.poll() is None
        with SnapshotPublisher(d, async_write=False) as pub:
            pub.publish(11, p0)             # the write completes late
        time.sleep(0.01)
        assert w.poll()[1] == 11
        assert w.bad_steps == {}            # dropped at/below served step

    def test_strict_watcher_raises(self, model, tmp_path):
        cfg, p0, _ = model
        d = str(tmp_path)
        open(os.path.join(d, "step_00000011.npz"), "wb").write(b"junk")
        with open(os.path.join(d, "step_00000011.npz.json"), "w") as f:
            json.dump({"step": 11}, f)
        with pytest.raises(Exception):
            SnapshotWatcher(d, p0, strict=True).poll()


class TestInferenceServer:
    def test_futures_and_hot_swap(self, model, tmp_path):
        cfg, p0, p1 = model
        d = str(tmp_path)
        pub = SnapshotPublisher(d, async_write=False)
        pub.publish(1, p0)
        eng = ServingEngine(p0, cfg, _scfg(), version=0)
        with InferenceServer(eng, watcher=SnapshotWatcher(d, p0),
                             poll_every=2) as srv:
            futs = [srv.submit(Request(
                prompt=np.arange(1, 6, dtype=np.int32))) for _ in range(3)]
            [f.result(timeout=300) for f in futs]
            pub.publish(5, p1)
            deadline = time.monotonic() + 300
            while srv.stats.swaps < 2 and time.monotonic() < deadline:
                time.sleep(0.01)                # idle poll picks it up
            fut = srv.submit(Request(prompt=np.arange(2, 6, dtype=np.int32)))
            comp = fut.result(timeout=300)
        assert comp.snapshot_version == 5
        assert srv.stats.swaps == 2
        assert srv.stats.completed == 4
        assert srv.stats.submitted == 4
        assert len(srv.stats.request_lat) == 4
        pub.close()

    def test_shutdown_drains(self, model):
        cfg, p0, _ = model
        eng = ServingEngine(p0, cfg, _scfg())
        srv = InferenceServer(eng)
        futs = [srv.submit(Request(prompt=np.asarray([1, 2, 3], np.int32)))
                for _ in range(5)]
        srv.shutdown()                          # drain=True: zero drops
        assert all(f.done() for f in futs)
        assert all(len(f.result().tokens) == 6 for f in futs)

    def test_unservable_request_fails_future(self, model):
        cfg, p0, _ = model
        eng = ServingEngine(p0, cfg, _scfg(max_len=16))
        with InferenceServer(eng) as srv:
            fut = srv.submit(Request(prompt=np.arange(30, dtype=np.int32)))
            with pytest.raises(ValueError, match="max_len"):
                fut.result(timeout=60)

    def test_queue_deadline_expires(self, model):
        cfg, p0, _ = model
        eng = ServingEngine(p0, cfg, _scfg())
        with InferenceServer(eng) as srv:
            # an already-expired deadline fails in admission, never decoded
            fut = srv.submit(Request(prompt=np.asarray([1, 2], np.int32),
                                     deadline_s=1e-9))
            with pytest.raises(TimeoutError):
                fut.result(timeout=60)
        assert srv.stats.timeouts == 1
        assert srv.stats.completed == 0

    def test_inflight_deadline_cancels(self, model):
        cfg, p0, _ = model
        eng = ServingEngine(p0, cfg, _scfg(max_new_tokens=64, max_len=128))
        with InferenceServer(eng) as srv:
            # 64 greedy tokens take well past 50ms (the first decode step
            # alone compiles); the deadline must cancel it mid-flight
            doomed = srv.submit(Request(
                prompt=np.asarray([1, 2, 3], np.int32), deadline_s=0.05))
            ok = srv.submit(Request(
                prompt=np.asarray([1, 2, 3], np.int32), max_new_tokens=2))
            with pytest.raises(TimeoutError):
                doomed.result(timeout=300)
            assert len(ok.result(timeout=300).tokens) == 2
        assert srv.stats.timeouts == 1
        assert not eng.has_pending()        # cancel freed the slot


class TestChaosServing:
    """The fault-plan-driven storm + worker-death satellites."""

    def _storm(self, model, tmp_path, *, corrupt):
        cfg, p0, p1 = model
        d = str(tmp_path)
        plan = make_plan("torn-storm:k=3,at=1"
                         + (",corrupt=1" if corrupt else ""),
                         n_workers=1, ticks=8)
        pub = ChaosPublisher(d, plan, async_write=False)
        pub.publish(1, p0)                  # index 0: clean v1
        eng = ServingEngine(p0, cfg, _scfg(), version=0)
        with InferenceServer(eng, watcher=SnapshotWatcher(
                d, p0, backoff_base=0.01, backoff_max=0.02,
                jitter_seed=0), poll_every=2) as srv:
            deadline = time.monotonic() + 300
            while srv.stats.swaps < 1 and time.monotonic() < deadline:
                time.sleep(0.01)            # v1 lands
            # the storm: every publication for K versions is bad
            futs = []
            for v in range(2, 2 + 3):
                pub.publish(v, p1)          # indices 1..3: all bad
                futs.append(srv.submit(Request(
                    prompt=np.arange(1, 5 + v, dtype=np.int32))))
            comps = [f.result(timeout=300) for f in futs]
            # zero drops, all served on the last good version
            assert [c.snapshot_version for c in comps] == [1, 1, 1]
            assert srv.stats.swaps == 1
            # first complete snapshot after the storm swaps immediately
            pub.publish(6, p1)              # index 4: past the storm
            deadline = time.monotonic() + 300
            while srv.stats.swaps < 2 and time.monotonic() < deadline:
                time.sleep(0.01)
            fut = srv.submit(Request(prompt=np.arange(1, 5,
                                                      dtype=np.int32)))
            assert fut.result(timeout=300).snapshot_version == 6
        assert srv.stats.swaps == 2
        assert srv.stats.completed == 4
        pub.close()
        return pub, srv

    def test_torn_storm_zero_drops(self, model, tmp_path):
        pub, srv = self._storm(model, tmp_path, corrupt=False)
        assert pub.counters["torn"] == 3
        # torn = invisible: the watcher never even discovered them
        assert srv.stats.snapshots_skipped == 0

    def test_corrupt_storm_zero_drops(self, model, tmp_path):
        pub, srv = self._storm(model, tmp_path, corrupt=True)
        assert pub.counters["corrupt"] == 3
        # corrupt = discovered and skipped (with backoff), never fatal
        assert srv.stats.snapshots_skipped >= 1

    def test_worker_death_readmits_bit_exact(self, model, tmp_path):
        cfg, p0, p1 = model
        d = str(tmp_path)
        prompt = np.arange(1, 7, dtype=np.int32)
        # no-fault reference: same prompt, same params, same version pin
        ref_eng = ServingEngine(p0, cfg, _scfg(max_new_tokens=24,
                                               max_len=128), version=0)
        ref_eng.submit(Request(prompt=prompt))
        (ref,) = ref_eng.drain()

        pub = SnapshotPublisher(d, async_write=False)
        eng = ServingEngine(p0, cfg, _scfg(max_new_tokens=24, max_len=128),
                            version=0)
        with InferenceServer(eng, watcher=SnapshotWatcher(d, p0),
                             poll_every=2) as srv:
            fut = srv.submit(Request(prompt=prompt))
            deadline = time.monotonic() + 300
            # wait until the request is tracked AND admitted (one decode
            # step ran): its group is pinned to version 0 from here on
            while ((srv.stats.submitted < 1 or srv.stats.steps < 1)
                   and time.monotonic() < deadline):
                time.sleep(0.005)
            pub.publish(1, p1)              # hot-swap races the decode
            srv.inject_worker_fault()
            comp = fut.result(timeout=300)
            late = srv.submit(Request(prompt=prompt)).result(timeout=300)
        # the dead worker's request was re-admitted on its PINNED
        # snapshot and re-decoded bit-exact to the no-fault reference
        assert srv.stats.worker_restarts == 1
        assert srv.stats.readmitted >= 1
        assert comp.snapshot_version == 0
        assert np.array_equal(comp.tokens, ref.tokens)
        # traffic admitted after the swap sees the new params
        assert late.snapshot_version == 1
        pub.close()

    def test_worker_death_exhausts_restarts(self, model):
        cfg, p0, _ = model
        eng = ServingEngine(p0, cfg, _scfg())
        srv = InferenceServer(eng, max_restarts=0)
        srv.inject_worker_fault(RuntimeError("boom"))
        deadline = time.monotonic() + 60
        while not srv._stop.is_set() and time.monotonic() < deadline:
            time.sleep(0.005)
        with pytest.raises(RuntimeError, match="serve worker"):
            srv.submit(Request(prompt=np.asarray([1, 2], np.int32)))
