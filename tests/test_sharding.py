"""Logical-axis rules resolution (divisibility-aware degradation)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.parallel.sharding import AxisRules, make_rules


class FakeMesh:
    """Duck-typed mesh: .shape mapping + .axis_names, no devices needed."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.devices = np.empty(tuple(shape.values()), dtype=object)


def rules_for(arch="gemma2-27b", shape="train_4k", mesh=None):
    mesh = mesh or FakeMesh({"data": 16, "model": 16})
    return make_rules(get_config(arch), INPUT_SHAPES[shape], mesh)


def test_weight_specs():
    r = rules_for()
    # mlp w_gate (d_model, d_ff): FSDP over data, TP over model
    assert r.spec(("d_model_w", "d_ff_w"), (4608, 36864)) == \
        P(("data",), ("model",))
    # embed (vocab, d_model)
    assert r.spec(("vocab_w", "d_model_w"), (256000, 4608)) == \
        P(("model",), ("data",))


def test_divisibility_degradation():
    r = rules_for("qwen2-0.5b")
    # kv=2 doesn't divide model=16 → replicated
    assert r.spec(("d_model_w", "kv_heads_w", None), (896, 2, 64)) == \
        P(("data",), None, None)
    # 14 heads don't divide 16 → replicated (padding happens in attn_apply)
    assert r.spec(("d_model_w", "heads_w", None), (896, 14, 64)) == \
        P(("data",), None, None)
    # padded activation heads DO shard
    assert r.spec(("attn_batch", "qseq", "heads", None),
                  (256, 4096, 16, 64)) == \
        P(("data",), None, ("model",), None)


def test_axis_used_once():
    r = rules_for()
    # if a leading dim consumes `data`, later dims must not reuse it
    spec = r.spec(("batch", "d_model_w"), (256, 4608))
    assert spec == P(("data",), None)


def test_decode_cache_rules():
    r = rules_for("qwen3-moe-30b-a3b", "decode_32k")
    assert r.spec(("cache_batch", "cache_seq", "kv_heads", None),
                  (128, 32768, 4, 128)) == \
        P(("data",), ("model",), None, None)
    # long_500k: batch 1 undividable → cache spread over data+model
    r = rules_for("mamba2-780m", "long_500k")
    assert r.spec(("cache_batch", "cache_seq", "kv_heads", None),
                  (1, 524288, 1, 64)) == \
        P(None, ("data", "model"), None, None)


def test_multi_pod_batch():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    r = rules_for(mesh=mesh)
    assert r.spec(("batch", "seq", None), (256, 4096, 4608)) == \
        P(("pod", "data"), None, None)


def test_no_mesh_is_noop():
    r = AxisRules({"batch": ("data",)}, None)
    assert r.spec(("batch",), (8,)) == P(None)
