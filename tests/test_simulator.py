"""Actor-system simulator: reproduces the paper's qualitative results."""
import numpy as np
import pytest

from repro.core.barriers import make_barrier
from repro.core.simulator import SimConfig, run_simulation


def run(barrier, **kw):
    defaults = dict(n_nodes=100, duration=20.0, dim=32, seed=3)
    defaults.update(kw)
    return run_simulation(SimConfig(barrier=barrier, **defaults))


@pytest.fixture(scope="module")
def five():
    return {name: run(make_barrier(name, staleness=4, sample_size=2))
            for name in ("bsp", "ssp", "asp", "pbsp", "pssp")}


class TestFig1:
    def test_progress_ordering(self, five):
        # Fig 1a: BSP slowest, ASP fastest, SSP between; probabilistic
        # versions improve on their classic counterparts
        assert five["bsp"].mean_progress < five["ssp"].mean_progress \
            < five["asp"].mean_progress
        assert five["pbsp"].mean_progress > five["bsp"].mean_progress
        assert five["pssp"].mean_progress > five["ssp"].mean_progress

    def test_dispersion_ordering(self, five):
        # Fig 1b/1d: ASP widest spread; BSP tightest
        spread = {k: int(v.steps.max() - v.steps.min())
                  for k, v in five.items()}
        assert spread["bsp"] <= 1
        assert spread["ssp"] <= 4 + 1
        assert spread["asp"] > spread["pssp"] >= spread["pbsp"]

    def test_all_converge(self, five):
        for name, r in five.items():
            assert r.final_error < 0.1, (name, r.final_error)

    @pytest.mark.slow
    def test_sample_size_sweep_tightens(self):
        # Fig 1c: larger sample size → tighter step distribution
        spreads = []
        for beta in (0, 2, 16):
            bar = (make_barrier("asp") if beta == 0 else
                   make_barrier("pbsp", sample_size=beta))
            r = run(bar)
            spreads.append(int(r.steps.max() - r.steps.min()))
        assert spreads[0] > spreads[1] >= spreads[2]

    def test_update_counts_track_progress(self, five):
        # Fig 1e: faster barriers generate more server updates
        assert five["asp"].total_updates > five["pbsp"].total_updates \
            > five["bsp"].total_updates


@pytest.mark.slow
class TestFig2Stragglers:
    """Event-driven straggler sweeps — the vectorized engine covers these
    sweep paths in the CI fast lane (see tests/test_vector_sim.py)."""
    def test_bsp_ssp_sensitive_probabilistic_robust(self):
        base, frac = {}, {}
        for name in ("bsp", "ssp", "asp", "pbsp"):
            bar = make_barrier(name, staleness=4, sample_size=1)
            base[name] = run(bar, seed=5).mean_progress
            frac[name] = run(bar, seed=5,
                             straggler_frac=0.1).mean_progress
        rel = {k: frac[k] / base[k] for k in base}
        # classic barriers crushed by 10% 4×-slow nodes; ASP unaffected;
        # pBSP (β=1% of nodes, as in the paper) in the robust group
        assert rel["bsp"] < 0.5
        assert rel["ssp"] < 0.6
        assert rel["asp"] > 0.85
        assert rel["pbsp"] > 2 * rel["bsp"]

    def test_slowness_sweep(self):
        # Fig 2c: BSP dominated by slowness multiplier; pBSP much less
        bsp, pbsp = [], []
        for slow in (1.0, 8.0):
            bsp.append(run(make_barrier("bsp"), seed=9, straggler_frac=0.05,
                           straggler_slowdown=slow).mean_progress)
            pbsp.append(run(make_barrier("pbsp", sample_size=1), seed=9,
                            straggler_frac=0.05,
                            straggler_slowdown=slow).mean_progress)
        assert bsp[1] / bsp[0] < 0.35
        assert pbsp[1] / pbsp[0] > 0.55


class TestDistributedScenario:
    def test_p2p_sampling_equivalent_progress(self):
        bar = make_barrier("pssp", staleness=4, sample_size=2)
        central = run(bar)
        dist = run(bar, distributed_sampling=True)
        assert abs(central.mean_progress - dist.mean_progress) \
            < 0.15 * central.mean_progress
        # distributed sampling pays control-plane hops; centralised doesn't
        assert dist.control_messages > 0
        assert central.control_messages == 0

    def test_churn(self):
        bar = make_barrier("pbsp", sample_size=2)
        r = run(bar, churn_leave_rate=0.5, churn_join_rate=0.5,
                distributed_sampling=True)
        assert r.mean_progress > 0
        assert np.isfinite(r.final_error)


def test_determinism():
    bar = make_barrier("pssp", staleness=4, sample_size=2)
    r1 = run(bar, seed=11)
    r2 = run(bar, seed=11)
    assert np.array_equal(r1.steps, r2.steps)
    assert r1.final_error == r2.final_error
