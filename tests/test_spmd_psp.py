"""SPMD PSP trainer: one jittable program covering all five barriers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spmd_psp import PSPConfig, psp_init, psp_train_step

D = 24


@pytest.fixture(scope="module")
def task():
    w_true = jax.random.normal(jax.random.PRNGKey(0), (D,)) / np.sqrt(D)

    def grad_fn(params, batch):
        x, y = batch
        loss = jnp.mean((x @ params["w"] - y) ** 2)
        g = jax.grad(lambda p: jnp.mean((x @ p["w"] - y) ** 2))(params)
        return loss, g

    def opt_update(g, s, p):
        return jax.tree.map(lambda gi: -0.1 * gi, g), s

    return w_true, grad_fn, opt_update


def run(task, barrier, ticks=500, straggler_frac=0.25, workers=8):
    w_true, grad_fn, opt_update = task
    cfg = PSPConfig(barrier=barrier, n_workers=workers, sample_size=2,
                    staleness=3, straggler_frac=straggler_frac)
    st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                  jax.random.PRNGKey(1))
    step = jax.jit(lambda s, b: psp_train_step(cfg, grad_fn, opt_update,
                                               s, b))
    kb = jax.random.PRNGKey(2)
    for _ in range(ticks):
        kb, k1 = jax.random.split(kb)
        x = jax.random.normal(k1, (workers, 16, D))
        st, m = step(st, (x, x @ w_true))
    err = float(jnp.linalg.norm(st.server_params["w"] - w_true)
                / jnp.linalg.norm(w_true))
    return st, m, err


@pytest.fixture(scope="module")
def results(task):
    return {b: run(task, b) for b in ("bsp", "ssp", "asp", "pbsp", "pssp")}


def test_all_barriers_converge(results):
    for name, (st, m, err) in results.items():
        assert err < 0.25, (name, err)


def test_throughput_ordering(results):
    # steps per virtual second: BSP < SSP < {pBSP,pSSP} < ASP under stragglers
    thr = {k: float(m["mean_step"] / m["virtual_time"])
           for k, (st, m, e) in results.items()}
    assert thr["bsp"] < thr["ssp"] < thr["pbsp"] <= thr["asp"] * 1.05
    assert thr["pssp"] > thr["ssp"]


def test_spread_ordering(results):
    spread = {k: int(m["step_spread"]) for k, (st, m, e) in results.items()}
    assert spread["bsp"] <= 1
    assert spread["ssp"] <= 4
    assert spread["asp"] >= spread["pssp"]


def test_step_counters_and_pushes(results):
    st, m, _ = results["pbsp"]
    assert int(st.total_pushes) > 0
    assert int(st.step.max()) > 0


def test_read_my_writes_views_update(task):
    """With zero heterogeneity, BSP workers complete/pull in lockstep, so
    every worker's view is the SAME server snapshot (read-my-writes)."""
    w_true, grad_fn, opt_update = task
    cfg = PSPConfig(barrier="bsp", n_workers=4, sample_size=2,
                    compute_jitter=0.0, straggler_frac=0.0)
    st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                  jax.random.PRNGKey(1))
    step = jax.jit(lambda s, b: psp_train_step(cfg, grad_fn, opt_update,
                                               s, b))
    kb = jax.random.PRNGKey(2)
    for _ in range(20):
        kb, k1 = jax.random.split(kb)
        x = jax.random.normal(k1, (4, 16, D))
        st, m = step(st, (x, x @ w_true))
    views = st.views["w"]
    assert float(jnp.abs(views).max()) > 0          # pulls happened
    assert int(m["step_spread"]) == 0               # true lockstep
    assert bool(jnp.allclose(views, views[0][None], atol=1e-6))


def test_jit_single_compilation(task):
    w_true, grad_fn, opt_update = task
    cfg = PSPConfig(barrier="pssp", n_workers=4, sample_size=2)
    st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                  jax.random.PRNGKey(0))
    calls = 0

    def counting(s, b):
        nonlocal calls
        calls += 1
        return psp_train_step(cfg, grad_fn, opt_update, s, b)

    step = jax.jit(counting)
    x = jnp.ones((4, 8, D))
    for _ in range(4):
        st, _ = step(st, (x, jnp.ones((4, 8))))
    assert calls == 1   # traced once — fully jittable barrier logic
