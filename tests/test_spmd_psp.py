"""SPMD PSP trainer: one jittable program covering all five barriers.

Includes the elastic-worker-set (churn) coverage: population bounds,
convergence under churn, single-trace jit compilation with the churn
phase compiled in, and a committed golden churn trace
(``tests/golden/spmd_churn_trace.json`` — regenerate by running this
file with ``PSP_REGEN_GOLDEN=1``).  The cross-layer trainer↔simulator
churn equivalence lives in ``tests/test_elastic_equiv.py``.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import env
from repro.core.spmd_psp import (ChurnConfig, PSPConfig, elastic_drive,
                                 linear_psp_task, psp_init, psp_train_step)

D = 24

GOLDEN_CHURN = os.path.join(os.path.dirname(__file__), "golden",
                            "spmd_churn_trace.json")


@pytest.fixture(scope="module")
def task():
    return linear_psp_task(D)


def run(task, barrier, ticks=500, straggler_frac=0.25, workers=8):
    w_true, grad_fn, opt_update = task
    cfg = PSPConfig(barrier=barrier, n_workers=workers, sample_size=2,
                    staleness=3, straggler_frac=straggler_frac)
    st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                  jax.random.PRNGKey(1))
    step = jax.jit(lambda s, b: psp_train_step(cfg, grad_fn, opt_update,
                                               s, b))
    kb = jax.random.PRNGKey(2)
    for _ in range(ticks):
        kb, k1 = jax.random.split(kb)
        x = jax.random.normal(k1, (workers, 16, D))
        st, m = step(st, (x, x @ w_true))
    err = float(jnp.linalg.norm(st.server_params["w"] - w_true)
                / jnp.linalg.norm(w_true))
    return st, m, err


@pytest.fixture(scope="module")
def results(task):
    return {b: run(task, b) for b in ("bsp", "ssp", "asp", "pbsp", "pssp")}


def test_all_barriers_converge(results):
    for name, (st, m, err) in results.items():
        assert err < 0.25, (name, err)


def test_throughput_ordering(results):
    # steps per virtual second: BSP < SSP < {pBSP,pSSP} < ASP under stragglers
    thr = {k: float(m["mean_step"] / m["virtual_time"])
           for k, (st, m, e) in results.items()}
    assert thr["bsp"] < thr["ssp"] < thr["pbsp"] <= thr["asp"] * 1.05
    assert thr["pssp"] > thr["ssp"]


def test_spread_ordering(results):
    spread = {k: int(m["step_spread"]) for k, (st, m, e) in results.items()}
    assert spread["bsp"] <= 1
    assert spread["ssp"] <= 4
    assert spread["asp"] >= spread["pssp"]


def test_step_counters_and_pushes(results):
    st, m, _ = results["pbsp"]
    assert int(st.total_pushes) > 0
    assert int(st.step.max()) > 0


def test_read_my_writes_views_update(task):
    """With zero heterogeneity, BSP workers complete/pull in lockstep, so
    every worker's view is the SAME server snapshot (read-my-writes)."""
    w_true, grad_fn, opt_update = task
    cfg = PSPConfig(barrier="bsp", n_workers=4, sample_size=2,
                    compute_jitter=0.0, straggler_frac=0.0)
    st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                  jax.random.PRNGKey(1))
    step = jax.jit(lambda s, b: psp_train_step(cfg, grad_fn, opt_update,
                                               s, b))
    kb = jax.random.PRNGKey(2)
    for _ in range(20):
        kb, k1 = jax.random.split(kb)
        x = jax.random.normal(k1, (4, 16, D))
        st, m = step(st, (x, x @ w_true))
    views = st.views["w"]
    assert float(jnp.abs(views).max()) > 0          # pulls happened
    assert int(m["step_spread"]) == 0               # true lockstep
    assert bool(jnp.allclose(views, views[0][None], atol=1e-6))


def run_churn(task, barrier, ticks=300, workers=8,
              churn=ChurnConfig(leave_rate=1.5, join_rate=1.5,
                                horizon=40.0, seed=7)):
    """Drive the elastic trainer, returning per-tick alive/step traces."""
    del task  # the shared elastic_drive harness owns the task draw
    cfg = PSPConfig(barrier=barrier, n_workers=workers, sample_size=2,
                    staleness=3, straggler_frac=0.25, churn=churn)
    w_true, it = elastic_drive(cfg, D, ticks)
    alive_trace, now_trace, mean_step_trace = [], [], []
    for st, m in it:
        bits = np.packbits(np.asarray(st.alive)).tobytes()
        alive_trace.append(int.from_bytes(bits, "big"))  # any worker count
        now_trace.append(float(st.now))
        mean_step_trace.append(float(m["mean_step"]))
    err = float(jnp.linalg.norm(st.server_params["w"] - w_true)
                / jnp.linalg.norm(w_true))
    return st, dict(alive=alive_trace, now=now_trace,
                    mean_step=mean_step_trace), err


class TestElasticChurn:
    """Elastic worker sets: the trainer under Poisson leave/join churn."""

    @pytest.fixture(scope="class")
    def churn_run(self, task):
        return run_churn(task, "pssp")

    def test_population_bounds_and_actual_churn(self, churn_run):
        st, trace, _ = churn_run
        counts = [bin(a).count("1") for a in trace["alive"]]
        assert min(counts) >= 2 and max(counts) <= 8
        assert len(np.asarray(st.alive)) == 8  # bitmask covers all workers
        assert len(set(trace["alive"])) > 2          # membership really moved
        assert int(st.leave_cursor) >= 2 and int(st.join_cursor) >= 2

    def test_converges_under_churn(self, churn_run):
        _, trace, err = churn_run
        assert err < 0.25, err
        # alive-masked progress is monotone-ish and positive
        assert trace["mean_step"][-1] > trace["mean_step"][0]

    def test_virtual_time_always_advances(self, churn_run):
        _, trace, _ = churn_run
        nows = np.asarray(trace["now"])
        assert np.all(np.diff(nows) >= 0) and nows[-1] > nows[0]

    def test_golden_churn_trace(self, churn_run):
        """Fixed-seed elastic run pinned to the committed golden trace —
        any drift in churn ordering, RNG consumption, or alive-masked
        barrier decisions flips the integer alive bitmasks."""
        st, trace, err = churn_run
        got = {
            "alive_bitmask": trace["alive"][:120],
            "final_now": round(trace["now"][-1], 4),
            "leave_cursor": int(st.leave_cursor),
            "join_cursor": int(st.join_cursor),
            "total_pushes": int(st.total_pushes),
            "final_error": round(err, 5),
        }
        if env.flag("PSP_REGEN_GOLDEN"):
            with open(GOLDEN_CHURN, "w") as f:
                json.dump(got, f, indent=1)
        with open(GOLDEN_CHURN) as f:
            golden = json.load(f)
        assert got["alive_bitmask"] == golden["alive_bitmask"]
        assert got["leave_cursor"] == golden["leave_cursor"]
        assert got["join_cursor"] == golden["join_cursor"]
        assert got["total_pushes"] == golden["total_pushes"]
        assert abs(got["final_now"] - golden["final_now"]) < 1e-3
        assert abs(got["final_error"] - golden["final_error"]) < 1e-3

    def test_churn_jit_single_compilation(self, task):
        """The churn phase is lax-only: one trace, even as events fire."""
        w_true, grad_fn, opt_update = task
        cfg = PSPConfig(barrier="pbsp", n_workers=4, sample_size=2,
                        churn=ChurnConfig(leave_rate=3.0, join_rate=3.0,
                                          horizon=10.0, seed=1))
        st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                      jax.random.PRNGKey(0))
        calls = 0

        def counting(s, b):
            nonlocal calls
            calls += 1
            return psp_train_step(cfg, grad_fn, opt_update, s, b)

        step = jax.jit(counting)
        x = jnp.ones((4, 8, D))
        for _ in range(30):
            st, _ = step(st, (x, jnp.ones((4, 8))))
        assert calls == 1
        assert int(st.leave_cursor) + int(st.join_cursor) > 0


class TestAdaptivePolicies:
    """Adaptive barrier policies threaded through the trainer: the policy
    state rides in ``PSPState.policy``, pinned ranges reduce bit-for-bit
    to the static parents, and ``contribution="mean-alive"`` co-locates
    its churn-aware denominator EMA in the same pytree."""

    @staticmethod
    def _traj(cfg, ticks=60, dim=8):
        w_true, it = elastic_drive(cfg, dim, ticks)
        out = []
        for st, m in it:
            out.append((np.asarray(st.server_params["w"]),
                        np.asarray(st.step), float(st.now),
                        np.asarray(st.key)))
        return out, st

    BASE = dict(n_workers=6, straggler_frac=0.3)
    PAIRS = [
        (dict(barrier="dssp", staleness=3, staleness_lo=3),
         dict(barrier="ssp", staleness=3)),
        (dict(barrier="ebsp", max_advance=0), dict(barrier="bsp")),
        (dict(barrier="apssp", staleness=3, sample_size=3,
              sample_size_lo=3),
         dict(barrier="pssp", staleness=3, sample_size=3)),
    ]

    @pytest.mark.parametrize("i", range(3))
    def test_pinned_range_reduces_to_static_parent(self, i):
        akw, skw = self.PAIRS[i]
        ta, _ = self._traj(PSPConfig(**akw, **self.BASE))
        tb, _ = self._traj(PSPConfig(**skw, **self.BASE))
        for (wa, sa, na, ka), (wb, sb, nb, kb) in zip(ta, tb):
            np.testing.assert_array_equal(wa, wb)
            np.testing.assert_array_equal(sa, sb)
            assert na == nb
            np.testing.assert_array_equal(ka, kb)

    @pytest.mark.parametrize("barrier", ("dssp", "ebsp", "apbsp", "apssp"))
    def test_adaptive_policies_converge(self, barrier):
        cfg = PSPConfig(barrier=barrier, staleness=3, sample_size=2,
                        staleness_lo=0, sample_size_lo=1, max_advance=3,
                        **self.BASE)
        _, st = self._traj(cfg, ticks=200, dim=8)
        w_true, _, _ = linear_psp_task(8)
        err = float(jnp.linalg.norm(st.server_params["w"] - w_true)
                    / jnp.linalg.norm(w_true))
        assert err < 0.3, (barrier, err)
        assert st.policy                      # stateful policy carried
        assert int(st.total_pushes) > 0

    def test_policy_state_evolves(self):
        cfg = PSPConfig(barrier="ebsp", max_advance=3, **self.BASE)
        _, st = self._traj(cfg, ticks=50)
        ema = np.asarray(st.policy["ema"])
        assert ema.shape == (6,) and np.all(ema > 0)
        # stragglers' duration EMA must exceed the fast workers'
        slow = np.asarray(st.slow)
        assert ema[slow].min() > ema[~slow].max()

    def test_static_policy_state_is_empty(self):
        cfg = PSPConfig(barrier="pssp", **self.BASE)
        _, st = self._traj(cfg, ticks=5)
        assert st.policy == {}

    def test_mean_alive_contribution_tracks_population(self):
        cfg = PSPConfig(barrier="pssp", contribution="mean-alive",
                        churn=ChurnConfig(leave_rate=2.0, join_rate=0.2,
                                          horizon=30.0, seed=3),
                        **self.BASE)
        _, st = self._traj(cfg, ticks=150)
        denom = float(st.policy["denom"])
        n_alive = int(np.asarray(st.alive).sum())
        assert 1.0 <= denom <= 6.0
        assert denom < 6.0                    # EMA followed the leaves
        assert abs(denom - n_alive) < 3.0
        w_true, _, _ = linear_psp_task(8)
        err = float(jnp.linalg.norm(st.server_params["w"] - w_true)
                    / jnp.linalg.norm(w_true))
        assert err < 0.4, err

    def test_adaptive_jit_single_compilation(self, task):
        w_true, grad_fn, opt_update = task
        cfg = PSPConfig(barrier="dssp", staleness=3, n_workers=4)
        st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                      jax.random.PRNGKey(0))
        calls = 0

        def counting(s, b):
            nonlocal calls
            calls += 1
            return psp_train_step(cfg, grad_fn, opt_update, s, b)

        step = jax.jit(counting)
        x = jnp.ones((4, 8, D))
        for _ in range(10):
            st, _ = step(st, (x, jnp.ones((4, 8))))
        assert calls == 1
        assert int(st.policy["thr"]) <= 3


def test_jit_single_compilation(task):
    w_true, grad_fn, opt_update = task
    cfg = PSPConfig(barrier="pssp", n_workers=4, sample_size=2)
    st = psp_init(cfg, {"w": jnp.zeros((D,))}, lambda p: None,
                  jax.random.PRNGKey(0))
    calls = 0

    def counting(s, b):
        nonlocal calls
        calls += 1
        return psp_train_step(cfg, grad_fn, opt_update, s, b)

    step = jax.jit(counting)
    x = jnp.ones((4, 8, D))
    for _ in range(4):
        st, _ = step(st, (x, jnp.ones((4, 8))))
    assert calls == 1   # traced once — fully jittable barrier logic
