"""Optimizers, schedules, data pipeline, checkpointing, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import SyntheticLM
from repro.optim import (adamw, apply_updates, clip_by_norm, constant,
                         cosine, global_norm, momentum, sgd, warmup_cosine)


class TestOptim:
    def quad(self, opt, steps=200):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)

        @jax.jit
        def step(params, state):
            g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            upd, state = opt.update(g, state, params)
            return apply_updates(params, upd), state

        for _ in range(steps):
            params, state = step(params, state)
        return float(jnp.max(jnp.abs(params["w"] - target)))

    def test_sgd(self):
        assert self.quad(sgd(0.1)) < 1e-3

    def test_momentum(self):
        assert self.quad(momentum(0.02)) < 1e-3

    def test_adamw(self):
        assert self.quad(adamw(0.05)) < 1e-2

    def test_clip(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped = clip_by_norm(g, 1.0)
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5

    def test_schedules(self):
        s = warmup_cosine(1.0, 10, 100)
        assert float(s(jnp.asarray(0))) == 0.0
        assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
        assert float(s(jnp.asarray(100))) < 0.2
        assert float(cosine(1.0, 100)(jnp.asarray(0))) == 1.0
        assert float(constant(0.5)(jnp.asarray(7))) == 0.5


class TestData:
    def test_deterministic_per_shard(self):
        a = next(iter(SyntheticLM(64, 32, 2, seed=1, shard=0)))
        b = next(iter(SyntheticLM(64, 32, 2, seed=1, shard=0)))
        c = next(iter(SyntheticLM(64, 32, 2, seed=1, shard=1)))
        assert jnp.array_equal(a["tokens"], b["tokens"])
        assert not jnp.array_equal(a["tokens"], c["tokens"])

    def test_learnable_structure(self):
        # the markov stream must be compressible: next-token entropy below
        # uniform
        batch = next(iter(SyntheticLM(32, 256, 8, seed=0)))["tokens"]
        t = np.asarray(batch)
        joint = np.zeros((32, 32))
        for row in t:
            for a, b in zip(row[:-1], row[1:]):
                joint[a, b] += 1
        cond = joint / np.maximum(joint.sum(1, keepdims=True), 1)
        ent = -np.nansum(np.where(cond > 0, cond * np.log(cond), 0), axis=1)
        assert np.nanmean(ent[joint.sum(1) > 10]) < 0.9 * np.log(32)

    def test_batch_specs(self):
        from repro.configs import INPUT_SHAPES, get_config
        from repro.data import make_batch_specs
        cfg = get_config("internvl2-2b")
        sp = make_batch_specs(cfg, INPUT_SHAPES["train_4k"])
        assert sp["tokens"].shape == (256, 4096 - 256)
        assert sp["embeds"].shape == (256, 256, 2048)
        sp = make_batch_specs(cfg, INPUT_SHAPES["decode_32k"])
        assert sp["tokens"].shape == (128, 1)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.bfloat16),
                      "d": [jnp.zeros(2), jnp.full((1,), 7.0)]}}
        save_checkpoint(str(tmp_path), 3, tree)
        save_checkpoint(str(tmp_path), 10, tree)
        assert latest_step(str(tmp_path)) == 10
        restored, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 10
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(x, np.float32),
                                  np.asarray(y, np.float32))

    def test_restore_missing(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(str(tmp_path / "nope"), {})


class TestServing:
    def test_batched_generation(self):
        from repro.configs import get_config, reduced
        from repro.models import init_model
        from repro.serving import ServeConfig, ServingEngine
        cfg = reduced(get_config("qwen2-0.5b"))
        params = init_model(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(params, cfg, ServeConfig(batch=4,
                                                     max_new_tokens=6))
        prompts = [np.arange(5 + i) % cfg.vocab_size for i in range(6)]
        outs = eng.generate(prompts)
        assert len(outs) == 6
        assert all(len(o) == 6 for o in outs)

    def test_greedy_deterministic(self):
        from repro.configs import get_config, reduced
        from repro.models import init_model
        from repro.serving import ServeConfig, ServingEngine
        cfg = reduced(get_config("mamba2-780m"))
        params = init_model(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(params, cfg, ServeConfig(batch=2,
                                                     max_new_tokens=5))
        p = [np.asarray([1, 2, 3], np.int32)]
        assert np.array_equal(eng.generate(p)[0], eng.generate(p)[0])

    def test_per_wave_embeds(self):
        # regression: waves after the first must decode against THEIR OWN
        # frontend embeddings, not a reused slice of wave 1's
        # (serving/engine.py once passed embeds[:B] to every wave)
        from repro.configs import get_config, reduced
        from repro.models import init_model
        from repro.serving import ServeConfig, ServingEngine
        cfg = reduced(get_config("internvl2-2b"))   # frontend_tokens > 0
        assert cfg.frontend_tokens
        params = init_model(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(params, cfg, ServeConfig(batch=2,
                                                     max_new_tokens=4))
        rng = np.random.default_rng(0)
        prompt = np.asarray([1, 2, 3, 4], np.int32)
        # four requests = two waves; give every request a DISTINCT embedding
        embeds = rng.normal(size=(4, cfg.frontend_tokens, cfg.d_model)) * 3
        embeds = embeds.astype(np.float32)
        outs = eng.generate([prompt] * 4, embeds=embeds)
        # same request served alone with its own embedding is the truth
        for i in (2, 3):
            solo = ServingEngine(params, cfg,
                                 ServeConfig(batch=2, max_new_tokens=4))
            ref = solo.generate([prompt], embeds=embeds[i:i + 1])[0]
            assert np.array_equal(outs[i], ref), (
                f"wave-2 request {i} decoded against the wrong embeddings")
        # and the two waves' embeddings genuinely distinguish the outputs
        assert not all(np.array_equal(outs[0], outs[i]) for i in (2, 3))
