"""Unit tests for the sweep execution planner (:mod:`repro.core.sweep_plan`).

The planner is pure host-side arithmetic, so these tests pin its
invariants directly: record alignment with the measurement grid, exact
pow2 chunk decomposition, memory-capped strides for score-heavy batches,
mesh clamping, and the env overrides the benchmarks/tests rely on.
"""
import numpy as np
import pytest

from repro.core.sweep_plan import plan_sweep


def _measure_idx(n_ticks, every):
    return np.arange(every - 1, n_ticks, every)


class TestStride:
    def test_stride_divides_measurement_cadence(self):
        plan = plan_sweep(1000, _measure_idx(1000, 25), 25, 100,
                          batch=8, d=32, k_max=1, masked=False,
                          has_churn=False, n_devices=1)
        assert plan.stride == 25
        # every measurement index lands exactly on a record boundary
        for m in _measure_idx(1000, 25):
            assert (m + 1) % plan.stride == 0

    def test_full_grid_lands_on_a_record(self):
        # 130 ticks, measurements every 25: gcd(25, 130) = 5
        plan = plan_sweep(130, _measure_idx(130, 25), 4, 16,
                          batch=4, d=8, k_max=1, masked=False,
                          has_churn=False, n_devices=1)
        assert plan.stride == 5
        assert plan.n_rec_live * plan.stride >= 130

    def test_masked_scores_cap_the_stride(self):
        # B·P² per-row score matrices: a large churn batch must pick a
        # smaller stride than the no-churn fast path would
        fast = plan_sweep(4096, _measure_idx(4096, 64), 64, 256,
                          batch=8, d=32, k_max=4, masked=False,
                          has_churn=False, n_devices=1)
        heavy = plan_sweep(4096, _measure_idx(4096, 64), 64, 256,
                           batch=8, d=32, k_max=4, masked=True,
                           has_churn=True, n_devices=1)
        assert heavy.stride < fast.stride
        assert fast.stride % heavy.stride == 0   # still cadence-aligned

    def test_env_override_snaps_to_divisor(self, monkeypatch):
        monkeypatch.setenv("PSP_TRACE_STRIDE", "10")
        plan = plan_sweep(1000, _measure_idx(1000, 25), 25, 100,
                          batch=8, d=32, k_max=1, masked=False,
                          has_churn=False, n_devices=1)
        # 10 does not divide 25; the nearest admissible divisor is 5
        assert plan.stride == 5


class TestChunks:
    def test_binary_decomposition_is_exact_largest_first(self):
        plan = plan_sweep(1000, _measure_idx(1000, 25), 25, 100,
                          batch=8, d=32, k_max=1, masked=False,
                          has_churn=False, n_devices=1)
        assert plan.chunks == (32, 8)
        assert sum(plan.chunks) == plan.n_rec == plan.n_rec_live
        assert list(plan.chunks) == sorted(plan.chunks, reverse=True)
        assert all(c & (c - 1) == 0 for c in plan.chunks)   # pow2

    def test_forced_uniform_chunks_cover_live_records(self, monkeypatch):
        monkeypatch.setenv("PSP_SWEEP_CHUNK", "16")
        plan = plan_sweep(1000, _measure_idx(1000, 25), 25, 100,
                          batch=8, d=32, k_max=1, masked=False,
                          has_churn=False, n_devices=1)
        assert plan.chunks == (16, 16, 16)
        assert plan.n_rec >= plan.n_rec_live


class TestMesh:
    def test_clamped_to_rows_and_available_devices(self):
        import jax
        plan = plan_sweep(100, _measure_idx(100, 25), 3, 16,
                          batch=4, d=8, k_max=1, masked=False,
                          has_churn=False, n_devices=64)
        assert plan.n_devices <= min(3, len(jax.devices()))
        assert plan.b_pad % plan.n_devices == 0
        assert plan.node_pad % plan.n_devices == 0
        assert plan.b_pad >= 3
        assert plan.node_pad >= 16

    def test_env_override(self, monkeypatch):
        from repro.kernels.psp_tick import DATA_PLANE_BLOCK
        monkeypatch.setenv("PSP_SWEEP_DEVICES", "1")
        plan = plan_sweep(100, _measure_idx(100, 25), 8, 16,
                          batch=4, d=8, k_max=1, masked=False,
                          has_churn=False)
        assert plan.n_devices == 1
        # rows pad to the data-plane GEMM block width per device
        assert plan.b_pad == DATA_PLANE_BLOCK


@pytest.mark.parametrize("B,ndev", [(5, 2), (7, 4), (1, 8)])
def test_row_padding_is_even(B, ndev, monkeypatch):
    import jax
    from repro.kernels.psp_tick import DATA_PLANE_BLOCK
    plan = plan_sweep(100, _measure_idx(100, 25), B, 12,
                      batch=4, d=8, k_max=1, masked=False,
                      has_churn=False, n_devices=ndev)
    eff = min(ndev, B, len(jax.devices()))
    assert plan.n_devices == eff
    # per-device block: ceil(B/eff) rows, rounded up to the GEMM width
    b_rows = -(-B // eff)
    b_loc = -(-b_rows // DATA_PLANE_BLOCK) * DATA_PLANE_BLOCK
    assert plan.b_pad == b_loc * eff
    assert plan.b_pad % (eff * DATA_PLANE_BLOCK) == 0
